"""DiT / UNet denoiser unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.diffusion import UNetConfig
from repro.models.diffusion import dit, unet


def test_patchify_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 16, 3))
    tok = dit.patchify(x, 2)
    assert tok.shape == (2, 64, 12)
    back = dit.unpatchify(tok, 2, 8, 8, 3)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_forward_patch_full_equals_forward():
    cfg = get_config("tiny-dit").reduced()
    params = dit.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (2, cfg.latent_size, cfg.latent_size, cfg.channels))
    eps_full = dit.forward(params, cfg, x, 100, jnp.array([0, 1]))
    # full-size patch with buffers primed from a full pass == local-only path
    _, kvs = dit.forward_patch(params, cfg, x, 100, jnp.array([0, 1]), 0,
                               buffers=None, return_kv=True)
    eps_buf, _ = dit.forward_patch(params, cfg, x, 100, jnp.array([0, 1]), 0,
                                   buffers=(kvs[0], kvs[1]))
    np.testing.assert_allclose(np.asarray(eps_buf), np.asarray(eps_full),
                               rtol=2e-5, atol=2e-5)
    assert eps_full.shape == x.shape
    assert np.all(np.isfinite(np.asarray(eps_full)))


def test_forward_patch_subrange_matches_full_slice_when_buffers_fresh():
    """With completely fresh buffers, a patch forward == the corresponding
    rows of the full forward (the zero-staleness limit)."""
    cfg = get_config("tiny-dit").reduced()
    params = dit.init_params(jax.random.PRNGKey(0), cfg)
    B = 1
    x = jax.random.normal(jax.random.PRNGKey(2),
                          (B, cfg.latent_size, cfg.latent_size, cfg.channels))
    cond = jnp.array([2])
    eps_full, kvs = dit.forward_patch(params, cfg, x, 77, cond, 0,
                                      buffers=None, return_kv=True)
    p = cfg.patch_size
    rows = cfg.tokens_per_side // 2
    x_lo = x[:, rows * p:]
    eps_lo, _ = dit.forward_patch(params, cfg, x_lo, 77, cond, rows,
                                  buffers=(kvs[0], kvs[1]))
    np.testing.assert_allclose(np.asarray(eps_lo),
                               np.asarray(eps_full[:, rows * p:]),
                               rtol=3e-5, atol=3e-5)


def test_pos_embed_slice():
    pe = dit.pos_embed_2d(8, 8, 64)
    assert pe.shape == (64, 64)
    # distinct rows get distinct embeddings
    assert float(jnp.min(jnp.linalg.norm(pe[0] - pe[9]))) > 1e-3


def test_unet_forward_shapes_and_grads():
    cfg = UNetConfig(image_size=16, base_width=16, channel_mults=(1, 2),
                     attn_levels=(1,), n_classes=4)
    params = unet.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    out = unet.forward(params, cfg, x, jnp.array([10., 500.]), jnp.array([0, 3]))
    assert out.shape == x.shape
    assert np.all(np.isfinite(np.asarray(out)))

    def loss(p):
        return jnp.mean(unet.forward(p, cfg, x, 100, None) ** 2)

    g = jax.grad(loss)(params)
    gn = sum(float(jnp.sum(jnp.square(l))) for l in jax.tree.leaves(g))
    assert np.isfinite(gn)
