"""Multi-device distributed tests, each in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count so the main pytest process
keeps seeing exactly 1 device (per the brief)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, n_devices: int = 8, timeout: int = 520):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    if n_devices > 1:
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                            + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_uneven_all_gather_equivalence():
    """Paper §V-A: padded all_gather == broadcast emulation == oracle."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.core import comm
        from repro.core.comm import shard_map_compat
        devs = jax.devices(); N = len(devs)
        mesh = Mesh(np.asarray(devs), ('dev',))
        sizes = [3, 1, 4, 2, 5, 1, 2, 6][:N]
        mx = max(sizes)
        rng = np.random.default_rng(0)
        slabs = [rng.normal(size=(s, 7)).astype(np.float32) for s in sizes]
        oracle = np.concatenate(slabs, 0)
        padded = np.stack([np.pad(s, ((0, mx - s.shape[0]), (0, 0))) for s in slabs])
        x = jnp.asarray(padded)    # [N, mx, 7]

        def f_pad(xl):
            return comm.uneven_all_gather_padded(xl[0], sizes, 'dev')
        def f_bc(xl):
            return comm.uneven_all_gather_broadcast(xl[0], sizes, 'dev')
        for f in (f_pad, f_bc):
            got = np.asarray(jax.jit(shard_map_compat(
                f, mesh, P('dev'), P(None)))(x))
            np.testing.assert_allclose(got, oracle, rtol=1e-6)
        print('COMM_OK')
    """)
    assert "COMM_OK" in out


def test_spmd_stadi_matches_emulation():
    """Real shard_map STADI on 4 devices == logical-worker emulation."""
    out = _run("""
        import sys
        sys.argv = ['x', '--spmd', '--occupancies', '0.0,0.2,0.4,0.6',
                    '--m-base', '12', '--m-warmup', '4', '--arch', 'tiny-dit',
                    '--reduced', '--check-vs-emulation']
        from repro.launch.stadi_infer import main
        main()
        print('SPMD_OK')
    """, n_devices=4)
    assert "SPMD_OK" in out
    assert "rel_err_vs_emulation" in out


def test_tensor_parallel_baseline_lowers_and_runs():
    """TP DiT forward executes on 4 devices and matches single-device."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.configs import get_config
        from repro.core.tensor_parallel import tp_forward
        from repro.models.diffusion import dit
        cfg = get_config('tiny-dit').reduced()
        params = dit.init_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (1, cfg.latent_size, cfg.latent_size, cfg.channels))
        mesh = Mesh(np.asarray(jax.devices()), ('model',))
        with mesh:
            out = jax.jit(lambda p, x: tp_forward(p, cfg, x, 50, None, mesh))(params, x)
        ref = dit.forward(params, cfg, x, 50, None)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        print('TP_OK')
    """, n_devices=4)
    assert "TP_OK" in out


@pytest.mark.slow
def test_dryrun_one_config_512_devices():
    """launch/dryrun compiles a real (arch x shape) on the 16x16 mesh."""
    out = _run("""
        import sys
        sys.argv = ['x', '--arch', 'xlstm-125m', '--shape', 'decode_32k']
        from repro.launch.dryrun import main
        main()
    """, n_devices=1, timeout=560)   # dryrun sets its own XLA_FLAGS
    assert "all dry-runs OK" in out
