"""Video / multi-frame diffusion as the sixth schedule dimension
(DESIGN.md §16): frame partitioner properties, FrameShard IR cadence and
the cross-frame staleness bound, placement-invariant emulated numerics
with frame 0 / ``num_frames=1`` bitwise the image path, the stadi_video
joint planner + frame cost model, the spmd_frames mesh executor, and
video serving lanes."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import comm as comm_lib
from repro.core import events as ir
from repro.core import frames as frames_lib
from repro.core import patch_parallel as pp
from repro.core import sampler as sampler_lib
from repro.core.frames import FramePlan
from repro.core.pipeline import (FRAME_BACKENDS, StadiConfig, StadiPipeline,
                                 check_backend_can_run, get_executor)
from repro.core.planners import get_planner
from repro.core.schedule import TemporalPlan
from repro.core.simulate import CostModel
from repro.models.diffusion import dit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tiny-dit").reduced()      # 4 heads, 8 token rows
    params = dit.nondegenerate_params(dit.init_params(jax.random.PRNGKey(0),
                                                      cfg))
    sched = sampler_lib.linear_schedule(T=100)
    F = 3
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (1, F, cfg.latent_size, cfg.latent_size,
                             cfg.channels))
    cond = jnp.array([1])
    return cfg, params, sched, x_T, cond


# ----------------------------------------------------------------------
# frame partitioner + group layout (satellite: property coverage)
# ----------------------------------------------------------------------

def _check_frame_partition(num_frames, n_groups, speeds):
    groups = frames_lib.frame_partition(num_frames, n_groups, speeds)
    assert len(groups) == n_groups
    assert sum(groups) == num_frames                   # covers, disjoint
    assert all(g >= 1 for g in groups)                 # >= 1 frame per row
    sp = (list(speeds)[:n_groups] if speeds else [1.0] * n_groups)
    if len(sp) < n_groups:
        sp = sp + [sp[-1]] * (n_groups - len(sp))
    for i, vi in enumerate(sp):                        # speed-proportional
        for j, vj in enumerate(sp):
            if vi > vj:
                assert groups[i] >= groups[j], (groups, sp)
    # the FramePlan built from it validates and its bounds tile [0, F)
    plan = frames_lib.make_frame_plan(num_frames, n_groups, speeds)
    bounds = plan.bounds
    assert bounds[0][0] == 0 and bounds[-1][1] == num_frames
    assert all(b[1] == c[0] for b, c in zip(bounds, bounds[1:]))


def test_frame_partition_basics():
    assert frames_lib.frame_partition(4, 1) == [4]
    assert frames_lib.frame_partition(4, 2) == [2, 2]
    assert frames_lib.frame_partition(4, 2, [1.0, 0.5]) == [3, 1]
    assert frames_lib.frame_partition(3, 3, [10.0, 0.01, 0.01]) == [1, 1, 1]
    with pytest.raises(ValueError, match="1 frame per group"):
        frames_lib.frame_partition(2, 3)
    with pytest.raises(ValueError, match="at least one frame group"):
        frames_lib.frame_partition(4, 0)


def test_frame_partition_properties_deterministic():
    for num_frames, n_groups, speeds in [
        (4, 1, None), (4, 2, None), (8, 4, [1.0, 0.8, 0.6, 0.5]),
        (16, 3, [2.0, 1.0, 0.5]), (8, 8, None), (5, 2, [9.0, 1.0]),
    ]:
        _check_frame_partition(num_frames, n_groups, speeds)


def test_frame_plan_validation():
    with pytest.raises(ValueError, match="at least one frame"):
        FramePlan(0, (1,))
    with pytest.raises(ValueError, match="at least one group"):
        FramePlan(4, ())
    with pytest.raises(ValueError, match=">= 1 frame"):
        FramePlan(4, (4, 0))
    with pytest.raises(ValueError, match="sum to"):
        FramePlan(4, (2, 1))
    assert not FramePlan(1, (1,)).framed
    assert FramePlan(2, (2,)).framed


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                     # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=100, deadline=None)
    @given(num_frames=st.integers(1, 64), n_groups=st.integers(1, 8),
           speeds=st.one_of(st.none(),
                            st.lists(st.floats(0.05, 4.0), min_size=1,
                                     max_size=8)))
    def test_frame_partition_properties(num_frames, n_groups, speeds):
        n_groups = min(n_groups, num_frames)
        _check_frame_partition(num_frames, n_groups, speeds)


def test_frame_group_layout_row_dealt():
    """Devices are dealt ROW-wise (contiguous speed-sorted blocks), so the
    fast member row gets the biggest frame chunk and one global patch
    column split fits every row."""
    rows, row_speeds = frames_lib.frame_group_layout([1.0, 0.5, 0.8, 0.6],
                                                     2)
    assert rows == [[1.0, 0.8], [0.6, 0.5]]
    assert row_speeds == [1.8, 1.1]
    # leftover devices idle (5 devices, 2 groups -> 2x2, slowest idles)
    rows5, _ = frames_lib.frame_group_layout([1.0, 0.9, 0.8, 0.7, 0.1], 2)
    assert len(rows5) == 2 and all(len(r) == 2 for r in rows5)
    assert 0.1 not in [v for r in rows5 for v in r]
    with pytest.raises(ValueError, match="at least 3 devices"):
        frames_lib.frame_group_layout([1.0, 0.5], 3)


# ----------------------------------------------------------------------
# IR: FrameShard cadence + cross-frame staleness bound
# ----------------------------------------------------------------------

def test_frameshard_emitted_per_adaptive_interval():
    plan = TemporalPlan([16, 16], [1, 1], [False, False], 16, 4)
    policy = comm_lib.get_exchange("stale_async", 2)
    fplan = FramePlan(4, (3, 1))
    evs = list(ir.lower(plan, [4, 4], policy, frames=fplan))
    shards = [e for e in evs if isinstance(e, ir.FrameShard)]
    intervals = [e for e in evs if isinstance(e, ir.ComputeInterval)]
    assert len(shards) == len(intervals)               # one per interval
    assert all(s.frames == (3, 1) for s in shards)
    assert all(s.num_frames == 4 for s in shards)
    assert [s.fine_step for s in shards] == [c.fine_step for c in intervals]
    assert [s.index for s in shards] == list(range(len(shards)))
    # no FrameShard without a multi-frame plan
    assert not any(isinstance(e, ir.FrameShard)
                   for e in ir.lower(plan, [4, 4], policy))
    assert not any(isinstance(e, ir.FrameShard)
                   for e in ir.lower(plan, [4, 4], policy,
                                     frames=FramePlan(1, (1,))))


def test_replay_records_frame_count():
    plan = TemporalPlan([16, 16], [1, 2], [False, False], 16, 4)
    policy = comm_lib.get_exchange("stale_async", 3)
    recs = ir.replay(plan, [4, 4], policy, frames=FramePlan(3, (2, 1)))
    assert all(r.frames == 3 for r in recs)
    plain = ir.replay(plan, [4, 4], policy)
    assert all(r.frames == 1 for r in plain)


def test_max_frame_staleness_bounded_by_refresh(setup):
    """The previous-frame half of the 2N context ages under the boundary
    policy exactly like the within-frame halo: worst age <= refresh_every
    under stale_async (snapshot semantics make even a fresh merge one
    interval old at the next read)."""
    cfg, params, sched, x_T, cond = setup
    for E in (2, 3):
        config = StadiConfig.from_occupancies(
            [0.0, 0.4], m_base=8, m_warmup=2, num_frames=3,
            exchange="stale_async", exchange_refresh=E)
        res = StadiPipeline(cfg, params, sched, config).generate(x_T, cond)
        worst = frames_lib.max_frame_staleness(res.trace.events)
        assert 0 < worst <= E, (E, worst)
    # synthetic: single-frame records never contribute
    recs = ir.replay(TemporalPlan([16, 16], [1, 1], [False, False], 16, 4),
                     [4, 4], comm_lib.get_exchange("stale_async", 4))
    assert frames_lib.max_frame_staleness(recs) == 0


# ----------------------------------------------------------------------
# emulated reference: degeneration + frame-0 + placement invariance
# ----------------------------------------------------------------------

def test_num_frames_one_is_bitwise_image_path(setup):
    """num_frames=1 is the pre-frame image pipeline, bit for bit."""
    cfg, params, sched, x_T, cond = setup
    base = StadiConfig.from_occupancies([0.0, 0.4], m_base=8, m_warmup=2,
                                        exchange="stale_async")
    x1 = x_T[:, 0]
    ref = StadiPipeline(cfg, params, sched, base).generate(x1, cond)
    one = StadiPipeline(cfg, params, sched, dataclasses.replace(
        base, num_frames=1)).generate(x1, cond)
    np.testing.assert_array_equal(np.asarray(one.image),
                                  np.asarray(ref.image))
    assert one.trace.frames is None or not one.trace.frames.framed


def test_frame_zero_is_bitwise_image_trajectory(setup):
    """Frame 0 never sees a previous frame: its denoising trajectory is
    the image run, bit for bit, regardless of how many frames follow."""
    cfg, params, sched, x_T, cond = setup
    plan = TemporalPlan([8, 8], [1, 2], [False, False], 8, 2)
    img = pp.run_schedule(params, cfg, sched, x_T[:, 0], cond, plan, [4, 4],
                          exchange="stale_async").image
    vid = frames_lib.run_frames(params, cfg, sched, x_T, cond, plan, [4, 4],
                                exchange="stale_async",
                                frames=FramePlan(3, (3,))).image
    np.testing.assert_array_equal(np.asarray(vid[:, 0]), np.asarray(img))


def test_trajectory_is_placement_invariant(setup):
    """The frame grouping repartitions WHERE frames run, never WHAT is
    computed: with the (temporal, patches) plan held fixed, every grouping
    produces identical latents (like seq shard-count invariance)."""
    cfg, params, sched, x_T, cond = setup
    plan = TemporalPlan([8, 8], [1, 1], [False, False], 8, 2)
    imgs = {}
    for groups in [(3,), (2, 1), (1, 1, 1)]:
        res = frames_lib.run_frames(params, cfg, sched, x_T, cond, plan,
                                    [4, 4], exchange="stale_async",
                                    frames=FramePlan(3, groups))
        imgs[groups] = np.asarray(res.image)
        assert res.trace.frames.groups == groups
    np.testing.assert_array_equal(imgs[(3,)], imgs[(2, 1)])
    np.testing.assert_array_equal(imgs[(3,)], imgs[(1, 1, 1)])


# ----------------------------------------------------------------------
# fail-fast paths (satellite: registry + composition gates)
# ----------------------------------------------------------------------

def test_registry_errors_name_frame_entries():
    with pytest.raises(KeyError, match="spmd_frames"):
        get_executor("no-such-backend")
    with pytest.raises(KeyError, match="stadi_video"):
        get_planner("no-such-planner")


def test_pipeline_rejects_bad_frame_configs(setup):
    cfg, params, sched, _, _ = setup
    base = StadiConfig.from_occupancies([0.0, 0.4], m_base=8, m_warmup=2,
                                        num_frames=3)
    StadiPipeline(cfg, params, sched, base)                # fine
    for bad, match in [
        (dict(num_frames=0), "num_frames"),
        (dict(frame_groups=-1), "frame_groups"),
        (dict(backend="spmd"), "frame backend"),
        (dict(backend="pipefuse"), "frame backend"),
        (dict(frame_groups=4), "cannot split"),            # > num_frames
        (dict(num_frames=8, frame_groups=3,
              planner="stadi_video"), "infeasible"),       # > n_devices
        # §17 lifted the CFG x frames gate for FUSED placement only —
        # split/interleaved branch meshes still collide with member rows
        (dict(cfg_scale=2.0, guidance="split"), "fused"),
        (dict(cfg_scale=2.0, guidance="interleaved"), "fused"),
        (dict(seq_shards=2), "sequence sharding"),
        (dict(num_stages=2), "displaced patch pipeline"),
        (dict(rebalance_every=2), "rebalancing"),
        (dict(num_frames=1, frame_groups=2), "needs num_frames > 1"),
    ]:
        with pytest.raises(ValueError, match=match):
            StadiPipeline(cfg, params, sched,
                          dataclasses.replace(base, **bad))
    # frame-parallel placement needs the joint planner
    with pytest.raises(ValueError, match="stadi_video"):
        StadiPipeline(cfg, params, sched,
                      dataclasses.replace(base, frame_groups=2)).plan()
    # fused CFG on frames is allowed now (guided video, DESIGN.md §17)
    StadiPipeline(cfg, params, sched,
                  dataclasses.replace(base, cfg_scale=2.0,
                                      guidance="fused"))   # fine


def test_check_backend_can_run_rejects_frame_mismatch(setup):
    cfg, params, sched, _, _ = setup
    config = StadiConfig.from_occupancies([0.0, 0.4], m_base=8, m_warmup=2)
    plan = StadiPipeline(cfg, params, sched, config).plan()
    # a multi-frame run needs a frame backend
    with pytest.raises(ValueError, match="frame backend"):
        check_backend_can_run(plan, dataclasses.replace(
            config, num_frames=3, backend="spmd"))
    for backend in FRAME_BACKENDS:
        if backend == "spmd_frames":
            continue
        check_backend_can_run(plan, dataclasses.replace(
            config, num_frames=3, backend=backend))        # fine
    # spmd_frames without a multi-frame plan is a config error, not a
    # silent fall-through to plain spmd
    with pytest.raises(ValueError, match="multi-frame plan"):
        check_backend_can_run(plan, dataclasses.replace(
            config, backend="spmd_frames"))


# ----------------------------------------------------------------------
# stadi_video joint planner + frame cost model
# ----------------------------------------------------------------------

def _knobs(**kw):
    defaults = dict(occupancies=[0.0, 0.0, 0.5, 0.5], m_base=16, m_warmup=4,
                    planner="stadi_video", num_frames=4, frame_groups=0,
                    kv_row_bytes=4096, latent_bytes=16384,
                    exchange_refresh=2)
    occ = defaults.pop("occupancies")
    defaults.update(kw)
    return StadiConfig.from_occupancies(occ, **defaults)


def test_stadi_video_prefers_sequential_when_compute_bound():
    """With no attention term (t_ctx=0) frame rows buy nothing and cost a
    cross-row K/V handoff + coarser patch columns: the planner returns the
    frame-sequential placement."""
    knobs = _knobs(cost_model=CostModel(t_fixed=1e-3, t_row=5e-4, t_ctx=0.0,
                                        link_bw=1e6, link_latency=1e-3))
    plan = get_planner("stadi_video")(knobs.speeds, knobs, 8)
    assert plan.planner == "stadi_video"
    assert plan.frames.n_groups == 1
    assert plan.frames.groups == (4,)


def test_stadi_video_splits_when_attention_bound():
    """When the per-substep wall is the cross-frame context read (t_ctx
    dominates, every frame past the first reads 2N rows), dealing frames
    onto member rows divides it — a frame-parallel candidate wins despite
    the handoff traffic, with a speed-proportional chunk per row."""
    knobs = _knobs(cost_model=CostModel(t_fixed=1e-5, t_row=1e-5, t_ctx=5e-3,
                                        link_bw=1e9, link_latency=1e-7))
    plan = get_planner("stadi_video")(knobs.speeds, knobs, 8)
    fplan = plan.frames
    assert fplan is not None and fplan.n_groups > 1
    assert sum(fplan.groups) == 4
    assert list(fplan.groups) == sorted(fplan.groups, reverse=True)
    # grouped columns: patches has one slab per patch-worker COLUMN
    assert len(plan.patches) <= len(knobs.speeds) // fplan.n_groups
    assert plan.speeds == knobs.speeds        # raw cluster, not columns


def test_stadi_video_pinning_and_infeasible():
    knobs = _knobs(frame_groups=2,
                   cost_model=CostModel(t_fixed=1e-3, t_row=5e-4))
    plan = get_planner("stadi_video")(knobs.speeds, knobs, 8)
    assert plan.frames.n_groups == 2                       # pinned
    one = get_planner("stadi_video")(knobs.speeds, _knobs(frame_groups=1), 8)
    assert one.frames.groups == (4,)                       # pinned seq
    with pytest.raises(ValueError, match="infeasible"):
        get_planner("stadi_video")(knobs.speeds, _knobs(frame_groups=8), 8)
    with pytest.raises(ValueError, match="num_frames > 1"):
        get_planner("stadi_video")(knobs.speeds, _knobs(num_frames=1), 8)


def test_simulate_prices_frames(setup):
    """The simulate backend replays FrameShard rows: multi-frame costs
    more than single-frame, and at t_ctx-dominated profiles the
    frame-parallel plan models faster than the frame-sequential one."""
    cfg, params, sched, x_T, cond = setup
    bound = CostModel(t_fixed=1e-5, t_row=1e-5, t_ctx=2e-3)
    base = StadiConfig.from_occupancies(
        [0.0, 0.0, 0.5, 0.5], m_base=8, m_warmup=2, backend="simulate",
        exchange="stale_async", cost_model=bound)
    x4 = jnp.concatenate([x_T, x_T[:, :1]], axis=1)
    lat = {}
    for name, extra in [
        ("image", dict()),
        ("fseq", dict(num_frames=4)),
        ("fpar", dict(num_frames=4, planner="stadi_video")),
    ]:
        config = dataclasses.replace(base, **extra)
        res = StadiPipeline(cfg, params, sched, config).generate(
            x_T[:, 0] if name == "image" else x4, cond)
        assert res.image is None and res.latency_s > 0
        lat[name] = res.latency_s
    assert lat["fseq"] > lat["image"], lat
    assert lat["fpar"] < lat["fseq"], lat


# ----------------------------------------------------------------------
# serving: video lanes (run-to-completion cohorts, frame-priced rounds)
# ----------------------------------------------------------------------

def test_serving_video_lanes_bitwise(setup):
    from repro.serving import DiffusionServingEngine
    cfg, params, sched, x_T, cond = setup
    config = StadiConfig.from_occupancies(
        [0.0, 0.2, 0.4, 0.5], m_base=8, m_warmup=2, num_frames=3,
        planner="stadi_video", exchange="stale_async", exchange_refresh=2)
    pipe = StadiPipeline(cfg, params, sched, config)
    engine = DiffusionServingEngine(pipe, slots=2)
    assert engine.frames is not None and engine.frames.num_frames == 3
    reqs = [engine.submit(x_T, 1), engine.submit(x_T + 1.0, 2),
            engine.submit(x_T - 1.0, 3)]
    done = engine.run_to_completion()
    assert len(done) == 3 and len(engine.rounds) == 2      # 2 slots, 3 clips
    ref = pipe.generate(x_T, cond)
    np.testing.assert_array_equal(np.asarray(reqs[0].image),
                                  np.asarray(ref.image))
    # clips accrue the frame-priced schedule makespan sequentially
    lats = [r.modeled_latency_s for r in done]
    assert lats[0] < lats[1] < lats[2]
    stats = engine.stats()
    assert stats["n_completed"] == 3
    assert stats["modeled_makespan_s"] == pytest.approx(lats[2])


def test_serving_video_lane_rejections(setup):
    from repro.serving import DiffusionServingEngine
    cfg, params, sched, x_T, cond = setup
    config = StadiConfig.from_occupancies(
        [0.0, 0.4], m_base=8, m_warmup=2, num_frames=3)
    pipe = StadiPipeline(cfg, params, sched, config)
    with pytest.raises(ValueError, match="rebalance_every=0"):
        DiffusionServingEngine(pipe, slots=2, rebalance_every=2)
    engine = DiffusionServingEngine(pipe, slots=2)
    with pytest.raises(ValueError, match="carries 2 frames"):
        engine.submit(x_T[:, :2], 1)
    with pytest.raises(ValueError, match="one clip"):
        engine.submit(jnp.concatenate([x_T, x_T]), 1)
    # §17: guided video runs the PLAN's fused CFG — a per-request scale on
    # an unguided video plan is rejected toward planning guided instead
    with pytest.raises(ValueError, match="fused CFG"):
        engine.submit(x_T, 1, cfg_scale=2.0)


# ----------------------------------------------------------------------
# spmd_frames mesh executor (subprocess, real host devices)
# ----------------------------------------------------------------------

def test_spmd_frames_matches_emulated():
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core import sampler as sampler_lib
        from repro.core.pipeline import StadiConfig, StadiPipeline
        from repro.models.diffusion import dit

        cfg = get_config('tiny-dit').reduced()
        params = dit.nondegenerate_params(
            dit.init_params(jax.random.PRNGKey(0), cfg))
        sched = sampler_lib.linear_schedule(T=1000)
        x_T = jax.random.normal(jax.random.PRNGKey(1),
                                (1, 3, cfg.latent_size, cfg.latent_size,
                                 cfg.channels))
        cond = jnp.zeros((1,), jnp.int32)
        config = StadiConfig.from_occupancies(
            [0.0, 0.0, 0.5, 0.5], m_base=8, m_warmup=2,
            backend='spmd_frames', planner='stadi_video', num_frames=3,
            frame_groups=2, exchange='stale_async', exchange_refresh=2)
        spmd = StadiPipeline(cfg, params, sched, config).generate(x_T, cond)
        emu = StadiPipeline(cfg, params, sched, dataclasses.replace(
            config, backend='emulated')).generate(x_T, cond)
        a, b = np.asarray(spmd.image), np.asarray(emu.image)
        err = float(np.linalg.norm(a - b) / np.linalg.norm(b))
        assert err < 1e-5, err
        assert spmd.trace.frames is not None
        assert spmd.trace.frames.groups == (2, 1)
        print('SPMD_FRAMES_OK', err)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=520, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "SPMD_FRAMES_OK" in r.stdout
