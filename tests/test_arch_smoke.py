"""Per-architecture smoke tests (deliverable f).

For each assigned architecture: instantiate the REDUCED variant of the same
family (2 layers, d_model<=256, <=4 experts) and run one forward + one train
step on CPU, asserting output shapes and no NaNs; plus a prefill+decode step.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import build_model

SEQ = 32
BATCH = 2


@pytest.fixture(scope="module", params=ASSIGNED)
def arch(request):
    return request.param


def _setup(arch_id):
    cfg = get_config(arch_id).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = model.make_batch(jax.random.PRNGKey(1), BATCH, SEQ)
    return cfg, model, params, batch


def test_forward_shapes_no_nans(arch):
    cfg, model, params, batch = _setup(arch)
    logits = model.forward_logits(params, batch)
    n_tok = batch.get("tgt_tokens", batch.get("tokens")).shape[1]
    expect_seq = n_tok + (cfg.n_vision_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (BATCH, expect_seq, cfg.vocab), logits.shape
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32))), "NaN/inf in logits"


def test_one_train_step(arch):
    cfg, model, params, batch = _setup(arch)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert jnp.isfinite(loss), loss
    gnorm = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0)
    assert jnp.isfinite(gnorm) and gnorm > 0.0
    # actually apply an SGD step and confirm loss is still finite
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
    assert jnp.isfinite(model.loss(new_params, batch))


def test_prefill_then_decode(arch):
    cfg, model, params, batch = _setup(arch)
    window = cfg.sliding_window
    if cfg.family == "encdec":
        cache = model.init_cache(BATCH, SEQ, src_len=SEQ)
    elif cfg.family == "vlm":
        cache = model.init_cache(BATCH, cfg.n_vision_tokens + SEQ + 8)
    else:
        cache = model.init_cache(BATCH, SEQ + 8, window=window)
    logits, cache = model.prefill(params, batch, cache, window=window)
    assert logits.shape == (BATCH, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
    token = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(2):
        logits, cache = model.decode_step(params, cache, token, window=window)
        assert logits.shape == (BATCH, cfg.vocab)
        assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
        token = jnp.argmax(logits, -1).astype(jnp.int32)
