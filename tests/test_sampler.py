"""Sampler + schedule numerics, incl. empirical Theorem 1/2 order checks on a
closed-form score model (cheap; the trained-DiT versions live in
benchmarks/bench_redundancy.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sampler as sl


def test_schedules_monotone():
    for sched in (sl.linear_schedule(1000), sl.cosine_schedule(1000)):
        ab = np.asarray(sched.alpha_bar)
        assert ab[0] == pytest.approx(1.0)
        assert np.all(np.diff(ab) <= 1e-9)
        assert ab[-1] < 0.05


def test_alpha_sigma_vp_identity():
    sched = sl.linear_schedule(1000)
    t = jnp.linspace(0, 1000, 77)
    a, s = sched.alpha(t), sched.sigma(t)
    np.testing.assert_allclose(np.asarray(a ** 2 + s ** 2), 1.0, rtol=1e-5)


def test_ddim_full_steps_deterministic_and_finite():
    sched = sl.linear_schedule(100)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8))
    eps_fn = lambda x, t: 0.1 * x
    out1 = sl.ddim_sample(eps_fn, sched, x, M=100)
    out2 = sl.ddim_sample(eps_fn, sched, x, M=100)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert np.all(np.isfinite(np.asarray(out1)))


def test_ddpm_runs_finite():
    sched = sl.linear_schedule(50)
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 4))
    out = sl.ddpm_sample(lambda x, t: 0.1 * x, sched, x, jax.random.PRNGKey(1))
    assert np.all(np.isfinite(np.asarray(out)))


def test_theorem1_redundancy_order():
    """|x_{t_m} - x_{t_{m+1}}| max-step-difference scales ~ 1/M (Thm. 1)."""
    sched = sl.linear_schedule(1000)
    x_T = jax.random.normal(jax.random.PRNGKey(0), (1, 16))
    eps_fn = lambda x, t: jnp.tanh(x)              # bounded model output

    def max_diff(M):
        _, traj = sl.ddim_sample(eps_fn, sched, x_T, M=M, collect=True)
        d = jnp.abs(jnp.diff(traj, axis=0))
        return float(jnp.max(d))

    Ms = [25, 50, 100, 200]
    diffs = [max_diff(M) for M in Ms]
    # fit slope in log-log; O(1/M) => slope ~ -1 (tolerate [-1.35, -0.6])
    slope = np.polyfit(np.log(Ms), np.log(diffs), 1)[0]
    assert -1.35 < slope < -0.6, (slope, diffs)


def test_theorem2_mixed_rate_alignment():
    """Device j with 2x steps of device i: gap at shared timesteps O(1/M)."""
    sched = sl.linear_schedule(1000)
    x_T = jax.random.normal(jax.random.PRNGKey(1), (1, 16))
    eps_fn = lambda x, t: jnp.tanh(x)

    def gap(M):
        ts_f = sl.ddim_timesteps(sched.T, M)       # fine (device j)
        ts_c = ts_f[::2]                           # coarse (device i), M/2 steps
        xf = xc = x_T
        gaps = []
        for m in range(M // 2):
            for s in range(2):
                tf, tt = ts_f[2 * m + s], ts_f[2 * m + s + 1]
                xf = sl.ddim_step(sched, xf, eps_fn(xf, tf), tf, tt)
            tc_f, tc_t = ts_c[m], ts_c[m + 1]
            xc = sl.ddim_step(sched, xc, eps_fn(xc, tc_f), tc_f, tc_t)
            gaps.append(float(jnp.max(jnp.abs(xf - xc))))
        return max(gaps)

    Ms = [40, 80, 160]
    gaps = [gap(M) for M in Ms]
    slope = np.polyfit(np.log(Ms), np.log(gaps), 1)[0]
    assert slope < -0.6, (slope, gaps)             # decays at least ~1/M


def test_diffusion_loss_finite_and_positive():
    sched = sl.linear_schedule(100)
    x0 = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 8, 3))
    loss = sl.diffusion_loss(lambda x, t: jnp.zeros_like(x), sched, x0,
                             jax.random.PRNGKey(1))
    assert float(loss) == pytest.approx(1.0, rel=0.2)   # ||eps||^2 ~ 1
