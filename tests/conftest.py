import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.hostenv import force_host_devices

# Tests run on the real CPU device(s). The CI matrix exercises
# STADI_HOST_DEVICES in {1, 4}: translate it into forced host platform
# devices BEFORE jax initializes (shared helper, also used by the launch
# scripts). Unset locally -> single device, as before. Keep XLA quiet and
# deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")
force_host_devices()
