import os

# Tests run on the single real CPU device (the 512-device override is
# dryrun.py-only, per the brief). Keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")
