"""Persistent plan cache (DESIGN.md §14): hit/miss key semantics, drift
invalidation through the serving replanner, and loud corrupt-entry
fallback. The cache must make a second identical workload skip planner
search entirely (planner_calls counter) while any key-component change —
cluster speeds, model config, workload shape — misses."""
import dataclasses
import glob
import json
import os

import jax
import pytest

from repro.configs import get_config
from repro.core import sampler as sampler_lib
from repro.core.pipeline import StadiConfig, StadiPipeline
from repro.core.simulate import CostModel
from repro.models.diffusion import dit
from repro.serving.plan_cache import CACHE_VERSION, PlanCache


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tiny-dit").reduced()
    params = dit.nondegenerate_params(dit.init_params(jax.random.PRNGKey(0),
                                                      cfg))
    sched = sampler_lib.linear_schedule(T=100)
    return cfg, params, sched


def _config(speeds, **kw):
    from repro.core.hetero import DeviceProfile
    cluster = tuple(DeviceProfile(f"dev{i}", c=v)
                    for i, v in enumerate(speeds))
    return StadiConfig(cluster=cluster, **kw)


def _pipe(setup, tmp_path, speeds=(1.0, 0.5), cfg=None, **kw):
    mcfg, params, sched = setup
    config = _config(list(speeds), m_base=8, m_warmup=2,
                     plan_cache_dir=str(tmp_path), **kw)
    return StadiPipeline(cfg or mcfg, params, sched, config)


def test_hit_on_identical_key_skips_planner_search(setup, tmp_path):
    pipe = _pipe(setup, tmp_path)
    p1 = pipe.plan()
    assert pipe.planner_calls == 1
    assert pipe.plan_cache.stats()["misses"] == 1
    p2 = pipe.plan()
    assert p2 == p1
    assert pipe.planner_calls == 1          # search was skipped
    assert pipe.plan_cache.stats()["hits"] == 1
    assert pipe.plan_cache.stats()["hit_rate"] == 0.5


def test_restart_persistence(setup, tmp_path):
    _pipe(setup, tmp_path).plan()
    fresh = _pipe(setup, tmp_path)          # new process, same cache dir
    plan = fresh.plan()
    assert fresh.planner_calls == 0
    assert fresh.plan_cache.hits == 1
    assert plan == _pipe(setup, tmp_path).plan()


def test_miss_on_any_key_component_change(setup, tmp_path):
    cfg, params, sched = setup
    base = _pipe(setup, tmp_path)
    base.plan()
    # cluster signature: different profiled speeds
    other_speeds = _pipe(setup, tmp_path, speeds=(1.0, 0.6))
    other_speeds.plan()
    assert other_speeds.planner_calls == 1
    # workload shape: any planner-visible knob
    other_steps = StadiPipeline(cfg, params, sched, dataclasses.replace(
        base.config, m_base=16))
    other_steps.plan()
    assert other_steps.planner_calls == 1
    # model config hash
    cfg2 = dataclasses.replace(cfg, n_layers=cfg.n_layers + 1)
    other_model = _pipe(setup, tmp_path, cfg=cfg2)
    other_model.plan()
    assert other_model.planner_calls == 1
    # ... while the original key still hits
    again = _pipe(setup, tmp_path)
    again.plan()
    assert again.planner_calls == 0


def test_sub_jitter_speeds_share_an_entry(setup, tmp_path):
    """The cluster signature rounds speeds, so measurement jitter below
    the rounding grain maps onto the same cache entry."""
    _pipe(setup, tmp_path).plan()
    jittered = _pipe(setup, tmp_path, speeds=(1.001, 0.499))
    jittered.plan()
    assert jittered.planner_calls == 0
    assert jittered.plan_cache.hits == 1


def test_corrupt_entry_falls_back_loudly(setup, tmp_path):
    pipe = _pipe(setup, tmp_path)
    live = pipe.plan()
    path = pipe.plan_cache._path(pipe.last_plan_key)
    with open(path, "w") as f:
        f.write("{not json")
    fresh = _pipe(setup, tmp_path)
    with pytest.warns(RuntimeWarning, match="falling back to live planning"):
        recovered = fresh.plan()
    assert recovered == live                # live planning still works
    assert fresh.planner_calls == 1
    assert fresh.plan_cache.corrupt == 1
    # the bad entry was dropped and re-written by the live plan
    third = _pipe(setup, tmp_path)
    third.plan()
    assert third.planner_calls == 0


def test_unversioned_entry_is_corrupt(setup, tmp_path):
    pipe = _pipe(setup, tmp_path)
    pipe.plan()
    path = pipe.plan_cache._path(pipe.last_plan_key)
    with open(path, "w") as f:
        f.write('{"version": 999}')
    fresh = _pipe(setup, tmp_path)
    with pytest.warns(RuntimeWarning, match="version"):
        fresh.plan()
    assert fresh.plan_cache.corrupt == 1


def test_cache_roundtrips_all_five_axes(setup, tmp_path):
    """A fully-populated plan (stages + guidance + seq) survives the disk
    round trip bit-exactly — dataclass equality on every axis."""
    cfg, params, sched = setup
    config = _config([1.0, 0.5], m_base=8, m_warmup=2, num_stages=2,
                     cfg_scale=2.0, guidance="fused", seq_shards=2,
                     backend="simulate",
                     cost_model=CostModel(t_fixed=1e-3, t_row=1e-4),
                     plan_cache_dir=str(tmp_path))
    pipe = StadiPipeline(cfg, params, sched, config)
    planned = pipe.plan()
    cached = StadiPipeline(cfg, params, sched, config).plan()
    assert cached == planned
    assert cached.stages == planned.stages
    assert cached.guidance == planned.guidance
    assert cached.seq == planned.seq


def test_use_cache_false_bypasses(setup, tmp_path):
    pipe = _pipe(setup, tmp_path)
    pipe.plan()
    pipe.plan(use_cache=False)
    assert pipe.planner_calls == 2
    assert pipe.plan_cache.hits == 0


def test_no_cache_dir_means_no_cache(setup):
    cfg, params, sched = setup
    pipe = StadiPipeline(cfg, params, sched,
                         _config([1.0, 0.5], m_base=8, m_warmup=2))
    assert pipe.plan_cache is None
    pipe.plan()
    pipe.plan()
    assert pipe.planner_calls == 2


def test_drift_replan_invalidates_stale_entry(setup, tmp_path):
    """Serving-engine replanning: when OnlineProfiler drift exceeds the
    threshold, the engine replans from the profiled speeds AND drops the
    cache entry the stale plan came from (the persisted pairing no longer
    matches the cluster)."""
    from repro.serving.diffusion_engine import DiffusionServingEngine
    cfg, params, sched = setup
    cm = CostModel(t_fixed=5e-3, t_row=5.5e-4, link_bw=1.25e9,
                   link_latency=50e-6)
    config = _config([1.0, 1.0, 0.5, 0.5], m_base=16, m_warmup=2,
                     planner="stadi_guidance", cfg_scale=2.0,
                     guidance="split", cost_model=cm,
                     plan_cache_dir=str(tmp_path))
    pipe = StadiPipeline(cfg, params, sched, config)
    engine = DiffusionServingEngine(pipe, slots=4, rebalance_every=1,
                                    measured_speeds=[1.0, 0.1, 0.5, 0.5])
    stale_key = pipe.last_plan_key
    assert stale_key is not None
    for i in range(4):
        x = jax.random.normal(jax.random.PRNGKey(80 + i),
                              (1, cfg.latent_size, cfg.latent_size,
                               cfg.channels))
        engine.submit(x, i % cfg.n_classes)
    engine.run_to_completion()
    assert engine.stats()["replans"] >= 1
    cache_stats = engine.stats()["plan_cache"]
    assert cache_stats is not None and cache_stats["invalidations"] >= 1
    assert not os.path.exists(pipe.plan_cache._path(stale_key))
    # replanned entries for the drifted cluster were persisted in turn
    assert glob.glob(os.path.join(str(tmp_path), "*.json"))


def test_engine_stats_surface_cache_counters(setup, tmp_path):
    from repro.serving.diffusion_engine import DiffusionServingEngine
    cfg, params, sched = setup
    pipe = _pipe(setup, tmp_path, speeds=(1.0, 0.5),
                 cost_model=CostModel(t_fixed=1e-3, t_row=1e-4))
    engine = DiffusionServingEngine(pipe, slots=2)
    s = engine.stats()
    assert s["planner_calls"] == 1
    assert s["plan_cache"]["misses"] == 1
    # second engine over the same pipeline-config: pure cache hit
    pipe2 = _pipe(setup, tmp_path, speeds=(1.0, 0.5),
                  cost_model=CostModel(t_fixed=1e-3, t_row=1e-4))
    DiffusionServingEngine(pipe2, slots=2)
    assert pipe2.planner_calls == 0
    assert pipe2.plan_cache.hits == 1


def test_frame_axis_is_a_key_component(setup, tmp_path):
    """The frame axis (DESIGN.md §16) is part of the workload key: a video
    workload must never reuse an image plan, identical video workloads hit,
    and a frame-placement knob change misses — with the FramePlan surviving
    the disk round trip."""
    image = _pipe(setup, tmp_path)
    image.plan()
    assert image.planner_calls == 1
    video = _pipe(setup, tmp_path, num_frames=4, planner="stadi_video")
    planned = video.plan()
    assert video.planner_calls == 1          # image entry did not match
    assert planned.frames is not None and planned.frames.num_frames == 4
    again = _pipe(setup, tmp_path, num_frames=4, planner="stadi_video")
    cached = again.plan()
    assert again.planner_calls == 0          # identical video workload hits
    assert cached == planned
    assert cached.frames == planned.frames   # FramePlan round-trips
    pinned = _pipe(setup, tmp_path, num_frames=4, planner="stadi_video",
                   frame_groups=2)
    pinned.plan()
    assert pinned.planner_calls == 1         # placement knob is in the key
    assert pinned.plan().frames.n_groups == 2


def test_cache_version_bump_invalidates_old_entries_loudly(setup, tmp_path):
    """Migration across a CACHE_VERSION bump (v2 -> v3, DESIGN.md §17): an
    entry persisted by the previous release — valid layout, old version
    tag — must invalidate loudly (warning + corrupt counter + removal) and
    be re-planned live, never deserialize. A v2 plan was priced with
    t_xattn unthreaded, so silently reusing it would be wrong."""
    pipe = _pipe(setup, tmp_path)
    live = pipe.plan()
    path = pipe.plan_cache._path(pipe.last_plan_key)
    with open(path) as f:
        entry = json.load(f)
    entry["version"] = CACHE_VERSION - 1     # a pre-bump release's entry
    with open(path, "w") as f:
        json.dump(entry, f)
    fresh = _pipe(setup, tmp_path)
    with pytest.warns(RuntimeWarning, match="version"):
        recovered = fresh.plan()
    assert recovered == live                 # live planning took over
    assert fresh.planner_calls == 1
    assert fresh.plan_cache.corrupt == 1
    # the stale entry was dropped and re-persisted at the current version
    with open(path) as f:
        assert json.load(f)["version"] == CACHE_VERSION
    migrated = _pipe(setup, tmp_path)
    migrated.plan()
    assert migrated.planner_calls == 0


def test_prompt_bucket_is_a_key_component(setup, tmp_path):
    """The prompt bucket (DESIGN.md §17) is part of the workload key: the
    derived bucket (cond_seq_len) and an explicit equal cond_bucket share
    one entry, a shorter serving bucket prices differently and gets its
    own, and identical prompt workloads hit."""
    cfg, params, sched = setup
    tcfg = cfg.text_conditioned(cond_seq_len=16)
    derived = _pipe(setup, tmp_path, cfg=tcfg)
    derived.plan()
    assert derived.planner_calls == 1
    explicit = _pipe(setup, tmp_path, cfg=tcfg, cond_bucket=16)
    explicit.plan()
    assert explicit.planner_calls == 0       # same bucket -> same key
    short = _pipe(setup, tmp_path, cfg=tcfg, cond_bucket=8)
    short.plan()
    assert short.planner_calls == 1          # bucket change -> own entry
    again = _pipe(setup, tmp_path, cfg=tcfg, cond_bucket=8)
    again.plan()
    assert again.planner_calls == 0
    assert again.plan_cache.hits == 1


def test_cache_roundtrips_guided_video_prompt_plan(setup, tmp_path):
    """Seven knobs feed one key — steps, patches, stages, guidance, seq,
    frames, prompt bucket. The fullest co-resident plan (guided video on a
    text-conditioned model) survives the disk round trip bit-exactly."""
    cfg, params, sched = setup
    tcfg = cfg.text_conditioned(cond_seq_len=16)
    config = _config([1.0, 1.0, 0.5, 0.5], m_base=8, m_warmup=2,
                     planner="stadi_video", num_frames=4,
                     guidance="fused", cfg_scale=3.0, backend="simulate",
                     cost_model=CostModel(t_fixed=1e-3, t_row=1e-4,
                                          t_xattn=1e-6),
                     plan_cache_dir=str(tmp_path))
    pipe = StadiPipeline(tcfg, params, sched, config)
    planned = pipe.plan()
    assert planned.guidance is not None and planned.guidance.mode == "fused"
    assert planned.frames is not None
    fresh = StadiPipeline(tcfg, params, sched, config)
    cached = fresh.plan()
    assert fresh.planner_calls == 0
    assert cached == planned
    assert cached.guidance == planned.guidance
    assert cached.frames == planned.frames


def test_plan_cache_standalone_invalidate_counts_real_removals(tmp_path):
    cache = PlanCache(cache_dir=str(tmp_path))
    assert cache.invalidate("deadbeef") is False
    assert cache.invalidations == 0
