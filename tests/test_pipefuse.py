"""Displaced patch-pipeline parallelism (DESIGN.md §11): the hetero stage
partitioner, the pipefuse executor's bitwise/degenerate contracts, the
StageShift IR semantics, staged latency modeling, the joint planner, and
pipefuse serving. The SPMD stage chain runs in a subprocess with forced
host devices, like the other distributed tests."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import events as ir
from repro.core import hetero
from repro.core import pipefuse as pf
from repro.core import sampler as sampler_lib
from repro.core import simulate as sim
from repro.core.pipeline import (EXECUTORS, StadiConfig, StadiPipeline,
                                 get_executor, plan_stages)
from repro.core.planners import PLANNERS, get_planner
from repro.core.schedule import TemporalPlan
from repro.core.simulate import CostModel
from repro.models.diffusion import dit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tiny-dit").reduced()      # 2 blocks, 8 token rows
    params = dit.nondegenerate_params(dit.init_params(jax.random.PRNGKey(0),
                                                      cfg))
    sched = sampler_lib.linear_schedule(T=100)
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (2, cfg.latent_size, cfg.latent_size,
                             cfg.channels))
    cond = jnp.array([1, 2])
    return cfg, params, sched, x_T, cond


# ----------------------------------------------------------------------
# stage partitioner (satellite: property coverage)
# ----------------------------------------------------------------------

def test_stage_partition_basics():
    assert hetero.stage_partition(4, [1.0]) == [4]          # whole model
    assert hetero.stage_partition(8, [1.0, 0.5]) == [5, 3]
    assert hetero.stage_partition(3, [10.0, 0.01, 0.01]) == [1, 1, 1]
    with pytest.raises(ValueError):
        hetero.stage_partition(2, [1.0, 1.0, 1.0])          # S > blocks
    with pytest.raises(ValueError):
        hetero.stage_partition(4, [])
    with pytest.raises(ValueError):
        hetero.stage_partition(4, [1.0, 0.0])


def _check_partition(n_blocks, speeds):
    stages = hetero.stage_partition(n_blocks, speeds)
    assert sum(stages) == n_blocks                          # covers all
    assert all(s >= 1 for s in stages)
    bounds = pf.stage_bounds(stages)                        # contiguous
    assert bounds[0][0] == 0 and bounds[-1][1] == n_blocks
    assert all(b[1] == c[0] for b, c in zip(bounds, bounds[1:]))
    for i, vi in enumerate(speeds):                         # monotone
        for j, vj in enumerate(speeds):
            if vi > vj:
                assert stages[i] >= stages[j], (stages, speeds)


def test_stage_partition_properties_deterministic():
    for n_blocks, speeds in [
        (28, [1.0, 0.5]), (28, [1.0, 0.5, 0.25]), (4, [0.3, 0.3, 0.3]),
        (7, [2.0, 1.0, 1.0, 0.5]), (12, [1.0] * 12), (5, [9.0, 1.0]),
    ]:
        _check_partition(n_blocks, speeds)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                         # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=100, deadline=None)
    @given(n_blocks=st.integers(1, 64),
           speeds=st.lists(st.floats(0.05, 4.0), min_size=1, max_size=8))
    def test_stage_partition_properties(n_blocks, speeds):
        if len(speeds) > n_blocks:
            speeds = speeds[:n_blocks]
        _check_partition(n_blocks, speeds)


# ----------------------------------------------------------------------
# IR: StageShift fill cadence
# ----------------------------------------------------------------------

def test_stageshift_emitted_at_fills_only():
    """The pipe fills entering the adaptive phase and refills after every
    draining ("full") boundary; skip boundaries keep it full — so under
    stale_async the fill cadence follows the refresh cadence."""
    from repro.core import comm as comm_lib
    plan = TemporalPlan([16, 16], [1, 1], [False, False], 16, 4)
    policy = comm_lib.get_exchange("stale_async", 3)
    evs = list(ir.lower(plan, [4, 4], policy, stages=[1, 1]))
    shifts = [e.fine_step for e in evs if isinstance(e, ir.StageShift)]
    fulls = [e.fine_step for e in evs if isinstance(e, ir.Exchange)
             and e.kind == "full" and not e.last]
    assert shifts[0] == plan.m_warmup                       # entering
    assert shifts[1:] == fulls                              # after drains
    # without a stage split (or depth 1) no StageShift exists
    assert not any(isinstance(e, ir.StageShift)
                   for e in ir.lower(plan, [4, 4], policy))
    assert not any(isinstance(e, ir.StageShift)
                   for e in ir.lower(plan, [4, 4], policy, stages=[2]))
    # replay() marks exactly the post-fill intervals
    recs = ir.replay(plan, [4, 4], policy, stages=[1, 1])
    fill_steps = [r.fine_step for r in recs if r.fill]
    assert fill_steps == shifts


# ----------------------------------------------------------------------
# executor: bitwise at one stage, displaced (bounded) beyond
# ----------------------------------------------------------------------

def test_pipefuse_one_stage_bitwise_vs_emulated(setup):
    cfg, params, sched, x_T, cond = setup
    for exchange in ("sync", "stale_async"):
        base = StadiConfig.from_occupancies([0.0, 0.5], m_base=8, m_warmup=2,
                                            exchange=exchange)
        emu = StadiPipeline(cfg, params, sched, base).generate(x_T, cond)
        pfr = StadiPipeline(cfg, params, sched, dataclasses.replace(
            base, backend="pipefuse")).generate(x_T, cond)
        np.testing.assert_array_equal(np.asarray(pfr.image),
                                      np.asarray(emu.image))


def test_pipefuse_wrong_stage_sum_rejected(setup):
    cfg, params, sched, x_T, cond = setup
    plan = TemporalPlan([8, 8], [1, 1], [False, False], 8, 2)
    with pytest.raises(ValueError, match="cover all"):
        pf.run_pipefuse(params, cfg, sched, x_T, cond, plan, [4, 4],
                        stages=[cfg.n_layers, 1])


def test_displaced_staleness_bound(setup):
    """The displaced contract: remote context rows are at most one substep
    stale, so (a) the trajectory genuinely differs from the interval-stale
    baseline, (b) stays close to it, and (c) the displacement VANISHES when
    a single slab owns the whole image (no remote rows exist) — the
    degenerate case of the staleness bound."""
    cfg, params, sched, x_T, cond = setup
    base = StadiConfig.from_occupancies([0.0, 0.5], m_base=8, m_warmup=2)
    emu = np.asarray(StadiPipeline(cfg, params, sched,
                                   base).generate(x_T, cond).image)
    s2 = np.asarray(StadiPipeline(cfg, params, sched, dataclasses.replace(
        base, backend="pipefuse", num_stages=2)).generate(x_T, cond).image)
    assert np.all(np.isfinite(s2))
    assert np.abs(s2 - emu).max() > 0            # displacement is real...
    ref = np.linalg.norm(emu)
    assert np.linalg.norm(s2 - emu) / ref < 0.05  # ...and bounded
    # (c): one slab == no remote rows == no displaced reads at all
    plan = TemporalPlan([8], [1], [False], 8, 2)
    solo_pf = pf.run_pipefuse(params, cfg, sched, x_T, cond, plan,
                              [cfg.tokens_per_side], stages=[1, 1])
    from repro.core import patch_parallel as pp
    solo_emu = pp.run_schedule(params, cfg, sched, x_T, cond, plan,
                               [cfg.tokens_per_side])
    np.testing.assert_allclose(np.asarray(solo_pf.image),
                               np.asarray(solo_emu.image),
                               rtol=0, atol=1e-5)


def test_displaced_partition_invariance(setup):
    """PipeFusion contract: the stage COUNT maps depth to devices but does
    not change the math — outputs are invariant to the partition."""
    cfg, params, sched, x_T, cond = setup       # reduced: 2 blocks
    plan = TemporalPlan([8, 8], [1, 2], [False, False], 8, 2)
    a = pf.run_pipefuse(params, cfg, sched, x_T, cond, plan, [5, 3],
                        stages=[1, 1])
    b = pf.run_pipefuse(params, cfg, sched, x_T, cond, plan, [5, 3],
                        stages=[2])             # depth-1 path, same ctx? no:
    # stages=[2] is the S == 1 exact path; instead compare two multi-stage
    # partitions on the full tiny-dit (4 blocks)
    cfg4 = get_config("tiny-dit").reduced().replace(n_layers=3)
    params4 = dit.nondegenerate_params(
        dit.init_params(jax.random.PRNGKey(0), cfg4))
    x4 = jax.random.normal(jax.random.PRNGKey(2),
                           (1, cfg4.latent_size, cfg4.latent_size,
                            cfg4.channels))
    c4 = jnp.array([3])
    r21 = pf.run_pipefuse(params4, cfg4, sched, x4, c4, plan, [5, 3],
                          stages=[2, 1])
    r111 = pf.run_pipefuse(params4, cfg4, sched, x4, c4, plan, [5, 3],
                           stages=[1, 1, 1])
    np.testing.assert_allclose(np.asarray(r21.image), np.asarray(r111.image),
                               rtol=0, atol=1e-5)
    assert np.all(np.isfinite(np.asarray(a.image)))
    assert np.all(np.isfinite(np.asarray(b.image)))


def test_pipefuse_trace_matches_simulate_replay(setup):
    """pipefuse's executed trace and build_trace's replay are structurally
    identical (the shared-IR guarantee, extended to fills/stages)."""
    cfg, params, sched, x_T, cond = setup
    config = StadiConfig.from_occupancies(
        [0.0, 0.5], m_base=16, m_warmup=4, backend="pipefuse", num_stages=2,
        exchange="stale_async", exchange_refresh=2)
    pipe = StadiPipeline(cfg, params, sched, config)
    res = pipe.generate(x_T, cond)
    plan = pipe.plan()
    ref = sim.build_trace(plan.temporal, plan.patches, cfg,
                          batch=int(x_T.shape[0]), exchange="stale_async",
                          exchange_refresh=2,
                          stages=plan_stages(plan, cfg, config))
    key = lambda e: (e.fine_step, list(e.substeps), list(e.patches),  # noqa: E731
                     e.synchronous, e.exchange, e.fill)
    assert [key(e) for e in res.trace.events] == [key(e) for e in ref.events]
    assert res.trace.stages == ref.stages == [1, 1]


def test_num_stages_needs_staged_backend(setup):
    cfg, params, sched, *_ = setup
    config = StadiConfig.from_occupancies([0.0, 0.5], m_base=8, m_warmup=2,
                                          num_stages=2)   # backend emulated
    with pytest.raises(ValueError, match="staged backend"):
        StadiPipeline(cfg, params, sched, config)


def test_auto_staged_plan_rejected_on_patch_backend():
    """num_stages=0 passes construction (auto may pick S=1), but if the
    joint search picks a pipeline, a non-staged backend must fail fast
    instead of silently running the micro-batches as whole-model patch
    workers while staged costs get reported."""
    cfg = get_config("sdxl-dit")                 # deep enough for stages
    config = StadiConfig.from_occupancies(
        [0.0, 0.8, 0.8], m_base=16, m_warmup=4, planner="stadi_pipefuse",
        num_stages=0, granularity=2,
        cost_model=CostModel(t_fixed=1e-4, t_row=1e-3))
    pipe = StadiPipeline(cfg, None, None, config)         # backend emulated
    assert pipe.plan().stages is not None                 # auto chose depth
    with pytest.raises(ValueError, match="staged backend"):
        pipe.generate()
    from repro.serving.diffusion_engine import DiffusionServingEngine
    with pytest.raises(ValueError, match="staged backend"):
        DiffusionServingEngine(pipe, slots=2)


def test_num_stages_beyond_cluster_rejected(setup):
    """--num-stages larger than the cluster must error (it used to clamp
    silently to the device count), matching the planner's infeasible
    message."""
    cfg, params, sched, x_T, cond = setup
    config = StadiConfig.from_occupancies([0.0, 0.5], m_base=8, m_warmup=2,
                                          backend="pipefuse", num_stages=4)
    with pytest.raises(ValueError, match="infeasible"):
        StadiPipeline(cfg, params, sched, config).generate(x_T, cond)


def test_registry_error_messages_list_pipefuse():
    with pytest.raises(KeyError, match="pipefuse"):
        get_executor("nope")
    with pytest.raises(KeyError, match="stadi_pipefuse"):
        get_planner("nope")
    assert {"pipefuse", "spmd_pipefuse"} <= set(EXECUTORS)
    assert "stadi_pipefuse" in PLANNERS


# ----------------------------------------------------------------------
# planner: joint (steps, patches, stage split)
# ----------------------------------------------------------------------

def test_stadi_pipefuse_planner_degenerates_to_patch():
    knobs = StadiConfig.from_occupancies([0.0, 0.5], m_base=16, m_warmup=4,
                                         num_stages=1, depth=28)
    plan = get_planner("stadi_pipefuse")(knobs.speeds, knobs, 32)
    ref = get_planner("stadi")(knobs.speeds, knobs, 32)
    assert plan.stages is None
    assert plan.patches == ref.patches
    assert plan.temporal == ref.temporal


def test_stadi_pipefuse_planner_forced_stages():
    knobs = StadiConfig.from_occupancies([0.0, 0.5], m_base=16, m_warmup=4,
                                         num_stages=2, depth=28)
    plan = get_planner("stadi_pipefuse")(knobs.speeds, knobs, 32)
    assert plan.stages is not None and sum(plan.stages) == 28
    assert plan.stages[0] >= plan.stages[1]      # fastest device, most blocks
    assert sum(plan.patches) == 32               # micro slabs cover the image
    assert all(r == 1 for r in plan.temporal.ratios)
    with pytest.raises(ValueError, match="infeasible"):
        get_planner("stadi_pipefuse")(knobs.speeds,
                                      dataclasses.replace(knobs,
                                                          num_stages=9), 32)


def test_stadi_pipefuse_planner_auto_prefers_pipeline_when_tiers_cannot():
    """Devices below STADI's b-threshold contribute NOTHING in patch mode
    but host pipeline stages fine — with the speed skew [1, 0.2, 0.2] the
    joint search re-includes them as stages."""
    knobs = StadiConfig.from_occupancies(
        [0.0, 0.8, 0.8], m_base=16, m_warmup=4, num_stages=0, depth=28,
        cost_model=CostModel(t_fixed=1e-4, t_row=1e-3))
    plan = get_planner("stadi_pipefuse")(knobs.speeds, knobs, 32)
    assert plan.stages is not None and len(plan.stages) == 3
    ref = get_planner("stadi")(knobs.speeds, knobs, 32)
    assert len(ref.active) == 1                  # patch mode drops 2 devices


# ----------------------------------------------------------------------
# simulator: staged traces
# ----------------------------------------------------------------------

def test_staged_simulation_beats_pure_patch_when_depth_bound():
    """Mini version of bench_pipefuse's acceptance: on a depth-bound 2-tier
    profile the stage chain wins >= 20% modeled vs uniform patches."""
    cfg = get_config("sdxl-dit")
    cm = CostModel(t_fixed=45e-3, t_row=2e-4, link_bw=25e9)
    base = StadiConfig.from_occupancies(
        [0.0, 0.5], m_base=20, m_warmup=2, backend="simulate", cost_model=cm,
        granularity=2, exchange="stale_async", exchange_refresh=8)
    uni = StadiPipeline(cfg, None, None, dataclasses.replace(
        base, planner="uniform")).generate().latency_s
    pfl = StadiPipeline(cfg, None, None, dataclasses.replace(
        base, planner="stadi_pipefuse", num_stages=2)).generate().latency_s
    assert pfl < 0.8 * uni, (pfl, uni)


def test_staged_fill_bubble_charged_on_drains():
    """sync (drain every boundary) must model slower than stale_async
    (drain every 4th) for the same staged plan — the pipe-refill price."""
    cfg = get_config("tiny-dit")
    cm = CostModel(t_fixed=10e-3, t_row=1e-4)
    base = StadiConfig.from_occupancies(
        [0.0, 0.5], m_base=16, m_warmup=2, backend="simulate", cost_model=cm,
        planner="stadi_pipefuse", num_stages=2)
    lat_sync = StadiPipeline(cfg, None, None, base).generate().latency_s
    lat_stale = StadiPipeline(cfg, None, None, dataclasses.replace(
        base, exchange="stale_async",
        exchange_refresh=4)).generate().latency_s
    assert lat_stale < lat_sync


# ----------------------------------------------------------------------
# serving: stage chains + per-request bitwise parity
# ----------------------------------------------------------------------

def test_serving_pipefuse_bitwise_and_stage_placement(setup):
    from repro.serving.diffusion_engine import DiffusionServingEngine
    cfg, params, sched, _, _ = setup
    config = StadiConfig.from_occupancies([0.0, 0.5], m_base=8, m_warmup=2,
                                          backend="pipefuse", num_stages=2)
    pipe = StadiPipeline(cfg, params, sched, config)
    engine = DiffusionServingEngine(pipe, slots=2)
    xs = [jax.random.normal(jax.random.PRNGKey(10 + u),
                            (1, cfg.latent_size, cfg.latent_size,
                             cfg.channels)) for u in range(3)]
    reqs = [engine.submit(x, u % cfg.n_classes) for u, x in enumerate(xs)]
    engine.run_to_completion()
    assert engine.stages == [1, 1]
    for u, (x, r) in enumerate(zip(xs, reqs)):
        ref = pipe.generate(x, jnp.asarray([u % cfg.n_classes],
                                           jnp.int32)).image
        if jax.device_count() == 1:
            np.testing.assert_array_equal(np.asarray(r.image),
                                          np.asarray(ref))
        else:
            # with forced multi host devices XLA compiles the lane-stacked
            # and single-request kernels with different intra-op blocking,
            # so NON-DEGENERATE numerics (this fixture de-degenerates
            # adaLN) match to float tolerance, not bitwise — the emulated
            # engine's warmup dispatch shows the same ~2e-7 there; its
            # own bitwise tests only pass because untrained adaLN-zero
            # params force eps == 0 exactly
            np.testing.assert_allclose(np.asarray(r.image),
                                       np.asarray(ref), rtol=0, atol=1e-5)
    # placement maps STAGES (chain order) to devices, fastest first
    staged_rounds = [rr for rr in engine.rounds if rr.adaptive_lanes]
    assert staged_rounds and all(rr.placement == ((0, 0), (1, 1))
                                 for rr in staged_rounds)


def test_serving_pipefuse_one_stage_matches_emulated_engine(setup):
    """At one stage the pipefuse stepper IS the emulated stepper."""
    from repro.serving.diffusion_engine import DiffusionServingEngine
    cfg, params, sched, _, _ = setup
    x = jax.random.normal(jax.random.PRNGKey(33),
                          (1, cfg.latent_size, cfg.latent_size,
                           cfg.channels))
    imgs = {}
    for backend in ("emulated", "pipefuse"):
        config = StadiConfig.from_occupancies([0.0, 0.5], m_base=8,
                                              m_warmup=2, backend=backend)
        engine = DiffusionServingEngine(
            StadiPipeline(cfg, params, sched, config), slots=2)
        req = engine.submit(x, 1)
        engine.run_to_completion()
        imgs[backend] = np.asarray(req.image)
    np.testing.assert_array_equal(imgs["pipefuse"], imgs["emulated"])


# ----------------------------------------------------------------------
# SPMD stage chain (subprocess, real host devices)
# ----------------------------------------------------------------------

def test_spmd_pipefuse_matches_emulated():
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core import sampler as sampler_lib
        from repro.core.pipeline import StadiConfig, StadiPipeline
        from repro.models.diffusion import dit

        cfg = get_config('tiny-dit').reduced()
        params = dit.nondegenerate_params(
            dit.init_params(jax.random.PRNGKey(0), cfg))
        sched = sampler_lib.linear_schedule(T=1000)
        x_T = jax.random.normal(jax.random.PRNGKey(1),
                                (1, cfg.latent_size, cfg.latent_size,
                                 cfg.channels))
        cond = jnp.zeros((1,), jnp.int32)
        config = StadiConfig.from_occupancies(
            [0.0, 0.5], m_base=8, m_warmup=2, backend='spmd_pipefuse',
            num_stages=2, exchange='stale_async', exchange_refresh=2)
        spmd = StadiPipeline(cfg, params, sched, config).generate(x_T, cond)
        emu = StadiPipeline(cfg, params, sched, dataclasses.replace(
            config, backend='pipefuse')).generate(x_T, cond)
        a, b = np.asarray(spmd.image), np.asarray(emu.image)
        err = float(np.linalg.norm(a - b) / np.linalg.norm(b))
        assert err < 1e-3, err
        assert spmd.trace.stages == [1, 1]
        print('SPMD_PIPEFUSE_OK', err)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=520, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "SPMD_PIPEFUSE_OK" in r.stdout
