"""Sequence-parallel attention (DESIGN.md §13): the head/segment
partitioners, the device-grouping convention, the ring-attention reference,
SeqShard IR semantics, ring x stale-exchange staleness bounds, the bitwise
shard-invariance contract of the emulated reference, the stadi_seq joint
planner, the ring-contention cost model, seq-sharded serving, and the real
spmd_seq mesh executor (subprocess with forced host devices, like the other
distributed tests)."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import comm as comm_lib
from repro.core import events as ir
from repro.core import sampler as sampler_lib
from repro.core import seqpar
from repro.core import simulate as sim
from repro.core.pipeline import (SEQ_BACKENDS, StadiConfig, StadiPipeline,
                                 check_backend_can_run, get_executor,
                                 plan_seq)
from repro.core.planners import get_planner
from repro.core.schedule import TemporalPlan
from repro.core.simulate import CostModel
from repro.models import layers
from repro.models.diffusion import dit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# See tests/test_guidance.py: engine≡generate is bitwise for reference
# numerics; the forced-kernel CI leg compiles lane-batched vs unbatched
# kernel programs whose XLA fusion differs by ~1 ULP.
bitwise_vs_reference = pytest.mark.skipif(
    os.environ.get("STADI_USE_PALLAS", "").strip() not in ("", "0"),
    reason="engine bitwise invariant is defined for reference numerics; "
           "STADI_USE_PALLAS forces kernels process-wide")


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tiny-dit").reduced()      # 4 heads, 8 token rows
    params = dit.nondegenerate_params(dit.init_params(jax.random.PRNGKey(0),
                                                      cfg))
    sched = sampler_lib.linear_schedule(T=100)
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (1, cfg.latent_size, cfg.latent_size,
                             cfg.channels))
    cond = jnp.array([1])
    return cfg, params, sched, x_T, cond


# ----------------------------------------------------------------------
# head / ring-segment partitioners (satellite: property coverage)
# ----------------------------------------------------------------------

def test_head_partition_basics():
    assert seqpar.head_partition(4, 1) == [4]
    assert seqpar.head_partition(4, 2) == [2, 2]
    assert seqpar.head_partition(8, 2, [1.0, 0.5]) == [5, 3]
    assert seqpar.head_partition(3, 3, [10.0, 0.01, 0.01]) == [1, 1, 1]
    with pytest.raises(ValueError, match="1 head per shard"):
        seqpar.head_partition(2, 3)
    with pytest.raises(ValueError):
        seqpar.head_partition(4, 0)


def test_ring_segments_basics():
    assert seqpar.ring_segments(8, 1) == [8]
    assert seqpar.ring_segments(8, 2, [1.0, 1.0]) == [4, 4]
    assert seqpar.ring_segments(8, 2, [3.0, 1.0]) == [6, 2]
    with pytest.raises(ValueError, match="1 row per ring segment"):
        seqpar.ring_segments(2, 4)


def _check_seq_plan(n_heads, rows, n_shards, speeds):
    plan = seqpar.make_seq_plan(n_heads, rows, n_shards, speeds)
    assert plan.n_shards == n_shards
    assert plan.hops == n_shards - 1
    assert sum(plan.heads) == n_heads                      # covers, disjoint
    assert sum(plan.segments) == rows
    assert all(h >= 1 for h in plan.heads)
    assert all(s >= 1 for s in plan.segments)
    sp = (list(speeds)[:n_shards] if speeds else [1.0] * n_shards)
    if len(sp) < n_shards:
        sp = sp + [sp[-1]] * (n_shards - len(sp))
    for i, vi in enumerate(sp):                            # monotone
        for j, vj in enumerate(sp):
            if vi > vj:
                assert plan.heads[i] >= plan.heads[j], (plan.heads, sp)
                assert plan.segments[i] >= plan.segments[j], \
                    (plan.segments, sp)
    assert abs(sum(plan.head_fracs) - 1.0) < 1e-9
    assert abs(sum(plan.seg_fracs) - 1.0) < 1e-9


def test_seq_plan_properties_deterministic():
    for n_heads, rows, n_shards, speeds in [
        (4, 8, 1, None), (4, 8, 2, None), (4, 8, 4, [1.0, 0.8, 0.6, 0.5]),
        (16, 64, 3, [2.0, 1.0, 0.5]), (8, 8, 8, None), (5, 9, 2, [9.0, 1.0]),
    ]:
        _check_seq_plan(n_heads, rows, n_shards, speeds)


def test_seq_plan_validation():
    with pytest.raises(ValueError, match="disagree on the shard count"):
        seqpar.SeqPlan(heads=(2, 2), segments=(8,))
    with pytest.raises(ValueError, match=">= 1 head"):
        seqpar.SeqPlan(heads=(4, 0), segments=(4, 4))
    with pytest.raises(ValueError, match=">= 1 token row"):
        seqpar.SeqPlan(heads=(2, 2), segments=(8, 0))
    with pytest.raises(ValueError, match="sums to"):
        seqpar.validate_seq(seqpar.SeqPlan((2, 2), (4, 4)), n_heads=8,
                            rows=8)
    with pytest.raises(ValueError, match="token rows"):
        seqpar.validate_seq(seqpar.SeqPlan((2, 2), (4, 4)), n_heads=4,
                            rows=16)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                         # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=100, deadline=None)
    @given(n_heads=st.integers(1, 64), rows=st.integers(1, 128),
           n_shards=st.integers(1, 8),
           speeds=st.one_of(st.none(),
                            st.lists(st.floats(0.05, 4.0), min_size=1,
                                     max_size=8)))
    def test_seq_plan_properties(n_heads, rows, n_shards, speeds):
        n_shards = min(n_shards, n_heads, rows)
        _check_seq_plan(n_heads, rows, n_shards, speeds)


def test_seq_group_speeds_column_dealt():
    """4 devices, 2 shards: members are dealt column-wise so shard row j
    has comparable speed across groups (one global head partition fits)."""
    groups, shard_speeds = seqpar.seq_group_speeds([1.0, 0.5, 0.8, 0.6], 2)
    assert groups == [[1.0, 0.6], [0.8, 0.5]]
    assert shard_speeds == [1.0 + 0.8, 0.6 + 0.5]
    # leftover devices idle (5 devices, 2 shards -> 2 groups, 1 idle)
    groups5, _ = seqpar.seq_group_speeds([1.0, 0.9, 0.8, 0.7, 0.1], 2)
    assert len(groups5) == 2 and all(len(g) == 2 for g in groups5)
    assert 0.1 not in [v for g in groups5 for v in g]
    with pytest.raises(ValueError, match="at least 3 devices"):
        seqpar.seq_group_speeds([1.0, 0.5], 3)


# ----------------------------------------------------------------------
# ring-attention reference vs dense attend
# ----------------------------------------------------------------------

def test_ring_attention_reference_matches_attend():
    """Head-scattered, ring-segmented log-sum-exp attention equals the
    dense softmax up to reduction order — including uneven
    speed-proportional heads and segments."""
    B, S, T, H, hd = 2, 6, 8, 4, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, H, hd))
    dense = layers.attend(q, k, v)
    for seq in [seqpar.SeqPlan((4,), (8,)),
                seqpar.SeqPlan((2, 2), (4, 4)),
                seqpar.SeqPlan((2, 1, 1), (3, 3, 2))]:
        ring = seqpar.ring_attention_reference(q, k, v, seq)
        err = float(jnp.linalg.norm(ring - dense) / jnp.linalg.norm(dense))
        assert err <= 1e-5, (seq, err)
    # with a key mask (the buffered-attend contract)
    mask = (jnp.arange(T) < 6)[None, None, None, :]
    dense_m = layers.attend(q, k, v, mask=mask)
    ring_m = seqpar.ring_attention_reference(
        q, k, v, seqpar.SeqPlan((2, 2), (5, 3)), mask=mask)
    err = float(jnp.linalg.norm(ring_m - dense_m) / jnp.linalg.norm(dense_m))
    assert err <= 1e-5, err


# ----------------------------------------------------------------------
# IR: SeqShard cadence + ring policy + staleness bound
# ----------------------------------------------------------------------

def test_seqshard_emitted_per_adaptive_interval():
    plan = TemporalPlan([16, 16], [1, 1], [False, False], 16, 4)
    policy = comm_lib.get_exchange("ring", 2)
    seq = seqpar.SeqPlan((2, 2), (4, 4))
    evs = list(ir.lower(plan, [4, 4], policy, seq_shards=seq))
    shards = [e for e in evs if isinstance(e, ir.SeqShard)]
    intervals = [e for e in evs if isinstance(e, ir.ComputeInterval)]
    assert len(shards) == len(intervals)                   # one per interval
    assert all(s.hops == 1 for s in shards)
    assert [s.fine_step for s in shards] == [c.fine_step for c in intervals]
    # no SeqShard without a multi-shard plan
    assert not any(isinstance(e, ir.SeqShard)
                   for e in ir.lower(plan, [4, 4], policy))
    assert not any(isinstance(e, ir.SeqShard)
                   for e in ir.lower(plan, [4, 4], policy,
                                     seq_shards=seqpar.SeqPlan((4,), (8,))))


def test_replay_records_seq_hops():
    plan = TemporalPlan([16, 16], [1, 2], [False, False], 16, 4)
    policy = comm_lib.get_exchange("ring", 3)
    seq = seqpar.SeqPlan((2, 1, 1), (3, 3, 2))
    recs = ir.replay(plan, [4, 4], policy, seq_shards=seq)
    warm = [r for r in recs if r.synchronous]
    adapt = [r for r in recs if not r.synchronous]
    assert all(r.seq_hops == 0 for r in warm)
    assert all(r.seq_hops == 2 for r in adapt)
    # the ring policy's degraded boundaries are plain "skip" — nothing new
    # for executors to interpret
    kinds = {r.exchange for r in adapt}
    assert kinds <= {"full", "skip"}
    assert "skip" in kinds and "full" in kinds


def test_ring_policy_and_hop_rows():
    pol = comm_lib.get_exchange("ring", 3)
    assert pol.degraded_kind == "skip"
    assert comm_lib.ring_hop_rows([3, 3, 2]) == 3          # padded to max
    assert comm_lib.ring_hop_rows([8]) == 0                # nothing to hop
    assert comm_lib.ring_hop_rows([5, 0, 3]) == 5          # idle shard


def test_max_hop_staleness_bounded_by_refresh(setup):
    """Ring hops carry stale cross-worker neighbors exactly like
    DistriFusion halos: the worst staleness age of hopped K/V is bounded by
    refresh_every - 1 under the "ring" policy."""
    cfg, params, sched, x_T, cond = setup
    for E in (2, 3):
        config = StadiConfig.from_occupancies(
            [0.0, 0.4], m_base=8, m_warmup=2, seq_shards=2,
            exchange="ring", exchange_refresh=E)
        res = StadiPipeline(cfg, params, sched, config).generate(x_T, cond)
        worst = seqpar.max_hop_staleness(res.trace.events)
        assert 0 < worst <= E - 1, (E, worst)
    # synthetic: a synchronous step resets the age
    recs = ir.replay(TemporalPlan([16, 16], [1, 1], [False, False], 16, 4),
                     [4, 4], comm_lib.get_exchange("ring", 4),
                     seq_shards=seqpar.SeqPlan((2, 2), (4, 4)))
    assert seqpar.max_hop_staleness(recs) == 3


# ----------------------------------------------------------------------
# emulated reference: bitwise parity + shard-count invariance
# ----------------------------------------------------------------------

def test_seq_shards_one_is_bitwise_emulated(setup):
    """seq_shards=1 is the emulated backend, bit for bit."""
    cfg, params, sched, x_T, cond = setup
    base = StadiConfig.from_occupancies([0.0, 0.4], m_base=8, m_warmup=2,
                                        exchange="stale_async")
    ref = StadiPipeline(cfg, params, sched, base).generate(x_T, cond)
    one = StadiPipeline(cfg, params, sched, dataclasses.replace(
        base, seq_shards=1)).generate(x_T, cond)
    np.testing.assert_array_equal(np.asarray(one.image),
                                  np.asarray(ref.image))


def test_trajectory_is_shard_count_invariant(setup):
    """The sequence dimension repartitions WHERE attention runs, never WHAT
    is computed: the emulated trajectory is identical for every shard
    count (ring hops assemble exactly the context the dense read uses)."""
    cfg, params, sched, x_T, cond = setup
    imgs = {}
    for S in (1, 2, 4):
        config = StadiConfig.from_occupancies(
            [0.0, 0.2, 0.4, 0.5], m_base=8, m_warmup=2, seq_shards=S,
            exchange="ring", exchange_refresh=2)
        res = StadiPipeline(cfg, params, sched, config).generate(x_T, cond)
        imgs[S] = np.asarray(res.image)
        splan = res.trace.seq
        if S == 1:
            assert splan is None
        else:
            assert splan.n_shards == S
            assert all(r.seq_hops == S - 1 for r in res.trace.events
                       if not r.synchronous)
    np.testing.assert_array_equal(imgs[1], imgs[2])
    np.testing.assert_array_equal(imgs[1], imgs[4])


# ----------------------------------------------------------------------
# fail-fast paths (satellite)
# ----------------------------------------------------------------------

def test_plan_seq_rejects_bad_geometry(setup):
    cfg, params, sched, _, _ = setup
    config = StadiConfig.from_occupancies([0.0, 0.4], m_base=8, m_warmup=2,
                                          seq_shards=2)
    pipe = StadiPipeline(cfg, params, sched, config)
    plan = pipe.plan()
    assert plan.seq is not None and plan.seq.n_shards == 2
    # the shim resolves a planner-raw (seq-less) plan like plan() does
    raw = dataclasses.replace(plan, seq=None)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="seq_shards=3"):
            plan_seq(raw, cfg, dataclasses.replace(config, seq_shards=3))
    # pipeline-level validation mirrors the planner's
    with pytest.raises(ValueError, match="seq_shards"):
        StadiPipeline(cfg, params, sched,
                      dataclasses.replace(config, seq_shards=3))
    with pytest.raises(ValueError, match="1 head per shard"):
        StadiPipeline(cfg, params, sched, StadiConfig.from_occupancies(
            [0.0] * 8, m_base=8, m_warmup=2, seq_shards=8))  # 4 heads
    with pytest.raises(ValueError, match=">= 0"):
        StadiPipeline(cfg, params, sched,
                      dataclasses.replace(config, seq_shards=-1))
    with pytest.raises(ValueError, match="rebalancing"):
        StadiPipeline(cfg, params, sched,
                      dataclasses.replace(config, rebalance_every=2))


def test_check_backend_can_run_rejects_seq_mismatch(setup):
    cfg, params, sched, _, _ = setup
    config = StadiConfig.from_occupancies([0.0, 0.4], m_base=8, m_warmup=2)
    plan = StadiPipeline(cfg, params, sched, config).plan()
    # a seq-sharded run needs a seq backend
    with pytest.raises(ValueError, match="seq backend"):
        check_backend_can_run(plan, dataclasses.replace(
            config, seq_shards=2, backend="spmd"))
    with pytest.raises(ValueError, match="seq backend"):
        check_backend_can_run(plan, dataclasses.replace(
            config, seq_shards=2, backend="pipefuse"))
    for backend in SEQ_BACKENDS:
        if backend == "spmd_seq":
            continue
        check_backend_can_run(plan, dataclasses.replace(
            config, seq_shards=2, backend=backend))        # fine
    # spmd_seq without a seq-sharded plan is a config error, not a silent
    # fall-through to plain spmd
    with pytest.raises(ValueError, match="seq-sharded plan"):
        check_backend_can_run(plan, dataclasses.replace(
            config, backend="spmd_seq"))
    # uneven speed-proportional heads are the cost model's planning view;
    # the all-to-all needs the even scatter
    uneven = dataclasses.replace(plan,
                                 seq=seqpar.SeqPlan((2, 1, 1), (3, 3, 2)))
    with pytest.raises(ValueError, match="even head scatter"):
        check_backend_can_run(uneven, dataclasses.replace(
            config, seq_shards=3, backend="spmd_seq"))


def test_registry_errors_name_seq_entries():
    with pytest.raises(KeyError, match="spmd_seq"):
        get_executor("no-such-backend")
    with pytest.raises(KeyError, match="stadi_seq"):
        get_planner("no-such-planner")


def test_spmd_seq_rejects_indivisible_heads(setup):
    from repro.core import spmd
    cfg, params, sched, x_T, cond = setup                  # 4 heads
    plan = TemporalPlan([8, 8], [1, 1], [False, False], 8, 2)
    with pytest.raises(ValueError, match="divisible"):
        spmd.run_spmd_seq(params, cfg, sched, x_T, cond, plan, [4, 4],
                          seq=seqpar.SeqPlan((2, 1, 1), (3, 3, 2)))


# ----------------------------------------------------------------------
# stadi_seq joint planner + ring-contention cost model
# ----------------------------------------------------------------------

def _knobs(**kw):
    defaults = dict(occupancies=[0.0, 0.2, 0.4, 0.5], m_base=16, m_warmup=4,
                    planner="stadi_seq", seq_shards=0, n_heads=4,
                    kv_row_bytes=4096, latent_bytes=16384,
                    exchange_refresh=2)
    occ = defaults.pop("occupancies")
    defaults.update(kw)
    return StadiConfig.from_occupancies(occ, **defaults)


def test_stadi_seq_prefers_patch_when_compute_bound():
    """With no attention term (t_ctx=0) head scattering buys nothing and
    costs ring traffic: the planner returns the pure patch plan."""
    knobs = _knobs(cost_model=CostModel(t_fixed=1e-3, t_row=5e-4, t_ctx=0.0,
                                        link_bw=1e6, link_latency=1e-3))
    plan = get_planner("stadi_seq")(knobs.speeds, knobs, 8)
    assert plan.planner == "stadi_seq"
    assert plan.seq is None


def test_stadi_seq_shards_when_attention_bound():
    """When the per-substep wall is the full-context K/V read (t_ctx
    dominates), scattering heads divides it — a multi-shard candidate wins
    despite the ring traffic."""
    knobs = _knobs(cost_model=CostModel(t_fixed=1e-5, t_row=1e-5, t_ctx=5e-3,
                                        link_bw=1e9, link_latency=1e-7))
    plan = get_planner("stadi_seq")(knobs.speeds, knobs, 8)
    assert plan.seq is not None and plan.seq.n_shards > 1
    assert sum(plan.seq.heads) == 4
    assert sum(plan.seq.segments) == 8
    # grouped workers: patches has one slab per device GROUP
    assert len(plan.patches) <= len(knobs.speeds) // plan.seq.n_shards


def test_stadi_seq_pinning_and_infeasible():
    knobs = _knobs(seq_shards=2,
                   cost_model=CostModel(t_fixed=1e-3, t_row=5e-4))
    plan = get_planner("stadi_seq")(knobs.speeds, knobs, 8)
    assert plan.seq is not None and plan.seq.n_shards == 2   # pinned
    one = get_planner("stadi_seq")(knobs.speeds, _knobs(seq_shards=1), 8)
    assert one.seq is None                                   # pinned pure
    with pytest.raises(ValueError, match="infeasible"):
        get_planner("stadi_seq")(knobs.speeds, _knobs(seq_shards=8), 8)
    with pytest.raises(ValueError, match="n_heads"):
        get_planner("stadi_seq")([1.0, 1.0],
                                 _knobs(seq_shards=2, n_heads=None), 8)


def test_simulate_prices_ring_hops(setup):
    """The simulate backend replays SeqShard rows: latency is finite,
    grows with link latency (hops serialize), and at t_ctx-dominated
    profiles the sharded plan models faster than the pure patch one."""
    cfg, params, sched, x_T, cond = setup
    bound = CostModel(t_fixed=1e-5, t_row=1e-5, t_ctx=2e-3)
    lat = {}
    for S in (1, 2):
        config = StadiConfig.from_occupancies(
            [0.0, 0.2, 0.4, 0.5], m_base=8, m_warmup=2, backend="simulate",
            seq_shards=S, exchange="ring", cost_model=bound)
        res = StadiPipeline(cfg, params, sched, config).generate(x_T, cond)
        assert res.image is None and res.latency_s > 0
        lat[S] = res.latency_s
    assert lat[2] < lat[1], lat
    # ring hops pay link latency: a slower link costs more
    slow = dataclasses.replace(bound, link_latency=5e-3)
    config = StadiConfig.from_occupancies(
        [0.0, 0.2, 0.4, 0.5], m_base=8, m_warmup=2, backend="simulate",
        seq_shards=2, exchange="ring", cost_model=slow)
    res = StadiPipeline(cfg, params, sched, config).generate(x_T, cond)
    assert res.latency_s > lat[2]


# ----------------------------------------------------------------------
# serving: seq-sharded lanes batch by ring identity, bitwise unchanged
# ----------------------------------------------------------------------

@bitwise_vs_reference
def test_serving_seq_sharded_lanes_bitwise(setup):
    from repro.serving import DiffusionServingEngine
    cfg, params, sched, x_T, cond = setup
    config = StadiConfig.from_occupancies(
        [0.0, 0.2, 0.4, 0.5], m_base=8, m_warmup=2, seq_shards=2,
        exchange="ring", exchange_refresh=2)
    pipe = StadiPipeline(cfg, params, sched, config)
    engine = DiffusionServingEngine(pipe, slots=2)
    assert engine.seq is not None and engine.seq.n_shards == 2
    req = engine.submit(x_T, 1)
    engine.run_to_completion()
    ref = pipe.generate(x_T, cond)
    np.testing.assert_array_equal(np.asarray(req.image),
                                  np.asarray(ref.image))
    # the lane group key carries the ring-hop identity
    assert any(info[3] == 1 for info in engine._interval_info.values())


# ----------------------------------------------------------------------
# spmd_seq mesh executor (subprocess, real host devices)
# ----------------------------------------------------------------------

def test_spmd_seq_matches_emulated():
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core import sampler as sampler_lib
        from repro.core.pipeline import StadiConfig, StadiPipeline
        from repro.models.diffusion import dit

        cfg = get_config('tiny-dit').reduced()
        params = dit.nondegenerate_params(
            dit.init_params(jax.random.PRNGKey(0), cfg))
        sched = sampler_lib.linear_schedule(T=1000)
        x_T = jax.random.normal(jax.random.PRNGKey(1),
                                (1, cfg.latent_size, cfg.latent_size,
                                 cfg.channels))
        cond = jnp.zeros((1,), jnp.int32)
        config = StadiConfig.from_occupancies(
            [0.0, 0.4], m_base=8, m_warmup=2, backend='spmd_seq',
            seq_shards=2, exchange='ring', exchange_refresh=2)
        spmd = StadiPipeline(cfg, params, sched, config).generate(x_T, cond)
        emu = StadiPipeline(cfg, params, sched, dataclasses.replace(
            config, backend='emulated')).generate(x_T, cond)
        a, b = np.asarray(spmd.image), np.asarray(emu.image)
        err = float(np.linalg.norm(a - b) / np.linalg.norm(b))
        assert err < 1e-5, err
        assert spmd.trace.seq is not None
        assert spmd.trace.seq.n_shards == 2
        print('SPMD_SEQ_OK', err)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=520, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "SPMD_SEQ_OK" in r.stdout
