"""Kernel-path parity on every executor (DESIGN.md §15).

Each case runs in a subprocess with 4 forced host devices: the executor
with ``use_pallas_attention=True`` must (a) match its kernel-off reference
within 5e-5 and (b) actually contain the kernel in its traced program —
asserted via the trace-time hit counters, because a silent fallback would
still produce correct images.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CASE_TEMPLATE = """
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.core import sampler as sampler_lib
    from repro.core.pipeline import StadiConfig, StadiPipeline
    from repro.kernels import ops as kops
    from repro.models.diffusion import dit

    cfg = get_config('tiny-dit').reduced()
    params = dit.nondegenerate_params(
        dit.init_params(jax.random.PRNGKey(0), cfg))
    sched = sampler_lib.linear_schedule(T=1000)
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (1, cfg.latent_size, cfg.latent_size,
                             cfg.channels))
    cond = jnp.zeros((1,), jnp.int32)

    config = StadiConfig.from_occupancies([0.0, 0.4], m_base=8, m_warmup=2,
                                          backend={backend!r}, {knobs})
    ref = StadiPipeline(cfg, params, sched, dataclasses.replace(
        config, backend={ref_backend!r})).generate(x_T, cond)
    on = StadiPipeline(cfg, params, sched, dataclasses.replace(
        config, use_pallas_attention=True)).generate(x_T, cond)
    a, b = np.asarray(on.image), np.asarray(ref.image)
    err = float(np.linalg.norm(a - b) / np.linalg.norm(b))
    assert err < 5e-5, err
    hits = on.kernel_stats['hits']
    assert hits.get({hit_kind!r}, 0) > 0, on.kernel_stats
    assert not on.kernel_stats['misses'], on.kernel_stats
    print('KERNEL_EXEC_OK', {backend!r}, err, hits)
"""

CASES = {
    # backend -> (reference backend, expected hit kind, extra knobs)
    "emulated": ("emulated", "stale_kv.static", ""),
    "spmd": ("emulated", "stale_kv.padded", ""),
    "spmd_guidance": ("emulated", "stale_kv.padded",
                      "cfg_scale=3.0, guidance='split', "
                      "planner='stadi_guidance'"),
    "spmd_pipefuse": ("pipefuse", "stale_kv.static", "num_stages=2"),
    "spmd_seq": ("emulated", "ring.lse",
                 "seq_shards=2, exchange='ring', exchange_refresh=2"),
    # fused CFG on the spmd mesh: padded attention + fused combine
    "spmd-fused-cfg": ("emulated", "cfg_epilogue", "cfg_scale=3.0"),
}

# the multi-axis meshes compile the biggest programs — keep the default
# CI legs fast and run them in tier-1 / the dedicated pallas CI leg
_SLOW = {"spmd_guidance", "spmd_pipefuse", "spmd_seq"}


@pytest.mark.parametrize(
    "case", [pytest.param(c, marks=pytest.mark.slow) if c in _SLOW
             else c for c in sorted(CASES)])
def test_executor_kernel_parity(case):
    ref_backend, hit_kind, knobs = CASES[case]
    backend = case.split("-")[0]
    code = textwrap.dedent(CASE_TEMPLATE).format(
        backend=backend, ref_backend=ref_backend, hit_kind=hit_kind,
        knobs=knobs)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("STADI_PALLAS_INTERPRET", None)   # auto: interpreter off-TPU
    # the pallas CI leg forces kernels on process-wide; the whole point here
    # is the kernel-on vs kernel-off contrast, so keep the ref run clean
    env.pop("STADI_USE_PALLAS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=520, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "KERNEL_EXEC_OK" in r.stdout
