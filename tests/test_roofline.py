"""Roofline toolchain unit tests: HLO collective parser (loop-aware) and
analytic cost model sanity."""
import numpy as np

from repro.launch import analytic, roofline as rl

HLO = """
HloModule jit_step

%wide.body.1 (arg.1: (s32[], f32[128])) -> (s32[], f32[128]) {
  %p = (s32[], f32[128]) parameter(0)
  %ar = f32[128]{0} all-reduce(f32[128]{0} %x), replica_groups={}
  %ag.1 = f32[256]{0} all-gather(f32[128]{0} %y), dimensions={0}
}

%wide.cond.1 (arg.2: (s32[], f32[128])) -> pred[] {
  %iv = s32[] get-tuple-element(%p2), index=0
  %c = s32[] constant(12)
  %cmp = pred[] compare(s32[] %iv, s32[] %c), direction=LT
}

ENTRY %main.42 (a: f32[128]) -> f32[128] {
  %w = (s32[], f32[128]) while(%init), condition=%wide.cond.1, body=%wide.body.1
  %ag2 = f32[512]{0} all-gather(f32[128]{0} %z), dimensions={0}
}
"""


def test_collective_parser_loop_aware():
    out = rl.collective_bytes(HLO)
    # body: all-reduce 128 f32 = 512B, all-gather operand 128 f32 = 512B,
    # each scaled by trip count 12; entry all-gather operand 512B once
    assert out["all-reduce"] == 512 * 12
    assert out["all-gather"] == 512 * 12 + 512
    assert out["total"] == 512 * 12 * 2 + 512
    assert out["_counts"]["all-gather"] == 2


def test_shape_bytes():
    assert rl._shape_bytes("bf16", "8,128") == 8 * 128 * 2
    assert rl._shape_bytes("f32", "") == 4
    assert rl._shape_bytes("s8", "10") == 10


def test_analytic_ratios_sane():
    """Analytic flops within ~2x of the 6ND rule for standard dense shapes
    (6ND ignores attention quadratic + head, so analytic >= ~0.8 * 6ND)."""
    for arch in ("gemma-2b", "yi-9b", "minitron-8b", "llama3-405b"):
        c = analytic.step_cost(arch, "train_4k")
        nd = rl.model_flops_for(arch, "train_4k")
        assert 0.7 < nd / c.flops < 1.3, (arch, nd / c.flops)


def test_analytic_flash_reduces_bytes():
    naive = analytic.step_cost("yi-9b", "prefill_32k", flash=False)
    flash = analytic.step_cost("yi-9b", "prefill_32k", flash=True)
    assert flash.bytes < 0.5 * naive.bytes        # S^2 scores dominate at 32k
    assert flash.flops == naive.flops


def test_analytic_decode_memory_bound():
    """Decode must be memory-bound: bytes/819GB/s >> flops/197TF."""
    c = analytic.per_device("llama3-405b", "decode_32k", 256)
    assert c.bytes / 819e9 > c.flops / 197e12


def test_model_flops_moe_uses_active():
    dense_like = rl.model_flops_for("olmoe-1b-7b", "train_4k")
    from repro.configs import get_config
    cfg = get_config("olmoe-1b-7b")
    assert cfg.active_param_count() < 0.35 * cfg.param_count()
    assert dense_like == 6.0 * cfg.active_param_count() * 256 * 4096
