"""Prompt conditioning as a first-class workload (DESIGN.md §17): the
frozen text encoder, the cond_seq_len=0 bitwise degeneracy on emulated AND
spmd executors, prompt serving parity across every exchange policy, the
lifted CFG x frames gate (guided text-to-video), t_xattn pricing, the
recorded cross-attention kernel gap, and the engine's prompt validation."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import patch_parallel as pp
from repro.core import sampler as sampler_lib
from repro.core.guidance import NULL_COND, GuidancePlan
from repro.core.pipeline import StadiConfig, StadiPipeline
from repro.models import text_encoder
from repro.models.diffusion import dit
from repro.serving import DiffusionServingEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tiny-dit").reduced()
    tcfg = cfg.text_conditioned(cond_seq_len=8)
    params = dit.nondegenerate_params(dit.init_params(jax.random.PRNGKey(0),
                                                      cfg))
    tparams = dit.nondegenerate_params(
        dit.init_params(jax.random.PRNGKey(0), tcfg))
    sched = sampler_lib.linear_schedule(T=100)
    return cfg, tcfg, params, tparams, sched


def _x(cfg, seed=1, frames=0):
    shape = (1, cfg.latent_size, cfg.latent_size, cfg.channels)
    if frames:
        shape = shape[:1] + (frames,) + shape[1:]
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


# ----------------------------------------------------------------------
# the frozen text encoder
# ----------------------------------------------------------------------

def test_encoder_deterministic_and_shaped(setup):
    _, tcfg, *_ = setup
    a = text_encoder.encode(["a red fox", "fox"], tcfg)
    b = text_encoder.encode(["a red fox", "fox"], tcfg)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (2, 4, tcfg.cond_dim + 1)     # 3 tokens -> bucket 4
    # trailing channel is the validity mask
    np.testing.assert_array_equal(np.asarray(a[0, :, -1]), [1, 1, 1, 0])
    np.testing.assert_array_equal(np.asarray(a[1, :, -1]), [1, 0, 0, 0])
    # masked positions carry no features
    assert float(np.abs(np.asarray(a[1, 1:, :-1])).sum()) == 0.0
    # a different frozen seed is a different encoder
    c = text_encoder.encode(["a red fox", "fox"], tcfg, seed=5)
    assert not np.array_equal(np.asarray(a[..., :-1]),
                              np.asarray(c[..., :-1]))
    # real-token embeddings are bucket-independent (key-masked attention)
    wide = text_encoder.encode(["a red fox"], tcfg, length=8)
    np.testing.assert_allclose(np.asarray(wide[0, :3, :-1]),
                               np.asarray(a[0, :3, :-1]), atol=1e-5)


def test_bucket_length_grid():
    assert [text_encoder.bucket_length(n, 32) for n in (1, 4, 5, 8, 9, 40)] \
        == [4, 4, 8, 8, 16, 32]
    with pytest.raises(ValueError, match="cond_seq_len"):
        text_encoder.bucket_length(3, 0)


def test_encode_requires_text_config(setup):
    cfg, *_ = setup
    with pytest.raises(ValueError, match="text_conditioned"):
        text_encoder.encode(["fox"], cfg)


def test_null_semantics(setup):
    _, tcfg, *_ = setup
    tok = text_encoder.encode(["a red fox"], tcfg)
    null = text_encoder.null_cond(1, tok.shape[1], tcfg)
    assert float(np.abs(np.asarray(null)).sum()) == 0.0
    # dit.null_like is polymorphic: zero tokens for prompts, the reserved
    # NULL_COND id for class conds
    np.testing.assert_array_equal(np.asarray(dit.null_like(tok)),
                                  np.asarray(null))
    assert int(dit.null_like(jnp.asarray([3]))[0]) == NULL_COND
    # guidance_conds stacks [cond, null] for either kind
    g = dit.guidance_conds(tok)
    assert g.shape == (2,) + tok.shape
    np.testing.assert_array_equal(np.asarray(g[1]), np.asarray(null))


# ----------------------------------------------------------------------
# cond_seq_len=0 degeneracy: bitwise the class-conditional path
# ----------------------------------------------------------------------

def test_text_config_draws_class_params_bitwise(setup):
    """Cross-attention params come from previously-unconsumed key streams,
    so every pre-§17 param is drawn bit-identically."""
    cfg, tcfg, *_ = setup
    base = dit.init_params(jax.random.PRNGKey(0), cfg)
    text = dit.init_params(jax.random.PRNGKey(0), tcfg)
    for extra in ("xq", "xkv", "xo"):
        assert extra in text["blocks"] and extra not in base["blocks"]
    assert "ctx_pool" in text and "ctx_pool" not in base
    for k, v in base.items():
        if k == "blocks":
            for bk, bv in base["blocks"].items():
                np.testing.assert_array_equal(np.asarray(bv),
                                              np.asarray(text["blocks"][bk]))
        else:
            np.testing.assert_array_equal(np.asarray(v),
                                          np.asarray(text[k]))


def test_class_cond_forward_bitwise_under_text_config(setup):
    """A text-conditioned model fed CLASS ids runs the class path bitwise
    — cross-attention only traces when the cond is a token tensor."""
    cfg, tcfg, params, tparams, sched = setup
    x = _x(cfg)
    t = jnp.asarray([10])
    cond = jnp.asarray([3])
    a = dit.forward(params, cfg, x, t, cond)
    b = dit.forward(tparams, tcfg, x, t, cond)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("backend", ["emulated"])
def test_class_cond_pipeline_bitwise_under_text_config(setup, backend):
    cfg, tcfg, params, tparams, sched = setup
    x = _x(cfg)
    cond = jnp.asarray([3])
    config = StadiConfig.from_occupancies([0.0, 0.5], m_base=8, m_warmup=2,
                                          backend=backend)
    a = StadiPipeline(cfg, params, sched, config).generate(x, cond).image
    b = StadiPipeline(tcfg, tparams, sched, config).generate(x, cond).image
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_spmd_degeneracy_and_prompt_parity():
    """Subprocess with 4 host devices: (a) class conds under the text
    config stay BITWISE the class-conditional spmd path; (b) prompt conds
    flow opaquely through shard_map and match the emulated reference."""
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core import sampler as sampler_lib
        from repro.core.pipeline import StadiConfig, StadiPipeline
        from repro.models import text_encoder
        from repro.models.diffusion import dit

        cfg = get_config('tiny-dit').reduced()
        tcfg = cfg.text_conditioned(cond_seq_len=8)
        params = dit.nondegenerate_params(
            dit.init_params(jax.random.PRNGKey(0), cfg))
        tparams = dit.nondegenerate_params(
            dit.init_params(jax.random.PRNGKey(0), tcfg))
        sched = sampler_lib.linear_schedule(T=100)
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (1, cfg.latent_size, cfg.latent_size,
                               cfg.channels))
        cond = jnp.asarray([3])
        for backend, kw in [
                ('spmd', {}),
                ('spmd_guidance', dict(planner='stadi_guidance',
                                       guidance='split', cfg_scale=2.5))]:
            config = StadiConfig.from_occupancies(
                [0.0, 0.5], m_base=8, m_warmup=2, backend=backend, **kw)
            a = StadiPipeline(cfg, params, sched, config).generate(
                x, cond).image
            b = StadiPipeline(tcfg, tparams, sched, config).generate(
                x, cond).image
            assert np.array_equal(np.asarray(a), np.asarray(b)), backend
        tok = text_encoder.encode(['a red fox'], tcfg)
        config = StadiConfig.from_occupancies([0.0, 0.5], m_base=8,
                                              m_warmup=2, backend='spmd')
        spmd = StadiPipeline(tcfg, tparams, sched, config).generate(
            x, tok).image
        emu = StadiPipeline(tcfg, tparams, sched, dataclasses.replace(
            config, backend='emulated')).generate(x, tok).image
        a, b = np.asarray(spmd), np.asarray(emu)
        err = float(np.linalg.norm(a - b) / np.linalg.norm(b))
        assert err < 1e-5, err
        print('SPMD_TEXTCOND_OK', err)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=520, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "SPMD_TEXTCOND_OK" in r.stdout


# ----------------------------------------------------------------------
# prompt generation + CFG null branch
# ----------------------------------------------------------------------

def test_prompt_steers_trajectory(setup):
    _, tcfg, _, tparams, sched = setup
    config = StadiConfig.from_occupancies([0.0, 0.5], m_base=8, m_warmup=2)
    pipe = StadiPipeline(tcfg, tparams, sched, config)
    x = _x(tcfg)
    a = pipe.generate(x, text_encoder.encode(["a red fox"], tcfg)).image
    b = pipe.generate(x, text_encoder.encode(["blue whale song"],
                                             tcfg)).image
    assert np.isfinite(np.asarray(a)).all()
    assert not np.array_equal(np.asarray(a), np.asarray(b))


def test_guided_prompt_null_matches_explicit_null(setup):
    """The fused CFG null branch over zero tokens IS the explicit
    null_cond forward — NULL_COND semantics carried into token space."""
    _, tcfg, _, tparams, _ = setup
    x = _x(tcfg)
    t = jnp.asarray([10])
    tok = text_encoder.encode(["a red fox"], tcfg)
    eps_null = dit.forward(tparams, tcfg, x, t, dit.null_like(tok))
    eps_explicit = dit.forward(tparams, tcfg, x, t,
                               text_encoder.null_cond(1, tok.shape[1], tcfg))
    np.testing.assert_array_equal(np.asarray(eps_null),
                                  np.asarray(eps_explicit))
    scale = 3.0
    fused = dit.forward_cfg(tparams, tcfg, x, t, tok, scale)
    eps_c = dit.forward(tparams, tcfg, x, t, tok)
    np.testing.assert_allclose(
        np.asarray(fused),
        np.asarray(eps_null + scale * (eps_c - eps_null)), atol=1e-5)


# ----------------------------------------------------------------------
# prompt serving: length-bucketed lanes, bitwise vs generate
# ----------------------------------------------------------------------

@pytest.mark.parametrize("exchange", ["sync", "stale_async", "predictive"])
def test_prompt_serving_bitwise_vs_generate(setup, exchange):
    """Mixed-length prompt lanes (buckets 4 and 8) plus a guided lane
    drain bitwise-identically to single-request generate under every
    boundary-exchange policy."""
    _, tcfg, _, tparams, sched = setup
    config = StadiConfig.from_occupancies([0.0, 0.5], m_base=8, m_warmup=2,
                                          exchange=exchange)
    pipe = StadiPipeline(tcfg, tparams, sched, config)
    engine = DiffusionServingEngine(pipe, slots=3)
    prompts = ["fox", "a red fox in the deep winter snow",
               "blue whale", "one two three four five six seven"]
    subs = []
    for uid, p in enumerate(prompts):
        x = _x(tcfg, seed=40 + uid)
        tok = text_encoder.encode([p], tcfg)
        scale = 2.5 if uid == 2 else None
        subs.append((engine.submit(x, tok, cfg_scale=scale), x, tok, scale))
    engine.run_to_completion()
    buckets = {tok.shape[1] for _, _, tok, _ in subs}
    assert buckets == {4, 8}                  # both buckets really served
    for req, x, tok, scale in subs:
        ref_cfg = dataclasses.replace(config, cfg_scale=scale or 0.0)
        ref = StadiPipeline(tcfg, tparams, sched, ref_cfg).generate(
            x, tok).image
        np.testing.assert_array_equal(np.asarray(req.image),
                                      np.asarray(ref))


def test_engine_prompt_validation(setup):
    cfg, tcfg, params, tparams, sched = setup
    config = StadiConfig.from_occupancies([0.0, 0.5], m_base=8, m_warmup=2)
    class_engine = DiffusionServingEngine(
        StadiPipeline(cfg, params, sched, config), slots=2)
    tok = text_encoder.encode(["fox"], tcfg)
    with pytest.raises(ValueError, match="text-conditioned"):
        class_engine.submit(_x(cfg), tok)
    text_engine = DiffusionServingEngine(
        StadiPipeline(tcfg, tparams, sched, config), slots=2)
    with pytest.raises(ValueError, match="prompt tokens"):
        text_engine.submit(_x(tcfg), 3)       # class id on a prompt engine
    with pytest.raises(ValueError, match="cond_dim"):
        text_engine.submit(_x(tcfg), jnp.zeros((1, 4, tcfg.cond_dim)))
    with pytest.raises(ValueError, match="cond_seq_len"):
        text_engine.submit(_x(tcfg),
                           jnp.zeros((1, 16, tcfg.cond_dim + 1)))


# ----------------------------------------------------------------------
# CFG x frames: the lifted gate (guided text-to-video)
# ----------------------------------------------------------------------

def test_guided_video_plans_and_runs(setup):
    """stadi_video + cfg_scale composes guidance with the frame axis: the
    plan carries BOTH, the emulated executor runs the guided clip, and
    frame 0 is bitwise the guided image path under the same schedule."""
    from repro.core import frames as frames_lib
    _, tcfg, _, tparams, sched = setup
    config = StadiConfig.from_occupancies(
        [0.0, 0.0, 0.5, 0.5], m_base=8, m_warmup=2, planner="stadi_video",
        num_frames=2, guidance="fused", cfg_scale=3.0)
    pipe = StadiPipeline(tcfg, tparams, sched, config)
    plan = pipe.plan()
    assert plan.guidance is not None and plan.guidance.mode == "fused"
    assert plan.frames is not None and plan.frames.num_frames == 2
    tok = text_encoder.encode(["a red fox"], tcfg)
    x = _x(tcfg, frames=2)
    clip = pipe.generate(x, tok).image
    assert np.asarray(clip).shape[1] == 2
    assert np.isfinite(np.asarray(clip)).all()
    # frame 0 attends no previous frame: bitwise the guided IMAGE path
    gp = GuidancePlan("fused", 3.0)
    seq_clip = frames_lib.run_frames(
        tparams, tcfg, sched, x, tok, plan.temporal, plan.patches,
        frames=frames_lib.FramePlan(2, (2,)), guidance=gp).image
    img = pp.run_schedule(tparams, tcfg, sched, x[:, 0], tok,
                          plan.temporal, plan.patches, guidance=gp).image
    np.testing.assert_array_equal(np.asarray(seq_clip)[:, 0],
                                  np.asarray(img))


def test_split_guidance_still_gated_on_frames(setup):
    """Only FUSED CFG composes with the frame axis — split/interleaved
    placement still raises loudly everywhere."""
    _, tcfg, _, tparams, sched = setup
    config = StadiConfig.from_occupancies(
        [0.0, 0.0, 0.5, 0.5], m_base=8, m_warmup=2, planner="stadi_video",
        num_frames=2, guidance="split", cfg_scale=3.0)
    with pytest.raises(ValueError, match="fused"):
        StadiPipeline(tcfg, tparams, sched, config).plan()


def test_guided_video_serving_scale_contract(setup):
    """Video lanes run the PLAN's fused CFG: per-request scales must match
    the plan (or the plan must be guided at all)."""
    _, tcfg, _, tparams, sched = setup
    guided_cfg = StadiConfig.from_occupancies(
        [0.0, 0.0, 0.5, 0.5], m_base=8, m_warmup=2, planner="stadi_video",
        num_frames=2, guidance="fused", cfg_scale=3.0)
    engine = DiffusionServingEngine(
        StadiPipeline(tcfg, tparams, sched, guided_cfg), slots=2)
    x = _x(tcfg, frames=2)
    tok = text_encoder.encode(["fox"], tcfg)
    with pytest.raises(ValueError, match="cannot override"):
        engine.submit(x, tok, cfg_scale=5.0)
    req = engine.submit(x, tok, cfg_scale=3.0)   # matching scale is fine
    assert req.guided
    plain_cfg = dataclasses.replace(guided_cfg, cfg_scale=0.0,
                                    guidance="none")
    plain = DiffusionServingEngine(
        StadiPipeline(tcfg, tparams, sched, plain_cfg), slots=2)
    with pytest.raises(ValueError, match="fused CFG"):
        plain.submit(x, tok, cfg_scale=3.0)


def test_guided_video_serving_matches_generate(setup):
    """A guided clip served through the engine is bitwise the guided
    pipeline clip (the whole-schedule frame executor runs both)."""
    _, tcfg, _, tparams, sched = setup
    config = StadiConfig.from_occupancies(
        [0.0, 0.0, 0.5, 0.5], m_base=8, m_warmup=2, planner="stadi_video",
        num_frames=2, guidance="fused", cfg_scale=3.0)
    pipe = StadiPipeline(tcfg, tparams, sched, config)
    engine = DiffusionServingEngine(pipe, slots=2)
    x = _x(tcfg, frames=2)
    tok = text_encoder.encode(["a red fox"], tcfg)
    req = engine.submit(x, tok, cfg_scale=3.0)
    engine.run_to_completion()
    ref = pipe.generate(x, tok).image
    np.testing.assert_array_equal(np.asarray(req.image), np.asarray(ref))


# ----------------------------------------------------------------------
# pricing + kernel visibility
# ----------------------------------------------------------------------

def test_t_xattn_prices_prompt_tokens(setup):
    """The simulate backend charges t_xattn * cond_tokens per row: a
    text-conditioned workload models strictly slower than the identical
    class workload, monotonically in the bucket."""
    from repro.core.simulate import CostModel
    cfg, *_ = setup
    cm = CostModel(t_fixed=1e-3, t_row=1e-4, t_xattn=1e-5)
    lats = {}
    for bucket in (0, 8, 32):
        mcfg = (cfg if bucket == 0 else
                cfg.text_conditioned(cond_seq_len=bucket))
        config = StadiConfig.from_occupancies(
            [0.0, 0.5], m_base=8, m_warmup=2, backend="simulate",
            cost_model=cm)
        lats[bucket] = StadiPipeline(mcfg, None, None,
                                     config).generate().latency_s
    assert lats[0] < lats[8] < lats[32]
    # with t_xattn unset the class model's pricing is untouched
    cm0 = CostModel(t_fixed=1e-3, t_row=1e-4)
    config = StadiConfig.from_occupancies([0.0, 0.5], m_base=8, m_warmup=2,
                                          backend="simulate", cost_model=cm0)
    base = StadiPipeline(cfg, None, None, config).generate().latency_s
    text = StadiPipeline(cfg.text_conditioned(cond_seq_len=8), None, None,
                         config).generate().latency_s
    assert text == base


def test_cross_attn_kernel_miss_recorded(setup):
    """use_pallas_attention on a text-conditioned model records the
    cross-attention kernel gap instead of silently falling back."""
    _, tcfg, _, tparams, sched = setup
    config = StadiConfig.from_occupancies([0.0, 0.5], m_base=8, m_warmup=2,
                                          use_pallas_attention=True)
    res = StadiPipeline(tcfg, tparams, sched, config).generate(
        _x(tcfg), text_encoder.encode(["fox"], tcfg))
    assert np.isfinite(np.asarray(res.image)).all()
    assert res.kernel_stats["misses"].get("cross-attn-unsupported", 0) > 0


def test_pipeline_cond_bucket_validation(setup):
    cfg, tcfg, params, tparams, sched = setup
    with pytest.raises(ValueError, match="text_conditioned"):
        StadiPipeline(cfg, params, sched, StadiConfig.from_occupancies(
            [0.0, 0.5], m_base=8, m_warmup=2, cond_bucket=8))
    with pytest.raises(ValueError, match="cond_seq_len"):
        StadiPipeline(tcfg, tparams, sched, StadiConfig.from_occupancies(
            [0.0, 0.5], m_base=8, m_warmup=2, cond_bucket=16))
