"""chunked (flash-style) attention == naive attention, across mask modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers
from repro.models.attention import chunked_attend


@pytest.mark.parametrize("S,T,H,K,hd,window,prefix", [
    (64, 64, 4, 2, 16, 0, 0),
    (64, 64, 4, 4, 16, 24, 0),
    (96, 96, 2, 1, 32, 32, 8),        # window + pinned prefix, pad path
    (100, 100, 2, 2, 16, 0, 0),       # non-multiple chunk
])
def test_chunked_equals_naive(S, T, H, K, hd, window, prefix):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, S, H, hd))
    k = jax.random.normal(ks[1], (2, T, K, hd))
    v = jax.random.normal(ks[2], (2, T, K, hd))
    got = chunked_attend(q, k, v, causal=True, window=window,
                         prefix_len=prefix, chunk=32)
    if window:
        mask = layers.window_mask(S, T, 0, window)
        if prefix:
            kj = jnp.arange(T)[None, :]
            qi = jnp.arange(S)[:, None]
            mask = mask | ((kj < prefix) & (kj <= qi))[None, None]
    else:
        mask = layers.causal_mask(S, T, 0)
    want = layers.attend(q, k, v, mask=mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_model_forward_invariant_to_attn_impl():
    """End-to-end: gemma-reduced logits identical for naive vs chunked."""
    from repro.configs import get_config
    from repro.models import build_model
    cfg_n = get_config("gemma-2b").reduced()
    cfg_c = cfg_n.replace(attn_impl="chunked", attn_chunk=16)
    m_n, m_c = build_model(cfg_n), build_model(cfg_c)
    params = m_n.init(jax.random.PRNGKey(0))
    batch = m_n.make_batch(jax.random.PRNGKey(1), 2, 48)
    ln = m_n.forward_logits(params, batch)
    lc = m_c.forward_logits(params, batch)
    np.testing.assert_allclose(np.asarray(ln), np.asarray(lc),
                               rtol=3e-5, atol=3e-5)
