"""Continuous-batching diffusion serving engine (DESIGN.md §9): FIFO
admission/refill order, per-request step isolation (bitwise parity with
single-request ``generate`` under staggered admissions), deterministic
heterogeneous placement, SLO accounting, ``generate_many``, an 8-request
end-to-end drain on tiny-dit, and SPMD cohort-stepper parity (subprocess)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import sampler as sampler_lib
from repro.core.pipeline import (StadiConfig, StadiPipeline,
                                 get_stepper_factory)
from repro.models.diffusion import dit
from repro.serving import DiffusionServingEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tiny-dit").reduced()      # 16x16 latent, 8 token rows
    params = dit.init_params(jax.random.PRNGKey(0), cfg)
    sched = sampler_lib.linear_schedule(T=100)
    return cfg, params, sched


def _pipe(setup, occupancies=(0.0, 0.5), **kw):
    cfg, params, sched = setup
    kw.setdefault("m_base", 6)
    kw.setdefault("m_warmup", 2)
    config = StadiConfig.from_occupancies(list(occupancies), **kw)
    return StadiPipeline(cfg, params, sched, config)


def _requests(cfg, n, seed=0):
    xs = [jax.random.normal(jax.random.PRNGKey(seed + 1 + i),
                            (1, cfg.latent_size, cfg.latent_size,
                             cfg.channels)) for i in range(n)]
    conds = [jnp.asarray([i % cfg.n_classes], jnp.int32) for i in range(n)]
    return xs, conds


# ----------------------------------------------------------------------
# registry / validation
# ----------------------------------------------------------------------

def test_stepper_registry_and_validation(setup):
    for name in ("emulated", "spmd"):
        assert get_stepper_factory(name) is not None
    with pytest.raises(KeyError):
        get_stepper_factory("simulate")       # no numerics to serve
    with pytest.raises(ValueError):
        DiffusionServingEngine(_pipe(setup, rebalance_every=1))
    with pytest.raises(ValueError):
        DiffusionServingEngine(_pipe(setup), slots=0)
    cfg = setup[0]
    engine = DiffusionServingEngine(_pipe(setup), slots=2)
    with pytest.raises(ValueError):           # one request = one image
        engine.submit(jnp.zeros((2, cfg.latent_size, cfg.latent_size,
                                 cfg.channels)), 0)


# ----------------------------------------------------------------------
# admission & refill order
# ----------------------------------------------------------------------

def test_admission_fifo_and_refill(setup):
    cfg = setup[0]
    engine = DiffusionServingEngine(_pipe(setup), slots=2)
    xs, conds = _requests(cfg, 5)
    reqs = [engine.submit(x, c) for x, c in zip(xs, conds)]
    engine.run_to_completion()
    assert len(engine.completed) == 5
    # wave 1: FIFO into the lowest free slots
    assert engine.rounds[0].admitted == [(0, 0), (1, 1)]
    # refills: slots freed together are refilled FIFO, lowest slot first
    waves = [r.admitted for r in engine.rounds if r.admitted]
    assert waves == [[(0, 0), (1, 1)], [(2, 0), (3, 1)], [(4, 0)]]
    # queueing is visible in per-request stats, in submission order
    assert [r.queue_rounds for r in reqs] == pytest.approx(
        [0, 0, reqs[2].queue_rounds, reqs[2].queue_rounds,
         reqs[4].queue_rounds])
    assert 0 < reqs[2].queue_rounds < reqs[4].queue_rounds


# ----------------------------------------------------------------------
# per-request step isolation: staggered admissions, bitwise parity
# ----------------------------------------------------------------------

def test_staggered_requests_bitwise_match_generate(setup):
    """Requests admitted mid-flight share vmapped denoise dispatches with
    requests several noise-schedule steps ahead; nobody's latent may change
    by a single bit vs a lone generate() call."""
    cfg = setup[0]
    pipe = _pipe(setup)
    engine = DiffusionServingEngine(pipe, slots=3)
    xs, conds = _requests(cfg, 5)
    reqs = [engine.submit(xs[i], conds[i]) for i in range(2)]
    engine.step()
    engine.step()       # wave 1 is past warmup now
    reqs += [engine.submit(xs[i], conds[i]) for i in range(2, 5)]
    engine.run_to_completion()
    # the schedule genuinely mixed phases in one round (warmup lane admitted
    # next to adaptive lanes), so isolation was actually exercised
    assert any(r.warmup_lanes and r.adaptive_lanes for r in engine.rounds)
    assert all(r.fine_step == 6 for r in engine.completed)
    for i, req in enumerate(reqs):
        ref = pipe.generate(xs[i], conds[i])
        np.testing.assert_array_equal(np.asarray(req.image),
                                      np.asarray(ref.image))


def test_no_warmup_bootstrap_bitwise(setup):
    """m_warmup == 0: admission bootstraps the stale-KV buffers with one
    full forward (run_schedule's M_w==0 path) — still bitwise."""
    cfg = setup[0]
    pipe = _pipe(setup, m_base=4, m_warmup=0)
    engine = DiffusionServingEngine(pipe, slots=2)
    xs, conds = _requests(cfg, 3, seed=30)
    reqs = [engine.submit(x, c) for x, c in zip(xs, conds)]
    engine.run_to_completion()
    for i, req in enumerate(reqs):
        ref = pipe.generate(xs[i], conds[i])
        np.testing.assert_array_equal(np.asarray(req.image),
                                      np.asarray(ref.image))


def test_generate_many_matches_generate(setup):
    from repro.core.simulate import CostModel
    cfg = setup[0]
    pipe = _pipe(setup)
    xs, conds = _requests(cfg, 3, seed=50)
    results = pipe.generate_many(xs, conds, slots=2)
    assert len(results) == 3
    for x, c, res in zip(xs, conds, results):
        ref = pipe.generate(x, c)
        np.testing.assert_array_equal(np.asarray(res.image),
                                      np.asarray(ref.image))
        assert res.plan.patches == ref.plan.patches
        assert res.latency_s is None          # no cost model configured
    pipe_cm = _pipe(setup, cost_model=CostModel(t_fixed=1e-3, t_row=1e-3))
    results = pipe_cm.generate_many(xs, conds, slots=2)
    assert all(r.latency_s is not None and r.latency_s > 0 for r in results)


# ----------------------------------------------------------------------
# heterogeneous placement: deterministic, cost-model-driven
# ----------------------------------------------------------------------

def test_placement_deterministic_and_speed_ordered(setup):
    cfg = setup[0]

    def drain():
        engine = DiffusionServingEngine(_pipe(setup), slots=3)
        xs, conds = _requests(cfg, 4)
        for x, c in zip(xs, conds):
            engine.submit(x, c)
        engine.run_to_completion()
        return engine

    a, b = drain(), drain()
    pa = [r.placement for r in a.rounds]
    pb = [r.placement for r in b.rounds]
    assert pa == pb and any(p is not None for p in pa)
    # largest patch -> fastest device (speeds [1.0, 0.5])
    patches = a.plan.patches
    placement = next(p for p in pa if p is not None)
    w_big = max(range(len(patches)), key=lambda i: patches[i])
    assert dict(placement)[w_big] == 0
    # modeled accounting identical run-to-run
    assert a.modeled_clock_s == b.modeled_clock_s


# ----------------------------------------------------------------------
# SLO accounting
# ----------------------------------------------------------------------

def test_slo_accounting(setup):
    cfg = setup[0]
    engine = DiffusionServingEngine(_pipe(setup), slots=2)
    xs, conds = _requests(cfg, 2)
    tight = engine.submit(xs[0], conds[0], slo_s=1e-9)
    loose = engine.submit(xs[1], conds[1], slo_s=1e9)
    engine.run_to_completion()
    assert tight.slo_met is False and loose.slo_met is True
    assert engine.stats()["slo_met_frac"] == 0.5
    # no SLO -> no verdict
    engine2 = DiffusionServingEngine(_pipe(setup), slots=2)
    req = engine2.submit(xs[0], conds[0])
    engine2.run_to_completion()
    assert req.slo_met is None
    assert engine2.stats()["slo_met_frac"] is None


# ----------------------------------------------------------------------
# end-to-end drain on tiny-dit
# ----------------------------------------------------------------------

def test_e2e_8_request_drain(setup):
    cfg = setup[0]
    engine = DiffusionServingEngine(_pipe(setup), slots=3)
    xs, conds = _requests(cfg, 8, seed=80)
    reqs = [engine.submit(x, c) for x, c in zip(xs, conds)]
    done = engine.run_to_completion()
    assert len(done) == len(engine.completed) == 8
    assert {r.uid for r in done} == set(range(8))
    for r in reqs:
        assert r.done and r.fine_step == 6
        assert np.isfinite(np.asarray(r.image)).all()
        assert r.image.shape == (1, cfg.latent_size, cfg.latent_size,
                                 cfg.channels)
        assert r.modeled_latency_s > 0 and r.wall_latency_s > 0
    # queued waves pay queueing latency on top of service latency
    assert reqs[7].modeled_latency_s > reqs[0].modeled_latency_s
    stats = engine.stats()
    assert stats["n_completed"] == 8
    assert stats["throughput_modeled_rps"] > 0
    assert stats["throughput_wall_rps"] > 0
    assert stats["latency_p95_s"] >= stats["latency_mean_s"] > 0
    assert [r["uid"] for r in stats["requests"]] == list(range(8))


# ----------------------------------------------------------------------
# SPMD cohort stepper (real host devices, subprocess)
# ----------------------------------------------------------------------

def test_spmd_engine_matches_emulated():
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core import sampler as sampler_lib
        from repro.core.pipeline import StadiConfig, StadiPipeline
        from repro.models.diffusion import dit
        from repro.serving import DiffusionServingEngine

        cfg = get_config('tiny-dit').reduced()
        params = dit.init_params(jax.random.PRNGKey(0), cfg)
        sched = sampler_lib.linear_schedule(T=100)
        config = StadiConfig.from_occupancies([0.0, 0.5], m_base=4,
                                              m_warmup=2, backend='spmd')
        pipe = StadiPipeline(cfg, params, sched, config)
        emu = StadiPipeline(cfg, params, sched, dataclasses.replace(
            config, backend='emulated'))
        engine = DiffusionServingEngine(pipe, slots=2)
        xs = [jax.random.normal(jax.random.PRNGKey(1 + i),
                                (1, cfg.latent_size, cfg.latent_size,
                                 cfg.channels)) for i in range(3)]
        conds = [jnp.asarray([i], jnp.int32) for i in range(3)]
        reqs = [engine.submit(x, c) for x, c in zip(xs, conds)]
        engine.run_to_completion()
        for i, r in enumerate(reqs):
            ref = emu.generate(xs[i], conds[i])
            a, b = np.asarray(r.image), np.asarray(ref.image)
            err = float(np.linalg.norm(a - b) / np.linalg.norm(b))
            assert err < 1e-3, (i, err)
        print('SPMD_SERVE_OK')
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=520, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "SPMD_SERVE_OK" in r.stdout


# ----------------------------------------------------------------------
# boundary-exchange policies in the serving hot path (DESIGN.md §10)
# ----------------------------------------------------------------------

def _nondegenerate_pipe(setup, **kw):
    """adaLN-zero untrained params make eps buffer-independent; perturb the
    modulation/output weights (`dit.nondegenerate_params`) so staleness
    genuinely matters, then build a pipeline over the perturbed params."""
    cfg, params, sched = setup
    params = dit.nondegenerate_params(params)
    kw.setdefault("m_base", 8)
    kw.setdefault("m_warmup", 2)
    config = StadiConfig.from_occupancies([0.0, 0.5], **kw)
    return StadiPipeline(cfg, params, sched, config)


@pytest.mark.parametrize("exchange", ["stale_async", "predictive"])
def test_serving_degraded_modes_bitwise_vs_generate(setup, exchange):
    """Staggered lanes sit at different boundary phases, so the engine must
    group them by exchange info — and every request must still be bitwise
    identical to a lone ``generate`` under the same policy."""
    cfg = setup[0]
    pipe = _nondegenerate_pipe(setup, exchange=exchange, exchange_refresh=2)
    xs, conds = _requests(cfg, 3)
    singles = [np.asarray(pipe.generate(x, c).image)
               for x, c in zip(xs, conds)]
    engine = DiffusionServingEngine(pipe, slots=2)        # forces stagger
    reqs = [engine.submit(x, c) for x, c in zip(xs, conds)]
    engine.run_to_completion()
    for req, ref in zip(reqs, singles):
        if len(jax.devices()) == 1:
            np.testing.assert_array_equal(np.asarray(req.image), ref)
        else:
            # forced multi-device CPU hosts reorder XLA reductions between
            # the vmapped and single-request dispatches at ~1e-6 (true for
            # "sync" too — hidden elsewhere by adaLN-zero untrained params)
            np.testing.assert_allclose(np.asarray(req.image), ref,
                                       rtol=0, atol=1e-5)
    kinds = [k for r in engine.rounds for k in r.exchange_kinds]
    assert set(kinds) >= {"full"}
    assert ("skip" in kinds) if exchange == "stale_async" \
        else ("predict" in kinds)


def test_serving_stale_async_models_cheaper_rounds(setup):
    """Skipped boundaries move no modeled bytes: with a comm-heavy cost
    model the stale_async drain must be modeled strictly faster than the
    sync drain of the same workload."""
    from repro.core.simulate import CostModel
    cfg = setup[0]
    cm = CostModel(t_fixed=1e-3, t_row=1e-4, link_bw=1e6, link_latency=1e-4)
    makespans = {}
    for ex in ("sync", "stale_async"):
        pipe = _pipe(setup, exchange=ex, exchange_refresh=2, cost_model=cm)
        engine = DiffusionServingEngine(pipe, slots=2)
        xs, conds = _requests(cfg, 4)
        for x, c in zip(xs, conds):
            engine.submit(x, c)
        engine.run_to_completion()
        makespans[ex] = engine.modeled_clock_s
    assert makespans["stale_async"] < makespans["sync"]
