"""Schedule IR (core/events.py) + boundary-exchange policies: the three
executors interpret ONE event stream, sync mode is bitwise-preserving, and
the degraded modes (stale_async / predictive) behave per their contract."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import buffers as buf_lib
from repro.core import comm as comm_lib
from repro.core import events as ir
from repro.core import patch_parallel as pp
from repro.core import sampler as sampler_lib
from repro.core import simulate as sim
from repro.core.pipeline import StadiConfig, StadiPipeline
from repro.core.schedule import TemporalPlan, patch_bounds
from repro.models.diffusion import dit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tiny-dit").reduced()      # 16x16 latent, 8 token rows
    # de-degenerate adaLN-zero init so stale remote K/V genuinely matters
    params = dit.nondegenerate_params(dit.init_params(jax.random.PRNGKey(0),
                                                      cfg))
    sched = sampler_lib.linear_schedule(T=100)
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (2, cfg.latent_size, cfg.latent_size,
                             cfg.channels))
    cond = jnp.array([1, 2])
    return cfg, params, sched, x_T, cond


def _ev_tuple(e):
    return (e.fine_step, list(e.substeps), list(e.patches), e.synchronous,
            e.exchange)


# ----------------------------------------------------------------------
# policy registry
# ----------------------------------------------------------------------

def test_exchange_registry():
    assert {"sync", "stale_async", "predictive"} <= set(comm_lib.EXCHANGES)
    with pytest.raises(KeyError):
        comm_lib.get_exchange("nope")
    with pytest.raises(ValueError):
        comm_lib.get_exchange("stale_async", 0)
    sync = comm_lib.get_exchange("sync", 5)     # refresh ignored by sync
    assert all(sync.kind(b) == "full" for b in range(10))
    stale = comm_lib.get_exchange("stale_async", 3)
    assert [stale.kind(b) for b in range(6)] == \
        ["skip", "skip", "full", "skip", "skip", "full"]
    pred = comm_lib.get_exchange("predictive", 2)
    assert [pred.kind(b) for b in range(4)] == \
        ["predict", "full", "predict", "full"]


def test_lower_kinds_cadence_and_forced_final_full():
    plan = TemporalPlan([16, 16], [1, 1], [False, False], 16, 4)
    policy = comm_lib.get_exchange("stale_async", 3)
    exchanges = [e for e in ir.lower(plan, [4, 4], policy)
                 if isinstance(e, ir.Exchange)]
    assert len(exchanges) == 12
    # cadence skip,skip,full,... but the LAST boundary is forced full
    assert [e.kind for e in exchanges] == \
        ["skip", "skip", "full"] * 3 + ["skip", "skip", "full"]
    assert exchanges[-1].last and exchanges[-1].kind == "full"
    policy = comm_lib.get_exchange("stale_async", 5)
    kinds = [e.kind for e in ir.lower(plan, [4, 4], policy)
             if isinstance(e, ir.Exchange)]
    assert kinds[-1] == "full"                  # would be "skip" by cadence


def test_lower_replan_via_send():
    plan = TemporalPlan([8, 8], [1, 1], [False, False], 8, 2)
    gen = ir.lower(plan, [4, 4])
    seen, sent = [], False
    ev = next(gen)
    while True:
        seen.append(ev)
        try:
            if isinstance(ev, ir.Exchange) and not sent and ev.fine_step >= 4:
                new = TemporalPlan([4, 4], [1, 1], [False, False], 4, 0)
                ev = gen.send((new, [6, 2]))
                sent = True
            else:
                ev = next(gen)
        except StopIteration:
            break
    replans = [e for e in seen if isinstance(e, ir.Replan)]
    assert len(replans) == 1 and replans[0].patches == (6, 2)
    # every interval after the replan carries the new allocation
    after = [e for e in seen if isinstance(e, ir.ComputeInterval)
             and e.fine_step >= replans[0].fine_step]
    assert after and all(e.patches == (6, 2) for e in after)


# ----------------------------------------------------------------------
# satellite: zero-patch ACTIVE device must not diverge numerics vs trace
# ----------------------------------------------------------------------

def test_zero_patch_active_device_traces_agree(setup):
    """Regression: build_trace used to mark a worker active from
    plan.excluded alone while run_schedule also required patches[i] > 0; a
    zero-patch active device yielded divergent traces. The shared IR makes
    the two structurally identical by construction."""
    cfg, params, sched, x_T, cond = setup
    plan = TemporalPlan([8, 8], [1, 1], [False, False], 8, 2)
    patches = [cfg.tokens_per_side, 0]           # active but owns no rows
    res = pp.run_schedule(params, cfg, sched, x_T, cond, plan, patches)
    ref = sim.build_trace(plan, patches, cfg, batch=int(x_T.shape[0]))
    assert [_ev_tuple(e) for e in res.trace.events] == \
        [_ev_tuple(e) for e in ref.events]
    # the zero-patch worker never executes a substep anywhere
    assert all(e.substeps[1] == 0 for e in res.trace.events)


@pytest.mark.parametrize("exchange,refresh", [
    ("sync", 2), ("stale_async", 2), ("stale_async", 3), ("predictive", 2)])
def test_build_trace_matches_run_schedule_events(setup, exchange, refresh):
    cfg, params, sched, x_T, cond = setup
    config = StadiConfig.from_occupancies([0.0, 0.5], m_base=16, m_warmup=4,
                                          exchange=exchange,
                                          exchange_refresh=refresh)
    pipe = StadiPipeline(cfg, params, sched, config)
    res = pipe.generate(x_T, cond)
    ref = sim.build_trace(pipe.plan().temporal, pipe.plan().patches, cfg,
                          batch=int(x_T.shape[0]), exchange=exchange,
                          exchange_refresh=refresh)
    assert [_ev_tuple(e) for e in res.trace.events] == \
        [_ev_tuple(e) for e in ref.events]


# ----------------------------------------------------------------------
# satellite: sync mode is bitwise-identical to the pre-refactor loop
# ----------------------------------------------------------------------

def _reference_run_schedule(params, cfg, sched, x_T, cond, plan, patches):
    """Verbatim re-implementation of the PRE-refactor run_schedule loop
    (hard-coded warmup -> interval -> sync merge), kept as the bitwise
    oracle for exchange="sync"."""
    p = cfg.patch_size
    M_base, M_w = plan.m_base, plan.m_warmup
    ts = sampler_lib.ddim_timesteps(sched.T, M_base)
    workers = [i for i in plan.active if patches[i] > 0]
    x = x_T
    published = None
    for m in range(M_w):
        eps, kvs = pp._jit_full_step(params, cfg, x, ts[m], cond)
        x = sampler_lib.ddim_step(sched, x, eps, ts[m], ts[m + 1])
        published = buf_lib.Published(kvs[0], kvs[1], m)
    if published is None:
        _, kvs = pp._jit_full_step(params, cfg, x, ts[0], cond)
        published = buf_lib.Published(kvs[0], kvs[1], -1)
    m0 = M_w
    while m0 + plan.lcm <= M_base:
        R = plan.lcm
        bounds_tok = patch_bounds(patches)
        bounds_lat = [(a * p, b * p) for a, b in bounds_tok]
        pending, new_slabs = {}, {}
        for i in workers:
            r = plan.ratios[i]
            x_loc = x[:, bounds_lat[i][0]:bounds_lat[i][1]]
            for s in range(R // r):
                t_from, t_to = ts[m0 + s * r], ts[m0 + (s + 1) * r]
                eps, kvs = pp._jit_patch_step(
                    params, cfg, x_loc, t_from, cond, bounds_tok[i][0],
                    published.k, published.v)
                x_loc = sampler_lib.ddim_step(sched, x_loc, eps, t_from, t_to)
                if s == 0:
                    buf_lib.publish_local(pending, i, kvs[0], kvs[1],
                                          bounds_tok[i][0]
                                          * cfg.tokens_per_side)
            new_slabs[i] = x_loc
        for i in workers:
            lat = bounds_lat[i]
            x = x.at[:, lat[0]:lat[1]].set(new_slabs[i])
        published = buf_lib.merge(published, pending, m0 + R)
        m0 += R
    return x


@pytest.mark.parametrize("ratios,steps,patches", [
    ([1, 1], [8, 8], [4, 4]),                 # DistriFusion uniform
    ([1, 2], [8, 5], [5, 3]),                 # STADI two-tier
])
def test_sync_bitwise_identical_to_pre_refactor_loop(setup, ratios, steps,
                                                     patches):
    cfg, params, sched, x_T, cond = setup
    plan = TemporalPlan(steps, ratios, [False, False], 8, 2)
    ref = _reference_run_schedule(params, cfg, sched, x_T, cond, plan,
                                  patches)
    res = pp.run_schedule(params, cfg, sched, x_T, cond, plan, patches,
                          exchange="sync")
    np.testing.assert_array_equal(np.asarray(res.image), np.asarray(ref))
    # and "sync" is the default
    res2 = pp.run_schedule(params, cfg, sched, x_T, cond, plan, patches)
    np.testing.assert_array_equal(np.asarray(res2.image), np.asarray(ref))


# ----------------------------------------------------------------------
# degraded-mode numerics (emulated backend, de-degenerated denoiser)
# ----------------------------------------------------------------------

def test_stale_and_predictive_drift_is_real_and_bounded(setup):
    cfg, params, sched, x_T, cond = setup
    imgs = {}
    for ex in ("sync", "stale_async", "predictive"):
        config = StadiConfig.from_occupancies(
            [0.0, 0.5], m_base=16, m_warmup=4, exchange=ex,
            exchange_refresh=2)
        imgs[ex] = np.asarray(
            StadiPipeline(cfg, params, sched, config).generate(x_T,
                                                               cond).image)
        assert np.all(np.isfinite(imgs[ex]))
    # the degraded modes genuinely change the trajectory...
    assert np.abs(imgs["stale_async"] - imgs["sync"]).max() > 0
    assert np.abs(imgs["predictive"] - imgs["sync"]).max() > 0
    # ...but stay close to sync (quality contract, DESIGN.md §10)
    ref = np.linalg.norm(imgs["sync"])
    for ex in ("stale_async", "predictive"):
        assert np.linalg.norm(imgs[ex] - imgs["sync"]) / ref < 0.05, ex


def test_predictive_falls_back_to_stale_before_two_refreshes(setup):
    """With refresh_every > n_boundaries no second full exchange ever lands,
    so predictive has nothing to difference and must equal stale reuse."""
    cfg, params, sched, x_T, cond = setup
    out = {}
    for ex in ("stale_async", "predictive"):
        config = StadiConfig.from_occupancies(
            [0.0, 0.5], m_base=16, m_warmup=4, exchange=ex,
            exchange_refresh=100)
        out[ex] = np.asarray(StadiPipeline(cfg, params, sched,
                                           config).generate(x_T, cond).image)
    np.testing.assert_array_equal(out["predictive"], out["stale_async"])


def test_extrapolate_linear_and_fallback():
    k = jnp.ones((1, 1, 4, 1, 2))
    prev = buf_lib.Published(k, 2 * k, step=2)
    last = buf_lib.Published(3 * k, 4 * k, step=4)
    out = buf_lib.extrapolate(prev, last, fine_step=6)
    np.testing.assert_allclose(np.asarray(out.k), 5.0)   # 3 + 1*(3-1)
    np.testing.assert_allclose(np.asarray(out.v), 6.0)
    assert buf_lib.extrapolate(None, last, 6) is last
    assert buf_lib.extrapolation_factor(4, 4, 6) == 0.0  # degenerate gap


# ----------------------------------------------------------------------
# simulate: comm accounting + mode-aware boundaries
# ----------------------------------------------------------------------

def test_simulate_charges_uneven_gather_not_full_image():
    """Satellite fix: each worker contributes its own slab, so a boundary
    moves (N-1)*max_slab rows per rank — and N=1 moves nothing."""
    cm = sim.CostModel(t_fixed=0.0, t_row=0.0, link_bw=1e6, link_latency=0.0)
    tr = ir.ExecutionTrace(
        [ir.IntervalEvent(0, [1, 1], [12, 4])], None, [12, 4],
        n_tokens=256, latent_bytes=16_000, kv_bytes_per_worker=[0, 0])
    # row_bytes = 1000; gather = (2-1) * 12 rows = 12_000 bytes (< 16_000)
    assert sim.simulate_trace(tr, [1.0, 1.0], cm) == pytest.approx(0.012)
    solo = ir.ExecutionTrace(
        [ir.IntervalEvent(0, [1, 0], [16, 0])], None, [16, 0],
        n_tokens=256, latent_bytes=16_000, kv_bytes_per_worker=[0, 0])
    assert sim.simulate_trace(solo, [1.0, 1.0], cm) == 0.0


def test_simulate_degraded_boundaries_are_compute_only():
    cm = sim.CostModel(t_fixed=0.01, t_row=0.0, link_bw=1e3,
                       link_latency=0.5)
    full = ir.IntervalEvent(0, [1, 1], [8, 8], exchange="full")
    skip = ir.IntervalEvent(0, [1, 1], [8, 8], exchange="skip")
    pred = ir.IntervalEvent(0, [1, 1], [8, 8], exchange="predict")
    mk = lambda evs: ir.ExecutionTrace(evs, None, [8, 8], 256, 16_000,
                                       [0, 0])
    t_full = sim.simulate_trace(mk([full]), [1.0, 1.0], cm)
    t_skip = sim.simulate_trace(mk([skip]), [1.0, 1.0], cm)
    t_pred = sim.simulate_trace(mk([pred]), [1.0, 1.0], cm)
    assert t_skip == t_pred == pytest.approx(0.01)       # pure compute
    assert t_full > t_skip + 0.5                         # pays the boundary


def test_pipeline_simulate_stale_async_is_faster(setup):
    cfg, *_ = setup
    cm = sim.CostModel(t_fixed=1e-3, t_row=1e-4, link_bw=1e6,
                       link_latency=1e-4)
    base = StadiConfig.from_occupancies([0.0, 0.5], m_base=16, m_warmup=4,
                                        backend="simulate", cost_model=cm)
    lat = {}
    for ex in ("sync", "stale_async", "predictive"):
        config = dataclasses.replace(base, exchange=ex, exchange_refresh=2)
        lat[ex] = StadiPipeline(cfg, None, None, config).generate().latency_s
    assert lat["stale_async"] < lat["sync"]
    assert lat["predictive"] < lat["sync"]


def test_unknown_exchange_fails_fast(setup):
    cfg, params, sched, *_ = setup
    config = StadiConfig.from_occupancies([0.0, 0.5], m_base=16, m_warmup=4,
                                          exchange="nope")
    with pytest.raises(KeyError):
        StadiPipeline(cfg, params, sched, config)


# ----------------------------------------------------------------------
# SPMD backend drives the same stream (subprocess, real devices)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("exchange", ["stale_async", "predictive"])
def test_spmd_degraded_modes_match_emulated(exchange):
    code = textwrap.dedent(f"""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core import sampler as sampler_lib
        from repro.core.pipeline import StadiConfig, StadiPipeline
        from repro.models.diffusion import dit

        cfg = get_config('tiny-dit').reduced()
        params = dit.nondegenerate_params(
            dit.init_params(jax.random.PRNGKey(0), cfg))
        sched = sampler_lib.linear_schedule(T=1000)
        x_T = jax.random.normal(jax.random.PRNGKey(1),
                                (1, cfg.latent_size, cfg.latent_size,
                                 cfg.channels))
        cond = jnp.zeros((1,), jnp.int32)
        config = StadiConfig.from_occupancies(
            [0.0, 0.5], m_base=8, m_warmup=2, backend='spmd',
            exchange={exchange!r}, exchange_refresh=2)
        spmd = StadiPipeline(cfg, params, sched, config).generate(x_T, cond)
        emu = StadiPipeline(cfg, params, sched, dataclasses.replace(
            config, backend='emulated')).generate(x_T, cond)
        a, b = np.asarray(spmd.image), np.asarray(emu.image)
        err = float(np.linalg.norm(a - b) / np.linalg.norm(b))
        assert err < 1e-3, err
        sync = StadiPipeline(cfg, params, sched, dataclasses.replace(
            config, exchange='sync')).generate(x_T, cond)
        drift = float(np.abs(np.asarray(sync.image) - a).max())
        assert drift > 0.0, 'degraded mode should differ from sync'
        print('SPMD_EXCHANGE_OK', err)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=520, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "SPMD_EXCHANGE_OK" in r.stdout
