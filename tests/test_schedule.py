"""Property-based tests (hypothesis) for STADI's allocators (Eq. 4 / Eq. 5).

Deterministic allocator tests that need no hypothesis live in
tests/test_pipeline.py, so this module may be skipped wholesale when the
``test`` extra is not installed."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import schedule as sl  # noqa: E402

speeds_st = st.lists(st.floats(0.05, 1.0), min_size=1, max_size=8)


@settings(max_examples=200, deadline=None)
@given(speeds=speeds_st)
def test_temporal_allocation_properties(speeds):
    plan = sl.temporal_allocation(speeds, m_base=100, m_warmup=4)
    vmax = max(speeds)
    for v, M, r, ex in zip(speeds, plan.steps, plan.ratios, plan.excluded):
        if v <= 0.25 * vmax and not all(plan.excluded):
            if ex:
                assert M == 0 and r == 0
                continue
        if not ex:
            # Eq. 4: two tiers only
            assert M in (100, 52), (v, M)         # (100+4)/2 = 52
            assert r in (1, 2)
            # faster tier never gets fewer steps
    # monotonicity: sort by speed => steps non-decreasing
    act = [(v, M) for v, M, e in zip(speeds, plan.steps, plan.excluded) if not e]
    act.sort()
    for (v1, m1), (v2, m2) in zip(act, act[1:]):
        assert m1 <= m2
    # fastest device always gets M_base
    assert plan.steps[speeds.index(vmax)] == 100
    # LCM of ratios stays minimal (paper's quantization goal)
    assert plan.lcm in (1, 2)


@settings(max_examples=200, deadline=None)
@given(speeds=speeds_st, p_total=st.sampled_from([16, 32, 64]),
       gran=st.sampled_from([1, 2, 4]))
def test_spatial_allocation_properties(speeds, p_total, gran):
    plan = sl.temporal_allocation(speeds, 100, 4)
    n_active = sum(1 for e in plan.excluded if not e)
    if p_total // gran < n_active:
        # not enough granules to give every active device its min_patch
        with pytest.raises(ValueError):
            sl.spatial_allocation(speeds, plan.steps, p_total, gran)
        return
    patches = sl.spatial_allocation(speeds, plan.steps, p_total, gran)
    # exact coverage
    assert sum(patches) == p_total
    # granularity respected
    assert all(p % gran == 0 for p in patches)
    # excluded devices get nothing
    for p, ex in zip(patches, plan.excluded):
        if ex:
            assert p == 0
    # rounding error bounded by one granule vs the ideal Eq.5 allocation
    rate = [v / m if m else 0.0 for v, m in zip(speeds, plan.steps)]
    tot = sum(rate)
    for p, r in zip(patches, rate):
        ideal = r / tot * p_total
        assert abs(p - ideal) <= 2 * gran + 1e-6


@settings(max_examples=200, deadline=None)
@given(speeds=st.lists(st.floats(0.05, 1.0), min_size=1, max_size=8),
       p_total=st.sampled_from([16, 32, 64]),
       gran=st.sampled_from([1, 2]),
       min_mult=st.sampled_from([1, 2, 3]))
def test_spatial_allocation_min_patch_enforced(speeds, p_total, gran, min_mult):
    """Adversarial speed vectors: every active device gets >= min_patch rows
    while sum invariance and granularity are preserved."""
    plan = sl.temporal_allocation(speeds, 100, 4)
    min_patch = gran * min_mult
    n_active = sum(1 for e in plan.excluded if not e)
    slots = p_total // gran
    if slots < n_active * max(1, min_patch // gran):
        with pytest.raises(ValueError):
            sl.spatial_allocation(speeds, plan.steps, p_total, gran, min_patch)
        return
    patches = sl.spatial_allocation(speeds, plan.steps, p_total, gran, min_patch)
    assert sum(patches) == p_total                       # sum invariance
    for p, ex in zip(patches, plan.excluded):
        if ex:
            assert p == 0
        else:
            assert p >= min_patch                        # min enforced
            assert p % gran == 0


@settings(max_examples=200, deadline=None)
@given(speeds=st.lists(st.floats(0.3, 1.0), min_size=2, max_size=6),
       p_total=st.sampled_from([32, 64]))
def test_spatial_allocation_monotone_in_speed(speeds, p_total):
    """With equal step counts, a faster device never gets fewer rows."""
    steps = [100] * len(speeds)
    patches = sl.spatial_allocation(speeds, steps, p_total)
    pairs = sorted(zip(speeds, patches))
    for (v1, p1), (v2, p2) in zip(pairs, pairs[1:]):
        assert p1 <= p2 + 1, (pairs,)   # one-granule rounding slack


@settings(max_examples=100, deadline=None)
@given(speeds=st.lists(st.floats(0.3, 1.0), min_size=2, max_size=6))
def test_makespan_optimal_not_worse_than_paper(speeds):
    """Beyond-paper DP allocator: modeled interval cost <= paper Eq.4+Eq.5."""
    m_base, m_w, p_total = 100, 4, 32
    plan = sl.temporal_allocation(speeds, m_base, m_w)
    patches = sl.spatial_allocation(speeds, plan.steps, p_total)
    fixed = 0.05

    def interval_cost(pl, pt):
        c = 0.0
        for v, r, p in zip(speeds, pl.ratios, pt):
            if r:
                c = max(c, (fixed + p / p_total) / v / r)
        return c

    paper_cost = interval_cost(plan, patches)
    opt_plan, opt_patches, opt_cost = sl.makespan_optimal_allocation(
        speeds, m_base, m_w, p_total, fixed_overhead=fixed)
    assert opt_cost <= paper_cost + 1e-9


def test_eq4_exact_paper_values():
    """Paper §V: a=0.75, b=0.25, M_base=100, M_warmup=4."""
    plan = sl.temporal_allocation([1.0, 0.5], 100, 4, a=0.75, b=0.25)
    assert plan.steps == [100, 52]                # ½·100 + ½·4 = 52
    assert plan.ratios == [1, 2]
    plan = sl.temporal_allocation([1.0, 0.8], 100, 4)
    assert plan.steps == [100, 100]               # both in top tier: no TA
    plan = sl.temporal_allocation([1.0, 0.2], 100, 4)
    assert plan.excluded == [False, True]


def test_eq5_exact():
    # v = [1, .5], M = [100, 52]: rates .01/.009615 -> ideal 16.31:15.69;
    # largest-remainder gives the extra granule to the .69 remainder
    patches = sl.spatial_allocation([1.0, 0.5], [100, 52], 32)
    assert patches == [16, 16]
    # clearer split: v=[1, .3] -> rates .01/.00577 -> ideal 20.3:11.7 -> 20:12
    patches = sl.spatial_allocation([1.0, 0.3], [100, 52], 32)
    assert patches == [20, 12]


def test_temporal_validation_errors():
    with pytest.raises(ValueError):
        sl.temporal_allocation([1.0], 100, 4, a=0.2, b=0.5)
    with pytest.raises(ValueError):
        sl.temporal_allocation([1.0], 4, 4)
    with pytest.raises(ValueError):
        sl.temporal_allocation([1.0], 101, 4)     # 97 not divisible by 2
    with pytest.raises(ValueError):
        sl.spatial_allocation([1.0], [100], 33, granularity=2)


def test_patch_bounds():
    assert sl.patch_bounds([3, 0, 5]) == [(0, 3), (3, 3), (3, 8)]
