"""Core STADI engine correctness: exactness in degenerate cases, closeness
under staleness, schedule bookkeeping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import patch_parallel as pp
from repro.core import sampler as sampler_lib
from repro.core import stadi as stadi_lib
from repro.models.diffusion import dit


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tiny-dit").reduced()      # 16x16 latent, 8 token rows
    params = dit.init_params(jax.random.PRNGKey(0), cfg)
    sched = sampler_lib.linear_schedule(T=100)
    x_T = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.latent_size, cfg.latent_size, cfg.channels))
    cond = jnp.array([1, 2])
    return cfg, params, sched, x_T, cond


def test_single_worker_full_patch_equals_origin(setup):
    cfg, params, sched, x_T, cond = setup
    origin = pp.run_origin(params, cfg, sched, x_T, cond, m_base=8)
    res = pp.run_distrifusion(params, cfg, sched, x_T, cond, n_workers=1,
                              m_base=8, m_warmup=2)
    np.testing.assert_allclose(np.asarray(res.image), np.asarray(origin),
                               rtol=2e-4, atol=2e-4)


def test_patch_parallel_close_to_origin(setup):
    cfg, params, sched, x_T, cond = setup
    origin = pp.run_origin(params, cfg, sched, x_T, cond, m_base=16)
    res = pp.run_distrifusion(params, cfg, sched, x_T, cond, n_workers=2,
                              m_base=16, m_warmup=4)
    origin = np.asarray(origin); img = np.asarray(res.image)
    rel = np.linalg.norm(img - origin) / np.linalg.norm(origin)
    assert rel < 0.15, rel                     # stale KV => close, not exact
    assert np.all(np.isfinite(img))


def test_stadi_close_to_origin_and_uses_fewer_steps(setup):
    cfg, params, sched, x_T, cond = setup
    speeds = [1.0, 0.5]                        # slow device => ratio-2 tier
    res = stadi_lib.stadi_infer(params, cfg, sched, x_T, cond, speeds,
                                m_base=16, m_warmup=4)
    assert res.trace.plan.ratios == [1, 2]
    assert res.trace.plan.steps == [16, 10]    # (16+4)/2 = 10
    # slow worker never gets the bigger patch: v/M = 1/16 vs 0.5/10 = 0.05
    assert res.trace.patches[0] >= res.trace.patches[1]
    assert sum(res.trace.patches) == cfg.tokens_per_side
    origin = np.asarray(pp.run_origin(params, cfg, sched, x_T, cond, 16))
    img = np.asarray(res.image)
    rel = np.linalg.norm(img - origin) / np.linalg.norm(origin)
    assert rel < 0.25, rel
    assert np.all(np.isfinite(img))


def test_ablation_variants_run(setup):
    cfg, params, sched, x_T, cond = setup
    speeds = [1.0, 0.4]
    for ta, sa in [(False, False), (False, True), (True, False), (True, True)]:
        res = stadi_lib.stadi_infer(params, cfg, sched, x_T, cond, speeds,
                                    m_base=8, m_warmup=2, temporal=ta, spatial=sa)
        assert np.all(np.isfinite(np.asarray(res.image)))


def test_excluded_device(setup):
    cfg, params, sched, x_T, cond = setup
    speeds = [1.0, 0.1]                        # below b=0.25 => excluded
    res = stadi_lib.stadi_infer(params, cfg, sched, x_T, cond, speeds,
                                m_base=8, m_warmup=2)
    assert res.trace.plan.excluded == [False, True]
    assert res.trace.patches[1] == 0
    assert np.all(np.isfinite(np.asarray(res.image)))


def test_ddim_matches_closed_form_on_linear_model(setup):
    """eps_theta == x  =>  DDIM trajectory has closed form; check sampler."""
    _, _, sched, _, _ = setup
    x0 = jnp.ones((1, 4))
    eps_fn = lambda x, t: x
    out = sampler_lib.ddim_sample(eps_fn, sched, x0, M=50)
    # manual replay
    ts = sampler_lib.ddim_timesteps(sched.T, 50)
    x = x0
    for m in range(50):
        x = sampler_lib.ddim_step(sched, x, x, ts[m], ts[m + 1])
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-5)
