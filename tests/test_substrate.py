"""Optimizer / checkpoint / data / serving / simulator substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core import simulate as sim
from repro.core.patch_parallel import ExecutionTrace, IntervalEvent
from repro.data import SyntheticImages, TokenStream
from repro.optim import adamw


def test_adamw_reduces_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(1.0)}
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0)
    state = adamw.adamw_init(params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(100):
        g = jax.grad(loss_fn)(params)
        params, state = adamw.adamw_update(params, g, state, cfg)
    assert float(loss_fn(params)) < 1e-2


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    n = jnp.linalg.norm(clipped["a"])
    assert float(n) == pytest.approx(1.0, rel=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "nested": [jnp.ones(4), {"c": jnp.zeros(())}]}
    save_checkpoint(str(tmp_path), 7, tree)
    save_checkpoint(str(tmp_path), 12, jax.tree.map(lambda x: x + 1, tree))
    assert latest_step(str(tmp_path)) == 12
    out = restore_checkpoint(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(6).reshape(2, 3) + 1)
    out7 = restore_checkpoint(str(tmp_path), tree, step=7)
    np.testing.assert_array_equal(np.asarray(out7["a"]), np.arange(6).reshape(2, 3))
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"a": jnp.zeros((9, 9))})


def test_token_stream_structure_learnable():
    s = iter(TokenStream(vocab=128, seq_len=64, batch=4, seed=0))
    b = next(s)
    assert b["tokens"].shape == (4, 64)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 128
    # markov structure: repeated bigrams should far exceed uniform chance
    toks = np.concatenate([next(s)["tokens"].ravel() for _ in range(5)])
    big = set(zip(toks[:-1], toks[1:]))
    # uniform-random tokens over vocab 128 would give ~95% unique bigrams at
    # this sample size; Markov structure collapses that substantially
    assert len(big) < 0.75 * len(toks)


def test_synthetic_images_range_and_classes():
    ds = SyntheticImages(size=16, channels=3, n_classes=4)
    imgs, cls = ds.sample(np.random.default_rng(0), 8)
    assert imgs.shape == (8, 16, 16, 3)
    assert imgs.min() >= -1.0 and imgs.max() <= 1.0
    assert set(cls) <= set(range(4))
    # class-conditional structure: same-class images more similar on average
    imgs2, cls2 = ds.sample(np.random.default_rng(1), 64)


def test_serving_engine_end_to_end():
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import Request, ServingEngine
    cfg = get_config("gemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, slots=2, max_len=48)
    rng = np.random.default_rng(0)
    for uid in range(5):
        eng.submit(Request(uid=uid, prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                           max_new_tokens=6))
    done = eng.run_to_completion()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 6 for r in done)


# ----------------------------------------------------------------------
# latency simulator
# ----------------------------------------------------------------------

def _trace(substeps_list, patches, n=2, sync_first=0):
    events = []
    for i, subs in enumerate(substeps_list):
        events.append(IntervalEvent(i, subs, patches, synchronous=i < sync_first))
    return ExecutionTrace(events, None, patches, n_tokens=256,
                          latent_bytes=10_000, kv_bytes_per_worker=[5_000] * n)


def test_fit_cost_model():
    cm = sim.fit_cost_model([4, 8, 16], [0.14, 0.18, 0.26])
    assert cm.t_fixed == pytest.approx(0.10, rel=0.05)
    assert cm.t_row == pytest.approx(0.01, rel=0.05)


def test_simulator_stadi_beats_pp_under_heterogeneity():
    cm = sim.CostModel(t_fixed=0.01, t_row=0.01)
    speeds = [1.0, 0.4]
    # PP: equal patches [8,8], both step every interval, 16 intervals
    pp_trace = _trace([[1, 1]] * 16, [8, 8])
    t_pp = sim.simulate_trace(pp_trace, speeds, cm)
    # STADI: slow does 1 step per 2-fine interval, patches mended [10,6]
    stadi_events = [[1, 1]] * 4 + [[2, 1]] * 6          # warmup + 6 intervals
    t_st = sim.simulate_trace(_trace(stadi_events, [10, 6]), speeds, cm)
    assert t_st < t_pp
    # homogeneous: no benefit (equal-ish)
    t_pp_h = sim.simulate_trace(pp_trace, [1.0, 1.0], cm)
    assert t_pp_h < t_pp


def test_tp_straggler_bound():
    cm = sim.CostModel(t_fixed=0.01, t_row=0.01)
    t1 = sim.simulate_tensor_parallel(10, 2, 4, 16, [1.0, 1.0], cm, 1_000_000)
    t2 = sim.simulate_tensor_parallel(10, 2, 4, 16, [1.0, 0.4], cm, 1_000_000)
    assert t2 > t1


def test_online_profiler_drift():
    from repro.core.hetero import OnlineProfiler
    prof = OnlineProfiler([1.0, 1.0], alpha=1.0)
    prof.update(1, work=1.0, measured_time=2.5)        # device 1 slowed to 0.4
    assert prof.speeds[1] == pytest.approx(0.4)
    assert prof.drift([1.0, 1.0]) == pytest.approx(0.6)
