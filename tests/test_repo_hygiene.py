"""Repo hygiene: no Python bytecode may be tracked by git (the CI
check-hygiene job runs the same check; this makes tier-1 enforce it too)."""
import os
import subprocess

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tracked_files():
    try:
        r = subprocess.run(["git", "ls-files"], cwd=REPO, capture_output=True,
                           text=True, timeout=60)
    except (OSError, subprocess.TimeoutExpired):
        pytest.skip("git unavailable")
    if r.returncode != 0:
        pytest.skip("not a git checkout")
    return r.stdout.splitlines()


def test_no_bytecode_tracked():
    bad = [f for f in _tracked_files()
           if f.endswith(".pyc") or "__pycache__" in f.split("/")]
    assert not bad, f"bytecode artifacts tracked by git: {bad[:10]}"


def test_gitignore_covers_generated_artifacts():
    with open(os.path.join(REPO, ".gitignore")) as f:
        lines = {ln.strip() for ln in f}
    for pat in ("__pycache__/", "*.pyc", ".pytest_cache/", "results/*.tmp"):
        assert pat in lines, f".gitignore is missing {pat!r}"
