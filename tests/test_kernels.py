"""Per-kernel shape/dtype sweeps, interpret=True vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models import layers


def _rand(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.5).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("B,S,T,H,K,hd", [
    (1, 128, 128, 2, 2, 32),
    (2, 256, 256, 4, 2, 64),     # GQA
    (1, 128, 384, 2, 1, 32),     # MQA, cross lengths
    (2, 96, 96, 2, 2, 16),       # non-tile-multiple S (causal pad path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, T, H, K, hd, dtype, causal):
    if not causal and S != T:
        pytest.skip("cross-attn handled causal-only in this sweep")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, S, H, hd), dtype)
    k = _rand(ks[1], (B, T, K, hd), dtype)
    v = _rand(ks[2], (B, T, K, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal)
    kb = jnp.repeat(jnp.moveaxis(k, 2, 1), H // K, axis=1)
    vb = jnp.repeat(jnp.moveaxis(v, 2, 1), H // K, axis=1)
    want = ref.attention_ref(jnp.moveaxis(q, 2, 1), kb, vb, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(jnp.moveaxis(want, 1, 2), np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    B, S, H, hd = 1, 256, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (B, S, H, hd), jnp.float32)
    k = _rand(ks[1], (B, S, H, hd), jnp.float32)
    v = _rand(ks[2], (B, S, H, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.attention_ref(jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
                             jnp.moveaxis(v, 2, 1), causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.moveaxis(want, 1, 2)),
                               rtol=2e-5, atol=2e-5)


def test_flash_matches_model_attend():
    """Kernel agrees with the model-layer reference attend()."""
    B, S, H, K, hd = 2, 128, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], (B, S, H, hd), jnp.float32)
    k = _rand(ks[1], (B, S, K, hd), jnp.float32)
    v = _rand(ks[2], (B, S, K, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True)
    want = layers.attend(q, k, v, mask=layers.causal_mask(S, S, 0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------------
# stale-KV attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("N,Nl,tok_start", [
    (256, 64, 0), (256, 64, 64), (256, 64, 192), (256, 128, 128),
    (512, 256, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stale_kv_attention_sweep(N, Nl, tok_start, dtype):
    B, H, hd = 2, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = _rand(ks[0], (B, Nl, H, hd), dtype)
    kf = _rand(ks[1], (B, Nl, H, hd), dtype)
    vf = _rand(ks[2], (B, Nl, H, hd), dtype)
    kst = _rand(ks[3], (B, N, H, hd), dtype)
    vst = _rand(ks[4], (B, N, H, hd), dtype)
    out = ops.stale_kv_attention(q, kf, vf, kst, vst, tok_start=tok_start)
    want = ref.stale_kv_attention_ref(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(kf, 2, 1), jnp.moveaxis(vf, 2, 1),
        jnp.moveaxis(kst, 2, 1), jnp.moveaxis(vst, 2, 1), tok_start)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(jnp.moveaxis(want, 1, 2), np.float32),
                               **_tol(dtype))


# ----------------------------------------------------------------------
# ssm scan
# ----------------------------------------------------------------------

@pytest.mark.parametrize("B,S,Di,N", [
    (1, 64, 128, 8), (2, 128, 256, 16), (1, 100, 96, 16),  # pad path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_sweep(B, S, Di, N, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    x = _rand(ks[0], (B, S, Di), dtype)
    dt = jax.nn.softplus(_rand(ks[1], (B, S, Di), jnp.float32)) * 0.1
    b_t = _rand(ks[2], (B, S, N), jnp.float32)
    c_t = _rand(ks[3], (B, S, N), jnp.float32)
    a = -jnp.exp(jnp.linspace(-2.0, 1.0, N))[None].repeat(Di, 0)
    d_skip = jnp.ones((Di,))
    out = ops.ssm_scan(x.astype(jnp.float32), dt, b_t, c_t, a, d_skip)
    want = ref.ssm_scan_ref(x.astype(jnp.float32), dt, b_t, c_t, a, d_skip)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_ssm_scan_matches_mamba_module():
    """Kernel path == models.mamba reference recurrence."""
    from repro.models import mamba
    B, S, Di, N = 1, 64, 128, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    x = _rand(ks[0], (B, S, Di), jnp.float32)
    dt = jax.nn.softplus(_rand(ks[1], (B, S, Di), jnp.float32)) * 0.1
    b_t = _rand(ks[2], (B, S, N), jnp.float32)
    c_t = _rand(ks[3], (B, S, N), jnp.float32)
    a = -jnp.exp(jnp.linspace(-2.0, 1.0, N))[None].repeat(Di, 0)
    d_skip = jnp.ones((Di,))
    y_kernel = ops.ssm_scan(x, dt, b_t, c_t, a, d_skip)
    h0 = jnp.zeros((B, Di, N), jnp.float32)
    y_mod, _ = mamba.ssm_scan_ref(x, b_t, c_t, dt, a, d_skip, h0)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_mod),
                               rtol=1e-4, atol=1e-4)
