"""Per-kernel shape/dtype sweeps, interpret=True vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models import layers


def _rand(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.5).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("B,S,T,H,K,hd", [
    (1, 128, 128, 2, 2, 32),
    (2, 256, 256, 4, 2, 64),     # GQA
    (1, 128, 384, 2, 1, 32),     # MQA, cross lengths
    (2, 96, 96, 2, 2, 16),       # non-tile-multiple S (causal pad path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, T, H, K, hd, dtype, causal):
    if not causal and S != T:
        pytest.skip("cross-attn handled causal-only in this sweep")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, S, H, hd), dtype)
    k = _rand(ks[1], (B, T, K, hd), dtype)
    v = _rand(ks[2], (B, T, K, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal)
    kb = jnp.repeat(jnp.moveaxis(k, 2, 1), H // K, axis=1)
    vb = jnp.repeat(jnp.moveaxis(v, 2, 1), H // K, axis=1)
    want = ref.attention_ref(jnp.moveaxis(q, 2, 1), kb, vb, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(jnp.moveaxis(want, 1, 2), np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    B, S, H, hd = 1, 256, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (B, S, H, hd), jnp.float32)
    k = _rand(ks[1], (B, S, H, hd), jnp.float32)
    v = _rand(ks[2], (B, S, H, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.attention_ref(jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
                             jnp.moveaxis(v, 2, 1), causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.moveaxis(want, 1, 2)),
                               rtol=2e-5, atol=2e-5)


def test_flash_matches_model_attend():
    """Kernel agrees with the model-layer reference attend()."""
    B, S, H, K, hd = 2, 128, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], (B, S, H, hd), jnp.float32)
    k = _rand(ks[1], (B, S, K, hd), jnp.float32)
    v = _rand(ks[2], (B, S, K, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True)
    want = layers.attend(q, k, v, mask=layers.causal_mask(S, S, 0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------------
# stale-KV attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("N,Nl,tok_start", [
    (256, 64, 0), (256, 64, 64), (256, 64, 192), (256, 128, 128),
    (512, 256, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stale_kv_attention_sweep(N, Nl, tok_start, dtype):
    B, H, hd = 2, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = _rand(ks[0], (B, Nl, H, hd), dtype)
    kf = _rand(ks[1], (B, Nl, H, hd), dtype)
    vf = _rand(ks[2], (B, Nl, H, hd), dtype)
    kst = _rand(ks[3], (B, N, H, hd), dtype)
    vst = _rand(ks[4], (B, N, H, hd), dtype)
    out = ops.stale_kv_attention(q, kf, vf, kst, vst, tok_start=tok_start)
    want = ref.stale_kv_attention_ref(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(kf, 2, 1), jnp.moveaxis(vf, 2, 1),
        jnp.moveaxis(kst, 2, 1), jnp.moveaxis(vst, 2, 1), tok_start)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(jnp.moveaxis(want, 1, 2), np.float32),
                               **_tol(dtype))


# ----------------------------------------------------------------------
# ssm scan
# ----------------------------------------------------------------------

@pytest.mark.parametrize("B,S,Di,N", [
    (1, 64, 128, 8), (2, 128, 256, 16), (1, 100, 96, 16),  # pad path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_sweep(B, S, Di, N, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    x = _rand(ks[0], (B, S, Di), dtype)
    dt = jax.nn.softplus(_rand(ks[1], (B, S, Di), jnp.float32)) * 0.1
    b_t = _rand(ks[2], (B, S, N), jnp.float32)
    c_t = _rand(ks[3], (B, S, N), jnp.float32)
    a = -jnp.exp(jnp.linspace(-2.0, 1.0, N))[None].repeat(Di, 0)
    d_skip = jnp.ones((Di,))
    out = ops.ssm_scan(x.astype(jnp.float32), dt, b_t, c_t, a, d_skip)
    want = ref.ssm_scan_ref(x.astype(jnp.float32), dt, b_t, c_t, a, d_skip)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_ssm_scan_matches_mamba_module():
    """Kernel path == models.mamba reference recurrence."""
    from repro.models import mamba
    B, S, Di, N = 1, 64, 128, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    x = _rand(ks[0], (B, S, Di), jnp.float32)
    dt = jax.nn.softplus(_rand(ks[1], (B, S, Di), jnp.float32)) * 0.1
    b_t = _rand(ks[2], (B, S, N), jnp.float32)
    c_t = _rand(ks[3], (B, S, N), jnp.float32)
    a = -jnp.exp(jnp.linspace(-2.0, 1.0, N))[None].repeat(Di, 0)
    d_skip = jnp.ones((Di,))
    y_kernel = ops.ssm_scan(x, dt, b_t, c_t, a, d_skip)
    h0 = jnp.zeros((B, Di, N), jnp.float32)
    y_mod, _ = mamba.ssm_scan_ref(x, b_t, c_t, dt, a, d_skip, h0)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_mod),
                               rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------------
# padded-layout stale-KV attention (the shard_map form, DESIGN.md §15)
# ----------------------------------------------------------------------

def _padded_oracle(q, kf, vf, kst, vst, tok_start, valid, n_tokens):
    """Mask-blend + dynamic_update_slice + masked dense attend — the
    reference SPMD branch of dit.block_stack, in [B,S,H,hd] layout."""
    Nl = q.shape[1]
    mask = (jnp.arange(Nl) < valid)[None, :, None, None]
    cur_k = jax.lax.dynamic_slice_in_dim(kst, tok_start, Nl, axis=1)
    cur_v = jax.lax.dynamic_slice_in_dim(vst, tok_start, Nl, axis=1)
    ku = jnp.where(mask, kf, cur_k)
    vu = jnp.where(mask, vf, cur_v)
    full_k = jax.lax.dynamic_update_slice_in_dim(kst, ku, tok_start, axis=1)
    full_v = jax.lax.dynamic_update_slice_in_dim(vst, vu, tok_start, axis=1)
    key_mask = (jnp.arange(kst.shape[1]) < n_tokens)[None, None, None, :]
    return layers.attend(q, full_k, full_v, mask=key_mask)


def _padded_case(key, B, Nl, Npad, H, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    return (_rand(ks[0], (B, Nl, H, hd), dtype),
            _rand(ks[1], (B, Nl, H, hd), dtype),
            _rand(ks[2], (B, Nl, H, hd), dtype),
            _rand(ks[3], (B, Npad, H, hd), dtype),
            _rand(ks[4], (B, Npad, H, hd), dtype))


@pytest.mark.parametrize("tok_start,valid", [
    (0, 64), (64, 64), (192, 64),    # whole-slab fresh at several offsets
    (64, 40), (128, 8), (192, 33),   # uneven valid tails (incl. non-tile)
    (0, 0),                          # fully-stale slab (valid prefix empty)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stale_kv_padded_sweep(tok_start, valid, dtype):
    B, H, hd, N, Nl = 1, 2, 32, 256, 64
    q, kf, vf, kst, vst = _padded_case(10, B, Nl, N + Nl, H, hd, dtype)
    out = ops.stale_kv_attention_padded(q, kf, vf, kst, vst,
                                        tok_start, valid, n_tokens=N)
    want = _padded_oracle(q, kf, vf, kst, vst, tok_start, valid, N)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_stale_kv_padded_traced_offsets_one_trace():
    """tok_start/valid_tokens are scalar-prefetch operands: one jitted
    program serves every device's layout (the shard_map contract)."""
    B, H, hd, N, Nl = 1, 2, 32, 128, 32
    q, kf, vf, kst, vst = _padded_case(11, B, Nl, N + Nl, H, hd, jnp.float32)
    traces = []

    @jax.jit
    def f(ts, va):
        traces.append(None)
        return ops.stale_kv_attention_padded(q, kf, vf, kst, vst, ts, va,
                                             n_tokens=N)

    for ts, va in [(0, 32), (32, 32), (96, 16), (64, 7)]:
        out = f(jnp.int32(ts), jnp.int32(va))
        want = _padded_oracle(q, kf, vf, kst, vst, ts, va, N)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
    assert len(traces) == 1         # traced scalars never retrigger tracing


def test_stale_kv_padded_scratch_keys_masked():
    """Scratch keys (>= n_tokens) never contribute: poisoning the padded
    tail of the stale buffer with huge values must not move the output."""
    B, H, hd, N, Nl = 1, 2, 32, 128, 32
    q, kf, vf, kst, vst = _padded_case(12, B, Nl, N + Nl, H, hd, jnp.float32)
    base = ops.stale_kv_attention_padded(q, kf, vf, kst, vst, 32, 32,
                                         n_tokens=N)
    kst2 = kst.at[:, N:].set(1e4)
    vst2 = vst.at[:, N:].set(1e4)
    poisoned = ops.stale_kv_attention_padded(q, kf, vf, kst2, vst2, 32, 32,
                                             n_tokens=N)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(poisoned))


# ----------------------------------------------------------------------
# guided (branch-stacked) stale-KV attention
# ----------------------------------------------------------------------

@pytest.mark.parametrize("uncond_fresh", [1, 0])
@pytest.mark.parametrize("tok_start,valid", [(0, 32), (64, 32), (96, 9)])
def test_stale_kv_guided_sweep(uncond_fresh, tok_start, valid):
    B, H, hd, N, Nl = 1, 2, 32, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(13), 5)
    q = _rand(ks[0], (2, B, Nl, H, hd), jnp.float32)
    kf = _rand(ks[1], (2, B, Nl, H, hd), jnp.float32)
    vf = _rand(ks[2], (2, B, Nl, H, hd), jnp.float32)
    kst = _rand(ks[3], (2, B, N + Nl, H, hd), jnp.float32)
    vst = _rand(ks[4], (2, B, N + Nl, H, hd), jnp.float32)
    out = ops.stale_kv_attention_guided(q, kf, vf, kst, vst, tok_start,
                                        valid, uncond_fresh, n_tokens=N)
    want_c = _padded_oracle(q[0], kf[0], vf[0], kst[0], vst[0],
                            tok_start, valid, N)
    # the interleaved body: uncond_fresh=0 masks the uncond branch's fresh
    # slab in-kernel, so branch 1 attends pure-stale
    want_u = _padded_oracle(q[1], kf[1], vf[1], kst[1], vst[1], tok_start,
                            valid if uncond_fresh else 0, N)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(jnp.stack([want_c, want_u])),
        rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------------
# LSE ring partial (flash-style per-hop accumulation, DESIGN.md §15)
# ----------------------------------------------------------------------

@pytest.mark.parametrize("n_segs,T_seg,valid_last", [
    (2, 128, 128), (2, 128, 96), (3, 64, 17), (4, 32, 32),
])
def test_lse_attention_streamed_merge(n_segs, T_seg, valid_last):
    """Per-segment (out, lse) partials merged with the online-softmax
    update == one dense attend over the concatenated valid keys."""
    B, S, H, hd = 1, 64, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(14), 1 + 2 * n_segs)
    q = _rand(ks[0], (B, S, H, hd), jnp.float32)
    segs = [(_rand(ks[1 + 2 * i], (B, T_seg, H, hd), jnp.float32),
             _rand(ks[2 + 2 * i], (B, T_seg, H, hd), jnp.float32))
            for i in range(n_segs)]
    valids = [T_seg] * (n_segs - 1) + [valid_last]
    num = den = run_m = None
    for (k, v), valid in zip(segs, valids):
        o, lse = ops.lse_attention(q, k, v, valid)
        o = o.astype(jnp.float32)
        if num is None:
            num, den, run_m = o, jnp.ones_like(lse), lse
        else:
            m_new = jnp.maximum(run_m, lse)
            corr, w = jnp.exp(run_m - m_new), jnp.exp(lse - m_new)
            num = num * corr[..., None] + o * w[..., None]
            den = den * corr + w
            run_m = m_new
    merged = num / jnp.maximum(den, 1e-30)[..., None]
    kcat = jnp.concatenate([k[:, :va] for (k, _), va in zip(segs, valids)], 1)
    vcat = jnp.concatenate([v[:, :va] for (_, v), va in zip(segs, valids)], 1)
    want = layers.attend(q, kcat, vcat)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_lse_attention_empty_segment_zero_weight():
    """A fully-masked segment (valid_len=0) returns lse ~= -inf, giving it
    exactly zero weight in the streamed merge — the property the ring
    executor's scratch hops rely on."""
    B, S, H, hd, T = 1, 32, 2, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(15), 5)
    q = _rand(ks[0], (B, S, H, hd), jnp.float32)
    k1, v1 = _rand(ks[1], (B, T, H, hd), jnp.float32), \
        _rand(ks[2], (B, T, H, hd), jnp.float32)
    k0, v0 = _rand(ks[3], (B, T, H, hd), jnp.float32), \
        _rand(ks[4], (B, T, H, hd), jnp.float32)
    o1, l1 = ops.lse_attention(q, k1, v1, T)
    o0, l0 = ops.lse_attention(q, k0, v0, 0)
    assert float(jnp.max(l0)) < -1e29
    m = jnp.maximum(l1, l0)
    w1, w0 = jnp.exp(l1 - m), jnp.exp(l0 - m)
    merged = ((o1.astype(jnp.float32) * w1[..., None]
               + o0.astype(jnp.float32) * w0[..., None])
              / (w1 + w0)[..., None])
    np.testing.assert_allclose(np.asarray(merged), np.asarray(o1),
                               rtol=1e-6, atol=1e-6)


# ----------------------------------------------------------------------
# fused CFG epilogue
# ----------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 16, 16, 3), (2, 33, 7), (5,),
                                   (1, 128, 128, 3)])
@pytest.mark.parametrize("scale", [0.0, 1.0, 7.5])
def test_cfg_epilogue_matches_sampler(shape, scale):
    from repro.core import sampler as sampler_lib
    ks = jax.random.split(jax.random.PRNGKey(16), 2)
    ec = _rand(ks[0], shape, jnp.float32)
    eu = _rand(ks[1], shape, jnp.float32)
    comb, delta = ops.cfg_epilogue(ec, eu, scale)
    # combine agrees to FMA-contraction rounding (the jitted kernel may
    # fuse w*d+eu); delta is a single subtract, so it stays bitwise
    np.testing.assert_allclose(
        np.asarray(comb), np.asarray(sampler_lib.cfg_combine(ec, eu, scale)),
        rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(delta), np.asarray(sampler_lib.cfg_delta(ec, eu)))


def test_cfg_epilogue_traced_scale_and_counters():
    """Traced scalar scales stay on the kernel (one compiled program per
    shape); per-lane scale ARRAYS fall back and record a miss."""
    ks = jax.random.split(jax.random.PRNGKey(17), 2)
    ec = _rand(ks[0], (2, 8, 8, 3), jnp.float32)
    eu = _rand(ks[1], (2, 8, 8, 3), jnp.float32)
    before = ops.kernel_stats_snapshot()
    f = jax.jit(lambda s: ops.cfg_epilogue(ec, eu, s, with_delta=False))
    for s in (1.5, 4.0):
        from repro.core import sampler as sampler_lib
        np.testing.assert_allclose(
            np.asarray(f(s)),
            np.asarray(sampler_lib.cfg_combine(ec, eu, s)),
            rtol=1e-5, atol=1e-6)
    delta = ops.kernel_stats_delta(before, ops.kernel_stats_snapshot())
    assert delta["hits"].get("cfg_epilogue", 0) >= 1
    # per-lane array scale: unfused fallback, recorded as a miss
    before = ops.kernel_stats_snapshot()
    lane = jnp.array([1.0, 3.0])[:, None, None, None]
    comb = ops.cfg_epilogue(ec, eu, lane, with_delta=False)
    from repro.core import sampler as sampler_lib
    np.testing.assert_allclose(
        np.asarray(comb), np.asarray(sampler_lib.cfg_combine(ec, eu, lane)),
        rtol=1e-5, atol=1e-6)
    delta = ops.kernel_stats_delta(before, ops.kernel_stats_snapshot())
    assert delta["misses"].get("cfg-per-lane-scale", 0) == 1


# ----------------------------------------------------------------------
# STADI_PALLAS_INTERPRET override
# ----------------------------------------------------------------------

def test_interpret_env_override(monkeypatch):
    monkeypatch.setenv("STADI_PALLAS_INTERPRET", "1")
    assert ops._interpret() is True
    monkeypatch.setenv("STADI_PALLAS_INTERPRET", "0")
    if jax.default_backend() == "tpu":      # pragma: no cover - CPU CI
        assert ops._interpret() is False
    else:
        with pytest.raises(RuntimeError, match="NOT a TPU proxy"):
            ops._interpret()
    monkeypatch.setenv("STADI_PALLAS_INTERPRET", "bogus")
    with pytest.raises(ValueError, match="STADI_PALLAS_INTERPRET"):
        ops._interpret()


# ----------------------------------------------------------------------
# hypothesis shape sweeps (skipped when hypothesis is not installed)
# ----------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 7).map(lambda r: r * 32),   # tok_start, tile-mult
           st.integers(0, 64))                        # valid, any tail
    def test_stale_kv_padded_hypothesis(tok_start, valid):
        B, H, hd, N, Nl = 1, 2, 32, 256, 64
        q, kf, vf, kst, vst = _padded_case(18, B, Nl, N + Nl, H, hd,
                                           jnp.float32)
        out = ops.stale_kv_attention_padded(q, kf, vf, kst, vst,
                                            tok_start, valid, n_tokens=N)
        want = _padded_oracle(q, kf, vf, kst, vst, tok_start, valid, N)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
