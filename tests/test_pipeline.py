"""Unified StadiPipeline API: planner/executor registries, bitwise parity
with the legacy entry points, online rebalancing, and emulated-vs-SPMD
parity (subprocess). Also deterministic allocator tests (no hypothesis)."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import patch_parallel as pp
from repro.core import sampler as sampler_lib
from repro.core import schedule as sl
from repro.core import stadi as stadi_lib
from repro.core.pipeline import (EXECUTORS, StadiConfig, StadiPipeline,
                                 get_executor)
from repro.core.planners import PLANNERS, get_planner
from repro.models.diffusion import dit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tiny-dit").reduced()      # 16x16 latent, 8 token rows
    params = dit.init_params(jax.random.PRNGKey(0), cfg)
    sched = sampler_lib.linear_schedule(T=100)
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (2, cfg.latent_size, cfg.latent_size, cfg.channels))
    cond = jnp.array([1, 2])
    return cfg, params, sched, x_T, cond


def _config(speeds, **kw):
    from repro.core.hetero import DeviceProfile
    cluster = tuple(DeviceProfile(f"dev{i}", c=v) for i, v in enumerate(speeds))
    return StadiConfig(cluster=cluster, **kw)


# ----------------------------------------------------------------------
# registries
# ----------------------------------------------------------------------

def test_registries_complete():
    assert {"uniform", "spatial", "temporal", "stadi", "makespan"} <= set(PLANNERS)
    assert {"emulated", "spmd", "simulate"} <= set(EXECUTORS)
    with pytest.raises(KeyError):
        get_planner("nope")
    with pytest.raises(KeyError):
        get_executor("nope")


def test_config_from_occupancies():
    config = StadiConfig.from_occupancies([0.0, 0.6], m_base=16, m_warmup=4)
    assert config.speeds == [1.0, pytest.approx(0.4)]
    assert config.n_devices == 2


def test_all_planners_produce_valid_plans(setup):
    cfg, params, sched, *_ = setup
    speeds = [1.0, 0.5, 0.3]
    for name in ("uniform", "spatial", "temporal", "stadi", "makespan"):
        config = _config(speeds, m_base=16, m_warmup=4, planner=name)
        plan = StadiPipeline(cfg, params, sched, config).plan()
        assert plan.planner == name
        assert sum(plan.patches) == cfg.tokens_per_side
        assert len(plan.patches) == len(speeds)
        if name == "makespan":
            assert plan.modeled_interval_cost is not None


# ----------------------------------------------------------------------
# ablation matrix: bitwise parity with the legacy entry points
# ----------------------------------------------------------------------

def test_uniform_planner_bitwise_matches_run_distrifusion(setup):
    cfg, params, sched, x_T, cond = setup
    ref = pp.run_distrifusion(params, cfg, sched, x_T, cond, n_workers=2,
                              m_base=8, m_warmup=2)
    config = _config([1.0, 0.5], m_base=8, m_warmup=2, planner="uniform")
    res = StadiPipeline(cfg, params, sched, config).generate(x_T, cond)
    np.testing.assert_array_equal(np.asarray(res.image), np.asarray(ref.image))


@pytest.mark.filterwarnings("ignore::DeprecationWarning")
@pytest.mark.parametrize("flags,planner", [
    ((False, False), "uniform"), ((False, True), "spatial"),
    ((True, False), "temporal"), ((True, True), "stadi")])
def test_ablation_matrix_bitwise_matches_stadi_infer(setup, flags, planner):
    cfg, params, sched, x_T, cond = setup
    speeds = [1.0, 0.4]
    ta, sa = flags
    ref = stadi_lib.stadi_infer(params, cfg, sched, x_T, cond, speeds,
                                m_base=8, m_warmup=2, temporal=ta, spatial=sa)
    config = _config(speeds, m_base=8, m_warmup=2, planner=planner)
    res = StadiPipeline(cfg, params, sched, config).generate(x_T, cond)
    np.testing.assert_array_equal(np.asarray(res.image), np.asarray(ref.image))
    assert res.plan.planner == planner
    assert res.plan.patches == ref.trace.patches


def test_makespan_backend_reachable_and_finite(setup):
    cfg, params, sched, x_T, cond = setup
    config = _config([1.0, 0.5], m_base=8, m_warmup=4, planner="makespan")
    res = StadiPipeline(cfg, params, sched, config).generate(x_T, cond)
    assert np.all(np.isfinite(np.asarray(res.image)))
    assert res.plan.modeled_interval_cost is not None


# ----------------------------------------------------------------------
# simulate backend
# ----------------------------------------------------------------------

def test_simulate_backend_needs_cost_model(setup):
    cfg, params, sched, *_ = setup
    config = _config([1.0, 0.5], m_base=16, m_warmup=4, backend="simulate")
    with pytest.raises(ValueError):
        StadiPipeline(cfg, params, sched, config).generate()


def test_simulate_backend_matches_direct_trace_replay(setup):
    from repro.core import simulate as sim
    cfg, params, sched, *_ = setup
    cm = sim.CostModel(t_fixed=1e-3, t_row=5e-4)
    speeds = [1.0, 0.5]
    config = _config(speeds, m_base=16, m_warmup=4, backend="simulate",
                     cost_model=cm)
    res = StadiPipeline(cfg, params, sched, config).generate()
    assert res.image is None
    plan = sl.temporal_allocation(speeds, 16, 4)
    patches = sl.spatial_allocation(speeds, plan.steps, cfg.tokens_per_side)
    ref = sim.simulate_trace(sim.build_trace(plan, patches, cfg), speeds, cm)
    assert res.latency_s == pytest.approx(ref)


# ----------------------------------------------------------------------
# online rebalancing (OnlineProfiler in the hot path)
# ----------------------------------------------------------------------

def test_rebalance_replans_on_drift(setup):
    cfg, params, sched, x_T, cond = setup
    config = _config([1.0, 1.0], m_base=16, m_warmup=4, planner="stadi",
                     rebalance_every=1, rebalance_threshold=0.2)
    pipe = StadiPipeline(cfg, params, sched, config)
    # ground truth drifted: device 1 is really only half as fast as planned
    res = pipe.generate(x_T, cond, measured_speeds=[1.0, 0.5])
    assert len(res.replans) >= 1
    ev = res.replans[0]
    assert ev.drift > config.rebalance_threshold
    assert ev.speeds_after[1] < ev.speeds_before[1]
    # the new allocation shifts rows toward the genuinely faster device
    assert ev.plan.patches[0] > ev.plan.patches[1]
    assert np.all(np.isfinite(np.asarray(res.image)))
    # post-replan intervals in the trace carry the new patch split, while
    # trace-level provenance stays the initial plan/allocation
    assert res.trace.events[-1].patches == res.replans[-1].plan.patches
    assert res.trace.plan.m_base == 16 and res.trace.plan.m_warmup == 4
    assert res.trace.patches == res.plan.patches


def test_rebalance_noop_without_drift(setup):
    cfg, params, sched, x_T, cond = setup
    config = _config([1.0, 0.5], m_base=16, m_warmup=4,
                     rebalance_every=1, rebalance_threshold=0.2)
    res = StadiPipeline(cfg, params, sched, config).generate(x_T, cond)
    assert res.replans == []


def test_rebalance_requires_emulated_backend(setup):
    cfg, params, sched, x_T, cond = setup
    config = _config([1.0, 0.5], m_base=16, m_warmup=4, backend="simulate",
                     rebalance_every=1)
    with pytest.raises(ValueError):
        StadiPipeline(cfg, params, sched, config).generate(x_T, cond)


# ----------------------------------------------------------------------
# deterministic allocator properties (run even without hypothesis)
# ----------------------------------------------------------------------

def test_spatial_allocation_min_patch_deterministic():
    # adversarial: near-zero-rate active device must still get min_patch
    patches = sl.spatial_allocation([1.0, 0.01], [100, 100], 32)
    assert patches == [31, 1]
    patches = sl.spatial_allocation([1.0, 0.01], [100, 100], 32, min_patch=4)
    assert patches == [28, 4]
    # granularity interacts with min_patch
    patches = sl.spatial_allocation([1.0, 0.01], [100, 100], 32,
                                    granularity=2, min_patch=4)
    assert patches[1] >= 4 and patches[0] + patches[1] == 32
    assert all(p % 2 == 0 for p in patches)


def test_spatial_allocation_min_patch_infeasible_raises():
    with pytest.raises(ValueError):
        sl.spatial_allocation([1.0, 0.9, 0.8], [10, 10, 10], 8, min_patch=4)


def test_single_tier_temporal_allocation():
    plan = sl.temporal_allocation([1.0, 0.5], 16, 4, tiers=(1,))
    assert plan.ratios == [1, 1]
    assert plan.steps == [16, 16]


# ----------------------------------------------------------------------
# emulated vs SPMD parity through the pipeline (subprocess, real devices)
# ----------------------------------------------------------------------

def test_spmd_backend_matches_emulated():
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.core import sampler as sampler_lib
        from repro.core.pipeline import StadiConfig, StadiPipeline
        from repro.models.diffusion import dit

        cfg = get_config('tiny-dit').reduced()
        params = dit.init_params(jax.random.PRNGKey(0), cfg)
        sched = sampler_lib.linear_schedule(T=1000)
        x_T = jax.random.normal(jax.random.PRNGKey(1),
                                (1, cfg.latent_size, cfg.latent_size,
                                 cfg.channels))
        cond = jnp.zeros((1,), jnp.int32)
        config = StadiConfig.from_occupancies([0.0, 0.5], m_base=8,
                                              m_warmup=2, backend='spmd')
        spmd = StadiPipeline(cfg, params, sched, config).generate(x_T, cond)
        emu = StadiPipeline(cfg, params, sched, dataclasses.replace(
            config, backend='emulated')).generate(x_T, cond)
        a, b = np.asarray(spmd.image), np.asarray(emu.image)
        err = float(np.linalg.norm(a - b) / np.linalg.norm(b))
        assert err < 1e-3, err
        print('PIPE_SPMD_OK', err)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=2 "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=520, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "PIPE_SPMD_OK" in r.stdout


# ----------------------------------------------------------------------
# capability registry + normalized executor kwargs + unified plan() API
# ----------------------------------------------------------------------

def test_every_executor_accepts_normalized_kwargs():
    """Satellite regression: generate/generate_many invoke executors strictly
    by keyword, so every registered backend must accept exactly the
    normalized kwarg set (registration enforces it; pin it here too)."""
    import inspect
    from repro.core.pipeline import EXECUTOR_KWARGS
    for name, spec in EXECUTORS.items():
        sig = tuple(inspect.signature(spec.fn).parameters)
        assert sig == EXECUTOR_KWARGS, (name, sig)
        hook = inspect.signature(spec.fn).parameters["interval_hook"]
        assert hook.default is None, name


def test_capability_registry_declarations():
    from repro.core.pipeline import (PLAN_FEATURES, backends_supporting,
                                     get_executor_spec, register_executor)
    for spec in EXECUTORS.values():
        assert spec.supports <= set(PLAN_FEATURES)
    assert "stages" in get_executor_spec("pipefuse").supports
    assert get_executor_spec("simulate").supports == set(PLAN_FEATURES)
    assert "guidance" in get_executor_spec("spmd_guidance").requires
    assert "seq" in get_executor_spec("spmd_seq").requires
    # axis-prefix query covers every mode of the axis
    assert set(backends_supporting("guidance")) >= {"emulated", "simulate",
                                                    "spmd", "spmd_guidance"}
    assert backends_supporting("seq") == ("emulated", "simulate", "spmd_seq")
    # uniform rejection comes from declarations, not an if-chain
    with pytest.raises(ValueError, match="unknown capability"):
        register_executor("bogus", supports=("guidance.sideways",))
    with pytest.raises(TypeError, match="normalized"):
        register_executor("bogus")(lambda params, plan: None)
    assert "bogus" not in EXECUTORS


def test_unified_plan_populates_all_axes(setup):
    cfg, params, sched, x_T, cond = setup
    from repro.core.simulate import CostModel
    config = _config([1.0, 0.5], m_base=8, m_warmup=2, num_stages=2,
                     cfg_scale=2.0, guidance="fused", seq_shards=2,
                     backend="simulate", cost_model=CostModel(t_fixed=1e-3, t_row=1e-4))
    plan = StadiPipeline(cfg, params, sched, config).plan()
    assert plan.stages is not None and len(plan.stages) == 2
    assert plan.guidance is not None and plan.guidance.mode == "fused"
    assert plan.seq is not None and plan.seq.n_shards == 2


def test_deprecated_plan_helpers_shim(setup):
    """plan_stages/plan_seq/plan_guidance warn and resolve identically to
    the fields the unified plan() already populated."""
    from repro.core.pipeline import plan_guidance, plan_seq, plan_stages
    cfg, params, sched, x_T, cond = setup
    from repro.core.simulate import CostModel
    config = _config([1.0, 0.5], m_base=8, m_warmup=2, num_stages=2,
                     cfg_scale=2.0, guidance="fused", seq_shards=2,
                     backend="simulate", cost_model=CostModel(t_fixed=1e-3, t_row=1e-4))
    pipe = StadiPipeline(cfg, params, sched, config)
    plan = pipe.plan()
    with pytest.warns(DeprecationWarning, match="plan_stages"):
        assert plan_stages(plan, cfg, config) == plan.stages
    with pytest.warns(DeprecationWarning, match="plan_guidance"):
        assert plan_guidance(plan, config) == plan.guidance
    raw = dataclasses.replace(plan, seq=None)
    with pytest.warns(DeprecationWarning, match="plan_seq"):
        assert plan_seq(raw, cfg, config) == plan.seq
