"""In-process tests for core/comm.py exchange primitives: padded vs
broadcast uneven all-gather equivalence at N=1 and under uneven tails,
plus the analytic gather-cost helper (simulator satellite fix).

Deterministic cases always run; hypothesis widens the size space when the
``test`` extra is installed. The mesh spans jax.devices() (the CI matrix
forces 1 or 4 host devices via STADI_HOST_DEVICES, honored by
tests/conftest.py), so the N=1 degenerate case is exercised in the
single-device leg and true multi-rank uneven tails in the 4-device leg.
jit programs are cached per sizes tuple so repeated examples reuse
compilations."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

N_DEV = len(jax.devices())


def _mesh():
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()), ("dev",))


@functools.lru_cache(maxsize=None)
def _gather_fns(sizes):
    from jax.sharding import PartitionSpec as P
    mesh = _mesh()

    def f_pad(xl):
        return comm.uneven_all_gather_padded(xl[0], sizes, "dev")

    def f_bc(xl):
        return comm.uneven_all_gather_broadcast(xl[0], sizes, "dev")

    return tuple(jax.jit(comm.shard_map_compat(f, mesh, P("dev"), P(None)))
                 for f in (f_pad, f_bc))


def _run_case(sizes, width=5, seed=0):
    sizes = tuple(int(s) for s in sizes)
    mx = max(sizes)
    rng = np.random.default_rng(seed)
    slabs = [rng.normal(size=(s, width)).astype(np.float32) for s in sizes]
    oracle = np.concatenate(slabs, 0)
    padded = np.stack([np.pad(s, ((0, mx - s.shape[0]), (0, 0)))
                       for s in slabs])
    x = jnp.asarray(padded)                       # [N, mx, width]
    f_pad, f_bc = _gather_fns(sizes)
    np.testing.assert_allclose(np.asarray(f_pad(x)), oracle, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(f_bc(x)), oracle, rtol=1e-6)


def test_single_rank_identity():
    """N=1: both strategies must return the local slab verbatim."""
    if N_DEV != 1:
        pytest.skip(f"needs exactly 1 device, have {N_DEV}")
    _run_case((4,))
    _run_case((1,))


@pytest.mark.parametrize("seed,tail", [(0, 1), (1, 3), (2, 6)])
def test_uneven_tail_vs_equal_heads(seed, tail):
    """The classic uneven-tail layout: all ranks equal except the last."""
    sizes = (4,) * (N_DEV - 1) + (tail,)
    _run_case(sizes, seed=seed)


def test_fully_uneven_sizes():
    sizes = tuple(([3, 1, 4, 2, 5, 1, 2, 6])[:N_DEV])
    _run_case(sizes, seed=9)


def test_zero_size_rank_contributes_nothing():
    """A rank with 0 valid rows (excluded device) is sliced away."""
    if N_DEV < 2:
        pytest.skip("needs >= 2 devices for a zero-size rank")
    sizes = (3,) + (0,) * (N_DEV - 1)
    _run_case(sizes)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(sizes=st.lists(st.integers(1, 6), min_size=N_DEV,
                          max_size=N_DEV),
           seed=st.integers(0, 3))
    def test_padded_equals_broadcast_equals_oracle(sizes, seed):
        """Paper §V-A equivalence under arbitrary uneven tails (any N)."""
        _run_case(tuple(sizes), seed=seed)


# ----------------------------------------------------------------------
# analytic gather cost (simulator satellite fix)
# ----------------------------------------------------------------------

def test_uneven_all_gather_rows():
    assert comm.uneven_all_gather_rows([8, 8]) == 8
    assert comm.uneven_all_gather_rows([12, 4]) == 12
    assert comm.uneven_all_gather_rows([5, 0, 3]) == 5    # 0-row excluded
    assert comm.uneven_all_gather_rows([16]) == 0         # N=1: no traffic
    assert comm.uneven_all_gather_rows([16, 0]) == 0
    assert comm.uneven_all_gather_rows([]) == 0
    assert comm.uneven_all_gather_rows([2, 2, 2, 2]) == 6


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(sizes=st.lists(st.integers(0, 32), min_size=1, max_size=8))
    def test_uneven_all_gather_rows_bounds(sizes):
        """Wire rows never exceed (N-1) * max; never charge a lone rank."""
        rows = comm.uneven_all_gather_rows(sizes)
        active = [s for s in sizes if s > 0]
        if len(active) <= 1:
            assert rows == 0
        else:
            assert rows == (len(active) - 1) * max(active)
            assert rows < len(active) * max(active)
