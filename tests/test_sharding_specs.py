"""Sharding rule tests on a 512-placeholder mesh structure (no device state:
uses Mesh of abstract shape via jax.sharding.AbstractMesh)."""
import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.sharding import specs as sh

def _mesh(sizes, names):
    """AbstractMesh compat: jax >= 0.5 takes (sizes, names), 0.4.x takes
    ((name, size), ...)."""
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


MESH = _mesh((16, 16), ("data", "model"))
MESH3 = _mesh((2, 16, 16), ("pod", "data", "model"))


def _specs_for(arch):
    cfg = get_config(arch).replace(param_dtype="bfloat16", dtype="bfloat16")
    from repro.models import build_model
    params_s = jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))
    return cfg, params_s, sh.param_specs(params_s, MESH, cfg)


def test_dense_rules_llama():
    cfg, params_s, specs = _specs_for("llama3-405b")
    b = specs["blocks"]
    assert b["attn"]["wq"] == P(None, "data", "model")      # 128 heads: sharded
    # kv heads (8) don't divide model axis (16): replicated output dim
    assert b["attn"]["wk"] == P(None, "data", None)
    assert b["attn"]["wv"] == P(None, "data", None)
    assert b["mlp"]["w_down"] == P(None, "model", "data")
    assert specs["embed"] == P("model", "data")
    assert specs["ln_f"] == P()


def test_gemma_small_heads_fully_replicated_attention():
    cfg, params_s, specs = _specs_for("gemma-2b")
    b = specs["blocks"]
    # 8 q heads and 1 kv head on a 16-wide axis: head-dim must never split
    assert b["attn"]["wq"] == P(None, "data", None)
    assert b["attn"]["wk"] == P(None, "data", None)
    assert b["attn"]["wo"] == P(None, None, "data")
    # MLP stays tensor-parallel (16384 % 16 == 0)
    assert b["mlp"]["w_up"] == P(None, "data", "model")


def test_moe_expert_parallel():
    cfg, params_s, specs = _specs_for("olmoe-1b-7b")
    e = specs["blocks"]["moe"]["experts"]
    assert e["w_gate"] == P(None, "model", "data", None)    # experts on model
    assert e["w_down"] == P(None, "model", None, "data")
    assert specs["blocks"]["moe"]["router"] == P(None, "data", None)


def test_guard_drops_nondivisible():
    # vocab 50304 not divisible by 16? 50304/16 = 3144 ok; check odd dim
    spec = sh._guard(("model", "data"), (10, 32), MESH)
    assert spec == P(None, "data")                          # 10 % 16 != 0


def test_batch_specs_multi_pod():
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), np.int32)}
    s = sh.batch_specs(batch, MESH3)
    assert s["tokens"] == P(("pod", "data"), None)
    tiny = {"tokens": jax.ShapeDtypeStruct((1, 64), np.int32)}
    s = sh.batch_specs(tiny, MESH3)
    assert s["tokens"] == P(None, None)                     # batch 1: replicated


def test_cache_specs_kv_vs_seq():
    # kv=16 divides model: shard kv heads
    c = {"k": jax.ShapeDtypeStruct((16, 128, 32768, 16, 64), np.float32)}
    assert sh.cache_specs(c, MESH)["k"] == P(None, "data", None, "model", None)
    # kv=8 doesn't: shard sequence instead
    c = {"k": jax.ShapeDtypeStruct((126, 128, 32768, 8, 128), np.float32)}
    assert sh.cache_specs(c, MESH)["k"] == P(None, "data", "model", None, None)


def test_xlstm_heterogeneous_blocks_get_specs():
    cfg, params_s, specs = _specs_for("xlstm-125m")
    assert isinstance(specs["blocks"], list) and len(specs["blocks"]) == 12
    # mLSTM block (idx 0) and sLSTM block (idx 3) both resolve
    assert specs["blocks"][0]["w_up"] == P("data", "model")
    assert specs["blocks"][3]["w_x"] == P("data", "model")
