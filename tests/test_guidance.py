"""Classifier-free guidance as a fourth scheduling dimension (DESIGN.md
§12): the guidance-group partitioner (hypothesis properties), the null-cond
model path, the split==fused bitwise contract on the emulated backend, the
interleaved uncond-reuse cadence, the GuidanceExchange IR semantics, the
stadi_guidance planner, guided latency modeling, mixed CFG/non-CFG serving
parity under every exchange policy, the Pallas stale-KV attention flag, and
the SPMD guidance mesh (subprocess, forced host devices)."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import events as ir
from repro.core import patch_parallel as pp
from repro.core import sampler as sampler_lib
from repro.core import simulate as sim
from repro.core.guidance import GuidancePlan, guidance_groups, split_plan
from repro.core.pipeline import (EXECUTORS, StadiConfig, StadiPipeline,
                                 get_executor, plan_guidance)
from repro.core.planners import PLANNERS, get_planner
from repro.core.schedule import TemporalPlan
from repro.core.simulate import CostModel
from repro.models.diffusion import dit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# The engine≡generate guarantee is bitwise for the reference numerics
# (DESIGN.md §15): the STADI_USE_PALLAS CI leg forces interpret-mode
# kernels process-wide, and XLA fuses the lane-batched engine program
# differently from the unbatched generate program (~1 ULP drift).
# Kernel-on executor parity is asserted with tolerances in
# tests/test_kernel_executors.py.
bitwise_vs_reference = pytest.mark.skipif(
    os.environ.get("STADI_USE_PALLAS", "").strip() not in ("", "0"),
    reason="engine bitwise invariant is defined for reference numerics; "
           "STADI_USE_PALLAS forces kernels process-wide")


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tiny-dit").reduced()      # 2 blocks, 8 token rows
    params = dit.nondegenerate_params(dit.init_params(jax.random.PRNGKey(0),
                                                      cfg))
    sched = sampler_lib.linear_schedule(T=100)
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (2, cfg.latent_size, cfg.latent_size,
                             cfg.channels))
    cond = jnp.array([1, 2])
    return cfg, params, sched, x_T, cond


def _config(speeds, **kw):
    from repro.core.hetero import DeviceProfile
    cluster = tuple(DeviceProfile(f"dev{i}", c=v) for i, v in enumerate(speeds))
    return StadiConfig(cluster=cluster, **kw)


# ----------------------------------------------------------------------
# guidance-group partitioner (satellite: property coverage)
# ----------------------------------------------------------------------

def _check_groups(speeds):
    cond, uncond = guidance_groups(speeds)
    both = cond + uncond
    assert len(set(both)) == len(both)                  # disjoint
    assert sorted(both) == list(range(len(speeds)))     # cover all devices
    assert abs(len(cond) - len(uncond)) <= 1            # pairable sizes
    sc = sum(speeds[i] for i in cond)
    su = sum(speeds[i] for i in uncond)
    assert sc >= su - 1e-9                              # cond = faster group
    # split respects speed ratios: no size-respecting bipartition balances
    # the aggregate speeds strictly better (brute force, n is small here)
    import itertools
    n, size_a = len(speeds), len(speeds) // 2
    best = min(abs(sum(speeds[i] for i in combo)
                   - (sum(speeds) - sum(speeds[i] for i in combo)))
               for combo in itertools.combinations(range(n), size_a))
    assert abs(sc - su) <= best + 1e-9, (cond, uncond, speeds)
    # groups come back fastest-first (the rank pairing order)
    for grp in (cond, uncond):
        vs = [speeds[i] for i in grp]
        assert vs == sorted(vs, reverse=True)


def test_guidance_groups_deterministic():
    for speeds in [[1.0, 0.5], [1.0, 1.0, 0.5, 0.5], [1.0, 0.5, 0.9, 0.4],
                   [2.0, 1.0, 1.0], [0.3] * 5, [4.0, 0.1, 0.1, 0.1]]:
        _check_groups(speeds)
    with pytest.raises(ValueError):
        guidance_groups([1.0])


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                     # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=100, deadline=None)
    @given(speeds=st.lists(st.floats(0.05, 4.0), min_size=2, max_size=8))
    def test_guidance_groups_properties(speeds):
        _check_groups(speeds)

    @settings(max_examples=50, deadline=None)
    @given(speeds=st.lists(st.floats(0.05, 4.0), min_size=2, max_size=8),
           scale=st.floats(0.5, 8.0))
    def test_split_plan_properties(speeds, scale):
        gp = split_plan(speeds, "split", scale)
        assert gp.n_pairs == len(speeds) // 2
        both = gp.cond_devices + gp.uncond_devices
        assert len(set(both)) == len(both)              # pairs disjoint
        ps = gp.pair_speeds(speeds)
        for i, (c, u) in enumerate(zip(gp.cond_devices, gp.uncond_devices)):
            assert ps[i] == min(speeds[c], speeds[u])


def test_guidance_plan_validation():
    with pytest.raises(ValueError, match="cfg_scale"):
        GuidancePlan("fused", 0.0)
    with pytest.raises(ValueError, match="mode"):
        GuidancePlan("both", 1.0)
    with pytest.raises(ValueError, match="disjoint"):
        GuidancePlan("split", 2.0, (0, 1), (1, 2))
    with pytest.raises(ValueError, match="1:1"):
        GuidancePlan("split", 2.0, (0, 1), (2,))
    with pytest.raises(ValueError, match="device groups"):
        GuidancePlan("fused", 2.0, (0,), (1,))
    gp = GuidancePlan("interleaved", 2.0, (0,), (1,), uncond_refresh=3)
    assert [gp.uncond_fresh(i) for i in range(6)] == \
        [True, False, False, True, False, False]
    assert GuidancePlan("split", 2.0, (0,), (1,)).uncond_fresh(5)


# ----------------------------------------------------------------------
# model layer: null cond + fused-batch CFG reference
# ----------------------------------------------------------------------

def test_null_cond_matches_uncond_bitwise(setup):
    cfg, params, _, x_T, _ = setup
    a = dit.forward(params, cfg, x_T, 50.0, jnp.array([-1, -1]))
    b = dit.forward(params, cfg, x_T, 50.0, None)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_positive_cond_unchanged_bitwise(setup):
    """The NULL_COND select must not perturb the existing cond path."""
    cfg, params, _, x_T, cond = setup
    a = dit.forward(params, cfg, x_T, 50.0, cond)
    gathered = params["cond_embed"][np.asarray(cond)]
    assert np.asarray(gathered).any()                  # gather is live
    np.testing.assert_array_equal(
        np.asarray(a), np.asarray(dit.forward(params, cfg, x_T, 50.0,
                                              jnp.asarray(cond))))


def test_cfg_combine_formula():
    ec, eu = jnp.array([3.0]), jnp.array([1.0])
    assert float(sampler_lib.cfg_combine(ec, eu, 2.0)[0]) == 5.0
    assert float(sampler_lib.cfg_combine(ec, eu, 1.0)[0]) == 3.0  # cond-only


def test_single_worker_guided_matches_origin_cfg(setup):
    """One full-row worker under sync == the fused-batch CFG Origin (the
    buffer is fully overwritten fresh every step)."""
    cfg, params, sched, x_T, cond = setup
    plan = TemporalPlan([8], [1], [False], 8, 2)
    res = pp.run_schedule(params, cfg, sched, x_T, cond, plan,
                          [cfg.tokens_per_side],
                          guidance=GuidancePlan("fused", 2.5))
    ref = pp.run_origin_cfg(params, cfg, sched, x_T, cond, 8, 2.5)
    np.testing.assert_allclose(np.asarray(res.image), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------------
# the bitwise contract: split == fused under one schedule
# ----------------------------------------------------------------------

@pytest.mark.parametrize("exchange", ["sync", "stale_async", "predictive"])
def test_split_cfg_bitwise_equals_fused_reference(setup, exchange):
    """Split guidance moves work between devices, never between math: under
    the same (temporal, patches) schedule its output is bitwise-identical
    to the fused-batch CFG reference — the acceptance contract."""
    cfg, params, sched, x_T, cond = setup
    plan = TemporalPlan([8, 6], [1, 2], [False, False], 8, 2)
    patches = [5, 3]
    kw = dict(exchange=exchange, exchange_refresh=2)
    fused = pp.run_schedule(params, cfg, sched, x_T, cond, plan, patches,
                            guidance=GuidancePlan("fused", 2.5), **kw)
    split = pp.run_schedule(params, cfg, sched, x_T, cond, plan, patches,
                            guidance=GuidancePlan("split", 2.5, (0, 1),
                                                  (2, 3)), **kw)
    np.testing.assert_array_equal(np.asarray(fused.image),
                                  np.asarray(split.image))


def test_interleaved_refresh_one_is_split_bitwise(setup):
    """uncond_refresh=1 recomputes every interval — exactly split."""
    cfg, params, sched, x_T, cond = setup
    plan = TemporalPlan([8, 6], [1, 2], [False, False], 8, 2)
    patches = [5, 3]
    gs = GuidancePlan("split", 2.5, (0, 1), (2, 3))
    g1 = GuidancePlan("interleaved", 2.5, (0, 1), (2, 3), uncond_refresh=1)
    a = pp.run_schedule(params, cfg, sched, x_T, cond, plan, patches,
                        guidance=gs)
    b = pp.run_schedule(params, cfg, sched, x_T, cond, plan, patches,
                        guidance=g1)
    np.testing.assert_array_equal(np.asarray(a.image), np.asarray(b.image))


def test_interleaved_reuse_drifts_but_stays_close(setup):
    cfg, params, sched, x_T, cond = setup
    plan = TemporalPlan([8, 6], [1, 2], [False, False], 8, 2)
    patches = [5, 3]
    gs = GuidancePlan("split", 2.0, (0, 1), (2, 3))
    g2 = GuidancePlan("interleaved", 2.0, (0, 1), (2, 3), uncond_refresh=2)
    a = pp.run_schedule(params, cfg, sched, x_T, cond, plan, patches,
                        guidance=gs)
    b = pp.run_schedule(params, cfg, sched, x_T, cond, plan, patches,
                        guidance=g2)
    assert not np.array_equal(np.asarray(a.image), np.asarray(b.image))
    # trace carries the reuse provenance (lcm 2 -> 3 adaptive intervals)
    fresh = [e.uncond_fresh for e in b.trace.events if not e.synchronous]
    assert fresh == [True, False, True]
    assert all(e.uncond_fresh for e in a.trace.events)
    err = float(jnp.abs(a.image - b.image).max())
    assert err < 0.5, err                               # bounded drift


def test_pipefuse_guided_matches_emulated(setup):
    """Single-stage pipefuse guided == emulated guided bitwise; staged
    guided runs with small displaced-context drift."""
    from repro.core import pipefuse as pf
    cfg, params, sched, x_T, cond = setup
    plan = TemporalPlan([8, 6], [1, 2], [False, False], 8, 2)
    patches = [5, 3]
    gp = GuidancePlan("fused", 2.5)
    a = pp.run_schedule(params, cfg, sched, x_T, cond, plan, patches,
                        guidance=gp)
    b = pf.run_pipefuse(params, cfg, sched, x_T, cond, plan, patches,
                        [cfg.n_layers], guidance=gp)
    np.testing.assert_array_equal(np.asarray(a.image), np.asarray(b.image))
    c = pf.run_pipefuse(params, cfg, sched, x_T, cond, plan, patches,
                        [1, 1], guidance=gp)
    assert np.isfinite(np.asarray(c.image)).all()
    rel = (np.linalg.norm(np.asarray(c.image - a.image))
           / np.linalg.norm(np.asarray(a.image)))
    assert rel < 0.05, rel


# ----------------------------------------------------------------------
# IR: GuidanceExchange cadence
# ----------------------------------------------------------------------

def test_guidance_exchange_cadence():
    plan = TemporalPlan([16, 16], [1, 1], [False, False], 16, 4)
    gi = GuidancePlan("interleaved", 2.0, (0,), (1,), uncond_refresh=3)
    evs = list(ir.lower(plan, [4, 4], guidance=gi))
    gx = [e for e in evs if isinstance(e, ir.GuidanceExchange)]
    ci = [e for e in evs if isinstance(e, ir.ComputeInterval)]
    assert len(gx) == len(ci)                       # one per interval
    assert [g.fine_step for g in gx] == [c.fine_step for c in ci]
    assert [g.fresh for g in gx] == [i % 3 == 0 for i in range(len(gx))]
    # every interval of a SPLIT plan is fresh; fused/unguided emit none
    gs = GuidancePlan("split", 2.0, (0,), (1,))
    assert all(e.fresh for e in ir.lower(plan, [4, 4], guidance=gs)
               if isinstance(e, ir.GuidanceExchange))
    assert not any(isinstance(e, ir.GuidanceExchange)
                   for e in ir.lower(plan, [4, 4]))
    assert not any(isinstance(e, ir.GuidanceExchange)
                   for e in ir.lower(plan, [4, 4],
                                     guidance=GuidancePlan("fused", 2.0)))
    # replay folds the verdicts into the trace records
    recs = [r for r in ir.replay(plan, [4, 4], guidance=gi)
            if not r.synchronous]
    assert [r.uncond_fresh for r in recs] == \
        [i % 3 == 0 for i in range(len(recs))]


# ----------------------------------------------------------------------
# registries (satellite: KeyError listings name the guidance entries)
# ----------------------------------------------------------------------

def test_registry_errors_list_guidance_names():
    assert "stadi_guidance" in PLANNERS
    assert "spmd_guidance" in EXECUTORS
    with pytest.raises(KeyError, match="stadi_guidance"):
        get_planner("nope")
    with pytest.raises(KeyError, match="spmd_guidance"):
        get_executor("nope")


# ----------------------------------------------------------------------
# planner + pipeline wiring
# ----------------------------------------------------------------------

def test_stadi_guidance_planner_modes():
    knobs = _config([1.0, 1.0, 0.5, 0.5], m_base=16, m_warmup=4,
                    planner="stadi_guidance", cfg_scale=2.0)
    for mode in ("fused", "split", "interleaved"):
        plan = get_planner("stadi_guidance")(
            knobs.speeds, dataclasses.replace(knobs, guidance=mode), 8)
        assert plan.guidance.mode == mode
        assert plan.planner == "stadi_guidance"
        assert plan.modeled_interval_cost is not None
        if mode != "fused":
            assert len(plan.patches) == 2           # pair workers
            assert sum(plan.patches) == 8
    with pytest.raises(ValueError, match="cfg_scale"):
        get_planner("stadi_guidance")(
            [1.0, 0.5], dataclasses.replace(knobs, cfg_scale=0.0), 8)


def test_stadi_guidance_auto_picks_split_when_comm_bound():
    """Fused CFG serializes both branches' staged K/V on one fabric; under
    the comm-bound 2-tier profile the planner must pick split."""
    cm = CostModel(t_fixed=5e-3, t_row=5.5e-4, link_bw=1.25e9,
                   link_latency=50e-6)
    cfg = get_config("sdxl-dit")
    config = _config([1.0, 1.0, 0.5, 0.5], m_base=16, m_warmup=4,
                     planner="stadi_guidance", cfg_scale=5.0,
                     cost_model=cm, granularity=2)
    plan = StadiPipeline(cfg, None, None,
                         dataclasses.replace(config,
                                             backend="simulate")).plan()
    assert plan.guidance.mode == "split"
    # compute-bound default: fused keeps all devices busy
    plan2 = get_planner("stadi_guidance")(config.speeds, config, 8)
    assert plan2.guidance.mode == "fused"


def test_guided_simulate_split_beats_fused():
    cm = CostModel(t_fixed=5e-3, t_row=5.5e-4, link_bw=1.25e9,
                   link_latency=50e-6)
    cfg = get_config("sdxl-dit")
    base = _config([1.0, 1.0, 0.5, 0.5], m_base=32, m_warmup=4,
                   planner="stadi_guidance", cfg_scale=5.0,
                   backend="simulate", cost_model=cm, granularity=2)
    lat = {}
    for mode in ("fused", "split"):
        res = StadiPipeline(cfg, None, None,
                            dataclasses.replace(base,
                                                guidance=mode)).generate()
        assert res.trace.guidance.mode == mode
        lat[mode] = res.latency_s
    assert lat["split"] < 0.8 * lat["fused"], lat   # >= 20% modeled win


def test_plan_guidance_wiring_and_errors(setup):
    cfg, params, sched, x_T, cond = setup
    config = _config([1.0, 0.5], m_base=8, m_warmup=2, cfg_scale=2.0)
    plan = StadiPipeline(cfg, params, sched, config).plan()
    # the unified plan() populates the guidance axis (--cfg-scale wiring)
    assert plan.guidance.mode == "fused" and plan.guidance.scale == 2.0
    with pytest.warns(DeprecationWarning):      # shim resolves identically
        assert plan_guidance(plan, config) == plan.guidance
    unguided = StadiPipeline(
        cfg, params, sched,
        dataclasses.replace(config, cfg_scale=0.0)).plan()
    assert unguided.guidance is None
    with pytest.raises(ValueError, match="stadi_guidance"):
        StadiPipeline(cfg, params, sched,
                      dataclasses.replace(config, guidance="split")).plan()
    with pytest.raises(ValueError, match="cfg_scale"):
        StadiPipeline(cfg, params, sched,
                      dataclasses.replace(config, cfg_scale=0.0,
                                          guidance="fused"))
    with pytest.raises(ValueError, match="rebalancing"):
        StadiPipeline(cfg, params, sched,
                      dataclasses.replace(config, rebalance_every=2))
    # backend gating
    with pytest.raises(ValueError, match="spmd_guidance"):
        StadiPipeline(cfg, params, sched,
                      dataclasses.replace(config, cfg_scale=0.0,
                                          backend="spmd_guidance")
                      ).generate(x_T, cond)
    split_cfg = _config([1.0, 1.0, 0.5, 0.5], m_base=8, m_warmup=2,
                        planner="stadi_guidance", cfg_scale=2.0,
                        guidance="split", backend="spmd")
    with pytest.raises(ValueError, match="guidance mesh"):
        StadiPipeline(cfg, params, sched, split_cfg).generate(x_T, cond)


def test_guided_generate_needs_cond(setup):
    cfg, params, sched, x_T, _ = setup
    config = _config([1.0, 0.5], m_base=8, m_warmup=2, cfg_scale=2.0)
    with pytest.raises(ValueError, match="condition"):
        StadiPipeline(cfg, params, sched, config).generate(x_T, None)


# ----------------------------------------------------------------------
# serving: mixed CFG / non-CFG lanes, per-request bitwise parity
# ----------------------------------------------------------------------

@bitwise_vs_reference
@pytest.mark.parametrize("exchange", ["sync", "stale_async", "predictive"])
def test_serving_mixed_cfg_bitwise_vs_generate(setup, exchange):
    """The acceptance contract: a mixed batch of CFG and non-CFG requests
    drains with every request bitwise-identical to a single-request
    ``generate`` under each exchange policy."""
    from repro.serving.diffusion_engine import DiffusionServingEngine
    cfg, params, sched, *_ = setup
    config = _config([1.0, 0.5], m_base=8, m_warmup=2, exchange=exchange)
    pipe = StadiPipeline(cfg, params, sched, config)
    engine = DiffusionServingEngine(pipe, slots=3)
    subs = []
    for uid in range(5):
        x = jax.random.normal(jax.random.PRNGKey(20 + uid),
                              (1, cfg.latent_size, cfg.latent_size,
                               cfg.channels))
        scale = 2.5 if uid % 2 == 0 else None
        subs.append((engine.submit(x, uid % cfg.n_classes,
                                   cfg_scale=scale), x, uid, scale))
    engine.run_to_completion()
    for req, x, uid, scale in subs:
        ref_cfg = dataclasses.replace(config, cfg_scale=scale or 0.0)
        ref = StadiPipeline(cfg, params, sched, ref_cfg).generate(
            x, jnp.array([uid % cfg.n_classes])).image
        np.testing.assert_array_equal(np.asarray(req.image),
                                      np.asarray(ref))


@bitwise_vs_reference
def test_serving_guided_bootstrap_no_warmup(setup):
    from repro.serving.diffusion_engine import DiffusionServingEngine
    cfg, params, sched, *_ = setup
    config = _config([1.0, 0.5], m_base=6, m_warmup=0)
    pipe = StadiPipeline(cfg, params, sched, config)
    engine = DiffusionServingEngine(pipe, slots=2)
    x = jax.random.normal(jax.random.PRNGKey(3),
                          (1, cfg.latent_size, cfg.latent_size,
                           cfg.channels))
    req = engine.submit(x, 4, cfg_scale=3.0)
    engine.run_to_completion()
    ref = StadiPipeline(cfg, params, sched,
                        dataclasses.replace(config, cfg_scale=3.0)
                        ).generate(x, jnp.array([4])).image
    np.testing.assert_array_equal(np.asarray(req.image), np.asarray(ref))


def test_serving_default_scale_and_guards(setup):
    from repro.serving.diffusion_engine import DiffusionServingEngine
    cfg, params, sched, *_ = setup
    # config-level cfg_scale becomes the default for every request
    config = _config([1.0, 0.5], m_base=8, m_warmup=2, cfg_scale=2.0)
    pipe = StadiPipeline(cfg, params, sched, config)
    engine = DiffusionServingEngine(pipe, slots=2)
    x = jax.random.normal(jax.random.PRNGKey(5),
                          (1, cfg.latent_size, cfg.latent_size,
                           cfg.channels))
    req = engine.submit(x, 1)
    assert req.guided and req.cfg_scale == 2.0
    # split placement is a first-class serving mode (DESIGN.md §14): the
    # engine runs pair-cohort lanes with the plan's device pairing
    split_cfg = _config([1.0, 1.0, 0.5, 0.5], m_base=8, m_warmup=2,
                        planner="stadi_guidance", cfg_scale=2.0,
                        guidance="split")
    split_engine = DiffusionServingEngine(
        StadiPipeline(cfg, params, sched, split_cfg), slots=2)
    assert split_engine.plan.guidance.mode == "split"
    assert split_engine._guide_pairs is not None
    # interleaved uncond reuse stays per-generation
    inter_cfg = dataclasses.replace(split_cfg, guidance="interleaved")
    with pytest.raises(ValueError, match="interleaved"):
        DiffusionServingEngine(StadiPipeline(cfg, params, sched, inter_cfg),
                               slots=2)


@bitwise_vs_reference
@pytest.mark.parametrize("exchange", ["sync", "stale_async", "predictive"])
def test_serving_split_guidance_bitwise_vs_generate(setup, exchange):
    """Tentpole acceptance (DESIGN.md §14): split-guidance serving lane
    cohorts stay per-request bitwise-identical to single-request
    ``generate`` under every exchange policy — split repartitions WHERE
    the branches run (device pairs, eps exchanged between dispatches),
    never WHAT is computed."""
    from repro.serving.diffusion_engine import DiffusionServingEngine
    cfg, params, sched, *_ = setup
    config = _config([1.0, 1.0, 0.5, 0.5], m_base=8, m_warmup=2,
                     planner="stadi_guidance", cfg_scale=2.0,
                     guidance="split", exchange=exchange)
    pipe = StadiPipeline(cfg, params, sched, config)
    engine = DiffusionServingEngine(pipe, slots=3)
    subs = []
    for uid in range(4):
        x = jax.random.normal(jax.random.PRNGKey(50 + uid),
                              (1, cfg.latent_size, cfg.latent_size,
                               cfg.channels))
        subs.append((engine.submit(x, uid % cfg.n_classes), x, uid))
    engine.run_to_completion()
    for req, x, uid in subs:
        ref = pipe.generate(x, jnp.array([uid % cfg.n_classes])).image
        np.testing.assert_array_equal(np.asarray(req.image),
                                      np.asarray(ref))


def test_serving_guidance_aware_replanning_improves_throughput(setup):
    """Tentpole acceptance (DESIGN.md §14): after an injected speed drift
    on the comm-bound 2-tier profile, engine replanning — which re-pairs
    the cond/uncond device groups via the stadi_guidance planner — must
    improve modeled drain throughput by >= 15% over the frozen plan."""
    from repro.serving.diffusion_engine import DiffusionServingEngine
    cfg, params, sched, *_ = setup
    cm = CostModel(t_fixed=5e-3, t_row=5.5e-4, link_bw=1.25e9,
                   link_latency=50e-6)
    config = _config([1.0, 1.0, 0.5, 0.5], m_base=16, m_warmup=2,
                     planner="stadi_guidance", cfg_scale=2.0,
                     guidance="split", cost_model=cm)
    pipe = StadiPipeline(cfg, params, sched, config)
    measured = [1.0, 0.1, 0.5, 0.5]        # device 1 fell off a cliff
    xs = [jax.random.normal(jax.random.PRNGKey(70 + i),
                            (1, cfg.latent_size, cfg.latent_size,
                             cfg.channels)) for i in range(6)]

    def drain(**kw):
        engine = DiffusionServingEngine(pipe, slots=4,
                                        measured_speeds=measured, **kw)
        for i, x in enumerate(xs):
            engine.submit(x, i % cfg.n_classes)
        engine.run_to_completion()
        return engine

    frozen = drain()
    live = drain(rebalance_every=1)
    assert frozen.stats()["replans"] == 0
    assert live.stats()["replans"] >= 1
    # the replanner actually re-paired the branch groups at least once
    pairings = {(ev.plan.guidance.cond_devices,
                 ev.plan.guidance.uncond_devices) for ev in live.replans}
    base_pairing = (frozen.plan.guidance.cond_devices,
                    frozen.plan.guidance.uncond_devices)
    assert pairings - {base_pairing}
    t_frozen = frozen.stats()["throughput_modeled_rps"]
    t_live = live.stats()["throughput_modeled_rps"]
    assert t_live >= 1.15 * t_frozen, (t_frozen, t_live)


@bitwise_vs_reference
def test_generate_many_guided_matches_generate(setup):
    cfg, params, sched, *_ = setup
    config = _config([1.0, 0.5], m_base=8, m_warmup=2, cfg_scale=2.0)
    pipe = StadiPipeline(cfg, params, sched, config)
    xs = [jax.random.normal(jax.random.PRNGKey(30 + i),
                            (1, cfg.latent_size, cfg.latent_size,
                             cfg.channels)) for i in range(3)]
    conds = [jnp.array([i]) for i in range(3)]
    results = pipe.generate_many(xs, conds, slots=2)
    for x, c, res in zip(xs, conds, results):
        ref = pipe.generate(x, c).image
        np.testing.assert_array_equal(np.asarray(res.image),
                                      np.asarray(ref))


# ----------------------------------------------------------------------
# Pallas stale-KV attention flag (satellite)
# ----------------------------------------------------------------------

def test_pallas_attention_parity(setup):
    """use_pallas_attention swaps the buffered attend for the fused
    freshness-select kernel (interpret mode): same schedule, tight
    tolerance (flash online softmax vs reference softmax)."""
    cfg, params, sched, x_T, cond = setup
    base = _config([1.0, 0.5], m_base=8, m_warmup=2)
    ref = StadiPipeline(cfg, params, sched, base).generate(x_T, cond).image
    out = StadiPipeline(cfg, params, sched,
                        dataclasses.replace(base, use_pallas_attention=True)
                        ).generate(x_T, cond).image
    assert not np.shares_memory(np.asarray(out), np.asarray(ref))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_pallas_attention_guided_parity(setup):
    cfg, params, sched, x_T, cond = setup
    base = _config([1.0, 0.5], m_base=8, m_warmup=2, cfg_scale=2.0)
    ref = StadiPipeline(cfg, params, sched, base).generate(x_T, cond).image
    out = StadiPipeline(cfg, params, sched,
                        dataclasses.replace(base, use_pallas_attention=True)
                        ).generate(x_T, cond).image
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_pallas_block_gating():
    """Static layouts get the compile-time kernel; traced offsets and SPMD
    scratch padding route to the padded scalar-prefetch kernel; layouts no
    tile fits fall back — with the decision recorded in the counters."""
    from repro.kernels import ops as kops
    cfg = get_config("tiny-dit").reduced().replace(use_pallas_attention=True)
    before = kops.kernel_stats_snapshot()
    assert dit._pallas_block(cfg, 0, 40, 64, None, None) == ("static", 8)
    assert dit._pallas_block(cfg, 24, 40, 64, None, None) == ("static", 8)
    # traced offsets / valid_tokens now hit the padded kernel (wp=8 tiles)
    assert dit._pallas_block(cfg, jnp.int32(0), 40, 64, None, None) == ("padded", 8)
    assert dit._pallas_block(cfg, 0, 40, 64, jnp.int32(40), None) == ("padded", 8)
    assert dit._pallas_block(cfg, 4, 40, 64, None, None) == ("off", 0)  # gcd 4 < 8
    # padded layouts must tile by tokens_per_side
    assert dit._pallas_block(cfg, jnp.int32(0), 44, 64, None, None) == ("off", 0)
    off = cfg.replace(use_pallas_attention=False)
    assert dit._pallas_block(off, 0, 40, 64, None, None) == ("off", 0)
    delta = kops.kernel_stats_delta(before, kops.kernel_stats_snapshot())
    assert delta["hits"]["stale_kv.static"] == 2
    assert delta["hits"]["stale_kv.padded"] == 2
    assert delta["misses"]["tile-too-small"] == 1
    assert delta["misses"]["padding-misaligned"] == 1


# ----------------------------------------------------------------------
# SPMD guidance mesh (subprocess, forced host devices)
# ----------------------------------------------------------------------

SPMD_GUIDANCE_SCRIPT = textwrap.dedent("""
    from repro.hostenv import force_host_devices
    force_host_devices()
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.core import patch_parallel as pp, sampler as sampler_lib
    from repro.core import spmd
    from repro.core.guidance import GuidancePlan
    from repro.core.schedule import TemporalPlan
    from repro.models.diffusion import dit

    cfg = get_config("tiny-dit").reduced()
    params = dit.nondegenerate_params(
        dit.init_params(jax.random.PRNGKey(0), cfg))
    sched = sampler_lib.linear_schedule(T=100)
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (2, cfg.latent_size, cfg.latent_size,
                             cfg.channels))
    cond = jnp.array([1, 2])
    plan = TemporalPlan([8, 6], [1, 2], [False, False], 8, 2)
    patches = [5, 3]

    gf = GuidancePlan("fused", 2.5)
    ref = pp.run_schedule(params, cfg, sched, x_T, cond, plan, patches,
                          guidance=gf).image
    img = spmd.run_spmd(params, cfg, sched, x_T, cond, plan, patches,
                        guidance=gf)
    err = float(np.linalg.norm(np.asarray(img) - np.asarray(ref))
                / np.linalg.norm(np.asarray(ref)))
    assert err < 1e-3, ("fused", err)

    gs = GuidancePlan("split", 2.5, (0, 1), (2, 3))
    ref2 = pp.run_schedule(params, cfg, sched, x_T, cond, plan, patches,
                           guidance=gs).image
    img2 = spmd.run_spmd_guidance(params, cfg, sched, x_T, cond, plan,
                                  patches, gs)
    err2 = float(np.linalg.norm(np.asarray(img2) - np.asarray(ref2))
                 / np.linalg.norm(np.asarray(ref2)))
    assert err2 < 1e-3, ("split", err2)
    print("OK", err, err2)
""")


@pytest.mark.slow
def test_spmd_guidance_subprocess():
    env = dict(os.environ, STADI_HOST_DEVICES="4",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", SPMD_GUIDANCE_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


def test_run_spmd_guidance_validation(setup):
    from repro.core import spmd
    cfg, params, sched, x_T, cond = setup
    plan = TemporalPlan([8], [1], [False], 8, 2)
    with pytest.raises(ValueError, match="split"):
        spmd.run_spmd_guidance(params, cfg, sched, x_T, cond, plan, [8],
                               GuidancePlan("fused", 2.0))
    with pytest.raises(ValueError, match="interleaved"):
        spmd.run_spmd_guidance(params, cfg, sched, x_T, cond, plan, [8],
                               GuidancePlan("interleaved", 2.0, (0,), (1,)))


# ----------------------------------------------------------------------
# guided trace provenance
# ----------------------------------------------------------------------

def test_simulate_staged_guided_charges_both_branches():
    """A guided displaced-pipeline trace (pipefuse + CFG) must cost more
    than the unguided one: both branches stream through the chain."""
    plan = TemporalPlan([8, 6], [1, 2], [False, False], 8, 2)
    cfg = get_config("tiny-dit").reduced()
    cm = CostModel(t_fixed=1e-3, t_row=1e-3)
    base = sim.simulate_trace(
        sim.build_trace(plan, [5, 3], cfg, stages=[1, 1]), [1.0, 0.5], cm)
    guided = sim.simulate_trace(
        sim.build_trace(plan, [5, 3], cfg, stages=[1, 1],
                        guidance=GuidancePlan("fused", 2.0)),
        [1.0, 0.5], cm)
    assert guided > base * 1.5, (guided, base)


def test_build_trace_guidance_provenance():
    plan = TemporalPlan([8, 6], [1, 2], [False, False], 8, 2)
    cfg = get_config("tiny-dit").reduced()
    gp = GuidancePlan("interleaved", 2.0, (0, 1), (2, 3), uncond_refresh=2)
    trace = sim.build_trace(plan, [5, 3], cfg, guidance=gp)
    assert trace.guidance is gp
    fresh = [e.uncond_fresh for e in trace.events if not e.synchronous]
    assert fresh == [True, False, True]
    # the emulated engine's trace carries the identical records
    params = dit.init_params(jax.random.PRNGKey(0), cfg)
    sched = sampler_lib.linear_schedule(T=100)
    x_T = jnp.zeros((1, cfg.latent_size, cfg.latent_size, cfg.channels))
    res = pp.run_schedule(params, cfg, sched, x_T, jnp.array([0]), plan,
                          [5, 3], guidance=gp)
    got = [(e.fine_step, tuple(e.substeps), e.exchange, e.uncond_fresh)
           for e in res.trace.events]
    want = [(e.fine_step, tuple(e.substeps), e.exchange, e.uncond_fresh)
            for e in trace.events]
    assert got == want
