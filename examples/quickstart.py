"""Quickstart: STADI in ~40 lines.

One config object, one pipeline, one call: plans steps (Eq. 4) + patches
(Eq. 5) for a heterogeneous 2-"GPU" cluster, runs the exact-numerics engine
on a tiny DiT, and compares the result against non-distributed DDIM.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import patch_parallel, sampler
from repro.core.pipeline import StadiConfig, StadiPipeline
from repro.models.diffusion import dit

# 1. a heterogeneous cluster: device 1 is 60%-occupied by background work
config = StadiConfig.from_occupancies([0.0, 0.6], m_base=16, m_warmup=4,
                                      planner="stadi", backend="emulated")
print(f"effective speeds: {config.speeds}")

# 2. a small denoiser + schedule
cfg = get_config("tiny-dit").reduced()
params = dit.init_params(jax.random.PRNGKey(0), cfg)
sched = sampler.linear_schedule(T=1000)
x_T = jax.random.normal(jax.random.PRNGKey(1),
                        (1, cfg.latent_size, cfg.latent_size, cfg.channels))
cond = jnp.asarray([3])

# 3. STADI: temporal + spatial adaptation (Algorithm 1) in one call
pipe = StadiPipeline(cfg, params, sched, config)
result = pipe.generate(x_T, cond)
print(f"steps per device:   {result.plan.temporal.steps}")
print(f"patch rows per dev: {result.plan.patches}")

# 4. compare with the non-distributed Origin trajectory
origin = patch_parallel.run_origin(params, cfg, sched, x_T, cond, m_base=16)
rel = np.linalg.norm(np.asarray(result.image) - np.asarray(origin)) \
    / np.linalg.norm(np.asarray(origin))
print(f"relative deviation from Origin: {rel:.4f} (stale-KV + mixed-rate)")
assert np.all(np.isfinite(np.asarray(result.image)))
print("ok")
