"""End-to-end heterogeneous serving driver — the paper's headline scenario.

Serves a batch of class-conditional generation requests on an emulated
2-device cluster under increasing occupancy skew, comparing Patch
Parallelism (DistriFusion), Tensor Parallelism and STADI on latency
(calibrated simulator) and quality (vs the Origin output). Uses the trained
tiny-DiT checkpoint when available (examples/train_tiny_diffusion.py).

  PYTHONPATH=src python examples/heterogeneous_stadi.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import hetero, patch_parallel as pp, simulate as sim, stadi
from benchmarks.bench_latency import M_WARMUP as _MW, build_trace

M_BASE, M_WARMUP = 48, 4


def main():
    cfg, params, sched = common.load_tiny_dit()
    cm = common.calibrate_cost_model(cfg, params)
    rng = np.random.default_rng(0)
    n_req = 2
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (n_req, cfg.latent_size, cfg.latent_size, cfg.channels))
    cond = jnp.asarray(rng.integers(0, cfg.n_classes, n_req))

    print(f"{'occupancy':>12} {'PP (s)':>8} {'TP (s)':>8} {'STADI (s)':>9} "
          f"{'reduction':>9} {'qual dev':>9}")
    for occ in ([0.0, 0.2], [0.0, 0.4], [0.0, 0.6]):
        speeds = hetero.speeds(hetero.make_cluster(occ))
        res = stadi.stadi_infer(params, cfg, sched, x_T, cond, speeds,
                                M_BASE, M_WARMUP)
        t_st = sim.simulate_trace(res.trace, speeds, cm)
        res_pp = pp.run_distrifusion(params, cfg, sched, x_T, cond, 2,
                                     M_BASE, M_WARMUP)
        t_pp = sim.simulate_trace(res_pp.trace, speeds, cm)
        t_tp = sim.simulate_tensor_parallel(
            M_BASE, 2, cfg.n_layers, cfg.tokens_per_side, speeds, cm,
            cfg.n_tokens * cfg.d_model * 2)
        origin = np.asarray(pp.run_origin(params, cfg, sched, x_T, cond, M_BASE))
        dev = np.linalg.norm(np.asarray(res.image) - origin) / np.linalg.norm(origin)
        red = (1 - t_st / t_pp) * 100
        print(f"{str(occ):>12} {t_pp:8.2f} {t_tp:8.2f} {t_st:9.2f} "
              f"{red:8.1f}% {dev:9.4f}")
    print("\nSTADI matches the paper's behaviour: latency drops with skew, "
          "quality stays near the Origin trajectory.")


if __name__ == "__main__":
    main()
