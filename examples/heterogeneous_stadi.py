"""End-to-end heterogeneous serving driver — the paper's headline scenario.

Serves a batch of class-conditional generation requests on an emulated
2-device cluster under increasing occupancy skew, comparing Patch
Parallelism (DistriFusion), Tensor Parallelism and STADI on latency
(calibrated simulator) and quality (vs the Origin output) — all through
``StadiPipeline`` by swapping the planner name. Uses the trained tiny-DiT
checkpoint when available (examples/train_tiny_diffusion.py).

  PYTHONPATH=src python examples/heterogeneous_stadi.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import patch_parallel as pp
from repro.core import simulate as sim
from repro.core.pipeline import StadiConfig, StadiPipeline

M_BASE, M_WARMUP = 48, 4


def main():
    cfg, params, sched = common.load_tiny_dit()
    cm = common.calibrate_cost_model(cfg, params)
    rng = np.random.default_rng(0)
    n_req = 2
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (n_req, cfg.latent_size, cfg.latent_size, cfg.channels))
    cond = jnp.asarray(rng.integers(0, cfg.n_classes, n_req))

    print(f"{'occupancy':>12} {'PP (s)':>8} {'TP (s)':>8} {'STADI (s)':>9} "
          f"{'reduction':>9} {'qual dev':>9}")
    for occ in ([0.0, 0.2], [0.0, 0.4], [0.0, 0.6]):
        config = StadiConfig.from_occupancies(occ, m_base=M_BASE,
                                              m_warmup=M_WARMUP,
                                              cost_model=cm)
        stadi_pipe = StadiPipeline(cfg, params, sched, config)
        res = stadi_pipe.generate(x_T, cond)
        t_st = res.latency_s
        pp_pipe = StadiPipeline(cfg, params, sched,
                                dataclasses.replace(config, planner="uniform"))
        t_pp = pp_pipe.generate(x_T, cond).latency_s
        t_tp = sim.simulate_tensor_parallel(
            M_BASE, 2, cfg.n_layers, cfg.tokens_per_side, config.speeds, cm,
            cfg.n_tokens * cfg.d_model * 2)
        origin = np.asarray(pp.run_origin(params, cfg, sched, x_T, cond, M_BASE))
        dev = np.linalg.norm(np.asarray(res.image) - origin) / np.linalg.norm(origin)
        red = (1 - t_st / t_pp) * 100
        print(f"{str(occ):>12} {t_pp:8.2f} {t_tp:8.2f} {t_st:9.2f} "
              f"{red:8.1f}% {dev:9.4f}")
    print("\nSTADI matches the paper's behaviour: latency drops with skew, "
          "quality stays near the Origin trajectory.")


if __name__ == "__main__":
    main()
