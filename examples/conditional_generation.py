"""Conditional generation with classifier-free guidance on a heterogeneous
cluster (DESIGN.md §12).

Quickstart
----------

    PYTHONPATH=src python examples/conditional_generation.py       # ~1 min
    PYTHONPATH=src python examples/conditional_generation.py \
        --cfg-scale 4.0 --guidance split --occupancies 0.0,0.0,0.5,0.5

What this shows
---------------

1.  Every real diffusion deployment runs CFG: two denoiser evaluations per
    fine step (class-conditional + unconditional), combined as
    ``eps = eps_u + w * (eps_c - eps_u)``. ``dit.forward_cfg`` is the
    fused-batch reference; the schedule-level entry point is just
    ``StadiConfig(cfg_scale=w)``.
2.  Guidance is a SCHEDULING dimension: the ``stadi_guidance`` planner
    chooses between
      - fused: every patch worker computes both branches (one
        branch-vmapped dispatch),
      - split: cond and uncond assigned to disjoint device groups sized by
        aggregate effective speed — only the epsilon combine crosses the
        group boundary, each branch's staged K/V stays home,
      - interleaved: split + straggler pairs reuse the cached guidance
        delta (eps_c - eps_u) on non-refresh intervals, idling their slow
        uncond device (quality-lossy, benchmarked < 1 dB).
3.  Split guidance is bitwise-identical to the fused-batch reference under
    one schedule — the demo checks it, plus proximity to the exact CFG
    Origin.
4.  The same request shape flows through serving: ``--serve`` drains a
    mixed CFG / non-CFG queue through the DiffusionServingEngine with
    per-lane guidance state.

CLI twins: ``python -m repro.launch.stadi_infer --cfg-scale 4 --guidance
split --planner stadi_guidance`` and ``python -m repro.launch.serve
--diffusion --cfg-scale 4``.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--occupancies", default="0.0,0.0,0.5,0.5")
    ap.add_argument("--cfg-scale", type=float, default=3.0)
    ap.add_argument("--guidance", default="none",
                    choices=["none", "fused", "split", "interleaved"],
                    help="'none' lets the stadi_guidance planner choose")
    ap.add_argument("--cond", type=int, default=7)
    ap.add_argument("--m-base", type=int, default=16)
    ap.add_argument("--m-warmup", type=int, default=4)
    ap.add_argument("--serve", action="store_true",
                    help="also drain a mixed CFG/non-CFG serving queue")
    args = ap.parse_args()

    import dataclasses

    from repro.configs import get_config
    from repro.core import patch_parallel as pp
    from repro.core import sampler as sampler_lib
    from repro.core.pipeline import StadiConfig, StadiPipeline
    from repro.models.diffusion import dit

    cfg = get_config("tiny-dit").reduced()
    params = dit.nondegenerate_params(
        dit.init_params(jax.random.PRNGKey(0), cfg))
    sched = sampler_lib.linear_schedule(T=1000)
    occ = [float(x) for x in args.occupancies.split(",")]
    B = 1
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (B, cfg.latent_size, cfg.latent_size,
                             cfg.channels))
    cond = jnp.full((B,), args.cond % cfg.n_classes, jnp.int32)

    # 1) the guided pipeline: one config knob turns CFG on
    config = StadiConfig.from_occupancies(
        occ, m_base=args.m_base, m_warmup=args.m_warmup,
        planner="stadi_guidance", cfg_scale=args.cfg_scale,
        guidance=args.guidance)
    pipe = StadiPipeline(cfg, params, sched, config)
    plan = pipe.plan()
    gp = plan.guidance                   # plan() populates every axis
    print(f"cluster speeds {config.speeds} -> guidance mode {gp.mode!r} "
          f"(scale {gp.scale})")
    if gp.mode != "fused":
        print(f"  cond devices   {gp.cond_devices}\n"
              f"  uncond devices {gp.uncond_devices}  "
              f"(pair i computes patch worker i's slab, one branch each)")
    print(f"  steps {plan.temporal.steps} ratios {plan.temporal.ratios} "
          f"patches {plan.patches}")

    res = pipe.generate(x_T, cond)
    img = np.asarray(res.image)
    print(f"guided image {img.shape} finite={np.isfinite(img).all()}")

    # 2) split CFG == fused-batch CFG reference, bitwise, under one schedule
    if gp.mode == "split":
        fused_same_plan = pp.run_schedule(
            params, cfg, sched, x_T, cond, plan.temporal, plan.patches,
            guidance=dataclasses.replace(gp, mode="fused", cond_devices=(),
                                         uncond_devices=()))
        same = np.array_equal(img, np.asarray(fused_same_plan.image))
        print(f"split == fused-batch reference (same schedule): "
              f"bitwise {'OK' if same else 'MISMATCH'}")
        assert same

    # 3) proximity to the exact CFG Origin (no patching, no staleness)
    origin = np.asarray(pp.run_origin_cfg(params, cfg, sched, x_T, cond,
                                          args.m_base, args.cfg_scale))
    mse = float(np.mean((img - origin) ** 2))
    psnr = 10 * np.log10(float((origin.max() - origin.min()) ** 2) / mse)
    print(f"PSNR vs fused-batch CFG Origin: {psnr:.1f} dB")

    # 4) optional: a mixed CFG / non-CFG serving queue
    if args.serve:
        from repro.serving import DiffusionServingEngine
        serve_cfg = StadiConfig.from_occupancies(
            occ[:2], m_base=args.m_base, m_warmup=args.m_warmup)
        engine = DiffusionServingEngine(
            StadiPipeline(cfg, params, sched, serve_cfg), slots=3)
        for uid in range(6):
            x = jax.random.normal(jax.random.PRNGKey(10 + uid),
                                  (1, cfg.latent_size, cfg.latent_size,
                                   cfg.channels))
            engine.submit(x, uid % cfg.n_classes,
                          cfg_scale=args.cfg_scale if uid % 2 == 0 else None)
        done = engine.run_to_completion()
        guided = sum(1 for r in done if r.guided)
        print(f"served {len(done)} requests ({guided} CFG / "
              f"{len(done) - guided} plain) in "
              f"{engine.stats()['rounds']} rounds")


if __name__ == "__main__":
    main()
