"""Serve a queue of diffusion requests with continuous batching.

Quickstart
----------

    PYTHONPATH=src python examples/serve_diffusion.py            # ~1 min
    PYTHONPATH=src python examples/serve_diffusion.py --requests 10 \
        --slots 4 --occupancies 0.0,0.55 --slo-ms 150

What this shows
---------------

1.  Build a :class:`StadiPipeline` for a 2-device heterogeneous cluster
    (occupancy 0 vs 55% -> effective speeds 1.0 vs 0.45, so the STADI
    planner gives the slow device half the steps and a smaller patch).
2.  Wrap it in a :class:`DiffusionServingEngine` with a fixed number of
    request *slots* — the diffusion analogue of continuous batching: a FIFO
    queue feeds free slots every scheduling round, and all in-flight
    requests (each at its OWN position on the noise schedule) share one
    vmapped denoise dispatch per round.
3.  Submit requests in two waves so admissions interleave with requests
    already mid-denoise, then drain and print per-request queueing /
    service rounds, modeled cluster latency, and SLO hits.
4.  Verify the serving fast path changes nothing: request 0's image is
    bitwise identical to a lone ``pipe.generate`` call.

Expected output: a table like

    uid  queued  served  modeled-latency  slo
      0       0       6          43.9ms  met
    ...
    throughput: N img/s wall / M img/s modeled; bitwise parity OK
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--occupancies", default="0.0,0.55")
    ap.add_argument("--m-base", type=int, default=16)
    ap.add_argument("--m-warmup", type=int, default=4)
    ap.add_argument("--slo-ms", type=float, default=None)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.core import sampler as sampler_lib
    from repro.core.pipeline import StadiConfig, StadiPipeline
    from repro.models.diffusion import dit
    from repro.serving import DiffusionServingEngine

    cfg = get_config("tiny-dit").reduced()
    params = dit.init_params(jax.random.PRNGKey(0), cfg)
    sched = sampler_lib.linear_schedule(T=1000)
    occ = [float(x) for x in args.occupancies.split(",")]
    config = StadiConfig.from_occupancies(occ, m_base=args.m_base,
                                          m_warmup=args.m_warmup)
    pipe = StadiPipeline(cfg, params, sched, config)
    engine = DiffusionServingEngine(pipe, slots=args.slots)
    print(f"cluster speeds {config.speeds} -> steps "
          f"{engine.plan.temporal.steps}, patches {engine.plan.patches}")

    rng = np.random.default_rng(0)
    xs = [jax.random.normal(jax.random.PRNGKey(1 + i),
                            (1, cfg.latent_size, cfg.latent_size,
                             cfg.channels)) for i in range(args.requests)]
    conds = [int(c) for c in rng.integers(0, cfg.n_classes, args.requests)]
    slo_s = args.slo_ms / 1e3 if args.slo_ms is not None else None

    # wave 1 fills the slots; wave 2 queues and is admitted mid-flight,
    # joining lanes that are already several denoise steps ahead
    wave1 = args.requests // 2
    for i in range(wave1):
        engine.submit(xs[i], conds[i], slo_s=slo_s)
    engine.step()
    engine.step()
    for i in range(wave1, args.requests):
        engine.submit(xs[i], conds[i], slo_s=slo_s)
    done = engine.run_to_completion()

    stats = engine.stats()
    print("\nuid  queued  served  modeled-latency  slo")
    for r in stats["requests"]:
        slo = {None: "-", True: "met", False: "MISSED"}[r["slo_met"]]
        print(f"{r['uid']:3d}  {r['queue_rounds']:6d}  "
              f"{r['service_rounds']:6d}  {r['modeled_latency_s']*1e3:13.1f}ms"
              f"  {slo}")
    print(f"\nthroughput: {stats['throughput_wall_rps']:.2f} img/s wall / "
          f"{stats['throughput_modeled_rps']:.2f} img/s modeled over "
          f"{stats['rounds']} rounds")

    ref = pipe.generate(xs[0], jnp.asarray([conds[0]]))
    req0 = next(r for r in done if r.uid == 0)
    assert bool(jnp.all(req0.image == ref.image)), "serving changed numerics!"
    print("bitwise parity with single-request generate: OK")


if __name__ == "__main__":
    main()
