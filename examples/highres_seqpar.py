"""High-resolution generation with sequence-parallel attention
(DESIGN.md §13): Ulysses head scattering + ring K/V staging as the fifth
dimension of the STADI schedule.

Quickstart
----------

    PYTHONPATH=src python examples/highres_seqpar.py               # ~1 min
    PYTHONPATH=src python examples/highres_seqpar.py \
        --occupancies 0.0,0.0,0.5,0.5 --seq-shards 0

What this shows
---------------

1.  At 2K-class resolutions, per-patch self-attention over the FULL token
    sequence becomes the wall no patch split can cut: every patch worker
    reads the whole-context K/V with all heads no matter how few query
    rows it owns. The ``stadi_seq`` planner makes the sequence itself an
    allocatable axis — patch workers become device GROUPS whose members
    split the attention heads (Ulysses all-to-all) and the ring K/V
    segments, both sized speed-proportionally.
2.  The shard count is PLANNED, not pinned: ``seq_shards=0`` scores the
    pure patch plan against every feasible shard count with the
    ring-contention cost model (per-hop K/V bytes x link speed, uneven
    segments) and picks the cheapest. On an attention-bound 2K profile it
    shards; on a compute-bound one it refuses.
3.  Numerics are shard-count invariant: the sequence dimension
    repartitions WHERE attention runs, never WHAT is computed — for a
    fixed patch schedule the demo generates the same image at
    seq_shards = 1, 2 and 4, bitwise, and bounds the staleness age of
    ring-hopped cross-worker K/V.

CLI twins: ``python -m repro.launch.stadi_infer --planner stadi_seq
--seq-shards 0 --exchange ring`` and ``python -m repro.launch.serve
--diffusion --seq-shards 2``.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--occupancies", default="0.0,0.0,0.5,0.5")
    ap.add_argument("--seq-shards", type=int, default=0,
                    help="0 = let the stadi_seq planner choose")
    ap.add_argument("--cond", type=int, default=7)
    ap.add_argument("--m-base", type=int, default=16)
    ap.add_argument("--m-warmup", type=int, default=4)
    args = ap.parse_args()

    import dataclasses

    from repro.configs import get_config
    from repro.core import sampler as sampler_lib
    from repro.core import seqpar
    from repro.core.pipeline import StadiConfig, StadiPipeline
    from repro.core.simulate import CostModel
    from repro.models.diffusion import dit

    occ = [float(x) for x in args.occupancies.split(",")]

    # ------------------------------------------------------------------
    # 1) plan the 2K run: sdxl-dit at a 256x256 latent (~2048px with an
    #    8x VAE), attention-bound cost model, modeled via the simulator
    # ------------------------------------------------------------------
    cfg2k = get_config("sdxl-dit").replace(latent_size=256)
    cm = CostModel(t_fixed=2e-3, t_row=1e-4, t_ctx=2e-4,
                   link_bw=50e9, link_latency=20e-6)
    base = StadiConfig.from_occupancies(
        occ, m_base=50, m_warmup=4, backend="simulate", cost_model=cm,
        exchange="ring", exchange_refresh=8)
    pure = StadiPipeline(cfg2k, None, None, dataclasses.replace(
        base, planner="stadi")).generate()
    auto = StadiPipeline(cfg2k, None, None, dataclasses.replace(
        base, planner="stadi_seq", seq_shards=args.seq_shards)).generate()
    seq = auto.plan.seq
    print(f"2K latent ({cfg2k.tokens_per_side} token rows, "
          f"{cfg2k.n_heads} heads) on cluster speeds {base.speeds}:")
    print(f"  pure patch parallelism : {pure.latency_s:.3f}s modeled "
          f"(patches {pure.plan.patches})")
    if seq is not None:
        groups, _ = seqpar.seq_group_speeds(base.speeds, seq.n_shards)
        print(f"  stadi_seq picked S={seq.n_shards}: heads "
              f"{list(seq.heads)}, ring segments {list(seq.segments)}, "
              f"worker groups {groups}")
    else:
        print("  stadi_seq kept the pure patch plan (compute-bound)")
    print(f"  sequence-parallel      : {auto.latency_s:.3f}s modeled "
          f"({(1 - auto.latency_s / pure.latency_s) * 100:.1f}% reduction)")

    # ------------------------------------------------------------------
    # 2) real numerics (tiny-dit): the planner-chosen shard count runs the
    #    exact same trajectory as the unsharded engine — bit for bit
    # ------------------------------------------------------------------
    cfg = get_config("tiny-dit").reduced()
    params = dit.nondegenerate_params(
        dit.init_params(jax.random.PRNGKey(0), cfg))
    sched = sampler_lib.linear_schedule(T=1000)
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (1, cfg.latent_size, cfg.latent_size,
                             cfg.channels))
    cond = jnp.full((1,), args.cond % cfg.n_classes, jnp.int32)
    run_cfg = StadiConfig.from_occupancies(
        occ, m_base=args.m_base, m_warmup=args.m_warmup,
        planner="stadi_seq", seq_shards=args.seq_shards, cost_model=cm,
        exchange="ring", exchange_refresh=4)
    pipe = StadiPipeline(cfg, params, sched, run_cfg)
    plan = pipe.plan()
    splan = plan.seq                     # plan() populates every axis
    print(f"\ntiny-dit run: planner chose seq="
          f"{splan and (list(splan.heads), list(splan.segments))} over "
          f"patches {plan.patches}")
    res = pipe.generate(x_T, cond)
    img = np.asarray(res.image)
    print(f"generated {img.shape} finite={np.isfinite(img).all()}")

    # shard-count invariance: pin the patch schedule (default planner) and
    # vary only the sequence dimension — every S generates the same image
    pin = StadiConfig.from_occupancies(
        occ, m_base=args.m_base, m_warmup=args.m_warmup,
        exchange="ring", exchange_refresh=4)
    pinned = {S: np.asarray(StadiPipeline(
        cfg, params, sched, dataclasses.replace(
            pin, seq_shards=S)).generate(x_T, cond).image)
        for S in (1, 2, 4)}
    same = all(np.array_equal(pinned[1], pinned[S]) for S in (2, 4))
    print(f"shard-count invariance (fixed patch plan, S=1/2/4): "
          f"bitwise {'OK' if same else 'MISMATCH'}")
    assert same

    worst = seqpar.max_hop_staleness(res.trace.events)
    print(f"worst ring-hop K/V staleness: {worst} intervals "
          f"(bound: refresh-1 = {run_cfg.exchange_refresh - 1})")
    assert worst <= run_cfg.exchange_refresh - 1


if __name__ == "__main__":
    main()
