"""Batched LLM serving with the framework's serving engine (any --arch).

  PYTHONPATH=src python examples/serve_llm.py --arch gemma-2b --requests 6
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()
    done = serve(args.arch, n_requests=args.requests, slots=3,
                 prompt_len=12, max_new=8)
    for r in done[:3]:
        print(f"req {r.uid}: prompt {r.prompt[:6].tolist()}... -> "
              f"{r.out_tokens}")


if __name__ == "__main__":
    main()
