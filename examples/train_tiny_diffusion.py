"""End-to-end diffusion training driver (deliverable b).

Trains the tiny class-conditional DiT denoiser on the synthetic structured
image dataset for a few hundred steps and checkpoints it — the model every
quality benchmark (Table II analogue) and redundancy benchmark samples from.

  PYTHONPATH=src python examples/train_tiny_diffusion.py --steps 400
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.core import sampler as sampler_lib
from repro.data import SyntheticImages
from repro.models.diffusion import dit
from repro.optim import adamw
from repro.optim.schedules import cosine_schedule

DEFAULT_CKPT = os.path.join(os.path.dirname(__file__), "..", "results",
                            "tiny_dit_ckpt")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--ckpt-dir", default=DEFAULT_CKPT)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config("tiny-dit")
    sched = sampler_lib.linear_schedule(T=1000)
    ds = SyntheticImages(size=cfg.latent_size, channels=cfg.channels,
                         n_classes=cfg.n_classes, seed=args.seed)
    params = dit.init_params(jax.random.PRNGKey(args.seed), cfg)
    n_params = sum(np.prod(np.shape(l)) for l in jax.tree.leaves(params))
    print(f"tiny-dit: {n_params/1e6:.2f}M params, latent {cfg.latent_size}, "
          f"{cfg.n_layers}L d{cfg.d_model}")

    opt_cfg = adamw.AdamWConfig(lr=args.lr, weight_decay=1e-4)
    opt_state = adamw.adamw_init(params)

    @jax.jit
    def train_step(params, opt_state, x0, cls, rng):
        def loss_fn(p):
            eps_fn = lambda x, t: dit.forward(p, cfg, x, t, cls)
            return sampler_lib.diffusion_loss(eps_fn, sched, x0, rng)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        lr_scale = cosine_schedule(opt_state["count"], args.steps,
                                   warmup_steps=20)
        params, opt_state = adamw.adamw_update(params, grads, opt_state,
                                               opt_cfg, lr_scale)
        return params, opt_state, loss

    rng = jax.random.PRNGKey(args.seed + 1)
    batches = ds.batches(args.batch, seed=args.seed + 2)
    t0 = time.time()
    first = None
    for step in range(args.steps):
        imgs, cls = next(batches)
        rng, k = jax.random.split(rng)
        params, opt_state, loss = train_step(
            params, opt_state, jnp.asarray(imgs), jnp.asarray(cls), k)
        if first is None:
            first = float(loss)
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)", flush=True)
    save_checkpoint(args.ckpt_dir, args.steps, {"params": params})
    print(f"done: loss {first:.3f} -> {float(loss):.3f}; "
          f"checkpoint at {args.ckpt_dir}")
    assert float(loss) < first, "training must reduce the loss"


if __name__ == "__main__":
    main()
