"""Text-to-image generation: prompt conditioning as a first-class workload
on a heterogeneous cluster (DESIGN.md §17).

Quickstart
----------

    PYTHONPATH=src python examples/text_to_image.py                # ~1 min
    PYTHONPATH=src python examples/text_to_image.py \
        --prompt "a watercolor fox in the snow" --cfg-scale 4.0

What this shows
---------------

1.  A frozen, seeded text encoder (``models/text_encoder.py``) maps a
    prompt to ``[1, L, cond_dim+1]`` conditioning tokens — the trailing
    channel is a validity mask, and L is the power-of-two length bucket.
    No learned checkpoint, fully deterministic: the same prompt always
    produces the same tokens.
2.  ``DiTConfig.text_conditioned()`` interleaves cross-attention into the
    DiT block stack; the cond tensor's *shape* selects the path (int
    ``[B]`` class ids vs float ``[B, L, D+1]`` prompt tokens), so every
    executor — emulated, spmd, frames — carries it opaquely.
3.  Classifier-free guidance composes: the null branch is the all-zero
    token tensor (``dit.null_like``), mirroring the class path's
    ``NULL_COND``, and the fused CFG epilogue is unchanged.
4.  Prompts are a SERVING axis: requests with different token counts land
    in different length buckets, the engine batches lanes per bucket, and
    each served image is bitwise identical to a single-request
    ``pipe.generate`` of the same prompt — the demo checks it.

CLI twins: ``python -m repro.launch.stadi_infer --prompt "..."`` and
``python -m repro.launch.serve --diffusion --cond-tokens 6``.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--prompt", default="a red fox in the snow")
    ap.add_argument("--occupancies", default="0.0,0.5")
    ap.add_argument("--cfg-scale", type=float, default=3.0)
    ap.add_argument("--cond-seq-len", type=int, default=16)
    ap.add_argument("--m-base", type=int, default=8)
    ap.add_argument("--m-warmup", type=int, default=2)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.core import sampler as sampler_lib
    from repro.core.pipeline import StadiConfig, StadiPipeline
    from repro.models import text_encoder
    from repro.models.diffusion import dit

    # 1) a text-conditioned DiT: one config call adds cross-attention
    cfg = get_config("tiny-dit").reduced().text_conditioned(
        cond_seq_len=args.cond_seq_len)
    params = dit.nondegenerate_params(
        dit.init_params(jax.random.PRNGKey(0), cfg))
    sched = sampler_lib.linear_schedule(T=1000)
    occ = [float(x) for x in args.occupancies.split(",")]

    tokens = text_encoder.encode([args.prompt], cfg)
    n_real = int(np.asarray(tokens[0, :, -1]).sum())
    print(f"prompt {args.prompt!r} -> {n_real} tokens in bucket "
          f"{tokens.shape[1]} (of {cfg.cond_seq_len}), dim {cfg.cond_dim}")

    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (1, cfg.latent_size, cfg.latent_size,
                             cfg.channels))

    # 2) unguided text-to-image on the heterogeneous schedule
    config = StadiConfig.from_occupancies(occ, m_base=args.m_base,
                                          m_warmup=args.m_warmup)
    pipe = StadiPipeline(cfg, params, sched, config)
    plan = pipe.plan()
    print(f"cluster speeds {config.speeds}: steps {plan.temporal.steps} "
          f"ratios {plan.temporal.ratios} patches {plan.patches} "
          f"(cond bucket prices t_xattn * {tokens.shape[1]} per row)")
    img = np.asarray(pipe.generate(x_T, tokens).image)
    print(f"text-to-image {img.shape} finite={np.isfinite(img).all()}")

    # 3) guided: the null branch is the all-zero token tensor, so CFG
    #    needs no new machinery — same fused epilogue as the class path
    gconfig = StadiConfig.from_occupancies(occ, m_base=args.m_base,
                                           m_warmup=args.m_warmup,
                                           cfg_scale=args.cfg_scale)
    gimg = np.asarray(StadiPipeline(cfg, params, sched, gconfig)
                      .generate(x_T, tokens).image)
    null = np.asarray(dit.null_like(tokens))
    print(f"CFG scale {args.cfg_scale}: guided image finite="
          f"{np.isfinite(gimg).all()} (null branch = zero tokens, "
          f"|null| = {float(np.abs(null).sum()):.0f})")

    # 4) prompts as a serving axis: varied lengths -> length-bucketed lane
    #    groups, each bitwise identical to single-request generate
    from repro.serving import DiffusionServingEngine
    engine = DiffusionServingEngine(
        StadiPipeline(cfg, params, sched, config), slots=4)
    prompts = [args.prompt, "fox", "a very detailed oil painting of a fox "
               "curled beneath a pine tree at dusk", "snow"]
    xs, conds = [], []
    for uid, p in enumerate(prompts):
        x = jax.random.normal(jax.random.PRNGKey(10 + uid),
                              (1, cfg.latent_size, cfg.latent_size,
                               cfg.channels))
        c = text_encoder.encode([p], cfg)
        xs.append(x)
        conds.append(c)
        engine.submit(x, c[0])
    done = {r.uid: r for r in engine.run_to_completion()}
    buckets = sorted({c.shape[1] for c in conds})
    print(f"served {len(done)} prompts across length buckets {buckets} "
          f"in {engine.stats()['rounds']} rounds")
    for uid in range(len(prompts)):
        ref = np.asarray(pipe.generate(xs[uid], conds[uid]).image)
        same = np.array_equal(np.asarray(done[uid].image), ref)
        print(f"  req {uid} (bucket {conds[uid].shape[1]}): bitwise vs "
              f"generate {'OK' if same else 'MISMATCH'}")
        assert same


if __name__ == "__main__":
    main()
