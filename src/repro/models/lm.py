"""Decoder-only LM skeleton: dense (llama3/yi/minitron/gemma), MoE
(olmoe/deepseek-moe), VLM (internvl2 = dense decoder consuming stub ViT
patch embeddings).

Scan-over-layers with stacked params keeps HLO size O(1) in depth.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers, moe as moe_lib


# ----------------------------------------------------------------------
# params
# ----------------------------------------------------------------------

def init_params(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)

    def init_block(k):
        ka, km = jax.random.split(k)
        block = {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "attn": layers.init_attention(ka, cfg),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
        }
        if cfg.n_experts:
            block["moe"] = moe_lib.init_moe(km, cfg)
        else:
            block["mlp"] = layers.init_mlp(km, cfg)
        return block

    blocks = jax.vmap(init_block)(jax.random.split(k_blocks, cfg.n_layers))
    params = {
        "embed": layers.embed_init(k_embed, (cfg.vocab, cfg.d_model), dtype),
        "blocks": blocks,
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = layers.dense_init(k_head, (cfg.d_model, cfg.vocab), dtype)
    return params


# ----------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------

def _embed(params, cfg, tokens, vision_embeds=None):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    if cfg.arch_id.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
    return x


def _logits(params, cfg, x):
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = x @ head.astype(x.dtype)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


def _constrain(x, cfg):
    """Residual-stream sharding constraint (cfg.act_shard, §Perf):
    batch  -> P(('data',), None, None)          (plain DP activations)
    seqpar -> P(('data',), 'model', None)       (sequence-parallel residual:
              GSPMD turns the per-layer megatron all-reduces into
              reduce-scatter + all-gather pairs, halving collective bytes)
    Requires an ambient mesh (the dry-run/perf lower inside ``with mesh:``).
    """
    if not cfg.act_shard:
        return x
    import jax as _jax
    from jax.sharding import PartitionSpec as P
    spec = P(("data",), "model" if cfg.act_shard == "seqpar" else None, None)
    try:
        return _jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def _block(p, x, cfg, *, window: int, prefix_len: int):
    x = _constrain(x, cfg)
    h, kv = layers.self_attention(
        p["attn"], layers.rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
        window=window, prefix_len=prefix_len)
    x = x + h
    xn = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        h, aux = moe_lib.moe_ffn(p["moe"], xn, cfg)
    else:
        h, aux = layers.mlp(p["mlp"], xn, cfg.activation), jnp.float32(0.0)
    return x + h, kv, aux


def forward(params, cfg, tokens, *, vision_embeds=None, window: int = 0,
            return_kv: bool = False, logits_last_only: bool = False):
    """tokens [B,S] -> logits [B, S(+Nv), V]. window=0 => full causal attn.

    logits_last_only: serving prefill only needs the final position — skips
    the [B,S,V] unembed (and its partial-sum all-reduce under sharding)."""
    prefix_len = vision_embeds.shape[1] if vision_embeds is not None else 0
    x = _embed(params, cfg, tokens, vision_embeds)

    def body(carry, p):
        x, aux = carry
        x, kv, a = _block(p, x, cfg, window=window, prefix_len=prefix_len)
        return (x, aux + a), (kv if return_kv else None)

    (x, aux), kvs = jax.lax.scan(body, (x, jnp.float32(0.0)), params["blocks"])
    if logits_last_only:
        x = x[:, -1:]
    return _logits(params, cfg, x), aux, kvs


def loss_fn(params, cfg, batch):
    """batch: tokens [B,S], labels [B,S] (+ vision_embeds for vlm)."""
    ve = batch.get("vision_embeds")
    logits, aux, _ = forward(params, cfg, batch["tokens"], vision_embeds=ve)
    if ve is not None:
        logits = logits[:, ve.shape[1]:]   # loss on text positions only
    ce = layers.cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
    return ce + cfg.router_aux_coef * aux if cfg.n_experts else ce


# ----------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ----------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, *, window: int = 0):
    T = window if window else max_len
    shape = (cfg.n_layers, batch, T, cfg.n_kv_heads, cfg.hd)
    dtype = jnp.dtype(cfg.dtype)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(params, cfg, tokens, cache, *, vision_embeds=None, window: int = 0):
    logits, _, kvs = forward(params, cfg, tokens, vision_embeds=vision_embeds,
                             window=window, return_kv=True,
                             logits_last_only=True)
    k, v = kvs                                   # [L,B,S,K,hd]
    S = k.shape[2]
    T = cache["k"].shape[2]
    if S >= T:                                   # keep last T (windowed)
        k, v = k[:, :, S - T:], v[:, :, S - T:]
        cache = {**cache, "k": k.astype(cache["k"].dtype), "v": v.astype(cache["v"].dtype)}
    else:
        cache = {**cache,
                 "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, axis=2),
                 "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, axis=2)}
    return logits[:, -1], {**cache, "pos": jnp.asarray(S, jnp.int32)}


def decode_step(params, cfg, cache, token, *, window: int = 0):
    """token [B] int32 -> (logits [B,V], new cache). One new token."""
    x = _embed(params, cfg, token[:, None])
    pos = cache["pos"]

    def body(x, scanned):
        p, ck, cv = scanned
        h, nk, nv = layers.decode_attention(
            p["attn"], layers.rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
            ck, cv, pos, window=window)
        x = x + h
        xn = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            h, _ = moe_lib.moe_ffn(p["moe"], xn, cfg)
        else:
            h = layers.mlp(p["mlp"], xn, cfg.activation)
        return x + h, (nk, nv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
    logits = _logits(params, cfg, x)[:, 0]
    return logits, {"k": nk, "v": nv, "pos": pos + 1}
