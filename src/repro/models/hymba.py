"""Hymba (arXiv:2411.13676): each block runs attention heads and SSM (mamba)
heads IN PARALLEL on the same input and fuses the branch outputs (mean of
per-branch RMS-normed outputs, learned scales). 128 learnable meta tokens are
prepended to every sequence and stay attendable outside the sliding window.

Homogeneous blocks => scan-over-layers with stacked params.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers, mamba as mamba_lib
from repro.models.lm import _constrain


def init_params(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    k_embed, k_meta, k_blocks, k_head = jax.random.split(key, 4)

    def init_block(k):
        ka, km = jax.random.split(k)
        return {
            "ln1": jnp.zeros((cfg.d_model,), dt),
            "attn": layers.init_attention(ka, cfg),
            "mamba": mamba_lib.init_mamba(km, cfg),
            "fuse_a": jnp.zeros((cfg.d_model,), dt),
            "fuse_m": jnp.zeros((cfg.d_model,), dt),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "mlp": layers.init_mlp(jax.random.fold_in(k, 7), cfg),
        }

    blocks = jax.vmap(init_block)(jax.random.split(k_blocks, cfg.n_layers))
    return {
        "embed": layers.embed_init(k_embed, (cfg.vocab, cfg.d_model), dt),
        "meta": layers.embed_init(k_meta, (cfg.n_meta_tokens, cfg.d_model), dt),
        "blocks": blocks,
        "ln_f": jnp.zeros((cfg.d_model,), dt),
        "head": layers.dense_init(k_head, (cfg.d_model, cfg.vocab), dt),
    }


def _block(p, x, cfg, ssm_state, *, window: int):
    x = _constrain(x, cfg)
    xn = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    attn_out, kv = layers.self_attention(p["attn"], xn, cfg, window=window,
                                         prefix_len=cfg.n_meta_tokens)
    ssm_out, new_state = mamba_lib.mamba_forward(p["mamba"], xn, cfg, ssm_state)
    fused = 0.5 * (layers.rms_norm(attn_out, p["fuse_a"], cfg.norm_eps) +
                   layers.rms_norm(ssm_out, p["fuse_m"], cfg.norm_eps))
    x = x + fused
    x = x + layers.mlp(p["mlp"], layers.rms_norm(x, p["ln2"], cfg.norm_eps), cfg.activation)
    return x, kv, new_state


def forward(params, cfg, tokens, ssm_states=None, *, window: int = None,
            return_kv: bool = False, logits_last_only: bool = False):
    """tokens [B,S] -> logits over [meta+S] positions (meta stripped)."""
    B, S = tokens.shape
    window = cfg.sliding_window if window is None else window
    if ssm_states is None:
        ssm_states = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
            mamba_lib.init_state(cfg, B))
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    meta = jnp.broadcast_to(params["meta"][None], (B,) + params["meta"].shape).astype(x.dtype)
    x = jnp.concatenate([meta, x], axis=1)

    def body(x, scanned):
        p, st = scanned
        x, kv, nst = _block(p, x, cfg, st, window=window)
        return x, (kv if return_kv else None, nst)

    x, (kvs, new_states) = jax.lax.scan(body, x, (params["blocks"], ssm_states))
    x = x[:, -1:] if logits_last_only else x[:, cfg.n_meta_tokens:]
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return x @ params["head"].astype(x.dtype), kvs, new_states


def loss_fn(params, cfg, batch):
    logits, _, _ = forward(params, cfg, batch["tokens"])
    return layers.cross_entropy(logits[:, :-1], batch["labels"][:, 1:])


# ----------------------------------------------------------------------
# serving
# ----------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, *, window: int = 0):
    """window=0 => full cache of max_len+meta; else meta-pinned ring cache."""
    M = cfg.n_meta_tokens
    T = (M + window) if window else (M + max_len)
    kv_shape = (cfg.n_layers, batch, T, cfg.n_kv_heads, cfg.hd)
    dt = jnp.dtype(cfg.dtype)
    ssm = jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
                       mamba_lib.init_state(cfg, batch))
    return {"k": jnp.zeros(kv_shape, dt), "v": jnp.zeros(kv_shape, dt),
            "ssm": ssm, "pos": jnp.zeros((), jnp.int32)}


def prefill(params, cfg, tokens, cache, *, window: int = 0):
    logits, kvs, ssm = forward(params, cfg, tokens, return_kv=True,
                               window=window or cfg.sliding_window,
                               logits_last_only=True)
    k, v = kvs                                        # [L,B,M+S,K,hd]
    M = cfg.n_meta_tokens
    T = cache["k"].shape[2]
    S_tot = k.shape[2]
    if S_tot > T:                                     # ring: meta + last (T-M)
        k = jnp.concatenate([k[:, :, :M], k[:, :, -(T - M):]], axis=2)
        v = jnp.concatenate([v[:, :, :M], v[:, :, -(T - M):]], axis=2)
        cache = {**cache, "k": k.astype(cache["k"].dtype), "v": v.astype(cache["v"].dtype)}
    else:
        cache = {**cache,
                 "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, axis=2),
                 "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, axis=2)}
    return logits[:, -1], {**cache, "ssm": ssm, "pos": jnp.asarray(S_tot, jnp.int32)}


def _decode_attn(p, x, cfg, ck, cv, pos, window: int):
    """Meta-pinned ring decode attention. pos counts meta+generated tokens."""
    B = x.shape[0]
    M = cfg.n_meta_tokens
    T = ck.shape[1]
    q = (x @ p["wq"]).reshape(B, 1, cfg.n_heads, cfg.hd)
    k = (x @ p["wk"]).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
    v = (x @ p["wv"]).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
    posv = jnp.full((B, 1), pos, jnp.int32)
    q = layers.apply_rope(q, posv, cfg.rope_theta)
    k = layers.apply_rope(k, posv, cfg.rope_theta)
    slot = (M + (pos - M) % window) if window else pos
    ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), slot, axis=1)
    kj = jnp.arange(T)[None, None, None, :]
    n_written = jnp.minimum(pos - M + 1, (window if window else T) - (0 if window else M))
    valid = (kj < M) | ((kj - M) < n_written)
    out = layers.attend(q, ck, cv, mask=valid)
    return out.reshape(B, 1, -1) @ p["wo"], ck, cv


def decode_step(params, cfg, cache, token, *, window: int = 0):
    B = token.shape[0]
    x = params["embed"][token[:, None]].astype(jnp.dtype(cfg.dtype))
    pos = cache["pos"]

    def body(x, scanned):
        p, ck, cv, st = scanned
        xn = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        a, nk, nv = _decode_attn(p["attn"], xn, cfg, ck, cv, pos, window)
        m, nst = mamba_lib.mamba_forward(p["mamba"], xn, cfg, st)
        fused = 0.5 * (layers.rms_norm(a, p["fuse_a"], cfg.norm_eps) +
                       layers.rms_norm(m, p["fuse_m"], cfg.norm_eps))
        x = x + fused
        x = x + layers.mlp(p["mlp"], layers.rms_norm(x, p["ln2"], cfg.norm_eps), cfg.activation)
        return x, (nk, nv, nst)

    x, (nk, nv, nssm) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"], cache["ssm"]))
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = (x @ params["head"].astype(x.dtype))[:, 0]
    return logits, {"k": nk, "v": nv, "ssm": nssm, "pos": pos + 1}
