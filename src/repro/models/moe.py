"""Mixture-of-Experts FFN (OLMoE / DeepSeekMoE style).

Capacity-based einsum dispatch: experts live on the ``model`` mesh axis
(expert parallelism); the dispatch/combine einsums lower to all-to-all-like
collectives under GSPMD. FLOPs scale with top_k (+ shared), not n_experts —
matching 6*N_active*D roofline accounting.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers


def init_moe(key, cfg, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": layers.dense_init(ks[0], (D, E), jnp.float32),  # router stays fp32
        "experts": {
            "w_gate": layers.dense_init(ks[1], (E, D, F), dtype),
            "w_up": layers.dense_init(ks[2], (E, D, F), dtype),
            "w_down": layers.dense_init(ks[3], (E, F, D), dtype,
                                        scale=1.0 / math.sqrt(2 * cfg.n_layers * F)),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.init_mlp(ks[4], cfg, d_ff=cfg.n_shared_experts * F)
    return p


def _capacity(S: int, cfg) -> int:
    return max(1, int(math.ceil(S * cfg.top_k / cfg.n_experts * cfg.capacity_factor)))


def moe_ffn(p, x, cfg):
    """x: [B,S,D] -> (y [B,S,D], aux_loss scalar fp32)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(S, cfg)

    logits = (x.astype(jnp.float32) @ p["router"])          # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                     # [B,S,K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k-slot) within its expert's queue
    sel = jax.nn.one_hot(idx, E, dtype=jnp.float32)         # [B,S,K,E]
    flat = sel.reshape(B, S * K, E)
    pos_in_e = (jnp.cumsum(flat, axis=1) - flat).reshape(B, S, K, E)  # rank in queue
    keep = pos_in_e < C
    sel = sel * keep
    pos_oh = jax.nn.one_hot(pos_in_e.astype(jnp.int32), C, dtype=jnp.float32)  # [B,S,K,E,C]
    dispatch = jnp.einsum("bske,bskec->bsec", sel, pos_oh)  # [B,S,E,C] 0/1
    combine = jnp.einsum("bsk,bske,bskec->bsec", gate, sel, pos_oh)

    xe = jnp.einsum("bsd,bsec->becd", x, dispatch.astype(x.dtype))      # [B,E,C,D]
    w = p["experts"]
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, w["w_gate"])) * \
        jnp.einsum("becd,edf->becf", xe, w["w_up"])
    ye = jnp.einsum("becf,efd->becd", h, w["w_down"])                   # [B,E,C,D]
    y = jnp.einsum("becd,bsec->bsd", ye, combine.astype(ye.dtype))

    if cfg.n_shared_experts:
        y = y + layers.mlp(p["shared"], x, cfg.activation)

    # load-balance auxiliary loss (Switch-style): E * sum_e f_e * p_e
    frac_tokens = jnp.mean(sel.sum(2).reshape(B * S, E), axis=0)        # fraction routed
    frac_probs = jnp.mean(probs.reshape(B * S, E), axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs) / K
    return y, aux
