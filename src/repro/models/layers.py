"""Shared neural building blocks (pure-functional JAX, pytree params).

Naming conventions matter: ``sharding/specs.py`` assigns PartitionSpecs from
parameter *path names* (wq/wk/wv/wo/w_gate/w_up/w_down/embed/head/...).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ----------------------------------------------------------------------
# init helpers
# ----------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (LeCun-style)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ----------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
    return out.astype(dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * weight + bias
    return out.astype(dtype)


# ----------------------------------------------------------------------
# rotary embeddings
# ----------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                 # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# attention core (reference path; Pallas kernels live in repro.kernels)
# ----------------------------------------------------------------------

def repeat_kv(kv, n_rep: int):
    """[B, T, K, hd] -> [B, T, K*n_rep, hd] (GQA broadcast)."""
    if n_rep == 1:
        return kv
    b, t, k, hd = kv.shape
    return jnp.broadcast_to(kv[:, :, :, None, :], (b, t, k, n_rep, hd)).reshape(b, t, k * n_rep, hd)


def attend(q, k, v, *, mask=None, scale: Optional[float] = None, softcap: float = 0.0):
    """q: [B,S,H,hd]; k,v: [B,T,K,hd] with K | H. mask: broadcastable [B,1,S,T] bool.

    Returns [B,S,H,hd]. fp32 softmax.
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    k = repeat_kv(k, H // K)
    v = repeat_kv(v, H // K)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def causal_mask(S: int, T: int, q_offset):
    """[1,1,S,T] bool: query i (global pos q_offset+i) sees keys <= its pos."""
    qi = jnp.arange(S)[:, None] + q_offset
    kj = jnp.arange(T)[None, :]
    return (kj <= qi)[None, None]


def window_mask(S: int, T: int, q_offset, window: int):
    qi = jnp.arange(S)[:, None] + q_offset
    kj = jnp.arange(T)[None, :]
    return ((kj <= qi) & (kj > qi - window))[None, None]


# ----------------------------------------------------------------------
# attention block (projection + rope + attend)
# ----------------------------------------------------------------------

def init_attention(key, cfg, d_model: Optional[int] = None, dtype=None):
    D = d_model or cfg.d_model
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (D, cfg.n_heads * cfg.hd), dtype),
        "wk": dense_init(ks[1], (D, cfg.n_kv_heads * cfg.hd), dtype),
        "wv": dense_init(ks[2], (D, cfg.n_kv_heads * cfg.hd), dtype),
        "wo": dense_init(ks[3], (cfg.n_heads * cfg.hd, D), dtype,
                         scale=1.0 / math.sqrt(2 * max(1, cfg.n_layers) * cfg.n_heads * cfg.hd)),
    }


def attention_qkv(p, x, cfg, positions):
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.hd)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def self_attention(p, x, cfg, *, positions=None, window: int = 0, prefix_len: int = 0):
    """Full-sequence self attention (training/prefill). causal unless enc.

    prefix_len: leading positions (vision/meta tokens) every query may attend to
    even outside the window.
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = attention_qkv(p, x, cfg, positions)
    if getattr(cfg, "attn_impl", "naive") == "chunked":
        from repro.models.attention import chunked_attend
        out = chunked_attend(q, k, v, causal=True, window=window,
                             prefix_len=prefix_len, chunk=cfg.attn_chunk)
    else:
        if window:
            mask = window_mask(S, S, 0, window)
            if prefix_len:
                kj = jnp.arange(S)[None, :]
                qi = jnp.arange(S)[:, None]
                mask = mask | ((kj < prefix_len) & (kj <= qi))[None, None]
        else:
            mask = causal_mask(S, S, 0)
        out = attend(q, k, v, mask=mask)
    return out.reshape(B, S, -1) @ p["wo"], (k, v)


def bidirectional_attention(p, x, cfg, positions=None):
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = attention_qkv(p, x, cfg, positions)
    if getattr(cfg, "attn_impl", "naive") == "chunked":
        from repro.models.attention import chunked_attend
        out = chunked_attend(q, k, v, causal=False, chunk=cfg.attn_chunk)
    else:
        out = attend(q, k, v, mask=None)
    return out.reshape(B, S, -1) @ p["wo"]


def cross_attention(p, x, memory_kv, cfg):
    """x: [B,S,D] queries; memory_kv: (k,v) [B,T,K,hd] precomputed from encoder."""
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, cfg.hd)
    k, v = memory_kv
    if getattr(cfg, "attn_impl", "naive") == "chunked":
        from repro.models.attention import chunked_attend
        out = chunked_attend(q, k, v, causal=False, chunk=cfg.attn_chunk)
    else:
        out = attend(q, k, v, mask=None)
    return out.reshape(B, S, -1) @ p["wo"]


def decode_attention(p, x, cfg, cache_k, cache_v, pos, *, window: int = 0):
    """Single-token decode. x: [B,1,D]; cache_[kv]: [B,T,K,hd]; pos: [] int32.

    Full cache: write at index ``pos``; mask keys > pos.
    Window cache (window>0): cache length == window ring buffer; write at
    ``pos % window``; mask unwritten slots.
    Returns (out [B,1,D], new_k, new_v).
    """
    B = x.shape[0]
    T = cache_k.shape[1]
    q = (x @ p["wq"]).reshape(B, 1, cfg.n_heads, cfg.hd)
    k = (x @ p["wk"]).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
    v = (x @ p["wv"]).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
    posv = jnp.full((B, 1), pos, dtype=jnp.int32)
    q = apply_rope(q, posv, cfg.rope_theta)
    k = apply_rope(k, posv, cfg.rope_theta)
    slot = (pos % window) if window else pos
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), slot, axis=1)
    kj = jnp.arange(T)[None, None, None, :]
    if window:
        # slots hold global positions in (pos-window, pos]; all valid once warm
        valid = kj <= jnp.minimum(pos, T - 1)
    else:
        valid = kj <= pos
    out = attend(q, cache_k, cache_v, mask=valid)
    return out.reshape(B, 1, -1) @ p["wo"], cache_k, cache_v


# ----------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------

def init_mlp(key, cfg, d_model: Optional[int] = None, d_ff: Optional[int] = None, dtype=None):
    D = d_model or cfg.d_model
    F = d_ff or cfg.d_ff
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (D, F), dtype),
        "w_up": dense_init(ks[1], (D, F), dtype),
        "w_down": dense_init(ks[2], (F, D), dtype, scale=1.0 / math.sqrt(2 * max(1, cfg.n_layers) * F)),
    }


def mlp(p, x, activation: str = "swiglu"):
    gate = x @ p["w_gate"]
    up = x @ p["w_up"]
    if activation == "geglu":
        h = jax.nn.gelu(gate) * up
    else:
        h = jax.nn.silu(gate) * up
    return h @ p["w_down"]


# ----------------------------------------------------------------------
# misc
# ----------------------------------------------------------------------

def sinusoidal_embedding(t, dim: int, max_period: float = 10_000.0):
    """t: [B] float timesteps -> [B, dim]."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t[:, None].astype(jnp.float32) * freqs[None]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def cross_entropy(logits, labels, ignore_id: int = -1):
    """logits [B,S,V] fp any; labels [B,S] int32. Mean over non-ignored.

    Shard-friendly formulation: the gold-logit term is a one-hot contraction
    (reduces over the vocab dim wherever it lives) rather than
    take_along_axis, which under GSPMD forces an all-gather of the
    vocab-sharded logits. logsumexp also reduces in-place. Verified
    numerically identical to the gather formulation in tests.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels.clip(0), logits.shape[-1], dtype=jnp.float32)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = logz - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
