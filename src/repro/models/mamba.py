"""Selective SSM (Mamba-style) branch used by Hymba's parallel heads.

Reference = exact recurrent ``lax.scan``; the chunked TPU kernel lives in
``repro.kernels.ssm_scan``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers


def d_inner(cfg) -> int:
    return cfg.d_model


def dt_rank(cfg) -> int:
    return max(8, cfg.d_model // 32)


def init_mamba(key, cfg, n_layers_scale: int = None):
    D = cfg.d_model
    Di, N, R = d_inner(cfg), cfg.ssm_state, dt_rank(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    return {
        "w_in": layers.dense_init(ks[0], (D, 2 * Di), dt),           # x, z
        "conv": layers.dense_init(ks[1], (cfg.ssm_conv, Di), dt, scale=0.3),
        "w_bc": layers.dense_init(ks[2], (Di, 2 * N), dt),           # B_t, C_t
        "w_dt1": layers.dense_init(ks[3], (Di, R), dt),
        "w_dt2": layers.dense_init(ks[4], (R, Di), dt),
        "b_dt": jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
            ks[5], (Di,), minval=math.log(1e-3), maxval=math.log(1e-1))))),
        "A_log": jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32))[None, :].repeat(Di, 0),
        "D_skip": jnp.ones((Di,), jnp.float32),
        "w_out": layers.dense_init(ks[6], (Di, D), dt,
                                   scale=1.0 / math.sqrt(2 * cfg.n_layers * Di)),
    }


def init_state(cfg, batch: int):
    Di, N = d_inner(cfg), cfg.ssm_state
    return {"h": jnp.zeros((batch, Di, N), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, Di), jnp.dtype(cfg.dtype))}


def _proj(p, xb, cfg, conv_state):
    """xb: [B,S,D] pre-normed -> per-step SSM inputs (all fp32)."""
    B, S, _ = xb.shape
    N = cfg.ssm_state
    x_br, z = jnp.split(xb @ p["w_in"], 2, axis=-1)
    pad = jnp.concatenate([conv_state.astype(x_br.dtype), x_br], axis=1)
    w = p["conv"]
    W = w.shape[0]
    xc = jax.nn.silu(sum(pad[:, i:i + S] * w[i] for i in range(W)))
    new_conv = pad[:, -(W - 1):] if W > 1 else conv_state
    bc = (xc @ p["w_bc"]).astype(jnp.float32)
    B_t, C_t = bc[..., :N], bc[..., N:]                               # [B,S,N]
    delta = jax.nn.softplus(((xc @ p["w_dt1"]) @ p["w_dt2"]).astype(jnp.float32) + p["b_dt"])
    A = -jnp.exp(p["A_log"])                                          # [Di,N]
    return xc.astype(jnp.float32), z, B_t, C_t, delta, A, new_conv


def ssm_scan_ref(xc, B_t, C_t, delta, A, D_skip, h0):
    """Exact recurrence. xc: [B,S,Di]; B_t/C_t: [B,S,N]; delta: [B,S,Di].

    h_t = exp(delta_t A) h_{t-1} + delta_t B_t x_t ;  y_t = <h_t, C_t> + D x_t
    Returns (y [B,S,Di], h_final [B,Di,N]).
    """
    def step(h, inp):
        x_t, b_t, c_t, d_t = inp                                      # [B,Di],[B,N],[B,N],[B,Di]
        da = jnp.exp(d_t[..., None] * A[None])                        # [B,Di,N]
        h = da * h + (d_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t) + D_skip * x_t
        return h, y

    inputs = (jnp.moveaxis(xc, 0, 1), jnp.moveaxis(B_t, 0, 1),
              jnp.moveaxis(C_t, 0, 1), jnp.moveaxis(delta, 0, 1))
    h, ys = jax.lax.scan(step, h0, inputs)
    return jnp.moveaxis(ys, 0, 1), h


def mamba_forward(p, xb, cfg, state):
    """xb: [B,S,D] (pre-normed) -> (y [B,S,D], new state)."""
    xc, z, B_t, C_t, delta, A, new_conv = _proj(p, xb, cfg, state["conv"])
    y, h = ssm_scan_ref(xc, B_t, C_t, delta, A, p["D_skip"], state["h"])
    y = (y.astype(xb.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return y, {"h": h, "conv": new_conv}
