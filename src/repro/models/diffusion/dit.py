"""DiT denoiser (arXiv:2212.09748) with first-class patch-parallel support.

Tokens are row-major over the latent grid; a *patch* is a contiguous range of
token ROWS (STADI's allocatable unit, P_total = tokens_per_side rows).

``forward_patch`` computes eps for a local row range while attending over
full-image K/V assembled from (fresh local) ⊕ (stale remote) buffers — the
DistriFusion mechanism that STADI schedules. With ``buffers=None`` and the
full row range it degenerates to exact single-device inference ("Origin").
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.diffusion import DiTConfig
from repro.models import layers


# ----------------------------------------------------------------------
# patchify helpers
# ----------------------------------------------------------------------

def patchify(x, patch: int):
    """[B,H,W,C] -> [B, (H/p)*(W/p), p*p*C], row-major token grid."""
    B, H, W, C = x.shape
    hp, wp = H // patch, W // patch
    x = x.reshape(B, hp, patch, wp, patch, C)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, hp * wp, patch * patch * C)


def unpatchify(tok, patch: int, hp: int, wp: int, channels: int):
    B = tok.shape[0]
    x = tok.reshape(B, hp, wp, patch, patch, channels)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, hp * patch, wp * patch, channels)


def pos_embed_2d(hp: int, wp: int, dim: int):
    """Fixed 2D sin-cos positional embedding [hp*wp, dim]."""
    def _1d(n, d):
        pos = jnp.arange(n, dtype=jnp.float32)
        omega = jnp.exp(-math.log(10_000.0) * jnp.arange(d // 2, dtype=jnp.float32) / (d // 2))
        out = pos[:, None] * omega[None]
        return jnp.concatenate([jnp.sin(out), jnp.cos(out)], axis=-1)   # [n, d]

    eh = _1d(hp, dim // 2)                     # [hp, dim/2]
    ew = _1d(wp, dim // 2)                     # [wp, dim/2]
    grid = jnp.concatenate([
        jnp.broadcast_to(eh[:, None], (hp, wp, dim // 2)),
        jnp.broadcast_to(ew[None, :], (hp, wp, dim // 2)),
    ], axis=-1)
    return grid.reshape(hp * wp, dim)


# ----------------------------------------------------------------------
# params
# ----------------------------------------------------------------------

def init_params(key, cfg: DiTConfig):
    dt = jnp.dtype(cfg.param_dtype)
    D, L = cfg.d_model, cfg.n_layers
    F = int(cfg.mlp_ratio * D)
    ks = jax.random.split(key, 8)

    def init_block(k):
        # km was never consumed pre-§17, so drawing the cross-attention
        # params from it leaves every existing draw bitwise untouched —
        # the cond_seq_len=0 degeneracy guarantee starts here
        kq, ko, k1, k2, km = jax.random.split(k, 5)
        blk = {
            "qkv": layers.dense_init(kq, (D, 3 * D), dt),
            "wo": layers.dense_init(ko, (D, D), dt, scale=1.0 / math.sqrt(2 * L * D)),
            "w1": layers.dense_init(k1, (D, F), dt),
            "w2": layers.dense_init(k2, (F, D), dt, scale=1.0 / math.sqrt(2 * L * F)),
            "mod_w": jnp.zeros((D, 6 * D), dt),          # adaLN-zero init
            "mod_b": jnp.zeros((6 * D,), dt),
        }
        if cfg.cross_attn:
            # prompt cross-attention (DESIGN.md §17): queries from the
            # hidden states, K/V projected from the cond_dim prompt tokens;
            # the out-projection follows the adaLN-zero idiom (exact zero —
            # an untrained model ignores the prompt entirely)
            kx1, kx2 = jax.random.split(km, 2)
            blk["xq"] = layers.dense_init(kx1, (D, D), dt)
            blk["xkv"] = layers.dense_init(kx2, (cfg.cond_dim, 2 * D), dt)
            blk["xo"] = jnp.zeros((D, D), dt)
        return blk

    blocks = jax.vmap(init_block)(jax.random.split(ks[0], L))
    out = {
        "patch_embed": layers.dense_init(ks[1], (cfg.token_dim, D), dt),
        "patch_bias": jnp.zeros((D,), dt),
        "t_w1": layers.dense_init(ks[2], (256, D), dt),
        "t_w2": layers.dense_init(ks[3], (D, D), dt),
        "cond_embed": layers.embed_init(ks[4], (cfg.n_classes, D), dt),
        "blocks": blocks,
        "final_mod_w": jnp.zeros((D, 2 * D), dt),
        "final_mod_b": jnp.zeros((2 * D,), dt),
        "final_proj": jnp.zeros((D, cfg.token_dim), dt),  # zero-init output
    }
    if cfg.cross_attn:
        # mean-pooled prompt tokens feed the adaLN conditioning vector
        # (ks[5] was never consumed pre-§17 — see init_block)
        out["ctx_pool"] = layers.dense_init(ks[5], (cfg.cond_dim, D), dt)
    return out


def nondegenerate_params(params, seed: int = 7):
    """Untrained params are adaLN-zero: modulation gates and the output head
    are exactly zero, so eps ignores attention (and hence the stale-KV
    buffers) entirely. Tests and benchmarks that probe staleness replace
    those zeros with small deterministic values so remote K/V genuinely
    influences the trajectory. Returns a modified copy."""
    params = dict(params)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    blk = dict(params["blocks"])
    blk["mod_w"] = 0.02 * jax.random.normal(ks[0], blk["mod_w"].shape)
    blk["mod_b"] = 0.02 * jax.random.normal(ks[1], blk["mod_b"].shape)
    params["blocks"] = blk
    params["final_mod_w"] = 0.02 * jax.random.normal(
        ks[2], params["final_mod_w"].shape)
    params["final_proj"] = 0.05 * jax.random.normal(
        ks[3], params["final_proj"].shape)
    if "xo" in blk:
        # prompt cross-attention out-projection is adaLN-zero too; give it
        # a deterministic value so prompts genuinely steer the trajectory.
        # Drawn from a distinct key stream so class-conditional params stay
        # bitwise what they were pre-§17.
        kx = jax.random.PRNGKey(seed + 101)
        blk["xo"] = 0.05 * jax.random.normal(kx, blk["xo"].shape)
    return params


def _stale_kernel_attend(q, k_fresh, v_fresh, k_stale, v_stale,
                         tok_start: int, blk: int):
    """Fused freshness-select attention via the Pallas stale-KV kernel
    (repro.kernels.stale_kv_attention): the per-block fresh/stale select
    happens inside the flash loop, so the stale buffer is never rewritten
    in HBM — the kernelized form of the dynamic_update_slice + attend
    reference path below. Layout [B,Nl,H,hd] <-> kernel's [B,H,Nl,hd]."""
    from repro.kernels import ops as kops
    from repro.kernels import stale_kv_attention as ska
    to = lambda a: jnp.moveaxis(a.astype(q.dtype), 2, 1)
    out = ska.stale_kv_attention_bhsd(
        to(q), to(k_fresh), to(v_fresh), to(k_stale), to(v_stale),
        tok_start, bq=blk, bk=blk, interpret=kops._interpret())
    return jnp.moveaxis(out, 1, 2)


def _stale_kernel_attend_padded(q, k_fresh, v_fresh, k_stale, v_stale,
                                tok_start, valid_tokens, n_tokens: int,
                                blk: int):
    """Padded-layout kernel dispatch (the shard_map form): traced
    tok_start/valid_tokens ride as scalar-prefetch arguments and the
    scratch tail of the stale buffer is masked in-kernel — the fused form
    of the mask-blend + dynamic_update_slice + masked-attend SPMD branch
    below."""
    from repro.kernels import ops as kops
    from repro.kernels import stale_kv_attention as ska
    to = lambda a: jnp.moveaxis(a.astype(q.dtype), 2, 1)
    out = ska.stale_kv_attention_padded_bhsd(
        to(q), to(k_fresh), to(v_fresh), to(k_stale), to(v_stale),
        tok_start, valid_tokens, n_tokens=n_tokens, bq=blk, bk=blk,
        interpret=kops._interpret())
    return jnp.moveaxis(out, 1, 2)


def _pallas_block(cfg, tok_start, Nl: int, N: int,
                  valid_tokens, enable):
    """Select the stale-KV attention body for this layout: ("off", 0) =
    reference path, else (mode, tile) with mode "static" (compile-time
    tok_start, full blend — the emulated/pipefuse interpreters) or
    "padded" (traced tok_start / valid_tokens scratch padding via
    scalar-prefetch — the shard_map executors). ``enable`` stage masking
    needs no kernel support: the disabled-block identity is applied by
    ``block_stack``'s outer ``jnp.where`` AFTER attention, so both kernel
    bodies run under it unchanged.

    Static layouts need tok_start/Nl/N to share a power-of-two tile >= 8;
    padded layouts tile by the largest power-of-two divisor of
    tokens_per_side (token starts/counts are row multiples of it, which
    keeps the traced offsets block-aligned). Every decision is recorded in
    the kernel-path counters (repro.kernels.ops) AT TRACE TIME — misses
    only when the kernel was requested."""
    if not cfg.use_pallas_attention:
        return ("off", 0)
    from repro.kernels import ops as kops
    if valid_tokens is None and isinstance(tok_start, int):
        g = (math.gcd(math.gcd(Nl, N), tok_start) if tok_start
             else math.gcd(Nl, N))
        blk = min(g & (-g), 128)         # largest power-of-two divisor
        if blk >= 8:
            kops.record_kernel_hit("stale_kv.static")
            return ("static", blk)
        kops.record_kernel_miss("tile-too-small")
        return ("off", 0)
    wp = cfg.tokens_per_side
    blk = min(wp & (-wp), 128)
    if blk < 8:
        kops.record_kernel_miss("tile-too-small")
        return ("off", 0)
    if Nl % blk or N % blk:
        kops.record_kernel_miss("padding-misaligned")
        return ("off", 0)
    kops.record_kernel_hit("stale_kv.padded")
    return ("padded", blk)


def _modulate(x, shift, scale):
    return x * (1 + scale[:, None]) + shift[:, None]


def _ln(x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def _cond_vector(params, cfg, t, cond, B, frame=None):
    t = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (B,))
    temb = layers.sinusoidal_embedding(t, 256)
    if frame is not None:
        # multi-frame conditioning (DESIGN.md §16): a sinusoidal frame-index
        # embedding is summed into the timestep features BEFORE the shared
        # MLP, so frames of one video are distinguishable without new
        # params. ``frame`` may be traced (one compile covers every frame).
        # Frame 0 — the anchor frame — passes None and is conditioned
        # exactly like an image, keeping its trajectory bitwise the image
        # path.
        fr = jnp.broadcast_to(jnp.asarray(frame, jnp.float32), (B,))
        temb = temb + layers.sinusoidal_embedding(fr, 256)
    temb = jax.nn.silu(temb.astype(params["t_w1"].dtype) @ params["t_w1"]) @ params["t_w2"]
    if cond is None:
        cemb = 0.0
    elif getattr(cond, "ndim", 0) >= 2:
        # prompt tokens (DESIGN.md §17): cond [B, L, cond_dim + 1], last
        # channel the validity mask. The masked mean of the real tokens
        # feeds the adaLN conditioning vector through ctx_pool; the CFG
        # null branch (all-zero tokens AND mask) pools to exactly 0.0 —
        # the token-space image of the NULL_COND zero embedding below.
        toks, w = cond[..., :-1], cond[..., -1:]
        pooled = jnp.sum(toks * w, axis=1) \
            / jnp.maximum(jnp.sum(w, axis=1), 1.0)
        # broadcast-multiply-reduce instead of ``pooled @ ctx_pool``: a
        # [1, Dc] x [Dc, D] matmul lowers to a gemv standalone but a gemm
        # under the serving engine's lane vmap, and the two accumulate in
        # different orders — this form is batch-shape-invariant, keeping
        # prompt lanes bitwise identical to single-request generate
        pooled = pooled.astype(params["ctx_pool"].dtype)
        cemb = jnp.sum(pooled[..., :, None] * params["ctx_pool"], axis=-2)
    else:
        # class ids >= 0 gather their embedding; the reserved NULL_COND (-1)
        # id selects the zero (unconditional) embedding — the traced-data
        # null branch classifier-free guidance evaluates (DESIGN.md §12)
        idx = jnp.broadcast_to(jnp.asarray(cond, jnp.int32), (B,))
        gathered = params["cond_embed"][jnp.clip(idx, 0)]
        cemb = jnp.where((idx >= 0)[:, None], gathered,
                         jnp.zeros_like(gathered))
    return jax.nn.silu(temb + cemb)                      # [B, D]


# ----------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------

def embed_patch(params, cfg: DiTConfig, x_rows, t, cond, row_start,
                frame=None):
    """Pre-block embedding of a row-patch: patchify + patch embed + 2D pos
    embed + conditioning vector. Returns (h [B,Nl,D], c [B,D])."""
    B = x_rows.shape[0]
    p = cfg.patch_size
    wp = cfg.tokens_per_side
    tok = patchify(x_rows, p)                            # [B, Nl, token_dim]
    Nl = tok.shape[1]
    D = cfg.d_model
    # pad the pos-embed table so padded tail tokens can't shift a clamped
    # dynamic_slice back over the valid region
    pe_full = jnp.concatenate([pos_embed_2d(wp, wp, D),
                               jnp.zeros((Nl, D))], axis=0)
    pe = jax.lax.dynamic_slice_in_dim(pe_full, row_start * wp, Nl, axis=0)
    h = tok @ params["patch_embed"] + params["patch_bias"] + pe.astype(tok.dtype)
    c = _cond_vector(params, cfg, t, cond, B, frame=frame)   # [B, D]
    return h, c


def block_stack(blocks, cfg: DiTConfig, h, c, tok_start,
                buffers: Optional[Tuple] = None, return_kv: bool = True,
                valid_tokens: Optional[jnp.ndarray] = None, enable=None,
                attend_fn=None, ctx_tokens: Optional[int] = None,
                prompt_ctx: Optional[Tuple] = None):
    """Run a contiguous stack of DiT blocks over hidden states ``h``.

    The ONE place the block math lives: ``forward_patch`` runs the whole
    depth through it, and the displaced patch pipeline (DESIGN.md §11) runs
    each stage's slice through it, so stage-segmented numerics can never
    drift from the monolithic forward.

    blocks:  pytree of per-block params, leading axis = block count
    buffers: None (local-only attention) or (buf_k, buf_v) each
             [n_blocks, B, N_total, H, hd] — the stale/displaced K/V context
             for these blocks; own region overwritten fresh before attending
    enable:  optional [n_blocks] bool — a disabled block is an exact
             identity (SPMD stage padding); None compiles with no masking at
             all, preserving the monolithic forward bitwise
    attend_fn: optional replacement for the buffered attention read,
             called as ``attend_fn(q, full_k, full_v, key_mask)`` with the
             freshness-blended whole-image context — the hook the
             sequence-parallel executor (DESIGN.md §13) uses to route the
             read through Ulysses all-to-all + ring hops without touching
             the block math. None preserves the dense read bitwise.
    ctx_tokens: scratch-padded layouts only (``valid_tokens`` set) — number
             of REAL context tokens in the buffers before the scratch tail.
             None = ``cfg.n_tokens`` (the pre-frames behavior); the
             multi-frame SPMD path (DESIGN.md §16) passes ``2 * n_tokens``
             for its (own frame ⊕ previous frame) concatenated context.
    prompt_ctx: prompt conditioning (DESIGN.md §17) — (tokens [B,Lc,Dc],
             key_mask [B,1,1,Lc] bool) cross-attended by every block
             between self-attention and the MLP. None (the
             cond_seq_len=0 degeneracy) traces ZERO extra ops, keeping
             the class-conditional path bitwise.
    Returns (h', kvs) with kvs [n_blocks, B, Nl, H, hd] pairs (or None).
    """
    B, Nl, D = h.shape[0], h.shape[1], cfg.d_model
    H = cfg.n_heads
    hd = D // H
    pallas_mode, pallas_blk = (
        _pallas_block(cfg, tok_start, Nl, buffers[0].shape[2],
                      valid_tokens, enable)
        if buffers is not None and attend_fn is None else ("off", 0))
    if prompt_ctx is not None and cfg.use_pallas_attention:
        # the prompt read runs the reference attend: no Pallas cross-attn
        # body yet (self-attention above still takes the kernel) — recorded
        # at trace time so kernel_stats surfaces the gap honestly
        from repro.kernels import ops as kops
        kops.record_kernel_miss("cross-attn-unsupported")
    # Padded kernel contract: real tokens = cfg.n_tokens when the buffers
    # carry the SPMD scratch tail, else the whole buffer; a local slab with
    # no valid_tokens is entirely fresh.
    if pallas_mode == "padded":
        n_real = ((ctx_tokens or cfg.n_tokens)
                  if valid_tokens is not None else buffers[0].shape[2])
        valid_arg = valid_tokens if valid_tokens is not None else Nl

    def block(x, scanned):
        if enable is not None:
            scanned, on = scanned
        if buffers is None:
            bp = scanned
        else:
            bp, bk, bv = scanned
        mod = c.astype(x.dtype) @ bp["mod_w"] + bp["mod_b"]
        sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
        xn = _modulate(_ln(x), sh1, sc1)
        qkv = (xn @ bp["qkv"]).reshape(B, Nl, 3, H, hd)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        if buffers is None:
            att = layers.attend(q, k, v)                 # local-only (exact if full)
        elif pallas_mode == "static":
            # fused freshness-select flash kernel: no HBM buffer rewrite
            att = _stale_kernel_attend(q, k, v, bk, bv, tok_start,
                                       pallas_blk)
        elif pallas_mode == "padded":
            # shard_map form of the same fusion: traced tok_start and the
            # valid_tokens scratch mask ride into the kernel as
            # scalar-prefetch operands, so the blend + dynamic_update_slice
            # + masked attend below collapses into one flash loop.
            att = _stale_kernel_attend_padded(q, k, v, bk, bv, tok_start,
                                              valid_arg, n_real, pallas_blk)
        else:
            # SPMD path: buffers are scratch-padded to N + Nl tokens so the
            # read-modify-write below never clamps; the padded tail of the
            # local slab is blended back to the buffer's current values so it
            # cannot overwrite a neighbour's stale region, and scratch keys
            # are masked out of the softmax.
            ku, vu, key_mask = k, v, None
            if valid_tokens is not None:
                mask = (jnp.arange(Nl) < valid_tokens)[None, :, None, None]
                cur_k = jax.lax.dynamic_slice_in_dim(bk, tok_start, Nl, axis=1)
                cur_v = jax.lax.dynamic_slice_in_dim(bv, tok_start, Nl, axis=1)
                ku = jnp.where(mask, k.astype(bk.dtype), cur_k)
                vu = jnp.where(mask, v.astype(bv.dtype), cur_v)
                key_mask = (jnp.arange(bk.shape[1])
                            < (ctx_tokens or cfg.n_tokens))[None, None, None, :]
            full_k = jax.lax.dynamic_update_slice_in_dim(bk, ku.astype(bk.dtype), tok_start, axis=1)
            full_v = jax.lax.dynamic_update_slice_in_dim(bv, vu.astype(bv.dtype), tok_start, axis=1)
            if attend_fn is not None:
                att = attend_fn(q, full_k, full_v, key_mask)
            else:
                att = layers.attend(q, full_k, full_v, mask=key_mask)
        x2 = x + g1[:, None] * (att.reshape(B, Nl, D) @ bp["wo"])
        if prompt_ctx is not None:
            # prompt cross-attention (DESIGN.md §17): every latent token
            # reads the prompt sequence. The CFG null branch (all-zero
            # tokens) projects to zero V, so its read contributes exactly
            # 0.0 — NULL_COND semantics in token space.
            ck, cmask = prompt_ctx
            xq = (_ln(x2) @ bp["xq"]).reshape(B, Nl, H, hd)
            xkv = (ck.astype(x.dtype) @ bp["xkv"]).reshape(
                B, ck.shape[1], 2, H, hd)
            xatt = layers.attend(xq, xkv[:, :, 0], xkv[:, :, 1], mask=cmask)
            x2 = x2 + xatt.reshape(B, Nl, D) @ bp["xo"]
        xn = _modulate(_ln(x2), sh2, sc2)
        hmid = jax.nn.gelu(xn @ bp["w1"]) @ bp["w2"]
        x2 = x2 + g2[:, None] * hmid
        if enable is not None:           # padded stage slot: exact identity
            x2 = jnp.where(on, x2, x)
        return x2, ((k, v) if return_kv else None)

    scanned = blocks if buffers is None else (blocks,) + tuple(buffers)
    if enable is not None:
        scanned = (scanned, enable)
    return jax.lax.scan(block, h, scanned)


def final_head(params, cfg: DiTConfig, h, c, rows_tok: int):
    """adaLN-zero output head: hidden states -> eps rows."""
    mod = c.astype(h.dtype) @ params["final_mod_w"] + params["final_mod_b"]
    sh, sc = jnp.split(mod, 2, axis=-1)
    out = _modulate(_ln(h), sh, sc) @ params["final_proj"]
    return unpatchify(out, cfg.patch_size, rows_tok, cfg.tokens_per_side,
                      cfg.channels)


def forward_patch(params, cfg: DiTConfig, x_rows, t, cond,
                  row_start: int, buffers: Optional[Tuple] = None,
                  return_kv: bool = True, valid_tokens: Optional[jnp.ndarray] = None,
                  attend_fn=None, frame=None, ctx_tokens=None):
    """Denoise a row-patch with stale remote K/V.

    x_rows: [B, rows_local, W, C] latent slab (full width).
    buffers: None (local-only attention: exact when patch == full image)
             or (buf_k, buf_v) each [L, B, N_total, H, hd] — stale K/V for the
             WHOLE image; the local region is overwritten with fresh values
             before attending (DistriFusion semantics). N_total may exceed
             the image token count: the multi-frame path (DESIGN.md §16)
             passes a 2N-token (own frame ⊕ previous frame) concatenation
             and the block math is oblivious — the fresh overwrite lands in
             the first N tokens and attention reads the whole context.
    row_start: first token-row of this patch (for positional embeddings);
               may be a traced int (SPMD path with per-device offsets).
    valid_tokens: SPMD path — number of REAL local tokens (rest is padding to
               the max patch size); padded tokens never pollute the buffer.
    frame: None (image; bitwise-unchanged path) or the latent frame index —
               may be traced — summed into the conditioning vector.

    Returns (eps_rows [B, rows_local, W, C], (fresh_k, fresh_v) [L,B,Nl,H,hd]).
    """
    rows_tok = x_rows.shape[1] // cfg.patch_size         # token rows in patch
    h, c = embed_patch(params, cfg, x_rows, t, cond, row_start, frame=frame)
    tok_start = row_start * cfg.tokens_per_side
    prompt_ctx = None
    if getattr(cond, "ndim", 0) >= 3:
        # prompt-token cond [B, L, cond_dim + 1] (DESIGN.md §17): split off
        # the trailing validity-mask channel into the cross-attention key
        # mask. cond.ndim is static under jit, so the class-conditional
        # trace (int cond) carries zero extra ops.
        if not cfg.cross_attn:
            raise ValueError(
                "prompt-token cond needs DiTConfig.cross_attn=True "
                "(see DiTConfig.text_conditioned())")
        ck = cond[..., :-1]
        cmask = (cond[..., -1] > 0.5)[:, None, None, :]
        prompt_ctx = (ck, cmask)
    h, kvs = block_stack(params["blocks"], cfg, h, c, tok_start,
                         buffers=buffers, return_kv=return_kv,
                         valid_tokens=valid_tokens, attend_fn=attend_fn,
                         ctx_tokens=ctx_tokens, prompt_ctx=prompt_ctx)
    eps = final_head(params, cfg, h, c, rows_tok)
    return eps, kvs


def forward(params, cfg: DiTConfig, x, t, cond=None, frame=None):
    """Full-image denoiser: [B,H,W,C] -> eps [B,H,W,C] (the Origin path)."""
    eps, _ = forward_patch(params, cfg, x, t, cond, 0, buffers=None,
                           return_kv=False, frame=frame)
    return eps


def null_like(cond) -> jnp.ndarray:
    """The unconditional branch for a cond of either kind: all-zero prompt
    tokens (empty sequence — mask channel included) for token conds
    [B, L, Dc+1], the reserved NULL_COND id for class conds [B]."""
    from repro.core.guidance import NULL_COND
    cond = jnp.asarray(cond)
    if cond.ndim >= 2:
        return jnp.zeros_like(cond)
    return jnp.full_like(cond.astype(jnp.int32), NULL_COND)


def guidance_conds(cond) -> jnp.ndarray:
    """Branch-stacked conds: row 0 = conditional, row 1 = the unconditional
    branch. [2, B] class ids for class conds; [2, B, L, Dc+1] for prompt
    tokens (row 1 the all-zero empty sequence — see text_encoder.null_cond)."""
    from repro.core.guidance import NULL_COND
    cond = jnp.asarray(cond)
    if cond.ndim >= 2:
        return jnp.stack([cond, jnp.zeros_like(cond)])
    cond = cond.astype(jnp.int32)
    return jnp.stack([cond, jnp.full_like(cond, NULL_COND)])


def forward_cfg(params, cfg: DiTConfig, x, t, cond, scale):
    """Fused-batch classifier-free guidance reference (DESIGN.md §12): one
    branch-vmapped dispatch evaluates the conditional and unconditional
    forwards, combined as ``eps_u + scale * (eps_c - eps_u)``. This is the
    CFG analogue of :func:`forward` ("Origin"): exact, single-device, and
    the bitwise reference every guided schedule path is tested against."""
    from repro.core.sampler import cfg_combine
    eps2 = jax.vmap(lambda c: forward(params, cfg, x, t, c))(
        guidance_conds(cond))
    return cfg_combine(eps2[0], eps2[1], scale)


def buffer_shape(cfg: DiTConfig, batch: int):
    D, H = cfg.d_model, cfg.n_heads
    return (cfg.n_layers, batch, cfg.n_tokens, H, D // H)


def init_buffers(cfg: DiTConfig, batch: int, dtype=None):
    dt = dtype or jnp.dtype(cfg.dtype)
    shape = buffer_shape(cfg, batch)
    return jnp.zeros(shape, dt), jnp.zeros(shape, dt)
