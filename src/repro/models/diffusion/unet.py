"""Small conv UNet denoiser (SDXL's architecture class, scaled down).

Single-device quality wing only: STADI's distributed path targets the DiT
(DESIGN.md §2 hardware-adaptation table). Pure JAX (lax.conv), functional.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.diffusion import UNetConfig
from repro.models import layers


def _conv_init(key, shape, dtype):
    fan_in = shape[0] * shape[1] * shape[2]
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            / math.sqrt(fan_in)).astype(dtype)


def conv2d(x, w, stride: int = 1):
    """x: [B,H,W,C]; w: [kh,kw,Cin,Cout]; SAME padding."""
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))


def group_norm(x, gamma, beta, groups: int = 8, eps: float = 1e-5):
    B, H, W, C = x.shape
    g = min(groups, C)
    x32 = x.astype(jnp.float32).reshape(B, H, W, g, C // g)
    mu = jnp.mean(x32, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(x32, axis=(1, 2, 4), keepdims=True)
    x32 = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (x32.reshape(B, H, W, C) * gamma + beta).astype(x.dtype)


def _res_block_init(key, cin, cout, temb_dim, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "gn1_g": jnp.ones((cin,), dtype), "gn1_b": jnp.zeros((cin,), dtype),
        "conv1": _conv_init(ks[0], (3, 3, cin, cout), dtype),
        "temb_w": layers.dense_init(ks[1], (temb_dim, cout), dtype),
        "gn2_g": jnp.ones((cout,), dtype), "gn2_b": jnp.zeros((cout,), dtype),
        "conv2": jnp.zeros((3, 3, cout, cout), dtype),        # zero-init last conv
    }
    if cin != cout:
        p["skip"] = _conv_init(ks[2], (1, 1, cin, cout), dtype)
    return p


def _res_block(p, x, temb):
    h = jax.nn.silu(group_norm(x, p["gn1_g"], p["gn1_b"]))
    h = conv2d(h, p["conv1"])
    h = h + (jax.nn.silu(temb) @ p["temb_w"])[:, None, None, :]
    h = jax.nn.silu(group_norm(h, p["gn2_g"], p["gn2_b"]))
    h = conv2d(h, p["conv2"])
    skip = conv2d(x, p["skip"]) if "skip" in p else x
    return skip + h


def _attn_init(key, c, dtype):
    ks = jax.random.split(key, 2)
    return {"gn_g": jnp.ones((c,), dtype), "gn_b": jnp.zeros((c,), dtype),
            "qkv": layers.dense_init(ks[0], (c, 3 * c), dtype),
            "out": jnp.zeros((c, c), dtype)}


def _attn_block(p, x):
    B, H, W, C = x.shape
    h = group_norm(x, p["gn_g"], p["gn_b"]).reshape(B, H * W, C)
    qkv = (h @ p["qkv"]).reshape(B, H * W, 3, 1, C)
    att = layers.attend(qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2])
    return x + (att.reshape(B, H * W, C) @ p["out"]).reshape(B, H, W, C)


def init_params(key, cfg: UNetConfig):
    dt = jnp.dtype(cfg.param_dtype)
    temb_dim = cfg.base_width * 4
    ks = iter(jax.random.split(key, 256))
    p = {
        "t_w1": layers.dense_init(next(ks), (256, temb_dim), dt),
        "t_w2": layers.dense_init(next(ks), (temb_dim, temb_dim), dt),
        "cond": layers.embed_init(next(ks), (cfg.n_classes, temb_dim), dt),
        "conv_in": _conv_init(next(ks), (3, 3, cfg.channels, cfg.base_width), dt),
        "down": [], "up": [],
    }
    widths = [cfg.base_width * m for m in cfg.channel_mults]
    cin = cfg.base_width
    for lvl, w in enumerate(widths):
        blocks = []
        for _ in range(cfg.n_res_blocks):
            blk = {"res": _res_block_init(next(ks), cin, w, temb_dim, dt)}
            if lvl in cfg.attn_levels:
                blk["attn"] = _attn_init(next(ks), w, dt)
            blocks.append(blk)
            cin = w
        p["down"].append({"blocks": blocks,
                          "downsample": _conv_init(next(ks), (3, 3, w, w), dt)
                          if lvl < len(widths) - 1 else None})
    p["mid1"] = _res_block_init(next(ks), cin, cin, temb_dim, dt)
    p["mid_attn"] = _attn_init(next(ks), cin, dt)
    p["mid2"] = _res_block_init(next(ks), cin, cin, temb_dim, dt)
    for lvl, w in reversed(list(enumerate(widths))):
        blocks = []
        for _ in range(cfg.n_res_blocks):
            blk = {"res": _res_block_init(next(ks), cin + w, w, temb_dim, dt)}
            if lvl in cfg.attn_levels:
                blk["attn"] = _attn_init(next(ks), w, dt)
            blocks.append(blk)
            cin = w
        p["up"].append({"blocks": blocks})
    p["gn_out_g"] = jnp.ones((cin,), dt)
    p["gn_out_b"] = jnp.zeros((cin,), dt)
    p["conv_out"] = jnp.zeros((3, 3, cin, cfg.channels), dt)
    return p


def forward(params, cfg: UNetConfig, x, t, cond=None):
    B = x.shape[0]
    t = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (B,))
    temb = layers.sinusoidal_embedding(t, 256).astype(x.dtype)
    temb = jax.nn.silu(temb @ params["t_w1"]) @ params["t_w2"]
    if cond is not None:
        temb = temb + params["cond"][jnp.broadcast_to(jnp.asarray(cond, jnp.int32), (B,))]

    h = conv2d(x, params["conv_in"])
    skips = []
    for level in params["down"]:
        for blk in level["blocks"]:
            h = _res_block(blk["res"], h, temb)
            if "attn" in blk:
                h = _attn_block(blk["attn"], h)
        skips.append(h)
        if level["downsample"] is not None:
            h = conv2d(h, level["downsample"], stride=2)
    h = _res_block(params["mid1"], h, temb)
    h = _attn_block(params["mid_attn"], h)
    h = _res_block(params["mid2"], h, temb)
    for level in params["up"]:
        skip = skips.pop()
        if h.shape[1] != skip.shape[1]:
            B_, H_, W_, C_ = h.shape
            h = jax.image.resize(h, (B_, skip.shape[1], skip.shape[2], C_), "nearest")
        h = jnp.concatenate([h, skip], axis=-1)
        for blk in level["blocks"]:
            h = _res_block(blk["res"], h, temb)
            if "attn" in blk:
                h = _attn_block(blk["attn"], h)
    h = jax.nn.silu(group_norm(h, params["gn_out_g"], params["gn_out_b"]))
    return conv2d(h, params["conv_out"])
