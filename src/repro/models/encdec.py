"""Encoder-decoder backbone (SeamlessM4T-medium text/speech-to-text).

The speech frontend is a STUB per the brief: the encoder consumes
precomputed frame embeddings [B, S_src, D] delivered by ``input_specs``.
Decoder = causal self-attn + cross-attn + FFN. Scan-over-layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.lm import _constrain


def tgt_len_for(src_len: int) -> int:
    """Convention: training/prefill target length = src_len // 4 (speech:text)."""
    return max(16, src_len // 4)


def init_params(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    k_enc, k_dec, k_embed, k_head = jax.random.split(key, 4)

    def init_enc_block(k):
        ka, km = jax.random.split(k)
        return {
            "ln1": jnp.zeros((cfg.d_model,), dt),
            "attn": layers.init_attention(ka, cfg),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "mlp": layers.init_mlp(km, cfg),
        }

    def init_dec_block(k):
        ka, kx, km = jax.random.split(k, 3)
        return {
            "ln1": jnp.zeros((cfg.d_model,), dt),
            "attn": layers.init_attention(ka, cfg),
            "lnx": jnp.zeros((cfg.d_model,), dt),
            "xattn": layers.init_attention(kx, cfg),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "mlp": layers.init_mlp(km, cfg),
        }

    return {
        "enc_blocks": jax.vmap(init_enc_block)(jax.random.split(k_enc, cfg.n_enc_layers)),
        "dec_blocks": jax.vmap(init_dec_block)(jax.random.split(k_dec, cfg.n_layers)),
        "embed": layers.embed_init(k_embed, (cfg.vocab, cfg.d_model), dt),
        "enc_ln_f": jnp.zeros((cfg.d_model,), dt),
        "dec_ln_f": jnp.zeros((cfg.d_model,), dt),
        "head": layers.dense_init(k_head, (cfg.d_model, cfg.vocab), dt),
    }


def encode(params, cfg, src_embeds):
    """src_embeds [B,Ss,D] (stub frontend output) -> memory [B,Ss,D]."""
    x = src_embeds.astype(jnp.dtype(cfg.dtype))

    def body(x, p):
        x = _constrain(x, cfg)
        h = layers.bidirectional_attention(p["attn"], layers.rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
        x = x + h
        x = x + layers.mlp(p["mlp"], layers.rms_norm(x, p["ln2"], cfg.norm_eps), cfg.activation)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layers.rms_norm(x, params["enc_ln_f"], cfg.norm_eps)


def cross_kv(params, cfg, memory):
    """Precompute per-decoder-layer cross-attention K/V: [L,B,Ss,K,hd]."""
    B, Ss, _ = memory.shape

    def body(_, p):
        k = (memory @ p["xattn"]["wk"]).reshape(B, Ss, cfg.n_kv_heads, cfg.hd)
        v = (memory @ p["xattn"]["wv"]).reshape(B, Ss, cfg.n_kv_heads, cfg.hd)
        return None, (k, v)

    _, (mk, mv) = jax.lax.scan(body, None, params["dec_blocks"])
    return mk, mv


def _dec_block(p, x, cfg, mem_kv, *, window: int = 0):
    x = _constrain(x, cfg)
    h, kv = layers.self_attention(p["attn"], layers.rms_norm(x, p["ln1"], cfg.norm_eps),
                                  cfg, window=window)
    x = x + h
    x = x + layers.cross_attention(p["xattn"], layers.rms_norm(x, p["lnx"], cfg.norm_eps),
                                   mem_kv, cfg)
    x = x + layers.mlp(p["mlp"], layers.rms_norm(x, p["ln2"], cfg.norm_eps), cfg.activation)
    return x, kv


def decode_forward(params, cfg, tgt_tokens, memory, *, window: int = 0,
                   return_kv: bool = False, logits_last_only: bool = False):
    mk, mv = cross_kv(params, cfg, memory)
    x = params["embed"][tgt_tokens].astype(jnp.dtype(cfg.dtype))

    def body(x, scanned):
        p, k, v = scanned
        x, kv = _dec_block(p, x, cfg, (k, v), window=window)
        return x, (kv if return_kv else None)

    x, kvs = jax.lax.scan(body, x, (params["dec_blocks"], mk, mv))
    if logits_last_only:
        x = x[:, -1:]
    x = layers.rms_norm(x, params["dec_ln_f"], cfg.norm_eps)
    return x @ params["head"].astype(x.dtype), kvs, (mk, mv)


def loss_fn(params, cfg, batch):
    """batch: src_embeds [B,Ss,D], tgt_tokens [B,St], labels [B,St]."""
    memory = encode(params, cfg, batch["src_embeds"])
    logits, _, _ = decode_forward(params, cfg, batch["tgt_tokens"], memory)
    return layers.cross_entropy(logits[:, :-1], batch["labels"][:, 1:])


# ----------------------------------------------------------------------
# serving
# ----------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, src_len: int, *, window: int = 0):
    T = window if window else max_len
    dt = jnp.dtype(cfg.dtype)
    kv = (cfg.n_layers, batch, T, cfg.n_kv_heads, cfg.hd)
    mem = (cfg.n_layers, batch, src_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt),
            "mem_k": jnp.zeros(mem, dt), "mem_v": jnp.zeros(mem, dt),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(params, cfg, src_embeds, tgt_tokens, cache, *, window: int = 0):
    memory = encode(params, cfg, src_embeds)
    logits, kvs, (mk, mv) = decode_forward(params, cfg, tgt_tokens, memory,
                                           window=window, return_kv=True,
                                           logits_last_only=True)
    k, v = kvs
    S = k.shape[2]
    T = cache["k"].shape[2]
    if S >= T:
        k, v = k[:, :, S - T:], v[:, :, S - T:]
        cache = {**cache, "k": k.astype(cache["k"].dtype), "v": v.astype(cache["v"].dtype)}
    else:
        cache = {**cache,
                 "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, axis=2),
                 "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, axis=2)}
    return logits[:, -1], {**cache, "mem_k": mk.astype(cache["mem_k"].dtype),
                           "mem_v": mv.astype(cache["mem_v"].dtype),
                           "pos": jnp.asarray(S, jnp.int32)}


def decode_step(params, cfg, cache, token, *, window: int = 0):
    x = params["embed"][token[:, None]].astype(jnp.dtype(cfg.dtype))
    pos = cache["pos"]

    def body(x, scanned):
        p, ck, cv, mk, mv = scanned
        h, nk, nv = layers.decode_attention(p["attn"], layers.rms_norm(x, p["ln1"], cfg.norm_eps),
                                            cfg, ck, cv, pos, window=window)
        x = x + h
        x = x + layers.cross_attention(p["xattn"], layers.rms_norm(x, p["lnx"], cfg.norm_eps),
                                       (mk, mv), cfg)
        x = x + layers.mlp(p["mlp"], layers.rms_norm(x, p["ln2"], cfg.norm_eps), cfg.activation)
        return x, (nk, nv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["dec_blocks"], cache["k"], cache["v"],
                                         cache["mem_k"], cache["mem_v"]))
    x = layers.rms_norm(x, params["dec_ln_f"], cfg.norm_eps)
    logits = (x @ params["head"].astype(x.dtype))[:, 0]
    return logits, {**cache, "k": nk, "v": nv, "pos": pos + 1}
