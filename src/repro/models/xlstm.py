"""xLSTM (arXiv:2405.04517): interleaved mLSTM (matrix memory) and sLSTM
(scalar memory, recurrent gating) blocks.

Reference path = exact recurrent ``lax.scan`` over time (exponential gating
with the paper's max-stabilizer). The chunkwise-parallel mLSTM form lives in
``repro.kernels.ssm_scan`` as the TPU Pallas kernel; its oracle is this file.

Blocks are heterogeneous (every ``slstm_every``-th is sLSTM), so layers are
unrolled in Python (12 layers => small HLO) instead of scan-over-layers.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.models import layers


def _d_inner(cfg) -> int:
    return int(cfg.proj_factor * cfg.d_model)


def is_slstm(cfg, layer_idx: int) -> bool:
    return cfg.slstm_every > 0 and (layer_idx % cfg.slstm_every) == (cfg.slstm_every - 1)


# ----------------------------------------------------------------------
# params
# ----------------------------------------------------------------------

def init_mlstm_block(key, cfg):
    D, Di, H = cfg.d_model, _d_inner(cfg), cfg.n_heads
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    return {
        "ln": jnp.zeros((D,), dt),
        "w_up": layers.dense_init(ks[0], (D, 2 * Di), dt),       # x, z branches
        "conv": layers.dense_init(ks[1], (cfg.ssm_conv, Di), dt, scale=0.3),
        "wq": layers.dense_init(ks[2], (Di, Di), dt),
        "wk": layers.dense_init(ks[3], (Di, Di), dt),
        "wv": layers.dense_init(ks[4], (Di, Di), dt),
        "w_if": layers.dense_init(ks[5], (Di, 2 * H), jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((H,)), jnp.linspace(3.0, 6.0, H)]),  # forget bias
        "w_down": layers.dense_init(ks[6], (Di, D), dt,
                                    scale=1.0 / math.sqrt(2 * cfg.n_layers * Di)),
    }


def init_slstm_block(key, cfg):
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.zeros((D,), dt),
        "w_x": layers.dense_init(ks[0], (D, 4 * D), dt),          # z,i,f,o from x
        "r_h": layers.dense_init(ks[1], (H, dh, 4 * dh), dt, scale=1.0 / math.sqrt(dh)),
        "b": jnp.concatenate([jnp.zeros((2 * D,)), jnp.full((D,), 3.0), jnp.zeros((D,))]),
        "w_down": layers.dense_init(ks[2], (D, D), dt,
                                    scale=1.0 / math.sqrt(2 * cfg.n_layers * D)),
    }


def init_params(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    bkeys = jax.random.split(k_blocks, cfg.n_layers)
    blocks: List[Dict[str, Any]] = []
    for l in range(cfg.n_layers):
        if is_slstm(cfg, l):
            blocks.append(init_slstm_block(bkeys[l], cfg))
        else:
            blocks.append(init_mlstm_block(bkeys[l], cfg))
    return {
        "embed": layers.embed_init(k_embed, (cfg.vocab, cfg.d_model), dt),
        "blocks": blocks,
        "ln_f": jnp.zeros((cfg.d_model,), dt),
        "head": layers.dense_init(k_head, (cfg.d_model, cfg.vocab), dt),
    }


# ----------------------------------------------------------------------
# mLSTM cell
# ----------------------------------------------------------------------

def mlstm_init_state(cfg, batch: int):
    Di, H = _d_inner(cfg), cfg.n_heads
    dh = Di // H
    f32 = jnp.float32
    return {
        "C": jnp.zeros((batch, H, dh, dh), f32),
        "n": jnp.zeros((batch, H, dh), f32),
        "m": jnp.full((batch, H), -1e30, f32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, Di), jnp.dtype(cfg.dtype)),
    }


def _mlstm_cell_step(state, qkvif):
    """One recurrence step. q,k,v: [B,H,dh]; logi,logf: [B,H]."""
    q, k, v, logi, logf = qkvif
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(logf + m, logi)
    decay = jnp.exp(logf + m - m_new)
    inp = jnp.exp(logi - m_new)
    C = decay[..., None, None] * C + inp[..., None, None] * (v[..., :, None] * k[..., None, :])
    n = decay[..., None] * n + inp[..., None] * k
    num = jnp.einsum("bhij,bhj->bhi", C, q)            # C q   (C = v k^T)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhi,bhi->bh", n, q)), jnp.exp(-m_new))
    h = num / den[..., None]
    return {"C": C, "n": n, "m": m_new, "conv": state["conv"]}, h


def _mlstm_proj(p, xb, cfg, conv_state):
    """Projections shared by scan/step. xb: [B,S,D] (pre-normed).

    Returns (q,k,v [B,S,H,dh] f32, logi/logf [B,S,H] f32, z [B,S,Di], new conv state).
    """
    B, S, D = xb.shape
    Di, H = _d_inner(cfg), cfg.n_heads
    dh = Di // H
    up = xb @ p["w_up"]
    x_br, z = jnp.split(up, 2, axis=-1)
    # causal depthwise conv over time (with carried state for decode)
    pad = jnp.concatenate([conv_state.astype(x_br.dtype), x_br], axis=1)
    w = p["conv"]                                      # [W, Di]
    W = w.shape[0]
    xc = sum(pad[:, i:i + S] * w[i] for i in range(W))
    xc = jax.nn.silu(xc)
    new_conv = pad[:, -(W - 1):] if W > 1 else conv_state
    q = (xc @ p["wq"]).reshape(B, S, H, dh).astype(jnp.float32) / math.sqrt(dh)
    k = (xc @ p["wk"]).reshape(B, S, H, dh).astype(jnp.float32) / math.sqrt(dh)
    v = (x_br @ p["wv"]).reshape(B, S, H, dh).astype(jnp.float32)
    gates = xc.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    logi, logf = gates[..., :H], jax.nn.log_sigmoid(gates[..., H:])
    return q, k, v, logi, logf, z, new_conv


def mlstm_forward(p, x, cfg, state):
    """x: [B,S,D] -> (y [B,S,D], new state). Sequential scan over S."""
    xb = layers.rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v, logi, logf, z, new_conv = _mlstm_proj(p, xb, cfg, state["conv"])

    def body(st, t):
        return _mlstm_cell_step(st, jax.tree.map(lambda a: a[:, t], (q, k, v, logi, logf)))

    S = x.shape[1]
    st, hs = jax.lax.scan(body, state, jnp.arange(S))
    hs = jnp.moveaxis(hs, 0, 1)                        # [B,S,H,dh]
    B = x.shape[0]
    h = hs.reshape(B, S, -1).astype(x.dtype) * jax.nn.silu(z)
    y = h @ p["w_down"]
    return x + y, {**st, "conv": new_conv}


# ----------------------------------------------------------------------
# sLSTM cell
# ----------------------------------------------------------------------

def slstm_init_state(cfg, batch: int):
    D = cfg.d_model
    H = cfg.n_heads
    dh = D // H
    f32 = jnp.float32
    z = jnp.zeros((batch, H, dh), f32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, H, dh), -1e30, f32)}


def _slstm_step(p, cfg, state, x_t):
    """x_t: [B,D] (pre-normed). Returns (new_state, h_out [B,D])."""
    B, D = x_t.shape
    H = cfg.n_heads
    dh = D // H
    gx = x_t @ p["w_x"] + p["b"].astype(x_t.dtype)     # [B,4D]
    h_prev = state["h"].astype(jnp.float32)            # [B,H,dh]
    gh = jnp.einsum("bhd,hde->bhe", h_prev, p["r_h"].astype(jnp.float32))  # [B,H,4dh]
    # w_x packs gates as [z|i|f|o] each D wide = H*dh; regroup per head
    gx = gx.astype(jnp.float32).reshape(B, 4, H, dh).transpose(0, 2, 1, 3).reshape(B, H, 4 * dh)
    g = gx + gh
    zg, ig, fg, og = jnp.split(g, 4, axis=-1)          # each [B,H,dh]
    z = jnp.tanh(zg)
    o = jax.nn.sigmoid(og)
    logi = ig
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + state["m"], logi)
    i_s = jnp.exp(logi - m_new)
    f_s = jnp.exp(logf + state["m"] - m_new)
    c = f_s * state["c"] + i_s * z
    n = f_s * state["n"] + i_s
    h = o * c / jnp.maximum(n, 1e-6)
    new = {"c": c, "n": n, "h": h, "m": m_new}
    return new, h.reshape(B, D)


def slstm_forward(p, x, cfg, state):
    xb = layers.rms_norm(x, p["ln"], cfg.norm_eps)

    def body(st, x_t):
        return _slstm_step(p, cfg, st, x_t)

    st, hs = jax.lax.scan(body, state, jnp.moveaxis(xb, 0, 1))
    hs = jnp.moveaxis(hs, 0, 1).astype(x.dtype)        # [B,S,D]
    return x + hs @ p["w_down"], st


# ----------------------------------------------------------------------
# model API
# ----------------------------------------------------------------------

def init_state(cfg, batch: int):
    states = []
    for l in range(cfg.n_layers):
        states.append(slstm_init_state(cfg, batch) if is_slstm(cfg, l)
                      else mlstm_init_state(cfg, batch))
    return states


def forward(params, cfg, tokens, state=None, *, logits_last_only: bool = False):
    B = tokens.shape[0]
    if state is None:
        state = init_state(cfg, B)
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    new_states = []
    for l, p in enumerate(params["blocks"]):
        fwd = slstm_forward if is_slstm(cfg, l) else mlstm_forward
        x, st = fwd(p, x, cfg, state[l])
        new_states.append(st)
    if logits_last_only:
        x = x[:, -1:]
    x = layers.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return x @ params["head"].astype(x.dtype), new_states


def loss_fn(params, cfg, batch):
    logits, _ = forward(params, cfg, batch["tokens"])
    return layers.cross_entropy(logits[:, :-1], batch["labels"][:, 1:])


def prefill(params, cfg, tokens, state=None):
    logits, state = forward(params, cfg, tokens, state, logits_last_only=True)
    return logits[:, -1], state


def decode_step(params, cfg, state, token):
    logits, state = forward(params, cfg, token[:, None], state)
    return logits[:, 0], state
