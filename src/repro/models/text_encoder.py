"""Frozen text-encoder stub: deterministic prompt tokens for the DiT
(DESIGN.md §17).

A real T2I/T2V deployment runs a CLIP/T5 tower whose output — a
``[B, L, D]`` sequence of prompt tokens — is what the denoiser
cross-attends. This module is that tower's *scheduling stand-in*: a
hash-token embedding + sinusoidal positions + a tiny frozen transformer,
all derived deterministically from one seed, so every executor / process /
test sees bitwise-identical prompt tokens for the same prompt string. The
encoder is FROZEN by construction (params are a pure function of the seed;
nothing is ever trained), which is also how production prompt towers are
served.

Conventions the rest of the stack relies on:

- ``encode`` returns ``[B, L, cond_dim + 1]``: the last channel is a
  validity mask (1.0 = real token, 0.0 = bucket padding). Padded
  positions are zeroed in EVERY channel, so one prompt encoded into one
  bucket is bitwise-identical regardless of what shares the batch — the
  serving engine's per-request-bitwise-vs-generate guarantee depends on
  it.
- The classifier-free-guidance null branch is the EMPTY sequence:
  ``null_cond`` is all-zeros (mask 0 everywhere). Zero tokens project to
  zero K/V, so cross-attention contributes exactly 0.0 and the pooled
  conditioning vector is exactly 0.0 — the token-space image of the
  reserved ``NULL_COND`` class id (dit._cond_vector's zero embedding).
- Variable-length prompts are padded to power-of-two BUCKETS
  (:func:`bucket_length`): each bucket is its own jit specialization and
  its own serving lane group / plan-cache key component.
"""
from __future__ import annotations

import functools
import hashlib
import math
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.configs.diffusion import DiTConfig
from repro.models import layers

#: hash-token vocabulary (open-vocab prompts fold onto it deterministically)
VOCAB = 1024
#: frozen-tower depth / width multipliers (tiny on purpose: the encoder is
#: a latency- and numerics-faithful stand-in, not a quality model)
N_LAYERS = 2
N_HEADS = 4
#: smallest prompt bucket (lengths below it still pad to it)
MIN_BUCKET = 4
#: the one seed every process derives the frozen tower from
DEFAULT_SEED = 1234


def tokenize(prompt: str, max_len: int) -> List[int]:
    """Deterministic open-vocabulary tokenization: whitespace words, each
    hashed (sha256) onto the fixed VOCAB. Truncates to ``max_len``.
    Stable across processes and Python hash randomization."""
    words = prompt.strip().lower().split()
    ids = []
    for w in words[:max_len]:
        h = hashlib.sha256(w.encode("utf-8")).digest()
        ids.append(int.from_bytes(h[:4], "big") % VOCAB)
    return ids


def bucket_length(n_tokens: int, cond_seq_len: int) -> int:
    """Smallest power-of-two bucket >= n_tokens (floor MIN_BUCKET, cap
    cond_seq_len). The bucket is the serving batching axis: lanes sharing
    a bucket share one jitted dispatch shape."""
    if cond_seq_len < 1:
        raise ValueError("bucket_length needs cond_seq_len >= 1 "
                         f"(got {cond_seq_len}) — is cross_attn configured?")
    n = max(int(n_tokens), 1)
    b = MIN_BUCKET
    while b < n:
        b *= 2
    return min(b, cond_seq_len)


@functools.lru_cache(maxsize=8)
def frozen_params(cond_dim: int, seed: int = DEFAULT_SEED):
    """The frozen tower's params — a pure function of (cond_dim, seed).

    Embedding table + N_LAYERS pre-LN bidirectional transformer blocks at
    width cond_dim. Cached so repeated encodes share one pytree (and one
    jit cache)."""
    D = cond_dim
    F = 4 * D
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 1 + N_LAYERS)
    dt = jnp.float32

    def init_block(k):
        kq, ko, k1, k2 = jax.random.split(k, 4)
        return {
            "qkv": layers.dense_init(kq, (D, 3 * D), dt),
            "wo": layers.dense_init(ko, (D, D), dt,
                                    scale=1.0 / math.sqrt(2 * N_LAYERS * D)),
            "w1": layers.dense_init(k1, (D, F), dt),
            "w2": layers.dense_init(k2, (F, D), dt,
                                    scale=1.0 / math.sqrt(2 * N_LAYERS * F)),
        }

    blocks = jax.vmap(init_block)(jax.random.split(ks[0], N_LAYERS))
    return {
        "embed": layers.embed_init(ks[1], (VOCAB, D), dt),
        "blocks": blocks,
    }


@functools.partial(jax.jit, static_argnames=("cond_dim",))
def _encode_ids(params, ids, mask, cond_dim: int):
    """[B, L] hash-token ids + [B, L] validity mask -> [B, L, cond_dim]
    prompt tokens (padded positions zeroed)."""
    B, L = ids.shape
    D = cond_dim
    H = N_HEADS
    hd = D // H
    pos = jnp.arange(L, dtype=jnp.float32)
    h = params["embed"][jnp.clip(ids, 0)] \
        + layers.sinusoidal_embedding(pos, D)[None]
    key_mask = (mask > 0.5)[:, None, None, :]            # [B,1,1,L]

    def block(x, bp):
        xn = layers.rms_norm(x, jnp.zeros((D,)))
        qkv = (xn @ bp["qkv"]).reshape(B, L, 3, H, hd)
        att = layers.attend(qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2],
                            mask=key_mask)
        x = x + att.reshape(B, L, D) @ bp["wo"]
        xn = layers.rms_norm(x, jnp.zeros((D,)))
        x = x + jax.nn.gelu(xn @ bp["w1"]) @ bp["w2"]
        return x, None

    h, _ = jax.lax.scan(block, h, params["blocks"])
    # zero out padding so a prompt's tokens are independent of bucket junk
    return h * mask[..., None]


def encode(prompts: Union[str, Sequence[str]], cfg: DiTConfig, *,
           length: Optional[int] = None,
           seed: int = DEFAULT_SEED) -> jnp.ndarray:
    """Encode prompt string(s) into ``[B, L, cond_dim + 1]`` cond tokens.

    ``length`` pins the padded bucket (defaults to the per-batch
    :func:`bucket_length`); the final channel is the validity mask. The
    returned array is the ``cond`` every executor consumes opaquely —
    ``cond.ndim == 3`` is the static (shape-level) signal that a workload
    is prompt- rather than class-conditioned.
    """
    if not cfg.cross_attn or cfg.cond_seq_len < 1:
        raise ValueError(
            "prompt conditioning needs a text-conditioned model config "
            "(DiTConfig.cross_attn=True, cond_seq_len >= 1) — see "
            "DiTConfig.text_conditioned()")
    if isinstance(prompts, str):
        prompts = [prompts]
    tok = [tokenize(p, cfg.cond_seq_len) for p in prompts]
    L = length or bucket_length(max((len(t) for t in tok), default=1),
                                cfg.cond_seq_len)
    if L > cfg.cond_seq_len:
        raise ValueError(f"bucket {L} exceeds cond_seq_len "
                         f"{cfg.cond_seq_len}")
    B = len(tok)
    ids = jnp.asarray([t[:L] + [0] * (L - len(t)) for t in tok], jnp.int32)
    mask = jnp.asarray([[1.0] * min(len(t), L) + [0.0] * (L - min(len(t), L))
                        for t in tok], jnp.float32)
    h = _encode_ids(frozen_params(cfg.cond_dim, seed), ids, mask,
                    cfg.cond_dim)
    return jnp.concatenate([h, mask[..., None]], axis=-1)


def null_cond(batch: int, length: int, cfg: DiTConfig) -> jnp.ndarray:
    """The CFG null branch: an EMPTY prompt sequence — all channels
    (including the validity mask) exactly zero. Cross-attending it
    contributes exactly 0.0 (zero tokens project to zero K/V), preserving
    NULL_COND semantics in token space."""
    return jnp.zeros((batch, length, cfg.cond_dim + 1), jnp.float32)


def cond_tokens_from_ids(ids: Sequence[int], cfg: DiTConfig, *,
                         length: Optional[int] = None,
                         seed: int = DEFAULT_SEED) -> jnp.ndarray:
    """Encode raw hash-token ids (the ``--cond-tokens`` CLI path) into one
    ``[1, L, cond_dim + 1]`` cond array."""
    ids = [int(i) % VOCAB for i in ids]
    if not ids:
        raise ValueError("--cond-tokens needs at least one token id")
    L = length or bucket_length(len(ids), cfg.cond_seq_len)
    if not cfg.cross_attn or cfg.cond_seq_len < 1:
        raise ValueError(
            "prompt conditioning needs a text-conditioned model config "
            "(DiTConfig.cross_attn=True, cond_seq_len >= 1)")
    ids = ids[:L]
    idv = jnp.asarray([ids + [0] * (L - len(ids))], jnp.int32)
    mask = jnp.asarray([[1.0] * len(ids) + [0.0] * (L - len(ids))],
                       jnp.float32)
    h = _encode_ids(frozen_params(cfg.cond_dim, seed), idv, mask,
                    cfg.cond_dim)
    return jnp.concatenate([h, mask[..., None]], axis=-1)
