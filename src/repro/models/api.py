"""Uniform model API across families.

``build_model(cfg)`` returns a :class:`Model` exposing:
  init(rng) -> params
  loss(params, batch) -> scalar            (training objective)
  init_cache(batch, max_len, window=0, src_len=0) -> decode cache
  prefill(params, batch, cache, window=0) -> (last_logits, cache)
  decode_step(params, cache, token, window=0) -> (logits, cache)
  make_batch(rng, batch, seq) -> concrete batch  (smoke tests)

batch dict keys by family:
  dense/moe : tokens, labels
  vlm       : + vision_embeds [B, n_vision_tokens, D]  (stub ViT frontend)
  encdec    : src_embeds [B,Ss,D] (stub audio frontend), tgt_tokens, labels
  ssm/hybrid: tokens, labels
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, hymba, lm, xlstm


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.family = cfg.family

    # -- params ---------------------------------------------------------
    def init(self, rng):
        f = {"ssm": xlstm.init_params, "hybrid": hymba.init_params,
             "encdec": encdec.init_params}.get(self.family, lm.init_params)
        return f(rng, self.cfg)

    # -- training -------------------------------------------------------
    def loss(self, params, batch: Dict[str, Any]):
        f = {"ssm": xlstm.loss_fn, "hybrid": hymba.loss_fn,
             "encdec": encdec.loss_fn}.get(self.family, lm.loss_fn)
        return f(params, self.cfg, batch)

    def forward_logits(self, params, batch):
        cfg = self.cfg
        if self.family == "ssm":
            return xlstm.forward(params, cfg, batch["tokens"])[0]
        if self.family == "hybrid":
            return hymba.forward(params, cfg, batch["tokens"])[0]
        if self.family == "encdec":
            memory = encdec.encode(params, cfg, batch["src_embeds"])
            return encdec.decode_forward(params, cfg, batch["tgt_tokens"], memory)[0]
        return lm.forward(params, cfg, batch["tokens"],
                          vision_embeds=batch.get("vision_embeds"))[0]

    # -- serving --------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, *, window: int = 0, src_len: int = 0):
        cfg = self.cfg
        if self.family == "ssm":
            return xlstm.init_state(cfg, batch)
        if self.family == "hybrid":
            return hymba.init_cache(cfg, batch, max_len, window=window)
        if self.family == "encdec":
            return encdec.init_cache(cfg, batch, max_len, src_len or max_len, window=window)
        return lm.init_cache(cfg, batch, max_len, window=window)

    def prefill(self, params, batch, cache, *, window: int = 0):
        cfg = self.cfg
        if self.family == "ssm":
            return xlstm.prefill(params, cfg, batch["tokens"], cache)
        if self.family == "hybrid":
            return hymba.prefill(params, cfg, batch["tokens"], cache, window=window)
        if self.family == "encdec":
            return encdec.prefill(params, cfg, batch["src_embeds"], batch["tgt_tokens"],
                                  cache, window=window)
        if self.family == "vlm":
            # vision embeddings consumed during prefill; cache covers meta+text
            logits, _, kvs = lm.forward(params, cfg, batch["tokens"],
                                        vision_embeds=batch["vision_embeds"],
                                        window=window, return_kv=True,
                                        logits_last_only=True)
            k, v = kvs
            S = k.shape[2]
            T = cache["k"].shape[2]
            if S >= T:
                k, v = k[:, :, S - T:], v[:, :, S - T:]
                cache = {**cache, "k": k.astype(cache["k"].dtype),
                         "v": v.astype(cache["v"].dtype)}
            else:
                cache = {**cache,
                         "k": jax.lax.dynamic_update_slice_in_dim(
                             cache["k"], k.astype(cache["k"].dtype), 0, axis=2),
                         "v": jax.lax.dynamic_update_slice_in_dim(
                             cache["v"], v.astype(cache["v"].dtype), 0, axis=2)}
            return logits[:, -1], {**cache, "pos": jnp.asarray(S, jnp.int32)}
        return lm.prefill(params, cfg, batch["tokens"], cache, window=window)

    def decode_step(self, params, cache, token, *, window: int = 0):
        cfg = self.cfg
        if self.family == "ssm":
            return xlstm.decode_step(params, cfg, cache, token)
        if self.family == "hybrid":
            return hymba.decode_step(params, cfg, cache, token, window=window)
        if self.family == "encdec":
            return encdec.decode_step(params, cfg, cache, token, window=window)
        return lm.decode_step(params, cfg, cache, token, window=window)

    # -- synthetic batches ----------------------------------------------
    def make_batch(self, rng, batch: int, seq: int) -> Dict[str, Any]:
        cfg = self.cfg
        kt, ke = jax.random.split(rng)
        tokens = jax.random.randint(kt, (batch, seq), 0, cfg.vocab, jnp.int32)
        out: Dict[str, Any] = {"tokens": tokens, "labels": tokens}
        if self.family == "vlm":
            out["vision_embeds"] = jax.random.normal(
                ke, (batch, cfg.n_vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype)) * 0.02
        if self.family == "encdec":
            st = encdec.tgt_len_for(seq)
            out = {"src_embeds": jax.random.normal(ke, (batch, seq, cfg.d_model),
                                                   jnp.dtype(cfg.dtype)) * 0.02,
                   "tgt_tokens": tokens[:, :st], "labels": tokens[:, :st]}
        return out


@functools.lru_cache(maxsize=None)
def _build_cached(arch_id: str) -> Model:
    from repro.configs import get_config
    return Model(get_config(arch_id))


def build_model(cfg_or_id) -> Model:
    if isinstance(cfg_or_id, str):
        return _build_cached(cfg_or_id)
    return Model(cfg_or_id)
