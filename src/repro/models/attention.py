"""Attention implementation dispatch.

``chunked_attend`` is a pure-JAX flash-style online-softmax over KV chunks:
it never materializes the [S,T] score matrix, cutting the memory roofline
term from O(S*T) to O(S*chunk) — the dry-run/CPU stand-in for the Pallas
``flash_attention`` kernel (same algorithm; the kernel additionally tiles
into VMEM). Selected per-arch via ``cfg.attn_impl`` and verified equivalent
to the naive path in tests.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers


def chunked_attend(q, k, v, *, causal: bool, window: int = 0,
                   prefix_len: int = 0, chunk: int = 512,
                   scale: Optional[float] = None):
    """q: [B,S,H,hd]; k,v: [B,T,K,hd] (K | H). Flash-style scan over T.

    Masks match layers.self_attention semantics: causal (+ sliding window,
    with a ``prefix_len`` of always-visible leading positions).
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    if T % chunk:
        pad = chunk - T % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        pad = 0
    Tp = T + pad
    nc = Tp // chunk
    kc = k.reshape(B, nc, chunk, K, hd)
    vc = v.reshape(B, nc, chunk, K, hd)
    q32 = q.astype(jnp.float32)
    qi = jnp.arange(S)[:, None]

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, c = inp                                   # [B,chunk,K,hd], idx
        kb = layers.repeat_kv(kb, H // K).astype(jnp.float32)
        vb = layers.repeat_kv(vb, H // K).astype(jnp.float32)
        s = jnp.einsum("bshd,bthd->bhst", q32, kb) * scale  # [B,H,S,chunk]
        kj = c * chunk + jnp.arange(chunk)[None, :]
        valid = kj < T
        if causal:
            valid = valid & (kj <= qi)
        if window:
            w_ok = kj > qi - window
            if prefix_len:
                w_ok = w_ok | (kj < prefix_len)
            valid = valid & w_ok
        s = jnp.where(valid[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhst,bthd->bhsd", p, vb)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    acc0 = jnp.zeros((B, H, S, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.arange(nc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)        # [B,S,H,hd]
