"""AdamW optimizer (pure-JAX, pytree-native; no optax in this container)."""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    gnorm = jnp.sqrt(jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0
                 ) -> Tuple[Any, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g32
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g32)
        step = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - cfg.lr * lr_scale * (step + decay)
        return newp.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}
