"""Chunked selective-SSM scan (Mamba recurrence) Pallas TPU kernel.

    h_t = exp(delta_t * A) * h_{t-1} + (delta_t * x_t) B_t^T
    y_t = <h_t, C_t> + D * x_t

Grid (B, n_dblocks, n_chunks): the chunk axis is sequential ("arbitrary")
with the running state h [dblk, N] carried in VMEM scratch across chunks —
HBM traffic is O(S * dblk) instead of O(S * dblk * N) for a naive
materialized-state scan, and each chunk's inner recurrence runs entirely in
VMEM/VREGs. dblk is lane-aligned (multiple of 128) in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x compat: CompilerParams was named TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _ssm_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, dskip_ref, o_ref,
                h_ref, *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)          # [chunk, dblk]
    dt = dt_ref[0].astype(jnp.float32)        # [chunk, dblk]
    bt = b_ref[0].astype(jnp.float32)         # [chunk, N]
    ct = c_ref[0].astype(jnp.float32)         # [chunk, N]
    a = a_ref[...].astype(jnp.float32)        # [dblk, N]
    dskip = dskip_ref[...].astype(jnp.float32)  # [dblk]

    def step(t, carry):
        h, ys = carry
        da = jnp.exp(dt[t][:, None] * a)                       # [dblk, N]
        h = da * h + (dt[t] * x[t])[:, None] * bt[t][None, :]  # [dblk, N]
        y = jnp.sum(h * ct[t][None, :], axis=1) + dskip * x[t]
        ys = jax.lax.dynamic_update_index_in_dim(ys, y, t, 0)
        return h, ys

    h0 = h_ref[...]
    ys0 = jnp.zeros((chunk, x.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    h_ref[...] = h
    o_ref[0] = ys.astype(o_ref.dtype)


def ssm_scan_chunked(x, dt, b_t, c_t, a, d_skip, *, chunk: int = 64,
                     dblk: int = 128, interpret: bool = True):
    """x, dt: [B,S,Di]; b_t, c_t: [B,S,N]; a: [Di,N]; d_skip: [Di].
    Returns y [B,S,Di]. S % chunk == 0, Di % dblk == 0 (ops.py pads)."""
    B, S, Di = x.shape
    N = b_t.shape[-1]
    n_chunks = S // chunk
    nd = Di // dblk

    kernel = functools.partial(_ssm_kernel, chunk=chunk, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=(B, nd, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, dblk), lambda bb, d, c: (bb, c, d)),
            pl.BlockSpec((1, chunk, dblk), lambda bb, d, c: (bb, c, d)),
            pl.BlockSpec((1, chunk, N), lambda bb, d, c: (bb, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda bb, d, c: (bb, c, 0)),
            pl.BlockSpec((dblk, N), lambda bb, d, c: (d, 0)),
            pl.BlockSpec((dblk,), lambda bb, d, c: (d,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dblk), lambda bb, d, c: (bb, c, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, Di), x.dtype),
        scratch_shapes=[pltpu.VMEM((dblk, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, b_t, c_t, a, d_skip)
