"""Jit'd public wrappers around the Pallas kernels: layout transforms
([B,S,H,hd] <-> [B,H,S,hd]), GQA head broadcast, shape padding to tile
multiples, interpret-mode selection (interpret=True off-TPU per the brief).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import ssm_scan as ss
from repro.kernels import stale_kv_attention as ska


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, mult: int, axis: int):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def _tile(n: int, target: int = 128, floor: int = 8) -> int:
    """Largest hardware-friendly tile <= n (prefers 128-multiples)."""
    if n >= target:
        return target
    t = floor
    while t * 2 <= n:
        t *= 2
    return t


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 0, bk: int = 0):
    """q: [B,S,H,hd]; k,v: [B,T,K,hd] (K | H). Returns [B,S,H,hd].

    Pads S/T to tile multiples; padded key positions are masked out by
    re-padding K with -inf-free semantics: queries in the pad region produce
    garbage that is sliced away; padded keys get zero K => their scores join
    softmax, so we mask them via an additional window/causal trick: we pad T
    only when causal (pad keys are in the future of every real query) or
    explicitly mask by appending keys at +inf distance (handled below).
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    q = jnp.moveaxis(q, 2, 1)                        # [B,H,S,hd]
    k = jnp.moveaxis(k, 2, 1)
    v = jnp.moveaxis(v, 2, 1)
    if K != H:
        rep = H // K
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    bq = bq or _tile(S)
    bk = bk or _tile(T)
    q, pad_s = _pad_to(q, bq, 2)
    k, pad_t = _pad_to(k, bk, 2)
    v, _ = _pad_to(v, bk, 2)
    if pad_t and not causal:
        # mask padded keys by forcing them outside every window; with no
        # causal/window mask, fall back to key masking via huge negative K
        # contribution: simplest robust route = causal=False, window covering
        # all real keys relative to padded query positions is not expressible,
        # so use an explicit validity trick: set padded K rows to a value that
        # yields -inf scores via q@k = 0 and subtract with a bias is not
        # available; instead shift to ref path for this rare case.
        out = jnp.moveaxis(
            _masked_ref(q, k, v, T, causal=causal, window=window), 1, 2)
        return out[:, :S]
    out = fa.flash_attention_bhsd(q, k, v, causal=causal, window=window,
                                  bq=bq, bk=bk, interpret=_interpret())
    out = jnp.moveaxis(out, 1, 2)                    # [B,S,H,hd]
    return out[:, :S]


def _masked_ref(q, k, v, T_valid, *, causal, window):
    from repro.kernels.ref import attention_ref
    T = k.shape[2]
    if T == T_valid:
        return attention_ref(q, k, v, causal=causal, window=window)
    # zero-out padded keys via an explicit mask on scores
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (q.shape[-1] ** -0.5)
    valid = (jnp.arange(T) < T_valid)[None, None, None]
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, v.astype(jnp.float32)).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("tok_start", "bq", "bk"))
def stale_kv_attention(q, k_fresh, v_fresh, k_stale, v_stale, *,
                       tok_start: int, bq: int = 0, bk: int = 0):
    """DistriFusion hot op. q/k_fresh/v_fresh: [B,Nl,H,hd] local fresh;
    k_stale/v_stale: [B,N,H,hd] full-image stale. Returns [B,Nl,H,hd].
    tok_start/Nl/N must share a common tile divisor (token rows are
    128-token multiples for sdxl-dit; ops picks bk = gcd-friendly tile)."""
    B, Nl, H, hd = q.shape
    N = k_stale.shape[1]
    q = jnp.moveaxis(q, 2, 1)
    kf = jnp.moveaxis(k_fresh, 2, 1)
    vf = jnp.moveaxis(v_fresh, 2, 1)
    ks = jnp.moveaxis(k_stale, 2, 1)
    vs = jnp.moveaxis(v_stale, 2, 1)
    import math
    g = math.gcd(math.gcd(Nl, N), tok_start) if tok_start else math.gcd(Nl, N)
    bk = bk or _tile(g, 128, 8)
    bq = bq or _tile(Nl, 128, 8)
    out = ska.stale_kv_attention_bhsd(q, kf, vf, ks, vs, tok_start,
                                      bq=bq, bk=bk, interpret=_interpret())
    return jnp.moveaxis(out, 1, 2)


@functools.partial(jax.jit, static_argnames=("chunk", "dblk"))
def ssm_scan(x, dt, b_t, c_t, a, d_skip, *, chunk: int = 0, dblk: int = 0):
    """Chunked SSM scan; pads S to chunk and Di to dblk multiples."""
    B, S, Di = x.shape
    chunk = chunk or _tile(S, 64, 4)
    dblk = dblk or _tile(Di, 128, 8)
    x, pad_s = _pad_to(x, chunk, 1)
    dt, _ = _pad_to(dt, chunk, 1)
    b_t, _ = _pad_to(b_t, chunk, 1)
    c_t, _ = _pad_to(c_t, chunk, 1)
    x, pad_d = _pad_to(x, dblk, 2)
    dt, _ = _pad_to(dt, dblk, 2)
    a2, _ = _pad_to(a, dblk, 0)
    dsk, _ = _pad_to(d_skip, dblk, 0)
    y = ss.ssm_scan_chunked(x, dt, b_t, c_t, a2, dsk, chunk=chunk, dblk=dblk,
                            interpret=_interpret())
    return y[:, :S, :Di]
