"""Jit'd public wrappers around the Pallas kernels: layout transforms
([B,S,H,hd] <-> [B,H,S,hd]), GQA head broadcast, shape padding to tile
multiples, interpret-mode selection (interpret=True off-TPU per the brief,
overridable via STADI_PALLAS_INTERPRET), and the kernel-path hit/miss
counters every executor reports through (DESIGN.md §15).
"""
from __future__ import annotations

import collections
import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import ssm_scan as ss
from repro.kernels import stale_kv_attention as ska


def _interpret() -> bool:
    """Pallas interpret-mode selection: real lowering on TPU, interpreter
    elsewhere. ``STADI_PALLAS_INTERPRET=1`` forces the interpreter even on
    TPU (CI determinism); ``=0`` demands real lowering and FAILS LOUDLY on
    a host with no TPU rather than silently timing the interpreter."""
    env = os.environ.get("STADI_PALLAS_INTERPRET", "").strip().lower()
    on_tpu = jax.default_backend() == "tpu"
    if env in ("1", "true", "yes"):
        return True
    if env in ("0", "false", "no"):
        if not on_tpu:
            raise RuntimeError(
                "STADI_PALLAS_INTERPRET=0 demands compiled Pallas kernels, "
                f"but jax.default_backend() == {jax.default_backend()!r} "
                "(no TPU). Interpret-mode timings are NOT a TPU proxy — "
                "unset the variable to run the interpreter explicitly.")
        return False
    if env:
        raise ValueError(
            f"STADI_PALLAS_INTERPRET={env!r} is not a recognized value "
            "(use 1/true/yes, 0/false/no, or unset for auto)")
    return not on_tpu


# ----------------------------------------------------------------------
# kernel-path visibility: trace-time hit/miss counters (DESIGN.md §15)
# ----------------------------------------------------------------------
#
# Counted when the kernel call (or its refusal) is TRACED, not executed:
# jit caching means a program traced once and run R times counts once, so
# the numbers answer "does this executor's compiled program contain the
# kernel?" — which is what the parity tests must assert (a silent fallback
# would still produce correct images). Misses are only recorded when
# use_pallas_attention asked for the kernel and the layout refused it.

_kernel_hits: collections.Counter = collections.Counter()
_kernel_misses: collections.Counter = collections.Counter()


def record_kernel_hit(kind: str) -> None:
    _kernel_hits[kind] += 1


def record_kernel_miss(reason: str) -> None:
    _kernel_misses[reason] += 1


def kernel_stats_snapshot() -> dict:
    """Copy of the process-wide counters: {"hits": {...}, "misses": {...}}."""
    return {"hits": dict(_kernel_hits), "misses": dict(_kernel_misses)}


def kernel_stats_delta(before: dict, after: dict) -> dict:
    """after - before, dropping zero entries (per-run attribution)."""
    out = {}
    for key in ("hits", "misses"):
        d = {k: after[key].get(k, 0) - before[key].get(k, 0)
             for k in after[key]}
        out[key] = {k: v for k, v in d.items() if v}
    return out


def reset_kernel_stats() -> None:
    _kernel_hits.clear()
    _kernel_misses.clear()


def _pad_to(x, mult: int, axis: int):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def _tile(n: int, target: int = 128, floor: int = 8) -> int:
    """Largest hardware-friendly tile <= n (prefers 128-multiples)."""
    if n >= target:
        return target
    t = floor
    while t * 2 <= n:
        t *= 2
    return t


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 0, bk: int = 0):
    """q: [B,S,H,hd]; k,v: [B,T,K,hd] (K | H). Returns [B,S,H,hd].

    Pads S/T to tile multiples; padded key positions are masked out by
    re-padding K with -inf-free semantics: queries in the pad region produce
    garbage that is sliced away; padded keys get zero K => their scores join
    softmax, so we mask them via an additional window/causal trick: we pad T
    only when causal (pad keys are in the future of every real query) or
    explicitly mask by appending keys at +inf distance (handled below).
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    q = jnp.moveaxis(q, 2, 1)                        # [B,H,S,hd]
    k = jnp.moveaxis(k, 2, 1)
    v = jnp.moveaxis(v, 2, 1)
    if K != H:
        rep = H // K
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    bq = bq or _tile(S)
    bk = bk or _tile(T)
    q, pad_s = _pad_to(q, bq, 2)
    k, pad_t = _pad_to(k, bk, 2)
    v, _ = _pad_to(v, bk, 2)
    if pad_t and not causal:
        # mask padded keys by forcing them outside every window; with no
        # causal/window mask, fall back to key masking via huge negative K
        # contribution: simplest robust route = causal=False, window covering
        # all real keys relative to padded query positions is not expressible,
        # so use an explicit validity trick: set padded K rows to a value that
        # yields -inf scores via q@k = 0 and subtract with a bias is not
        # available; instead shift to ref path for this rare case.
        out = jnp.moveaxis(
            _masked_ref(q, k, v, T, causal=causal, window=window), 1, 2)
        return out[:, :S]
    out = fa.flash_attention_bhsd(q, k, v, causal=causal, window=window,
                                  bq=bq, bk=bk, interpret=_interpret())
    out = jnp.moveaxis(out, 1, 2)                    # [B,S,H,hd]
    return out[:, :S]


def _masked_ref(q, k, v, T_valid, *, causal, window):
    from repro.kernels.ref import attention_ref
    T = k.shape[2]
    if T == T_valid:
        return attention_ref(q, k, v, causal=causal, window=window)
    # zero-out padded keys via an explicit mask on scores
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (q.shape[-1] ** -0.5)
    valid = (jnp.arange(T) < T_valid)[None, None, None]
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, v.astype(jnp.float32)).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("tok_start", "bq", "bk"))
def stale_kv_attention(q, k_fresh, v_fresh, k_stale, v_stale, *,
                       tok_start: int, bq: int = 0, bk: int = 0):
    """DistriFusion hot op. q/k_fresh/v_fresh: [B,Nl,H,hd] local fresh;
    k_stale/v_stale: [B,N,H,hd] full-image stale. Returns [B,Nl,H,hd].
    tok_start/Nl/N must share a common tile divisor (token rows are
    128-token multiples for sdxl-dit; ops picks bk = gcd-friendly tile)."""
    B, Nl, H, hd = q.shape
    N = k_stale.shape[1]
    q = jnp.moveaxis(q, 2, 1)
    kf = jnp.moveaxis(k_fresh, 2, 1)
    vf = jnp.moveaxis(v_fresh, 2, 1)
    ks = jnp.moveaxis(k_stale, 2, 1)
    vs = jnp.moveaxis(v_stale, 2, 1)
    import math
    g = math.gcd(math.gcd(Nl, N), tok_start) if tok_start else math.gcd(Nl, N)
    bk = bk or _tile(g, 128, 8)
    bq = bq or _tile(Nl, 128, 8)
    out = ska.stale_kv_attention_bhsd(q, kf, vf, ks, vs, tok_start,
                                      bq=bq, bk=bk, interpret=_interpret())
    return jnp.moveaxis(out, 1, 2)


@functools.partial(jax.jit, static_argnames=("chunk", "dblk"))
def ssm_scan(x, dt, b_t, c_t, a, d_skip, *, chunk: int = 0, dblk: int = 0):
    """Chunked SSM scan; pads S to chunk and Di to dblk multiples."""
    B, S, Di = x.shape
    chunk = chunk or _tile(S, 64, 4)
    dblk = dblk or _tile(Di, 128, 8)
    x, pad_s = _pad_to(x, chunk, 1)
    dt, _ = _pad_to(dt, chunk, 1)
    b_t, _ = _pad_to(b_t, chunk, 1)
    c_t, _ = _pad_to(c_t, chunk, 1)
    x, pad_d = _pad_to(x, dblk, 2)
    dt, _ = _pad_to(dt, dblk, 2)
    a2, _ = _pad_to(a, dblk, 0)
    dsk, _ = _pad_to(d_skip, dblk, 0)
    y = ss.ssm_scan_chunked(x, dt, b_t, c_t, a2, dsk, chunk=chunk, dblk=dblk,
                            interpret=_interpret())
    return y[:, :S, :Di]


@functools.partial(jax.jit, static_argnames=("n_tokens", "bq", "bk"))
def stale_kv_attention_padded(q, k_fresh, v_fresh, k_stale, v_stale,
                              tok_start, valid_tokens, *, n_tokens: int,
                              bq: int = 8, bk: int = 8):
    """Padded-layout DistriFusion hot op (the shard_map form).

    q/k_fresh/v_fresh: [B,Nl_max,H,hd] local slab padded to the max patch;
    k_stale/v_stale: [B,Npad,H,hd] whole-image stale buffer (scratch-padded);
    tok_start/valid_tokens: TRACED per-device layout scalars (multiples of
    the tile contract, see kernels/stale_kv_attention.py); n_tokens: static
    real-context length (key mask). Returns [B,Nl_max,H,hd]."""
    out = ska.stale_kv_attention_padded_bhsd(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k_fresh, 2, 1),
        jnp.moveaxis(v_fresh, 2, 1), jnp.moveaxis(k_stale, 2, 1),
        jnp.moveaxis(v_stale, 2, 1), tok_start, valid_tokens,
        n_tokens=n_tokens, bq=bq, bk=bk, interpret=_interpret())
    return jnp.moveaxis(out, 1, 2)


@functools.partial(jax.jit, static_argnames=("n_tokens", "bq", "bk"))
def stale_kv_attention_guided(q, k_fresh, v_fresh, k_stale, v_stale,
                              tok_start, valid_tokens, uncond_fresh, *,
                              n_tokens: int, bq: int = 8, bk: int = 8):
    """Branch-stacked guided stale-KV attention: operands carry a leading
    guidance-branch axis of 2 ([2,B,Nl_max,H,hd] fresh / [2,B,Npad,H,hd]
    stale); ``uncond_fresh`` (traced 0/1) gates the unconditional branch's
    freshness blend in-kernel (0 = interleaved reuse: attend pure-stale).
    Returns [2,B,Nl_max,H,hd]."""
    out = ska.stale_kv_attention_guided_bhsd(
        jnp.moveaxis(q, 3, 2), jnp.moveaxis(k_fresh, 3, 2),
        jnp.moveaxis(v_fresh, 3, 2), jnp.moveaxis(k_stale, 3, 2),
        jnp.moveaxis(v_stale, 3, 2), tok_start, valid_tokens, uncond_fresh,
        n_tokens=n_tokens, bq=bq, bk=bk, interpret=_interpret())
    return jnp.moveaxis(out, 2, 3)


@functools.partial(jax.jit, static_argnames=("bq", "bk"))
def lse_attention(q, k, v, valid_len, *, bq: int = 0, bk: int = 0):
    """Per-hop ring attention partial: attend q over ONE K/V segment whose
    first ``valid_len`` (traced) keys are real, returning the normalized
    partial output AND its log-sum-exp for the cross-hop merge
    (DESIGN.md §15). q: [B,S,H,hd]; k/v: [B,T,H,hd]; valid_len <= T.
    Returns (out [B,S,H,hd], lse [B,S,H] fp32)."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    qb = jnp.moveaxis(q, 2, 1)
    kb = jnp.moveaxis(k, 2, 1)
    vb = jnp.moveaxis(v, 2, 1)
    bq = bq or _tile(S, 128, 8)
    bk = bk or _tile(T, 128, 8)
    qb, _ = _pad_to(qb, bq, 2)
    # padded key rows sit at positions >= T >= valid_len, so the kernel's
    # validity mask already excludes them
    kb, _ = _pad_to(kb, bk, 2)
    vb, _ = _pad_to(vb, bk, 2)
    out, lse = ska.lse_attention_bhsd(qb, kb, vb, valid_len, bq=bq, bk=bk,
                                      interpret=_interpret())
    return (jnp.moveaxis(out, 1, 2)[:, :S],
            jnp.moveaxis(lse, 1, 2)[:, :S])


def _cfg_epilogue_ref(eps_c, eps_u, scale):
    """The unfused formulas (bitwise ``sampler.cfg_combine``/``cfg_delta``),
    kept here so the kernels package never imports the sampler."""
    ec = eps_c.astype(jnp.float32)
    eu = eps_u.astype(jnp.float32)
    d = ec - eu
    return (eu + scale * d).astype(eps_c.dtype), d


@functools.partial(jax.jit, static_argnames=("with_delta",))
def cfg_epilogue(eps_c, eps_u, scale, *, with_delta: bool = True):
    """Fused CFG epilogue: ``(cfg_combine, cfg_delta)`` in ONE elementwise
    HBM pass over the branch pair (repro.kernels.cfg_epilogue). Numerically
    identical to the sampler helpers; per-lane ``scale`` arrays fall back
    to the unfused formulas (recorded as a kernel miss). Any eps shape."""
    from repro.kernels import cfg_epilogue as cfe
    if jnp.ndim(scale):                  # per-lane serving scales
        record_kernel_miss("cfg-per-lane-scale")
        comb, d = _cfg_epilogue_ref(eps_c, eps_u, scale)
        return (comb, d) if with_delta else comb
    record_kernel_hit("cfg_epilogue")
    shape, n = eps_c.shape, eps_c.size
    tile = cfe.SUBLANE * cfe.LANE
    pad = (-n) % tile
    flat_c = jnp.pad(eps_c.reshape(-1), (0, pad)).reshape(-1, cfe.LANE)
    flat_u = jnp.pad(eps_u.reshape(-1), (0, pad)).reshape(-1, cfe.LANE)
    comb, d = cfe.cfg_epilogue_2d(flat_c, flat_u, scale,
                                  interpret=_interpret())
    comb = comb.reshape(-1)[:n].reshape(shape)
    d = d.reshape(-1)[:n].reshape(shape)
    return (comb, d) if with_delta else comb
