"""Fused classifier-free-guidance epilogue (DESIGN.md §12/§15).

The guided steps end with two elementwise passes over the branch pair:
``cfg_combine``  = eps_u + w * (eps_c - eps_u)   (the denoiser output)
``cfg_delta``    = eps_c - eps_u                 (the interleaved cache)
Unfused, eps_c/eps_u stream from HBM twice (once per formula). This kernel
computes both in ONE pass — each branch tensor is read once, both outputs
written once — and is numerically identical to the sampler helpers (same
fp32 op order, combined cast back to the eps dtype, delta kept fp32).

``w`` arrives as a (1, 1) array broadcast to every grid cell rather than a
compile-time constant so the serving engine's traced per-run scales reuse
one compiled kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8


def _cfg_kernel(w_ref, ec_ref, eu_ref, o_ref, d_ref):
    w = w_ref[0, 0]
    ec = ec_ref[...].astype(jnp.float32)
    eu = eu_ref[...].astype(jnp.float32)
    d = ec - eu
    d_ref[...] = d
    o_ref[...] = (eu + w * d).astype(o_ref.dtype)


def cfg_epilogue_2d(eps_c, eps_u, scale, *, bm: int = 256,
                    interpret: bool = True):
    """eps_c/eps_u: [M, 128] tiles (M a multiple of 8); scale: scalar.
    Returns (combined [M,128] eps dtype, delta [M,128] fp32)."""
    M, lane = eps_c.shape
    assert lane == LANE and M % SUBLANE == 0, (M, lane)
    bm = min(bm, M)
    while M % bm:
        bm //= 2
    w = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _cfg_kernel,
        grid=(M // bm,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((bm, LANE), lambda i: (i, 0)),
            pl.BlockSpec((bm, LANE), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, LANE), lambda i: (i, 0)),
            pl.BlockSpec((bm, LANE), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((M, LANE), eps_c.dtype),
                   jax.ShapeDtypeStruct((M, LANE), jnp.float32)],
        interpret=interpret,
    )(w, eps_c, eps_u)
