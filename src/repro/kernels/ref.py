"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = False, window: int = 0,
                  scale=None):
    """q: [B,H,S,hd]; k,v: [B,H,T,hd] -> [B,H,S,hd]; fp32 softmax."""
    B, H, S, hd = q.shape
    T = k.shape[2]
    scale = scale if scale is not None else hd ** -0.5
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal or window:
        qi = jnp.arange(S)[:, None]
        kj = jnp.arange(T)[None, :]
        mask = jnp.ones((S, T), bool)
        if causal:
            mask &= kj <= qi
        if window:
            mask &= kj > qi - window
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, v.astype(jnp.float32)).astype(q.dtype)


def stale_kv_attention_ref(q_fresh, k_fresh, v_fresh, k_stale, v_stale,
                           tok_start: int, scale=None):
    """Materialize full_kv = update_slice(stale, fresh) then attend."""
    full_k = jax.lax.dynamic_update_slice_in_dim(
        k_stale, k_fresh.astype(k_stale.dtype), tok_start, axis=2)
    full_v = jax.lax.dynamic_update_slice_in_dim(
        v_stale, v_fresh.astype(v_stale.dtype), tok_start, axis=2)
    return attention_ref(q_fresh, full_k, full_v, scale=scale)


def ssm_scan_ref(x, dt, b_t, c_t, a, d_skip):
    """x, dt: [B,S,Di]; b_t/c_t: [B,S,N]; a: [Di,N]; d_skip: [Di] -> y."""
    def step(h, inp):
        x_t, d_t, bt, ct = inp
        da = jnp.exp(d_t[..., None] * a[None])
        h = da * h + (d_t * x_t)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct) + d_skip * x_t
        return h, y

    B, S, Di = x.shape
    N = b_t.shape[-1]
    h0 = jnp.zeros((B, Di, N), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 0, 1),
          jnp.moveaxis(dt.astype(jnp.float32), 0, 1),
          jnp.moveaxis(b_t.astype(jnp.float32), 0, 1),
          jnp.moveaxis(c_t.astype(jnp.float32), 0, 1))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
