"""Stale-KV patch attention — the DistriFusion/STADI hot loop as a TPU kernel.

Q comes from the LOCAL fresh patch (Nl tokens); keys/values for the whole
image come from the stale buffer EXCEPT the local region, which must use the
fresh K/V computed this step. The naive formulation first materializes
  full_kv = dynamic_update_slice(stale, fresh)        (2x KV HBM traffic)
then runs attention. This kernel fuses the region-select into the flash
loop: for kv-block j it loads BOTH the stale block and the (clamped) fresh
block and selects per-block — tok_start and Nl are multiples of the block
size, so every block is purely fresh or purely stale and the select is a
no-op branch on the MXU path. Bidirectional (diffusion attention: no mask).

TPU adaptation note (DESIGN.md §2): DistriFusion implements this as a CUDA
attention call over a buffer patched by an async NCCL broadcast; on TPU the
freshness-select moves INTO the kernel so the buffer is never rewritten in
HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x compat: CompilerParams was named TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _stale_kernel(qf_ref, kf_ref, vf_ref, ks_ref, vs_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale, bq, bk, nk,
                  start_block, n_local_blocks):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = qf_ref[0, 0].astype(jnp.float32)
    is_local = (ik >= start_block) & (ik < start_block + n_local_blocks)
    k = jnp.where(is_local, kf_ref[0, 0], ks_ref[0, 0]).astype(jnp.float32)
    v = jnp.where(is_local, vf_ref[0, 0], vs_ref[0, 0]).astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_cur

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def stale_kv_attention_bhsd(q_fresh, k_fresh, v_fresh, k_stale, v_stale,
                            tok_start: int, *, scale=None,
                            bq: int = 128, bk: int = 128,
                            interpret: bool = True):
    """q_fresh/k_fresh/v_fresh: [B,H,Nl,hd] (local patch);
    k_stale/v_stale: [B,H,N,hd] (full-image stale buffer);
    tok_start: local patch offset in the token stream (multiple of bk; Nl too).
    Returns [B,H,Nl,hd].
    """
    B, H, Nl, hd = q_fresh.shape
    N = k_stale.shape[2]
    assert tok_start % bk == 0 and Nl % bk == 0 and N % bk == 0, \
        (tok_start, Nl, N, bk)
    nq, nk = Nl // bq, N // bk
    start_block = tok_start // bk
    n_local = Nl // bk
    scale = scale if scale is not None else hd ** -0.5

    def fresh_kv_index(b, h, i, j):
        # clamp j into the local block range so OOB loads read a valid block
        jj = jnp.clip(j - start_block, 0, n_local - 1)
        return (b, h, jj, 0)

    kernel = functools.partial(_stale_kernel, scale=scale, bq=bq, bk=bk,
                               nk=nk, start_block=start_block,
                               n_local_blocks=n_local)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), fresh_kv_index),
            pl.BlockSpec((1, 1, bk, hd), fresh_kv_index),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Nl, hd), q_fresh.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q_fresh, k_fresh, v_fresh, k_stale, v_stale)
