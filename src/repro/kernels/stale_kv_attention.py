"""Stale-KV patch attention — the DistriFusion/STADI hot loop as a TPU kernel.

Q comes from the LOCAL fresh patch (Nl tokens); keys/values for the whole
image come from the stale buffer EXCEPT the local region, which must use the
fresh K/V computed this step. The naive formulation first materializes
  full_kv = dynamic_update_slice(stale, fresh)        (2x KV HBM traffic)
then runs attention. This kernel fuses the region-select into the flash
loop: for kv-block j it loads BOTH the stale block and the (clamped) fresh
block and selects per-block — tok_start and Nl are multiples of the block
size, so every block is purely fresh or purely stale and the select is a
no-op branch on the MXU path. Bidirectional (diffusion attention: no mask).

TPU adaptation note (DESIGN.md §2): DistriFusion implements this as a CUDA
attention call over a buffer patched by an async NCCL broadcast; on TPU the
freshness-select moves INTO the kernel so the buffer is never rewritten in
HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x compat: CompilerParams was named TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _stale_kernel(qf_ref, kf_ref, vf_ref, ks_ref, vs_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale, bq, bk, nk,
                  start_block, n_local_blocks):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = qf_ref[0, 0].astype(jnp.float32)
    is_local = (ik >= start_block) & (ik < start_block + n_local_blocks)
    k = jnp.where(is_local, kf_ref[0, 0], ks_ref[0, 0]).astype(jnp.float32)
    v = jnp.where(is_local, vf_ref[0, 0], vs_ref[0, 0]).astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    _online_softmax_update(s, v, acc_ref, m_ref, l_ref)

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _online_softmax_update(s, v, acc_ref, m_ref, l_ref):
    """One flash-attention block update of the (acc, m, l) scratch state."""
    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_cur


def stale_kv_attention_bhsd(q_fresh, k_fresh, v_fresh, k_stale, v_stale,
                            tok_start: int, *, scale=None,
                            bq: int = 128, bk: int = 128,
                            interpret: bool = True):
    """q_fresh/k_fresh/v_fresh: [B,H,Nl,hd] (local patch);
    k_stale/v_stale: [B,H,N,hd] (full-image stale buffer);
    tok_start: local patch offset in the token stream (multiple of bk; Nl too).
    Returns [B,H,Nl,hd].
    """
    B, H, Nl, hd = q_fresh.shape
    N = k_stale.shape[2]
    assert tok_start % bk == 0 and Nl % bk == 0 and N % bk == 0, \
        (tok_start, Nl, N, bk)
    nq, nk = Nl // bq, N // bk
    start_block = tok_start // bk
    n_local = Nl // bk
    scale = scale if scale is not None else hd ** -0.5

    def fresh_kv_index(b, h, i, j):
        # clamp j into the local block range so OOB loads read a valid block
        jj = jnp.clip(j - start_block, 0, n_local - 1)
        return (b, h, jj, 0)

    kernel = functools.partial(_stale_kernel, scale=scale, bq=bq, bk=bk,
                               nk=nk, start_block=start_block,
                               n_local_blocks=n_local)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), fresh_kv_index),
            pl.BlockSpec((1, 1, bk, hd), fresh_kv_index),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Nl, hd), q_fresh.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q_fresh, k_fresh, v_fresh, k_stale, v_stale)


# ----------------------------------------------------------------------
# padded layout: traced offsets via scalar prefetch (the shard_map form)
# ----------------------------------------------------------------------

def _padded_kernel(scal_ref, qf_ref, kf_ref, vf_ref, ks_ref, vs_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale, bq, bk, nk, n_tokens):
    """Stale-KV flash body with a PER-TOKEN freshness select and an
    in-kernel key mask. ``scal_ref`` holds the traced layout scalars
    ``[tok_start, valid_tokens]``: context token t reads the fresh block
    when ``tok_start <= t < tok_start + valid_tokens`` and the stale
    buffer otherwise; tokens ``>= n_tokens`` (scratch padding) are masked
    out of the softmax. This is exactly the mask-blend +
    dynamic_update_slice + masked-attend reference path of
    ``dit.block_stack``'s SPMD branch, fused so the buffer is never
    rewritten in HBM."""
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    tok_start = scal_ref[0]
    valid = scal_ref[1]
    q = qf_ref[0, 0].astype(jnp.float32)
    toks = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)[:, 0]
    rel = toks - tok_start
    is_fresh = (rel >= 0) & (rel < valid)
    k = jnp.where(is_fresh[:, None], kf_ref[0, 0].astype(jnp.float32),
                  ks_ref[0, 0].astype(jnp.float32))
    v = jnp.where(is_fresh[:, None], vf_ref[0, 0].astype(jnp.float32),
                  vs_ref[0, 0].astype(jnp.float32))
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    s = jnp.where((toks < n_tokens)[None, :], s, NEG_INF)
    _online_softmax_update(s, v, acc_ref, m_ref, l_ref)

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def stale_kv_attention_padded_bhsd(q_fresh, k_fresh, v_fresh, k_stale,
                                   v_stale, tok_start, valid_tokens, *,
                                   n_tokens: int, scale=None, bq: int = 8,
                                   bk: int = 8, interpret: bool = True):
    """Padded-layout stale-KV attention for the shard_map executors.

    q_fresh/k_fresh/v_fresh: [B,H,Nl_max,hd] — the local slab padded to the
    MAX patch size; rows >= valid_tokens are scratch (their outputs are
    computed and discarded by the caller, exactly like the reference path).
    k_stale/v_stale: [B,H,Npad,hd] — the whole-image stale buffer,
    scratch-padded to n_tokens + Nl_max.
    tok_start/valid_tokens: TRACED scalars (per-device offsets under
    shard_map), carried as a scalar-prefetch argument so the fresh-block
    index map can still be block-aligned. CONTRACT: tok_start is a multiple
    of bk at runtime (token starts are row_start * tokens_per_side and bk
    divides tokens_per_side — asserted by the caller's tile choice, not
    checkable on a traced value).
    n_tokens: static count of REAL context tokens (key mask threshold).
    Returns [B,H,Nl_max,hd].
    """
    B, H, Nlm, hd = q_fresh.shape
    Np = k_stale.shape[2]
    assert Nlm % bq == 0 and Nlm % bk == 0 and Np % bk == 0, (Nlm, Np, bq, bk)
    nq, nk = Nlm // bq, Np // bk
    nlb = Nlm // bk
    scale = scale if scale is not None else hd ** -0.5

    def fresh_ix(b, h, i, j, scal):
        # clamp j into the local block range so OOB loads read a valid block
        jj = jnp.clip(j - scal[0] // bk, 0, nlb - 1)
        return (b, h, jj, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j, s: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), fresh_ix),
            pl.BlockSpec((1, 1, bk, hd), fresh_ix),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, s: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, s: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j, s: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
    )
    kernel = functools.partial(_padded_kernel, scale=scale, bq=bq, bk=bk,
                               nk=nk, n_tokens=n_tokens)
    scal = jnp.stack([jnp.asarray(tok_start, jnp.int32),
                      jnp.asarray(valid_tokens, jnp.int32)])
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Nlm, hd), q_fresh.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(scal, q_fresh, k_fresh, v_fresh, k_stale, v_stale)


# ----------------------------------------------------------------------
# guided body: branch-stacked CFG with in-kernel uncond freshness masking
# ----------------------------------------------------------------------

def _guided_kernel(scal_ref, qf_ref, kf_ref, vf_ref, ks_ref, vs_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale, bq, bk, nk, n_tokens):
    """Branch-dimensioned padded body (grid axis 0 = guidance branch).
    Branch 0 (conditional) blends its fresh K/V like ``_padded_kernel``;
    branch 1 (unconditional) blends only when ``scal[2]`` (uncond_fresh)
    is 1 — with 0 it attends the pure-stale buffer, the in-kernel form of
    interleaved guidance's "don't recompute the uncond slice" reuse
    (DESIGN.md §12): the caller can skip the uncond blend/publish work
    entirely and the branch still reads a consistent context."""
    g = pl.program_id(0)
    ik = pl.program_id(4)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    tok_start = scal_ref[0]
    valid = jnp.where(g == 0, scal_ref[1], scal_ref[1] * scal_ref[2])
    q = qf_ref[0, 0, 0].astype(jnp.float32)
    toks = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)[:, 0]
    rel = toks - tok_start
    is_fresh = (rel >= 0) & (rel < valid)
    k = jnp.where(is_fresh[:, None], kf_ref[0, 0, 0].astype(jnp.float32),
                  ks_ref[0, 0, 0].astype(jnp.float32))
    v = jnp.where(is_fresh[:, None], vf_ref[0, 0, 0].astype(jnp.float32),
                  vs_ref[0, 0, 0].astype(jnp.float32))
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    s = jnp.where((toks < n_tokens)[None, :], s, NEG_INF)
    _online_softmax_update(s, v, acc_ref, m_ref, l_ref)

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def stale_kv_attention_guided_bhsd(q_fresh, k_fresh, v_fresh, k_stale,
                                   v_stale, tok_start, valid_tokens,
                                   uncond_fresh, *, n_tokens: int,
                                   scale=None, bq: int = 8, bk: int = 8,
                                   interpret: bool = True):
    """Branch-stacked guided stale-KV attention: one kernel launch for both
    CFG branches instead of a vmapped pair.

    All tensor operands carry a leading branch axis of 2 (0 = conditional,
    1 = unconditional): q/k/v fresh [2,B,H,Nl_max,hd], stale
    [2,B,H,Npad,hd]. ``uncond_fresh`` (traced 0/1) gates the uncond
    branch's freshness blend in-kernel — 0 reproduces the interleaved-
    guidance reuse interval where the uncond forward was skipped and its
    published buffer must be read as-is. Other scalars as
    :func:`stale_kv_attention_padded_bhsd`. Returns [2,B,H,Nl_max,hd].
    """
    G, B, H, Nlm, hd = q_fresh.shape
    assert G == 2, G
    Np = k_stale.shape[3]
    assert Nlm % bq == 0 and Nlm % bk == 0 and Np % bk == 0, (Nlm, Np, bq, bk)
    nq, nk = Nlm // bq, Np // bk
    nlb = Nlm // bk
    scale = scale if scale is not None else hd ** -0.5

    def fresh_ix(g, b, h, i, j, scal):
        jj = jnp.clip(j - scal[0] // bk, 0, nlb - 1)
        return (g, b, h, jj, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G, B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, bq, hd),
                         lambda g, b, h, i, j, s: (g, b, h, i, 0)),
            pl.BlockSpec((1, 1, 1, bk, hd), fresh_ix),
            pl.BlockSpec((1, 1, 1, bk, hd), fresh_ix),
            pl.BlockSpec((1, 1, 1, bk, hd),
                         lambda g, b, h, i, j, s: (g, b, h, j, 0)),
            pl.BlockSpec((1, 1, 1, bk, hd),
                         lambda g, b, h, i, j, s: (g, b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, bq, hd),
                               lambda g, b, h, i, j, s: (g, b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
    )
    kernel = functools.partial(_guided_kernel, scale=scale, bq=bq, bk=bk,
                               nk=nk, n_tokens=n_tokens)
    scal = jnp.stack([jnp.asarray(tok_start, jnp.int32),
                      jnp.asarray(valid_tokens, jnp.int32),
                      jnp.asarray(uncond_fresh, jnp.int32)])
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, B, H, Nlm, hd), q_fresh.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "parallel", "arbitrary")),
        interpret=interpret,
    )(scal, q_fresh, k_fresh, v_fresh, k_stale, v_stale)


# ----------------------------------------------------------------------
# per-hop LSE body: the flash-style ring attention segment attend
# ----------------------------------------------------------------------

def _lse_kernel(scal_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                acc_ref, m_ref, l_ref, *, scale, bk, nk):
    """Masked flash attention over ONE ring segment, returning both the
    normalized partial output and its log-sum-exp so the caller can merge
    segments across ring hops without ever materializing the assembled
    context (DESIGN.md §15): final = sum_s o_s * exp(lse_s - M) /
    sum_s exp(lse_s - M). ``scal[0]`` is the traced number of valid
    (unmasked) leading keys in this segment."""
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    valid = scal_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    toks = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)[:, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    s = jnp.where((toks < valid)[None, :], s, NEG_INF)
    _online_softmax_update(s, v, acc_ref, m_ref, l_ref)

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        # a fully-masked segment keeps m at NEG_INF => lse ~ NEG_INF and
        # the caller's exp(lse - M) weight underflows to exactly 0
        lse_ref[0, 0] = m_ref[...] + jnp.log(l)


def lse_attention_bhsd(q, k, v, valid_len, *, scale=None, bq: int = 8,
                       bk: int = 8, interpret: bool = True):
    """q: [B,H,S,hd]; k/v: [B,H,T,hd]; valid_len: traced count of real
    leading keys (rest masked). Returns (out [B,H,S,hd], lse [B,H,S]) in
    fp32 lse — the per-hop partial of flash-style ring attention.
    """
    B, H, S, hd = q.shape
    T = k.shape[2]
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    nq, nk = S // bq, T // bk
    scale = scale if scale is not None else hd ** -0.5
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j, s: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, s: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, s: (b, h, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j, s: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j, s: (b, h, i)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
    )
    kernel = functools.partial(_lse_kernel, scale=scale, bk=bk, nk=nk)
    scal = jnp.asarray(valid_len, jnp.int32)[None]
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
                   jax.ShapeDtypeStruct((B, H, S), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(scal, q, k, v)
