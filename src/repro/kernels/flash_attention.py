"""Flash attention (tiled online-softmax) Pallas TPU kernel.

Grid (B, H, nq, nk); the innermost kv-block axis is sequential ("arbitrary")
and accumulates into VMEM scratch (running max m, denominator l, weighted
accumulator acc) — the standard TPU flash pattern. BlockSpecs keep one
(bq x hd) Q tile + one (bk x hd) K/V tile in VMEM; MXU-aligned tile sizes
(multiples of 128 where shapes allow) are chosen in ops.py.

Supports causal and sliding-window masks (window > 0 => keys in
(q_pos - window, q_pos]).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x compat: CompilerParams was named TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int, bq: int, bk: int,
                  nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [bq, bk]

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # [bq]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_cur

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool, window: int = 0,
                         scale: float = None, bq: int = 128, bk: int = 128,
                         interpret: bool = True):
    """q: [B,H,S,hd]; k,v: [B,H,T,hd] (kv heads already broadcast). Returns
    [B,H,S,hd]. S % bq == 0 and T % bk == 0 (ops.py pads)."""
    B, H, S, hd = q.shape
    T = k.shape[2]
    nq, nk = S // bq, T // bk
    scale = scale if scale is not None else hd ** -0.5

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
