"""Diffusion samplers: DDPM ancestral, DDIM / DPM-Solver-1 (paper Lemma 1),
and the noise schedules they share. All in VP (variance-preserving)
parameterization: alpha_t = sqrt(alpha_bar_t), sigma_t = sqrt(1 - alpha_bar_t),
lambda_t = log(alpha_t / sigma_t)  (log-SNR/2).

The paper's Lemma 1 (DPM-Solver-1 == DDIM):
    x_{t_m} = (alpha_{t_m}/alpha_{t_{m-1}}) x_{t_{m-1}}
              - sigma_{t_m} (e^{h_m} - 1) eps_theta(x_{t_{m-1}}, t_{m-1}),
    h_m = lambda_{t_m} - lambda_{t_{m-1}}.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class NoiseSchedule:
    """Discrete schedule over T training steps with continuous accessors."""
    T: int
    alpha_bar: jnp.ndarray        # [T+1]; alpha_bar[0] = 1 (t=0 is data)
    betas: jnp.ndarray            # [T+1]; betas[0] = 0

    def alpha(self, t):
        return jnp.sqrt(self._ab(t))

    def sigma(self, t):
        return jnp.sqrt(1.0 - self._ab(t))

    def lam(self, t):
        ab = self._ab(t)
        return 0.5 * (jnp.log(ab) - jnp.log1p(-ab))

    def _ab(self, t):
        """Linear interpolation of alpha_bar at (possibly fractional) t."""
        t = jnp.asarray(t, jnp.float32)
        lo = jnp.clip(jnp.floor(t).astype(jnp.int32), 0, self.T)
        hi = jnp.clip(lo + 1, 0, self.T)
        w = t - lo
        return (1 - w) * self.alpha_bar[lo] + w * self.alpha_bar[hi]


def linear_schedule(T: int = 1000, beta_min: float = 1e-4, beta_max: float = 2e-2) -> NoiseSchedule:
    betas = jnp.concatenate([jnp.zeros((1,)), jnp.linspace(beta_min, beta_max, T)])
    alpha_bar = jnp.cumprod(1.0 - betas)
    return NoiseSchedule(T, alpha_bar, betas)


def cosine_schedule(T: int = 1000, s: float = 8e-3) -> NoiseSchedule:
    t = jnp.arange(T + 1) / T
    f = jnp.cos((t + s) / (1 + s) * jnp.pi / 2) ** 2
    alpha_bar = jnp.clip(f / f[0], 1e-5, 1.0)
    ab_prev = jnp.concatenate([jnp.ones((1,)), alpha_bar[:-1]])
    betas = jnp.clip(1 - alpha_bar / ab_prev, 0.0, 0.999)
    return NoiseSchedule(T, alpha_bar, betas)


def ddim_timesteps(T: int, M: int, warmup_offset: int = 0) -> jnp.ndarray:
    """M+1 decreasing timesteps t_0=T .. t_M=0 (paper Lemma 1 grid)."""
    return jnp.round(jnp.linspace(T, 0, M + 1)).astype(jnp.int32)


# ----------------------------------------------------------------------
# classifier-free guidance (DESIGN.md §12)
# ----------------------------------------------------------------------

def cfg_combine(eps_c, eps_u, scale):
    """The CFG combiner: ``eps_u + w * (eps_c - eps_u)`` in fp32, cast back
    to eps_c's dtype. The ONE place the guidance formula lives — the fused-
    batch reference (:func:`repro.models.diffusion.dit.forward_cfg`), the
    emulated engine, the SPMD guidance bodies and the serving engine all
    route through it, so the rule cannot drift between executors. ``scale``
    may be a python float or a per-lane array broadcastable to eps_c."""
    ec = eps_c.astype(jnp.float32)
    eu = eps_u.astype(jnp.float32)
    return (eu + scale * (ec - eu)).astype(eps_c.dtype)


def cfg_delta(eps_c, eps_u):
    """The guidance direction ``eps_c - eps_u`` (fp32): what interleaved
    guidance caches. The class direction drifts far more slowly across
    fine steps than eps_u itself (which tracks the noisy latent), so
    reusing the DELTA keeps the reuse error ``(w-1) * dDelta`` small even
    at production guidance weights."""
    return eps_c.astype(jnp.float32) - eps_u.astype(jnp.float32)


def cfg_apply_delta(eps_c, delta, scale):
    """Interleaved reuse combiner: ``eps_c + (w-1) * delta`` — exactly
    :func:`cfg_combine` when ``delta`` is this step's true eps_c - eps_u."""
    ec = eps_c.astype(jnp.float32)
    return (ec + (scale - 1.0) * delta).astype(eps_c.dtype)


# ----------------------------------------------------------------------
# single steps
# ----------------------------------------------------------------------

def ddim_step(sched: NoiseSchedule, x, eps, t_from, t_to):
    """One Lemma-1 update from t_{m-1}=t_from to t_m=t_to (t_to < t_from)."""
    a_from, a_to = sched.alpha(t_from), sched.alpha(t_to)
    s_from, s_to = sched.sigma(t_from), sched.sigma(t_to)
    # sigma_to * (e^{h} - 1) == a_to*s_from/a_from - s_to  exactly (VP param);
    # this form is finite at the t_to = 0 endpoint where lambda -> +inf.
    coef = a_to * s_from / a_from - s_to
    x32 = x.astype(jnp.float32)
    out = (a_to / a_from) * x32 - coef * eps.astype(jnp.float32)
    return out.astype(x.dtype)


def ddpm_step(sched: NoiseSchedule, x, eps, t, noise):
    """Ancestral DDPM step t -> t-1 (stochastic)."""
    t = jnp.asarray(t, jnp.int32)
    beta = sched.betas[t]
    ab = sched.alpha_bar[t]
    alpha = 1.0 - beta
    x32 = x.astype(jnp.float32)
    mean = (x32 - beta / jnp.sqrt(1 - ab) * eps.astype(jnp.float32)) / jnp.sqrt(alpha)
    sigma = jnp.sqrt(beta)
    out = jnp.where(t > 1, mean + sigma * noise, mean)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# full trajectories (single device / oracle "Origin" path)
# ----------------------------------------------------------------------

def ddim_sample(eps_fn: Callable, sched: NoiseSchedule, x_T, M: int,
                collect: bool = False):
    """eps_fn(x, t_scalar) -> eps. Returns x_0 (and trajectory if collect)."""
    ts = ddim_timesteps(sched.T, M)

    def body(x, m):
        t_from, t_to = ts[m], ts[m + 1]
        eps = eps_fn(x, t_from)
        return ddim_step(sched, x, eps, t_from, t_to), (x if collect else None)

    x, traj = jax.lax.scan(body, x_T, jnp.arange(M))
    return (x, traj) if collect else x


def ddpm_sample(eps_fn: Callable, sched: NoiseSchedule, x_T, rng):
    def body(carry, t):
        x, rng = carry
        rng, k = jax.random.split(rng)
        eps = eps_fn(x, t)
        noise = jax.random.normal(k, x.shape, jnp.float32)
        return (ddpm_step(sched, x, eps, t, noise), rng), None

    (x, _), _ = jax.lax.scan(body, (x_T, rng), jnp.arange(sched.T, 0, -1))
    return x


# ----------------------------------------------------------------------
# diffusion training objective (eps-prediction)
# ----------------------------------------------------------------------

def diffusion_loss(eps_fn: Callable, sched: NoiseSchedule, x0, rng):
    """Standard eps-matching loss: E_t,eps ||eps_theta(x_t, t) - eps||^2."""
    B = x0.shape[0]
    kt, ke = jax.random.split(rng)
    t = jax.random.randint(kt, (B,), 1, sched.T + 1)
    eps = jax.random.normal(ke, x0.shape, jnp.float32)
    ab = sched.alpha_bar[t].reshape((B,) + (1,) * (x0.ndim - 1))
    xt = jnp.sqrt(ab) * x0 + jnp.sqrt(1 - ab) * eps
    pred = eps_fn(xt.astype(x0.dtype), t)
    return jnp.mean(jnp.square(pred.astype(jnp.float32) - eps))
