"""Heterogeneity modeling: device profiles, effective speeds, occupancy
simulation (paper §V-A "Occupancy Simulation"), and online re-profiling
(beyond-paper extension §7.1 in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

from repro.core.schedule import effective_speed


@dataclasses.dataclass
class DeviceProfile:
    """One (possibly virtual) accelerator.

    c: relative capability, fastest == 1.0 (offline benchmark, paper §III-B)
    rho: background occupancy in [0, 1] (system API / simulated)
    """
    name: str
    c: float = 1.0
    rho: float = 0.0

    @property
    def v(self) -> float:
        return effective_speed(self.c, self.rho)


def make_cluster(occupancies: Sequence[float],
                 capabilities: Optional[Sequence[float]] = None) -> List[DeviceProfile]:
    """Paper's experimental grid: homogeneous GPUs + per-device occupancy,
    e.g. [0.0, 0.6]; optionally heterogeneous capabilities too."""
    caps = capabilities or [1.0] * len(occupancies)
    return [DeviceProfile(f"dev{i}", c, r)
            for i, (c, r) in enumerate(zip(caps, occupancies))]


def speeds(cluster: Sequence[DeviceProfile]) -> List[float]:
    return [d.v for d in cluster]


# ----------------------------------------------------------------------
# depth partitioning (displaced patch pipeline, DESIGN.md §11)
# ----------------------------------------------------------------------

def stage_partition(n_blocks: int, speeds: Sequence[float]) -> List[int]:
    """Blocks per pipeline stage, proportional to each stage device's speed.

    The depth analogue of Eq. 5's patch allocator: stage ``s`` (chain order;
    callers place the chain on devices in this order) gets
    ``n_blocks * v_s / sum(v)`` contiguous DiT blocks, integerized by
    largest-remainder rounding with every stage keeping at least one block.
    ``len(speeds) == 1`` degenerates to the whole model on one device.
    """
    if n_blocks < 1:
        raise ValueError(f"need at least one block, got {n_blocks}")
    if not speeds:
        raise ValueError("need at least one stage device")
    if any(v <= 0 for v in speeds):
        raise ValueError(f"stage speeds must be positive, got {list(speeds)}")
    s = len(speeds)
    if s > n_blocks:
        raise ValueError(f"{s} stages cannot split {n_blocks} blocks")
    total = sum(speeds)
    ideal = [n_blocks * v / total for v in speeds]
    base = [max(1, int(x)) for x in ideal]
    rem = n_blocks - sum(base)
    order = sorted(range(s), key=lambda i: ideal[i] - base[i], reverse=True)
    for i in order:
        if rem <= 0:
            break
        base[i] += 1
        rem -= 1
    # the >=1 floor may have overshot: shrink the stages furthest above
    # their ideal share, never dropping below one block
    while rem < 0:
        j = max((j for j in range(s) if base[j] > 1),
                key=lambda j: base[j] - ideal[j])
        base[j] -= 1
        rem += 1
    assert sum(base) == n_blocks, (base, n_blocks)
    return base


# ----------------------------------------------------------------------
# profiling
# ----------------------------------------------------------------------

def profile_step_time(step_fn: Callable[[], None], warmup: int = 1,
                      iters: int = 3) -> float:
    """Wall-clock a single-step callable (used to calibrate the simulator)."""
    for _ in range(warmup):
        step_fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        step_fn()
    return (time.perf_counter() - t0) / iters


class OnlineProfiler:
    """Beyond-paper: EWMA re-estimation of v_i from measured per-interval
    latencies during inference; feeds re-allocation when drift > threshold.
    The paper profiles once, offline ("derived directly from historical
    inference time profiles") — this adapts to occupancy drift mid-request.
    """

    def __init__(self, init_speeds: Sequence[float], alpha: float = 0.5):
        self.speeds = list(init_speeds)
        self.alpha = alpha

    def update(self, device: int, work: float, measured_time: float) -> float:
        """work = nominal work units completed (e.g. patch_frac * steps)."""
        if measured_time <= 0:
            return self.speeds[device]
        observed_v = work / measured_time
        s = self.speeds[device]
        self.speeds[device] = (1 - self.alpha) * s + self.alpha * observed_v
        return self.speeds[device]

    def drift(self, init_speeds: Sequence[float]) -> float:
        return max(abs(s - s0) / max(s0, 1e-9)
                   for s, s0 in zip(self.speeds, init_speeds))


def feed_profiler(profiler: OnlineProfiler, cm, substeps: Sequence[int],
                  patches: Sequence[int], true_speeds: Sequence[float],
                  device_map: Optional[Sequence[Sequence[int]]] = None
                  ) -> None:
    """Synthesize one interval's measured per-device latencies and feed them
    through the profiler's EWMA — the single-host emulation of per-interval
    timers used by both the pipeline rebalance hook and the serving engine.

    Worker i did ``substeps[i]`` substeps over ``patches[i]`` rows; its
    nominal work (seconds at v=1, via the cost model) divided by the latency
    at the ground-truth speed makes ``observed_v`` converge on that speed.
    device_map[i] lists the devices worker i occupies (a cond/uncond pair
    under split guidance); default is the identity worker->device mapping.
    """
    for i, (sub, rows) in enumerate(zip(substeps, patches)):
        if sub == 0 or rows == 0:
            continue
        work = sub * (cm.t_fixed + cm.t_row * rows)
        devices = (device_map[i] if device_map is not None else (i,))
        for d in devices:
            profiler.update(d, work, work / max(true_speeds[d], 1e-9))
