"""Heterogeneity modeling: device profiles, effective speeds, occupancy
simulation (paper §V-A "Occupancy Simulation"), and online re-profiling
(beyond-paper extension §7.1 in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

from repro.core.schedule import effective_speed


@dataclasses.dataclass
class DeviceProfile:
    """One (possibly virtual) accelerator.

    c: relative capability, fastest == 1.0 (offline benchmark, paper §III-B)
    rho: background occupancy in [0, 1] (system API / simulated)
    """
    name: str
    c: float = 1.0
    rho: float = 0.0

    @property
    def v(self) -> float:
        return effective_speed(self.c, self.rho)


def make_cluster(occupancies: Sequence[float],
                 capabilities: Optional[Sequence[float]] = None) -> List[DeviceProfile]:
    """Paper's experimental grid: homogeneous GPUs + per-device occupancy,
    e.g. [0.0, 0.6]; optionally heterogeneous capabilities too."""
    caps = capabilities or [1.0] * len(occupancies)
    return [DeviceProfile(f"dev{i}", c, r)
            for i, (c, r) in enumerate(zip(caps, occupancies))]


def speeds(cluster: Sequence[DeviceProfile]) -> List[float]:
    return [d.v for d in cluster]


# ----------------------------------------------------------------------
# profiling
# ----------------------------------------------------------------------

def profile_step_time(step_fn: Callable[[], None], warmup: int = 1,
                      iters: int = 3) -> float:
    """Wall-clock a single-step callable (used to calibrate the simulator)."""
    for _ in range(warmup):
        step_fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        step_fn()
    return (time.perf_counter() - t0) / iters


class OnlineProfiler:
    """Beyond-paper: EWMA re-estimation of v_i from measured per-interval
    latencies during inference; feeds re-allocation when drift > threshold.
    The paper profiles once, offline ("derived directly from historical
    inference time profiles") — this adapts to occupancy drift mid-request.
    """

    def __init__(self, init_speeds: Sequence[float], alpha: float = 0.5):
        self.speeds = list(init_speeds)
        self.alpha = alpha

    def update(self, device: int, work: float, measured_time: float) -> float:
        """work = nominal work units completed (e.g. patch_frac * steps)."""
        if measured_time <= 0:
            return self.speeds[device]
        observed_v = work / measured_time
        s = self.speeds[device]
        self.speeds[device] = (1 - self.alpha) * s + self.alpha * observed_v
        return self.speeds[device]

    def drift(self, init_speeds: Sequence[float]) -> float:
        return max(abs(s - s0) / max(s0, 1e-9)
                   for s, s0 in zip(self.speeds, init_speeds))
