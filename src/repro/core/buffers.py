"""DistriFusion-style stale activation buffers.

``Published`` holds the full-image per-layer K/V as of the last completed
sync interval. Within an interval every worker reads ``published`` for
remote regions (stale) while its own fresh local K/V is overwritten inside
``dit.forward_patch``. Workers' newly published local K/V accumulate in
``pending`` and are merged at the interval boundary — the emulation-exact
counterpart of NCCL async broadcast landing by the next sync point.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Published:
    k: jnp.ndarray          # [L, B, N_tokens, H, hd]
    v: jnp.ndarray
    step: int = 0           # fine-step index of last merge (for staleness asserts)

    def copy(self) -> "Published":
        return Published(self.k, self.v, self.step)


def publish_local(pending: Dict[int, Tuple], worker: int, k_local, v_local,
                  tok_start: int) -> None:
    """Queue worker's fresh local K/V ([L,B,Nl,H,hd]) for the next merge."""
    pending[worker] = (k_local, v_local, tok_start)


def merge(published: Published, pending: Dict[int, Tuple], step: int) -> Published:
    """Apply all queued regional updates; returns new Published."""
    k, v = published.k, published.v
    for _, (kl, vl, start) in sorted(pending.items()):
        k = jax.lax.dynamic_update_slice_in_dim(k, kl.astype(k.dtype), start, axis=2)
        v = jax.lax.dynamic_update_slice_in_dim(v, vl.astype(v.dtype), start, axis=2)
    return Published(k, v, step)
