"""DistriFusion-style stale activation buffers.

``Published`` holds the full-image per-layer K/V as of the last completed
sync interval. Within an interval every worker reads ``published`` for
remote regions (stale) while its own fresh local K/V is overwritten inside
``dit.forward_patch``. Workers' newly published local K/V accumulate in
``pending`` and are merged at the interval boundary — the emulation-exact
counterpart of NCCL async broadcast landing by the next sync point.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Published:
    k: jnp.ndarray          # [L, B, N_tokens, H, hd]
    v: jnp.ndarray
    step: int = 0           # fine-step index of last merge (for staleness asserts)

    def copy(self) -> "Published":
        return Published(self.k, self.v, self.step)


def publish_local(pending: Dict[int, Tuple], worker: int, k_local, v_local,
                  tok_start: int) -> None:
    """Queue worker's fresh local K/V ([L,B,Nl,H,hd]) for the next merge."""
    pending[worker] = (k_local, v_local, tok_start)


def merge(published: Published, pending: Dict[int, Tuple], step: int,
          axis: int = 2) -> Published:
    """Apply all queued regional updates; returns new Published. ``axis``
    is the token axis — 2 for plain [L,B,N,H,hd] buffers, 3 for the
    branch-stacked [2,L,B,N,H,hd] guidance buffers (DESIGN.md §12)."""
    k, v = published.k, published.v
    for _, (kl, vl, start) in sorted(pending.items()):
        k = jax.lax.dynamic_update_slice_in_dim(k, kl.astype(k.dtype), start, axis=axis)
        v = jax.lax.dynamic_update_slice_in_dim(v, vl.astype(v.dtype), start, axis=axis)
    return Published(k, v, step)


def extrapolation_factor(prev_step: int, last_step: int, fine_step: int) -> float:
    """Linear-extrapolation coefficient for the "predict" exchange kind:
    how far past the last full refresh the boundary at ``fine_step`` sits,
    in units of the last refresh gap. Static per boundary (fine steps are
    schedule structure), so SPMD bodies bake it in as a constant."""
    gap = last_step - prev_step
    if gap <= 0:
        return 0.0
    return (fine_step - last_step) / gap


def extrapolate_arrays(last, prev, f: float):
    """The Reuse-then-Predict rule on raw arrays: ``last + f*(last - prev)``
    cast back to ``last``'s dtype. The ONE place the prediction formula
    lives — the emulated engine, the SPMD body and the serving engine all
    route through it, so the rule cannot drift between executors."""
    return (last + f * (last - prev)).astype(last.dtype)


def extrapolate(prev: "Published | None", last: Published,
                fine_step: int) -> Published:
    """Predict the remote K/V at ``fine_step`` from the last two exchanged
    versions (Reuse-then-Predict). Until two refreshes have landed there is
    nothing to difference, so fall back to stale reuse of ``last``. The
    local region is overwritten with fresh K/V inside ``dit.forward_patch``
    either way, so prediction only ever feeds the remote attention
    context."""
    if prev is None:
        return last
    f = extrapolation_factor(prev.step, last.step, fine_step)
    if f == 0.0:
        return last
    return Published(extrapolate_arrays(last.k, prev.k, f),
                     extrapolate_arrays(last.v, prev.v, f), last.step)
