"""STADI: Spatio-Temporal Adaptive Diffusion Inference (Algorithm 1).

DEPRECATED module-level entry point. The supported API is now

    from repro.core.pipeline import StadiConfig, StadiPipeline
    pipe = StadiPipeline(cfg, params, sched, StadiConfig(cluster, ...))
    result = pipe.generate(x_T, cond)

``stadi_infer`` remains as a thin shim mapping the old (temporal, spatial)
ablation flags onto the planner registry (see DESIGN.md §8 migration table):
(False, False) -> "uniform", (False, True) -> "spatial",
(True, False) -> "temporal", (True, True) -> "stadi".
"""
from __future__ import annotations

import warnings
from typing import Sequence

from repro.configs.diffusion import DiTConfig
from repro.core.patch_parallel import RunResult
from repro.core.sampler import NoiseSchedule

_PLANNER_BY_FLAGS = {(False, False): "uniform", (False, True): "spatial",
                     (True, False): "temporal", (True, True): "stadi"}


def stadi_infer(params, cfg: DiTConfig, sched: NoiseSchedule, x_T, cond,
                speeds: Sequence[float], m_base: int, m_warmup: int,
                a: float = 0.75, b: float = 0.25,
                granularity: int = 1,
                temporal: bool = True, spatial: bool = True,
                tiers: Sequence[int] = (1, 2)) -> RunResult:
    """Deprecated: use StadiPipeline. Full STADI (temporal=spatial=True);
    ablations by flipping the flags (paper Table III)."""
    warnings.warn("stadi_infer() is deprecated; use "
                  "repro.core.pipeline.StadiPipeline.generate()",
                  DeprecationWarning, stacklevel=2)
    from repro.core import hetero
    from repro.core.pipeline import StadiConfig, StadiPipeline

    cluster = tuple(hetero.DeviceProfile(f"dev{i}", c=v)
                    for i, v in enumerate(speeds))
    config = StadiConfig(cluster=cluster, m_base=m_base, m_warmup=m_warmup,
                         a=a, b=b, tiers=tuple(tiers),
                         granularity=granularity,
                         planner=_PLANNER_BY_FLAGS[(temporal, spatial)],
                         backend="emulated")
    res = StadiPipeline(cfg, params, sched, config).generate(x_T, cond)
    return RunResult(res.image, res.trace)
