"""STADI: Spatio-Temporal Adaptive Diffusion Inference (Algorithm 1).

    plan    = temporal_allocation(speeds, M_base, M_warmup, a, b)   # Eq. (4)
    patches = spatial_allocation(speeds, plan.steps, P_total)       # Eq. (5)
    result  = run_schedule(..., plan, patches)                      # lines 7-25

``stadi_infer`` wires the three together; ``ablation variants`` expose
None / +SA / +TA / +TA+SA (paper Table III).
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.configs.diffusion import DiTConfig
from repro.core import schedule as sched_lib
from repro.core.patch_parallel import RunResult, run_schedule, uniform_plan
from repro.core.sampler import NoiseSchedule


def stadi_infer(params, cfg: DiTConfig, sched: NoiseSchedule, x_T, cond,
                speeds: Sequence[float], m_base: int, m_warmup: int,
                a: float = 0.75, b: float = 0.25,
                granularity: int = 1,
                temporal: bool = True, spatial: bool = True,
                tiers: Sequence[int] = (1, 2)) -> RunResult:
    """Full STADI (temporal=spatial=True); ablations by flipping the flags:
       temporal=False, spatial=False  -> patch parallelism ("None")
       temporal=False, spatial=True   -> +SA
       temporal=True,  spatial=False  -> +TA
       temporal=True,  spatial=True   -> +TA+SA (STADI)
    """
    N = len(speeds)
    P_total = cfg.tokens_per_side
    if temporal:
        plan = sched_lib.temporal_allocation(speeds, m_base, m_warmup, a, b, tiers)
    else:
        plan = uniform_plan(N, m_base, m_warmup)
    if spatial:
        patches = sched_lib.spatial_allocation(speeds, plan.steps, P_total, granularity)
    else:
        base, rem = divmod(P_total, sum(1 for e in plan.excluded if not e))
        patches, j = [], 0
        for i in range(N):
            if plan.excluded[i]:
                patches.append(0)
            else:
                patches.append(base + (1 if j < rem else 0))
                j += 1
    return run_schedule(params, cfg, sched, x_T, cond, plan, patches)
