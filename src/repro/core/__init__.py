# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.

# Stable public surface (DESIGN.md §8): one config object, pluggable
# planners and execution backends. Re-exported lazily so that importing
# repro.core stays jax-free (spmd users must be able to set
# STADI_HOST_DEVICES / XLA_FLAGS before jax initializes).
_EXPORTS = {
    "PipelineResult": "repro.core.pipeline",
    "StadiConfig": "repro.core.pipeline",
    "StadiPipeline": "repro.core.pipeline",
    "register_executor": "repro.core.pipeline",
    "get_executor": "repro.core.pipeline",
    "ExecutionPlan": "repro.core.planners",
    "get_planner": "repro.core.planners",
    "register_planner": "repro.core.planners",
}


def __getattr__(name):
    if name in _EXPORTS:
        import importlib
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
