"""Unified STADI pipeline: one config object, pluggable planners and
execution backends (DESIGN.md §8, §14).

    cfg    = get_config("tiny-dit").reduced()
    params = dit.init_params(jax.random.PRNGKey(0), cfg)
    sched  = sampler.linear_schedule(T=1000)
    config = StadiConfig.from_occupancies([0.0, 0.6], m_base=16, m_warmup=4)
    pipe   = StadiPipeline(cfg, params, sched, config)
    result = pipe.generate(x_T, cond)          # result.image, result.trace

``StadiConfig`` captures the cluster (``DeviceProfile``s), the schedule knobs
(Eq. 4 / Eq. 5 parameters), the planner name and the backend name.
Planners live in :mod:`repro.core.planners`; backends are registered here:

    "emulated"  exact-numerics logical-worker engine (patch_parallel)
    "spmd"      real shard_map execution over jax.devices() (core/spmd)
    "simulate"  trace-only latency modeling (no numerics; needs a CostModel)

``StadiPipeline.plan()`` is the ONE planning entrypoint: it runs the
configured planner and returns a fully-populated five-axis
:class:`~repro.core.planners.ExecutionPlan` (steps x patches x stages x
guidance x seq) in a single pass — the ``--num-stages`` / ``--cfg-scale`` /
``--seq-shards`` config wiring is resolved onto the plan there, not at
execution time. The historical ``plan_stages`` / ``plan_guidance`` /
``plan_seq`` free functions survive as deprecation shims. With
``plan_cache_dir`` set, ``plan()`` consults a persistent
:class:`~repro.serving.plan_cache.PlanCache` before any planner search
(DESIGN.md §14).

Backends declare what they can execute at registration time —
``register_executor(name, supports={...}, requires={...})`` — and
:func:`check_backend_can_run` rejects plan/backend mismatches uniformly
from that declaration, so a new executor cannot silently skip gating.

``rebalance_every=k`` turns on online rebalancing (emulated backend): every k
adaptive intervals the measured per-device interval latencies are fed through
:class:`repro.core.hetero.OnlineProfiler`, and when the EWMA speed estimate
drifts past ``rebalance_threshold`` the remaining fine steps are re-planned
with the configured planner. In this single-host emulation "measured" latency
is synthesized from the cost model at ``measured_speeds`` (the ground-truth
speeds the run actually experiences, e.g. after an occupancy change).
"""
from __future__ import annotations

import dataclasses
import hashlib
import inspect
import os
import warnings
from typing import Callable, Dict, List, Optional, Protocol, Sequence, Tuple

from repro.configs.diffusion import DiTConfig
from repro.core import hetero
from repro.core import patch_parallel as pp
from repro.core import simulate as sim
from repro.core.hetero import DeviceProfile
from repro.core.patch_parallel import ExecutionTrace
from repro.core.planners import ExecutionPlan, get_planner
from repro.core.sampler import NoiseSchedule
from repro.core.simulate import CostModel


@dataclasses.dataclass(frozen=True)
class StadiConfig:
    """Everything STADI needs to know that is not the model or the input."""
    cluster: Tuple[DeviceProfile, ...]
    # schedule knobs (paper §IV, Eq. 4 / Eq. 5)
    m_base: int = 16
    m_warmup: int = 4
    a: float = 0.75
    b: float = 0.25
    tiers: Tuple[int, ...] = (1, 2)
    granularity: int = 1
    min_patch: Optional[int] = None
    # strategy selection
    planner: str = "stadi"
    backend: str = "emulated"
    # boundary-exchange policy (DESIGN.md §10): "sync" | "stale_async" |
    # "predictive"; exchange_refresh = E => one corrective full refresh
    # every E interval boundaries (ignored by "sync")
    exchange: str = "sync"
    exchange_refresh: int = 2
    # displaced patch pipeline (DESIGN.md §11): number of depth stages the
    # DiT block stack is split into (1 = no depth parallelism; 0 = let the
    # stadi_pipefuse planner search). micro_patches pins the micro-batch
    # count streaming through the stage chain (0 = auto). depth is the DiT
    # block count — StadiPipeline fills it in from the model config.
    num_stages: int = 1
    micro_patches: int = 0
    depth: Optional[int] = None
    # classifier-free guidance (DESIGN.md §12): cfg_scale > 0 turns every
    # generation into a guided one (eps = eps_u + w*(eps_c - eps_u));
    # guidance picks the placement — "none" defaults to "fused" when
    # cfg_scale is set, or lets the stadi_guidance planner auto-search.
    # "split"/"interleaved" placement requires planner="stadi_guidance"
    # (logical workers become cond/uncond device pairs); uncond_refresh is
    # the interleaved reuse cadence. latent_bytes / kv_row_bytes are byte
    # provenance for the guided planner cost model — StadiPipeline fills
    # them in from the model config (leave 0).
    guidance: str = "none"
    cfg_scale: float = 0.0
    uncond_refresh: int = 2
    latent_bytes: int = 0
    kv_row_bytes: int = 0
    # sequence-parallel attention (DESIGN.md §13): number of Ulysses/ring
    # shards each patch worker's attention is split across (1 = attention-
    # unsharded; 0 = let the stadi_seq planner search). n_heads is the
    # attention head count the seq planner scatters — StadiPipeline fills
    # it in from the model config (leave None).
    seq_shards: int = 1
    n_heads: Optional[int] = None
    # video / multi-frame diffusion (DESIGN.md §16): number of latent
    # frames denoised jointly (1 = image — every path is bitwise the
    # pre-frame pipeline). frame_groups picks the placement: 1 =
    # frame-sequential (every worker runs all frames), > 1 = frame-
    # parallel member rows (requires planner='stadi_video'), 0 = let the
    # stadi_video planner search.
    num_frames: int = 1
    frame_groups: int = 0
    # prompt conditioning (DESIGN.md §17): length bucket of the prompt-token
    # sequence the planner prices (CostModel.t_xattn per token read). 0 =
    # derive from the model config (cond_seq_len when cross_attn, else
    # unconditioned/class-conditioned — no cross-attention cost). Setting it
    # explicitly pins the serving bucket a cached plan is keyed under.
    cond_bucket: int = 0
    # run the Pallas stale-KV attention kernel (repro.kernels) inside the
    # DiT blocks instead of the reference buffer-rewrite attend — the
    # fused freshness-select hot path (interpret mode off-TPU)
    use_pallas_attention: bool = False
    # latency modeling ("simulate" backend; also latency reporting elsewhere)
    cost_model: Optional[CostModel] = None
    # online rebalancing (beyond-paper, DESIGN.md §7.1)
    rebalance_every: int = 0             # adaptive intervals between checks; 0 = off
    rebalance_threshold: float = 0.2     # max relative speed drift tolerated
    profiler_alpha: float = 0.5          # EWMA weight for OnlineProfiler
    # persistent plan cache (DESIGN.md §14): directory for serialized
    # planner outputs keyed by (cluster signature, model hash, workload
    # shape). None = no cache; StadiPipeline.plan() consults it before any
    # planner search and OnlineProfiler drift invalidates stale entries.
    plan_cache_dir: Optional[str] = None

    @classmethod
    def from_occupancies(cls, occupancies: Sequence[float],
                         capabilities: Optional[Sequence[float]] = None,
                         **knobs) -> "StadiConfig":
        """Paper's experimental grid: homogeneous GPUs + per-device occupancy."""
        cluster = tuple(hetero.make_cluster(occupancies, capabilities))
        return cls(cluster=cluster, **knobs)

    @property
    def speeds(self) -> List[float]:
        return [d.v for d in self.cluster]

    @property
    def n_devices(self) -> int:
        return len(self.cluster)


@dataclasses.dataclass
class ReplanEvent:
    """One online re-allocation (fine-step granularity provenance)."""
    fine_step: int
    drift: float
    speeds_before: List[float]
    speeds_after: List[float]
    plan: ExecutionPlan


@dataclasses.dataclass
class PipelineResult:
    """What ``StadiPipeline.generate`` returns, for every backend.

    image is None for the trace-only "simulate" backend; latency_s is None
    unless a cost model was configured.
    """
    image: Optional[object]
    trace: ExecutionTrace
    plan: ExecutionPlan
    latency_s: Optional[float] = None
    replans: List[ReplanEvent] = dataclasses.field(default_factory=list)
    #: Pallas kernel path hits/misses recorded while TRACING this call
    #: ({"hits": {kind: n}, "misses": {reason: n}}) — jit caching means a
    #: repeat call with cached traces legitimately reports {} (§15).
    kernel_stats: Dict = dataclasses.field(default_factory=dict)


class Executor(Protocol):
    """A backend: executes an ExecutionPlan, returns (image | None, trace)."""

    def __call__(self, params, model_cfg: DiTConfig, sched: NoiseSchedule,
                 x_T, cond, plan: ExecutionPlan, config: StadiConfig,
                 interval_hook=None) -> Tuple[Optional[object], ExecutionTrace]:
        ...


# ----------------------------------------------------------------------
# executor registry: declarative backend capabilities (DESIGN.md §14)
# ----------------------------------------------------------------------

#: the ONE normalized executor call signature — StadiPipeline invokes every
#: backend strictly by these keywords, and register_executor rejects any
#: executor whose signature spells them differently (the historical
#: per-backend kwarg drift cannot re-enter the registry)
EXECUTOR_KWARGS = ("params", "model_cfg", "sched", "x_T", "cond", "plan",
                   "config", "interval_hook")

#: every feature token a plan can demand from a backend
PLAN_FEATURES = ("stages", "guidance.fused", "guidance.split",
                 "guidance.interleaved", "seq", "seq.uneven", "frames")

#: valid ``requires=`` tokens: a concrete feature, or a bare axis prefix
#: ("guidance", "seq") satisfied by any mode of that axis
_REQUIRE_PREFIXES = ("guidance", "seq", "stages", "frames")


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """One registered executor plus its declared capabilities.

    supports: feature tokens (from :data:`PLAN_FEATURES`) the backend can
        execute; a plan demanding anything else is rejected uniformly by
        :func:`check_backend_can_run`.
    requires: tokens the backend NEEDS a plan to demand (e.g. the
        "spmd_guidance" mesh is meaningless without a guided plan).
    """
    fn: Executor
    supports: frozenset
    requires: frozenset


EXECUTORS: Dict[str, BackendSpec] = {}


def register_executor(name: str, *, supports: Sequence[str] = (),
                      requires: Sequence[str] = ()
                      ) -> Callable[[Executor], Executor]:
    supports_f = frozenset(supports)
    requires_f = frozenset(requires)
    bad = (supports_f - set(PLAN_FEATURES)) | \
        (requires_f - set(PLAN_FEATURES) - set(_REQUIRE_PREFIXES))
    if bad:
        raise ValueError(f"executor {name!r} declares unknown capability "
                         f"tokens {sorted(bad)}; known: {PLAN_FEATURES}")

    def deco(fn: Executor) -> Executor:
        sig = tuple(inspect.signature(fn).parameters)
        if sig != EXECUTOR_KWARGS:
            raise TypeError(
                f"executor {name!r} must accept exactly the normalized "
                f"kwargs {EXECUTOR_KWARGS}, got {sig}")
        EXECUTORS[name] = BackendSpec(fn, supports_f, requires_f)
        return fn
    return deco


def get_executor_spec(name: str) -> BackendSpec:
    try:
        return EXECUTORS[name]
    except KeyError:
        raise KeyError(f"unknown backend {name!r}; registered: "
                       f"{sorted(EXECUTORS)}") from None


def get_executor(name: str) -> Executor:
    return get_executor_spec(name).fn


def backends_supporting(feature: str) -> Tuple[str, ...]:
    """All registered backends whose declaration covers ``feature`` (a
    token from :data:`PLAN_FEATURES`, or a bare axis prefix matching any
    mode, e.g. "guidance")."""
    def covers(spec: BackendSpec) -> bool:
        return any(f == feature or f.startswith(feature + ".")
                   for f in spec.supports)
    return tuple(sorted(n for n, s in EXECUTORS.items() if covers(s)))


# ----------------------------------------------------------------------
# serving hooks: round-granular steppers for continuous batching
# ----------------------------------------------------------------------
#
# An Executor runs one whole generation; the diffusion serving engine
# (repro.serving.diffusion_engine) instead drives MANY in-flight requests one
# scheduling round at a time, so each backend that supports serving also
# registers a *stepper factory*: ``factory(pipeline, plan, slots) -> Stepper``
# where a Stepper exposes
#
#     warmup_step(xs, t_from, t_to, conds) -> (xs', pub_k, pub_v)
#     interval(xs, fine0, conds, pub_k, pub_v) -> (xs', pub_k', pub_v')
#     cohort_only: bool    # True => every lane of interval() shares fine0
#
# over lane-stacked state (leading axis = slot lane). The "emulated" stepper
# vmaps the denoiser so lanes at different noise-schedule positions share one
# dispatch; the "spmd" stepper shard_maps each interval across jax.devices().

STEPPER_FACTORIES: Dict[str, Callable] = {}


def register_stepper_factory(name: str) -> Callable:
    def deco(fn):
        STEPPER_FACTORIES[name] = fn
        return fn
    return deco


def get_stepper_factory(name: str):
    try:
        return STEPPER_FACTORIES[name]
    except KeyError:
        raise KeyError(
            f"backend {name!r} has no serving stepper; registered: "
            f"{sorted(STEPPER_FACTORIES)} (the 'simulate' backend has no "
            "numerics to serve)") from None


# ----------------------------------------------------------------------
# plan-axis resolution: config knobs -> plan fields (DESIGN.md §14)
# ----------------------------------------------------------------------
#
# StadiPipeline.plan() populates all five axes onto the ExecutionPlan in
# one pass via these private resolvers; executors read plan.stages /
# plan.guidance / plan.seq directly. The historical plan_stages /
# plan_guidance / plan_seq free functions below are deprecation shims.

def _resolve_stages(plan, model_cfg, config) -> Optional[List[int]]:
    """The stage split a staged executor should run: the plan's own (from
    the stadi_pipefuse planner) or, for plain planners, a speed-
    proportional split of config.num_stages (the --num-stages wiring)."""
    if plan.stages is not None:
        return list(plan.stages)
    if config.num_stages <= 1:
        return None
    if config.num_stages > config.n_devices:
        raise ValueError(
            f"num_stages={config.num_stages} is infeasible: the chain needs "
            f"one device per stage and the cluster has {config.n_devices} "
            "(the stadi_pipefuse planner rejects this identically)")
    chain = sim.chain_speeds(config.speeds, config.num_stages)
    return hetero.stage_partition(model_cfg.n_layers, chain)


def _resolve_seq(plan, model_cfg, config):
    """The SeqPlan an executor should run: the plan's own (from the
    stadi_seq planner) or, for plain planners with ``seq_shards > 1``, a
    uniform-shard plan (the --seq-shards wiring). None = attention-
    unsharded."""
    if plan.seq is not None and len(plan.seq.segments) > 1:
        return plan.seq
    S = config.seq_shards
    if S in (0, 1):
        return None
    from repro.core import seqpar
    if S > config.n_devices:
        raise ValueError(
            f"seq_shards={S} is infeasible: every patch-worker group needs "
            f"one device per sequence shard and the cluster has "
            f"{config.n_devices} (the stadi_seq planner rejects this "
            "identically)")
    if model_cfg.n_heads < S:
        raise ValueError(
            f"seq_shards={S} cannot scatter {model_cfg.n_heads} attention "
            "heads (Ulysses needs >= 1 head per shard)")
    return seqpar.make_seq_plan(model_cfg.n_heads, model_cfg.tokens_per_side,
                                S)


def _resolve_frames(plan, config):
    """The FramePlan an executor should run: the plan's own (from the
    stadi_video planner) or, for plain planners with ``num_frames > 1``,
    the frame-sequential placement (the --num-frames wiring: every patch
    worker evaluates all frames). None = single-frame image path."""
    if plan.frames is not None and plan.frames.num_frames > 1:
        return plan.frames
    F = config.num_frames
    if F <= 1:
        return None
    from repro.core import frames as frames_lib
    if config.frame_groups > 1:
        raise ValueError(
            f"frame_groups={config.frame_groups} places frame chunks on "
            "device member rows — plan it with planner='stadi_video' "
            f"(planner {config.planner!r} allocates per-device workers)")
    return frames_lib.FramePlan(F, (F,))


def _resolve_guidance(plan, config):
    """The GuidancePlan an executor should run: the plan's own (from the
    stadi_guidance planner) or, for plain planners with ``cfg_scale`` set,
    a fused-placement plan (the --cfg-scale wiring). None = unguided."""
    if plan.guidance is not None:
        return plan.guidance
    if config.cfg_scale <= 0.0 and config.guidance == "none":
        return None
    from repro.core.guidance import GuidancePlan
    if config.guidance in ("split", "interleaved"):
        raise ValueError(
            f"guidance={config.guidance!r} placement pairs devices across "
            "branch groups — plan it with planner='stadi_guidance' "
            f"(planner {config.planner!r} allocates per-device workers)")
    if config.cfg_scale <= 0.0:
        raise ValueError(f"guidance={config.guidance!r} needs cfg_scale > 0")
    return GuidancePlan("fused", config.cfg_scale)


def _deprecated(old: str, new: str) -> None:
    warnings.warn(f"{old} is deprecated; {new}", DeprecationWarning,
                  stacklevel=3)


def plan_stages(plan, model_cfg, config) -> Optional[List[int]]:
    """Deprecated: ``StadiPipeline.plan()`` populates ``plan.stages``."""
    _deprecated("plan_stages()",
                "StadiPipeline.plan() returns a fully-populated plan — "
                "read plan.stages")
    return _resolve_stages(plan, model_cfg, config)


def plan_seq(plan, model_cfg, config):
    """Deprecated: ``StadiPipeline.plan()`` populates ``plan.seq``."""
    _deprecated("plan_seq()",
                "StadiPipeline.plan() returns a fully-populated plan — "
                "read plan.seq")
    return _resolve_seq(plan, model_cfg, config)


def plan_guidance(plan, config):
    """Deprecated: ``StadiPipeline.plan()`` populates ``plan.guidance``."""
    _deprecated("plan_guidance()",
                "StadiPipeline.plan() returns a fully-populated plan — "
                "read plan.guidance")
    return _resolve_guidance(plan, config)


# ----------------------------------------------------------------------
# uniform plan/backend gating from the capability declarations
# ----------------------------------------------------------------------

def required_features(plan, config) -> Tuple[List[str], Optional[object]]:
    """Feature tokens a (plan, config) pair demands of a backend, in the
    deterministic check order (stages, guidance, seq, frames), plus the
    resolved GuidancePlan (None = unguided)."""
    feats: List[str] = []
    if plan.stages is not None and len(plan.stages) > 1:
        feats.append("stages")
    gplan = _resolve_guidance(plan, config)
    if gplan is not None:
        feats.append("guidance." + gplan.mode)
    seq_sharded = ((plan.seq is not None and len(plan.seq.segments) > 1)
                   or config.seq_shards > 1)
    if seq_sharded:
        feats.append("seq")
        if (plan.seq is not None and len(plan.seq.segments) > 1
                and not plan.seq.even_heads()):
            feats.append("seq.uneven")
    framed = ((plan.frames is not None and plan.frames.num_frames > 1)
              or config.num_frames > 1)
    if framed:
        feats.append("frames")
    return feats, gplan


#: per-(backend, feature) rejection messages more specific than the
#: generic capability complaint — kept at least as pointed as the historic
#: if-chain's (tested); format fields: mode, scale, backend, stages, heads
_BACKEND_FEATURE_ERRORS: Dict[Tuple[str, str], str] = {
    ("spmd", "guidance.split"):
        "{mode!r} guidance on SPMD needs the guidance mesh axis: use "
        "backend='spmd_guidance'",
    ("spmd", "guidance.interleaved"):
        "{mode!r} guidance on SPMD needs the guidance mesh axis: use "
        "backend='spmd_guidance'",
    ("spmd_guidance", "guidance.fused"):
        "backend 'spmd_guidance' runs the split guidance mesh; fused CFG "
        "runs on the plain 'spmd' backend",
    ("spmd_guidance", "guidance.interleaved"):
        "interleaved uncond reuse is not implemented on SPMD; use the "
        "'emulated' or 'pipefuse' backend",
    ("spmd_seq", "seq.uneven"):
        "spmd_seq needs an even head scatter for the all-to-all (got "
        "{heads}); speed-proportional uneven heads are the cost model's "
        "planning view — run uneven plans on the 'emulated' backend, or "
        "pin seq_shards to a divisor of n_heads",
}

#: messages for a backend whose ``requires`` declaration is unmet
_BACKEND_REQUIRES_ERRORS: Dict[Tuple[str, str], str] = {
    ("spmd_guidance", "guidance"):
        "backend 'spmd_guidance' needs a guided plan: set cfg_scale > 0 "
        "with planner='stadi_guidance' and guidance='split'",
    ("spmd_seq", "seq"):
        "backend 'spmd_seq' runs the sequence mesh and needs a "
        "seq-sharded plan: set seq_shards > 1, or planner='stadi_seq' "
        "with seq_shards=0 (auto); an attention-unsharded plan runs on "
        "the plain 'spmd' backend",
    ("spmd_frames", "frames"):
        "backend 'spmd_frames' runs the frame mesh and needs a "
        "multi-frame plan: set num_frames > 1 (optionally "
        "planner='stadi_video' for the frame-parallel placement); a "
        "single-frame plan runs on the plain 'spmd' backend",
}


def _reject_message(backend: str, feature: str, plan, gplan) -> str:
    heads = list(plan.seq.heads) if plan.seq is not None else None
    override = _BACKEND_FEATURE_ERRORS.get((backend, feature))
    if override is not None:
        return override.format(
            mode=getattr(gplan, "mode", None),
            scale=getattr(gplan, "scale", None),
            backend=backend, stages=plan.stages, heads=heads)
    if feature == "stages":
        return (f"the planned stage split {plan.stages} needs a staged "
                f"backend ({list(backends_supporting('stages'))}), not "
                f"{backend!r}; pin num_stages=1 to force pure patch "
                "parallelism")
    if feature.startswith("guidance."):
        return (f"guided generation (cfg_scale={gplan.scale}) needs a "
                f"guided backend ({list(backends_supporting('guidance'))}), "
                f"not {backend!r}")
    if feature == "seq":
        return (f"a sequence-sharded plan (seq_shards > 1) needs a seq "
                f"backend ({list(backends_supporting('seq'))}), not "
                f"{backend!r}; pin seq_shards=1 to force attention-"
                "unsharded execution")
    if feature == "frames":
        return (f"a multi-frame plan (num_frames > 1) needs a frame "
                f"backend ({list(backends_supporting('frames'))}), not "
                f"{backend!r}; pin num_frames=1 for the image path")
    return (f"{backend!r} does not support the planned {feature!r} "
            f"(supported by {list(backends_supporting(feature))})")


def check_backend_can_run(plan, config) -> None:
    """Reject plan/backend mismatches from the capability declarations.

    A staged plan silently degrades to whole-model patch parallelism on a
    non-staged backend (while staged costs/placements get reported), so
    fail fast — reachable via planner='stadi_pipefuse', num_stages=0
    (auto) picking a pipeline on backend='emulated'. Every demanded
    feature must be in the backend's ``supports``; every backend
    ``requires`` token must be demanded by the plan.
    """
    spec = get_executor_spec(config.backend)
    feats, gplan = required_features(plan, config)
    for f in feats:
        if f not in spec.supports:
            raise ValueError(_reject_message(config.backend, f, plan, gplan))
    for req in spec.requires:
        if not any(f == req or f.startswith(req + ".") for f in feats):
            msg = _BACKEND_REQUIRES_ERRORS.get((config.backend, req))
            raise ValueError(msg or f"backend {config.backend!r} requires "
                             f"a plan demanding {req!r}")


# ----------------------------------------------------------------------
# registered executors
# ----------------------------------------------------------------------

@register_executor("emulated", supports={"guidance.fused", "guidance.split",
                                         "guidance.interleaved", "seq",
                                         "seq.uneven", "frames"})
def emulated_executor(params, model_cfg, sched, x_T, cond, plan, config,
                      interval_hook=None):
    fplan = plan.frames
    if fplan is not None and fplan.num_frames > 1:
        # the multi-frame interpreter (DESIGN.md §16); fused CFG composes
        # with the frame axis (§17) — split/interleaved guidance and seq
        # sharding are rejected at pipeline construction
        from repro.core import frames as frames_lib
        res = frames_lib.run_frames(params, model_cfg, sched, x_T, cond,
                                    plan.temporal, plan.patches,
                                    interval_hook=interval_hook,
                                    exchange=config.exchange,
                                    exchange_refresh=config.exchange_refresh,
                                    frames=fplan,
                                    guidance=plan.guidance)
        return res.image, res.trace
    res = pp.run_schedule(params, model_cfg, sched, x_T, cond,
                          plan.temporal, plan.patches,
                          interval_hook=interval_hook,
                          exchange=config.exchange,
                          exchange_refresh=config.exchange_refresh,
                          guidance=plan.guidance,
                          seq=plan.seq)
    return res.image, res.trace


@register_executor("spmd", supports={"guidance.fused"})
def spmd_executor(params, model_cfg, sched, x_T, cond, plan, config,
                  interval_hook=None):
    # interval_hook is never passed here: generate() rejects rebalancing on
    # non-emulated backends (the shard_map program is static)
    from repro.core import spmd
    img = spmd.run_spmd(params, model_cfg, sched, x_T, cond,
                        plan.temporal, plan.patches,
                        exchange=config.exchange,
                        exchange_refresh=config.exchange_refresh,
                        guidance=plan.guidance)
    trace = sim.build_trace(plan.temporal, plan.patches, model_cfg,
                            batch=int(x_T.shape[0]),
                            exchange=config.exchange,
                            exchange_refresh=config.exchange_refresh,
                            guidance=plan.guidance)
    return img, trace


@register_executor("spmd_guidance", supports={"guidance.split"},
                   requires={"guidance"})
def spmd_guidance_executor(params, model_cfg, sched, x_T, cond, plan,
                           config, interval_hook=None):
    """Split-CFG over a ("guide", "dev") shard_map mesh (DESIGN.md §12):
    axis "guide" carries the cond/uncond branch groups, axis "dev" the
    patch workers of each group; needs 2 * n_pairs devices."""
    from repro.core import spmd
    img = spmd.run_spmd_guidance(params, model_cfg, sched, x_T, cond,
                                 plan.temporal, plan.patches, plan.guidance,
                                 exchange=config.exchange,
                                 exchange_refresh=config.exchange_refresh)
    trace = sim.build_trace(plan.temporal, plan.patches, model_cfg,
                            batch=int(x_T.shape[0]),
                            exchange=config.exchange,
                            exchange_refresh=config.exchange_refresh,
                            guidance=plan.guidance)
    return img, trace


@register_executor("simulate", supports=PLAN_FEATURES)
def simulate_executor(params, model_cfg, sched, x_T, cond, plan, config,
                      interval_hook=None):
    batch = int(x_T.shape[0]) if x_T is not None else 1
    trace = sim.build_trace(plan.temporal, plan.patches, model_cfg,
                            batch=batch, exchange=config.exchange,
                            exchange_refresh=config.exchange_refresh,
                            stages=plan.stages,
                            guidance=plan.guidance,
                            seq=plan.seq,
                            frames=plan.frames,
                            cond_tokens=(config.cond_bucket or None))
    return None, trace


@register_executor("spmd_seq", supports={"seq"}, requires={"seq"})
def spmd_seq_executor(params, model_cfg, sched, x_T, cond, plan, config,
                      interval_hook=None):
    """Sequence-parallel SPMD over a ("seq", "dev") shard_map mesh
    (DESIGN.md §13): axis "seq" carries the Ulysses/ring members of every
    patch-worker group; needs seq_shards * n_workers devices."""
    from repro.core import spmd
    splan = plan.seq
    if splan is None:
        raise ValueError(
            "backend 'spmd_seq' runs the sequence mesh and needs a "
            "seq-sharded plan: set seq_shards > 1, or planner='stadi_seq' "
            "with seq_shards=0 (auto); an attention-unsharded plan runs on "
            "the plain 'spmd' backend")
    if plan.guidance is not None:
        raise ValueError("guided generation is not implemented on the "
                         "'spmd_seq' backend; the 'emulated' backend runs "
                         "seq x CFG numerics")
    img = spmd.run_spmd_seq(params, model_cfg, sched, x_T, cond,
                            plan.temporal, plan.patches, splan,
                            exchange=config.exchange,
                            exchange_refresh=config.exchange_refresh)
    trace = sim.build_trace(plan.temporal, plan.patches, model_cfg,
                            batch=int(x_T.shape[0]),
                            exchange=config.exchange,
                            exchange_refresh=config.exchange_refresh,
                            seq=splan)
    return img, trace


@register_executor("spmd_frames", supports={"frames"}, requires={"frames"})
def spmd_frames_executor(params, model_cfg, sched, x_T, cond, plan, config,
                         interval_hook=None):
    """Multi-frame SPMD over a ("frame", "dev") shard_map mesh (DESIGN.md
    §16): axis "frame" carries the group-member rows of the frame
    partition, axis "dev" the patch-worker columns of each row; needs
    n_groups * n_workers devices."""
    from repro.core import spmd
    fplan = plan.frames
    if fplan is None or fplan.num_frames <= 1:
        raise ValueError(
            "backend 'spmd_frames' runs the frame mesh and needs a "
            "multi-frame plan: set num_frames > 1 (optionally "
            "planner='stadi_video' for the frame-parallel placement); a "
            "single-frame plan runs on the plain 'spmd' backend")
    img = spmd.run_spmd_frames(params, model_cfg, sched, x_T, cond,
                               plan.temporal, plan.patches, fplan,
                               exchange=config.exchange,
                               exchange_refresh=config.exchange_refresh)
    trace = sim.build_trace(plan.temporal, plan.patches, model_cfg,
                            batch=int(x_T.shape[0]),
                            exchange=config.exchange,
                            exchange_refresh=config.exchange_refresh,
                            frames=fplan)
    return img, trace


@register_executor("pipefuse", supports={"stages", "guidance.fused",
                                         "guidance.split",
                                         "guidance.interleaved"})
def pipefuse_executor(params, model_cfg, sched, x_T, cond, plan, config,
                      interval_hook=None):
    """Displaced patch pipeline (DESIGN.md §11): emulated interpreter;
    bitwise-identical to "emulated" when the stage count is 1."""
    from repro.core import pipefuse
    stages = plan.stages or [model_cfg.n_layers]
    res = pipefuse.run_pipefuse(params, model_cfg, sched, x_T, cond,
                                plan.temporal, plan.patches, stages,
                                exchange=config.exchange,
                                exchange_refresh=config.exchange_refresh,
                                interval_hook=interval_hook,
                                guidance=plan.guidance)
    return res.image, res.trace


@register_executor("spmd_pipefuse", supports={"stages"})
def spmd_pipefuse_executor(params, model_cfg, sched, x_T, cond, plan,
                           config, interval_hook=None):
    """Real shard_map stage chain over jax.devices() (devices = stages)."""
    from repro.core import spmd
    stages = plan.stages or [model_cfg.n_layers]
    img = spmd.run_spmd_pipefuse(params, model_cfg, sched, x_T, cond,
                                 plan.temporal, plan.patches, stages,
                                 exchange=config.exchange,
                                 exchange_refresh=config.exchange_refresh)
    trace = sim.build_trace(plan.temporal, plan.patches, model_cfg,
                            batch=int(x_T.shape[0]),
                            exchange=config.exchange,
                            exchange_refresh=config.exchange_refresh,
                            stages=stages)
    return img, trace


#: backends that can execute a depth-partitioned (staged) plan — derived
#: from the capability declarations, kept as module names for back-compat
STAGED_BACKENDS = backends_supporting("stages")

#: backends that can execute a sequence-sharded plan (DESIGN.md §13)
SEQ_BACKENDS = backends_supporting("seq")

#: backends that can execute a guided (classifier-free guidance) plan; the
#: mapping is mode-dependent — see check_backend_can_run
GUIDED_BACKENDS = backends_supporting("guidance")

#: backends that can execute a multi-frame (video) plan (DESIGN.md §16)
FRAME_BACKENDS = backends_supporting("frames")


def _env_use_pallas() -> bool:
    """STADI_USE_PALLAS=1 force-routes every pipeline through the Pallas
    kernel bodies (the CI kernel leg; combine with STADI_PALLAS_INTERPRET=1
    off-TPU)."""
    return os.environ.get("STADI_USE_PALLAS", "").strip() not in ("", "0")


class StadiPipeline:
    """One-call STADI inference: plan -> execute -> (optionally) rebalance.

    model_cfg/params/sched describe the denoiser; config describes the
    cluster and strategy. ``generate`` is the only entry point callers need;
    ``plan`` is the one planning entrypoint (a fully-populated five-axis
    ExecutionPlan, cached persistently when ``plan_cache_dir`` is set).
    """

    def __init__(self, model_cfg: DiTConfig, params, sched: NoiseSchedule,
                 config: StadiConfig):
        if config.use_pallas_attention or _env_use_pallas():
            # thread the kernel flag into the model config the executors'
            # jitted steps close over (DiTConfig is the static jit key).
            # STADI_USE_PALLAS=1 force-enables it process-wide — the CI
            # kernel leg runs the whole matrix through the Pallas bodies
            # without touching each test's config.
            model_cfg = model_cfg.replace(use_pallas_attention=True)
            config = dataclasses.replace(config, use_pallas_attention=True)
        self.model_cfg = model_cfg
        self.params = params
        self.sched = sched
        self.config = config
        get_planner(config.planner)      # fail fast on typos
        get_executor(config.backend)
        from repro.core.comm import get_exchange
        get_exchange(config.exchange, config.exchange_refresh)
        if config.num_stages < 0:
            raise ValueError(f"num_stages must be >= 0 (0 = auto), got "
                             f"{config.num_stages}")
        if config.num_stages > 1 and config.backend not in STAGED_BACKENDS:
            raise ValueError(
                f"num_stages={config.num_stages} needs a staged backend "
                f"({sorted(STAGED_BACKENDS)}), not {config.backend!r} — "
                "the displaced patch pipeline (DESIGN.md §11)")
        from repro.core.guidance import GUIDANCE_MODES
        if config.guidance != "none" and config.guidance not in GUIDANCE_MODES:
            raise ValueError(f"unknown guidance mode {config.guidance!r}; "
                             f"one of {('none',) + GUIDANCE_MODES}")
        if config.guidance != "none" and config.cfg_scale <= 0.0:
            raise ValueError(f"guidance={config.guidance!r} needs "
                             "cfg_scale > 0")
        guided = config.cfg_scale > 0.0 or config.guidance != "none"
        if guided and config.rebalance_every:
            raise ValueError("online rebalancing is not supported with "
                             "guidance (the branch pairing is static)")
        if config.seq_shards < 0:
            raise ValueError(f"seq_shards must be >= 0 (0 = auto), got "
                             f"{config.seq_shards}")
        if config.seq_shards > config.n_devices:
            raise ValueError(
                f"seq_shards={config.seq_shards} is infeasible: every "
                "patch-worker group needs one device per sequence shard "
                f"and the cluster has {config.n_devices}")
        if config.seq_shards > 1:
            if config.backend not in SEQ_BACKENDS:
                raise ValueError(
                    f"seq_shards={config.seq_shards} needs a seq backend "
                    f"({sorted(SEQ_BACKENDS)}), not {config.backend!r} — "
                    "sequence-parallel attention (DESIGN.md §13)")
            if model_cfg.n_heads < config.seq_shards:
                raise ValueError(
                    f"seq_shards={config.seq_shards} cannot scatter "
                    f"{model_cfg.n_heads} attention heads (Ulysses needs "
                    ">= 1 head per shard)")
            if config.rebalance_every:
                raise ValueError("online rebalancing is not supported with "
                                 "sequence sharding (the device grouping "
                                 "is static)")
        if config.num_frames < 1:
            raise ValueError(f"num_frames must be >= 1, got "
                             f"{config.num_frames}")
        if config.frame_groups < 0:
            raise ValueError(f"frame_groups must be >= 0 (0 = auto), got "
                             f"{config.frame_groups}")
        if config.num_frames > 1:
            if config.backend not in FRAME_BACKENDS:
                raise ValueError(
                    f"num_frames={config.num_frames} needs a frame backend "
                    f"({sorted(FRAME_BACKENDS)}), not {config.backend!r} — "
                    "multi-frame diffusion (DESIGN.md §16)")
            if config.frame_groups > config.num_frames:
                raise ValueError(
                    f"frame_groups={config.frame_groups} cannot split "
                    f"{config.num_frames} frames (>= 1 frame per group)")
            if config.frame_groups > config.n_devices:
                raise ValueError(
                    f"frame_groups={config.frame_groups} is infeasible: "
                    "every group-member row needs at least one device and "
                    f"the cluster has {config.n_devices}")
            if guided and config.guidance in ("split", "interleaved"):
                raise ValueError(
                    f"guidance={config.guidance!r} is not composed with "
                    "the frame axis: guided video runs FUSED classifier-"
                    "free guidance only (branch pairing and frame grouping "
                    "compete for the same devices) — use guidance='fused' "
                    "or guidance='none' with cfg_scale > 0")
            if config.seq_shards != 1:
                raise ValueError(
                    "sequence sharding is not composed with the frame axis "
                    "yet (ring groups and frame rows compete for the same "
                    "devices) — pin seq_shards=1 with num_frames > 1")
            if config.num_stages != 1:
                raise ValueError(
                    "the displaced patch pipeline is not composed with the "
                    "frame axis yet — pin num_stages=1 with num_frames > 1")
            if config.rebalance_every:
                raise ValueError("online rebalancing is not supported with "
                                 "the frame axis (the frame grouping is "
                                 "static)")
        elif config.frame_groups > 1:
            raise ValueError(f"frame_groups={config.frame_groups} needs "
                             "num_frames > 1 (there is only one frame to "
                             "place)")
        # prompt conditioning (DESIGN.md §17)
        if config.cond_bucket < 0:
            raise ValueError(f"cond_bucket must be >= 0 (0 = derive from "
                             f"the model config), got {config.cond_bucket}")
        if config.cond_bucket > 0 and not model_cfg.cross_attn:
            raise ValueError(
                f"cond_bucket={config.cond_bucket} prices prompt-token "
                "cross-attention but the model has cross_attn=False — "
                "use DiTConfig.text_conditioned()")
        if config.cond_bucket > model_cfg.cond_seq_len:
            raise ValueError(
                f"cond_bucket={config.cond_bucket} exceeds the model's "
                f"cond_seq_len={model_cfg.cond_seq_len} (the encoder "
                "never emits a longer prompt bucket)")
        # persistent plan cache (DESIGN.md §14)
        self.plan_cache = None
        self.last_plan_key: Optional[str] = None
        #: live planner searches actually executed (cache hits skip these)
        self.planner_calls = 0
        #: cumulative Pallas kernel path hits/misses traced by this
        #: pipeline's generate() calls (per-call deltas land on each
        #: PipelineResult.kernel_stats)
        self.kernel_stats: Dict[str, Dict[str, int]] = {"hits": {},
                                                        "misses": {}}
        if config.plan_cache_dir:
            from repro.serving.plan_cache import PlanCache
            self.plan_cache = PlanCache(config.plan_cache_dir)

    @property
    def p_total(self) -> int:
        return self.model_cfg.tokens_per_side

    # ------------------------------------------------------------------
    # planning: the ONE entrypoint (steps x patches x stages x guidance
    # x seq resolved in a single pass)
    # ------------------------------------------------------------------

    def _plan_knobs(self) -> StadiConfig:
        """The config with model-derived provenance filled in (depth, head
        count, byte sizes) — what planners actually see."""
        knobs = self.config
        if knobs.depth is None:          # stage planning needs the DiT depth
            knobs = dataclasses.replace(knobs, depth=self.model_cfg.n_layers)
        if knobs.n_heads is None:        # seq planning needs the head count
            knobs = dataclasses.replace(knobs,
                                        n_heads=self.model_cfg.n_heads)
        if knobs.latent_bytes == 0:      # guided planning needs byte sizes
            cfg = self.model_cfg
            knobs = dataclasses.replace(
                knobs,
                latent_bytes=int(cfg.latent_size ** 2 * cfg.channels * 4),
                kv_row_bytes=int(2 * cfg.n_layers * cfg.tokens_per_side
                                 * cfg.d_model * 2))
        if knobs.cond_bucket == 0 and self.model_cfg.cross_attn:
            # prompt planning prices the full cond_seq_len unless a
            # serving bucket pins a shorter one (DESIGN.md §17)
            knobs = dataclasses.replace(
                knobs, cond_bucket=self.model_cfg.cond_seq_len)
        return knobs

    def _model_key(self) -> str:
        """Content hash of the model config (DiTConfig is a frozen
        dataclass, so its repr is a deterministic fingerprint)."""
        return hashlib.sha256(repr(self.model_cfg).encode()).hexdigest()[:16]

    def _workload_key(self, knobs: StadiConfig) -> Dict:
        """The workload-shape component of the plan-cache key: every knob
        that changes what the planner returns (resolution enters through
        p_total / byte provenance, steps through m_base)."""
        cm = knobs.cost_model
        return {
            "planner": knobs.planner,
            "p_total": self.p_total,
            "m_base": knobs.m_base, "m_warmup": knobs.m_warmup,
            "a": knobs.a, "b": knobs.b, "tiers": list(knobs.tiers),
            "granularity": knobs.granularity, "min_patch": knobs.min_patch,
            "exchange": knobs.exchange,
            "exchange_refresh": knobs.exchange_refresh,
            "num_stages": knobs.num_stages,
            "micro_patches": knobs.micro_patches, "depth": knobs.depth,
            "guidance": knobs.guidance, "cfg_scale": knobs.cfg_scale,
            "uncond_refresh": knobs.uncond_refresh,
            "latent_bytes": knobs.latent_bytes,
            "kv_row_bytes": knobs.kv_row_bytes,
            "seq_shards": knobs.seq_shards, "n_heads": knobs.n_heads,
            # frame axis (DESIGN.md §16): a cached image plan must never be
            # served to a video workload (and vice versa)
            "num_frames": knobs.num_frames,
            "frame_groups": knobs.frame_groups,
            # prompt axis (DESIGN.md §17): a plan priced for one prompt
            # bucket must never be served to another (t_xattn scales with
            # the token count), nor a class-conditional plan to a prompt
            # workload
            "cond_bucket": knobs.cond_bucket,
            "cross_attn": bool(self.model_cfg.cross_attn),
            "cost_model": (None if cm is None else dataclasses.asdict(cm)),
        }

    def plan(self, speeds: Optional[Sequence[float]] = None, *,
             use_cache: bool = True) -> ExecutionPlan:
        """Run the configured planner (no execution) and return a fully-
        populated six-axis ExecutionPlan: ``stages`` / ``guidance`` /
        ``seq`` / ``frames`` are resolved from the planner output or the
        config knobs in this one pass. With a plan cache configured, the persistent cache
        is consulted before any planner search (``use_cache=False`` forces
        a live search without touching the cache)."""
        speeds = list(speeds) if speeds is not None else self.config.speeds
        knobs = self._plan_knobs()
        key = None
        if self.plan_cache is not None and use_cache:
            key = self.plan_cache.signature(speeds, self._model_key(),
                                            self._workload_key(knobs))
            hit = self.plan_cache.get(key)
            if hit is not None:
                self.last_plan_key = key
                return hit
        raw = get_planner(self.config.planner)(speeds, knobs, self.p_total)
        self.planner_calls += 1
        plan = dataclasses.replace(
            raw,
            stages=_resolve_stages(raw, self.model_cfg, knobs),
            guidance=_resolve_guidance(raw, knobs),
            seq=(raw.seq if raw.seq is not None
                 else _resolve_seq(raw, self.model_cfg, knobs)),
            frames=(raw.frames if raw.frames is not None
                    else _resolve_frames(raw, knobs)))
        if key is not None:
            self.plan_cache.put(key, plan)
            self.last_plan_key = key
        return plan

    def generate(self, x_T=None, cond=None, *,
                 measured_speeds: Optional[Sequence[float]] = None
                 ) -> PipelineResult:
        """Plan and execute one generation.

        measured_speeds: ground-truth effective speeds the run experiences
        (defaults to the configured cluster's). When they drift from the
        planned speeds and ``rebalance_every`` is on, the profiler detects it
        and the remaining steps are re-planned mid-run.
        """
        config = self.config
        plan = self.plan()
        check_backend_can_run(plan, config)
        replans: List[ReplanEvent] = []
        hook = None
        if config.rebalance_every > 0:
            if config.backend != "emulated":
                raise ValueError("rebalance_every requires the 'emulated' "
                                 f"backend, not {config.backend!r}")
            hook = self._make_rebalance_hook(plan, measured_speeds, replans)
        # ONE normalized call shape for every backend (EXECUTOR_KWARGS):
        # strictly keyword, so per-backend kwarg drift cannot creep back in
        from repro.kernels import ops as kops
        kstats_before = kops.kernel_stats_snapshot()
        image, trace = get_executor(config.backend)(
            params=self.params, model_cfg=self.model_cfg, sched=self.sched,
            x_T=x_T, cond=cond, plan=plan, config=config,
            interval_hook=hook)
        kernel_stats = kops.kernel_stats_delta(
            kstats_before, kops.kernel_stats_snapshot())
        for bucket, counts in kernel_stats.items():
            for key, n in counts.items():
                self.kernel_stats[bucket][key] = (
                    self.kernel_stats[bucket].get(key, 0) + n)
        latency = None
        if config.cost_model is not None:
            lat_speeds = (list(measured_speeds) if measured_speeds is not None
                          else config.speeds)
            latency = sim.simulate_trace(trace, lat_speeds, config.cost_model)
        elif config.backend == "simulate":
            raise ValueError("the 'simulate' backend needs config.cost_model")
        return PipelineResult(image, trace, plan, latency, replans,
                              kernel_stats)

    def generate_many(self, x_Ts: Sequence, conds: Sequence, *,
                      slots: int = 4) -> List[PipelineResult]:
        """Continuous-batched generation of many requests (serving engine).

        Admits all requests into a :class:`repro.serving.diffusion_engine.
        DiffusionServingEngine` with ``slots`` concurrent lanes and drains
        them; per-request images are bitwise identical to calling
        :meth:`generate` once per request on the emulated backend. Each
        result's ``latency_s`` is the per-request modeled serving latency
        (queueing + batched service, via the cost model) rather than the
        single-request makespan — None when no cost model is configured.
        Results come back in submission order. For SLO verdicts and
        round-level stats, drive a DiffusionServingEngine directly.
        """
        from repro.serving.diffusion_engine import DiffusionServingEngine
        if len(x_Ts) != len(conds):
            raise ValueError(f"{len(x_Ts)} inputs vs {len(conds)} conds")
        engine = DiffusionServingEngine(self, slots=slots)
        reqs = [engine.submit(x, c) for x, c in zip(x_Ts, conds)]
        engine.run_to_completion()
        trace = sim.build_trace(engine.plan.temporal, engine.plan.patches,
                                self.model_cfg, batch=1,
                                exchange=self.config.exchange,
                                exchange_refresh=self.config.exchange_refresh,
                                stages=engine.stages,
                                guidance=engine.plan.guidance)
        report_latency = self.config.cost_model is not None
        return [PipelineResult(r.image, trace, engine.plan,
                               r.modeled_latency_s if report_latency else None)
                for r in reqs]

    # ------------------------------------------------------------------
    # online rebalancing (beyond-paper §7.1): OnlineProfiler in the hot path
    # ------------------------------------------------------------------

    def _make_rebalance_hook(self, plan: ExecutionPlan,
                             measured_speeds: Optional[Sequence[float]],
                             replans: List[ReplanEvent]):
        config = self.config
        cm = config.cost_model or CostModel(t_fixed=1e-3, t_row=1e-3)
        true_speeds = (list(measured_speeds) if measured_speeds is not None
                       else config.speeds)
        profiler = hetero.OnlineProfiler(plan.speeds, alpha=config.profiler_alpha)
        state = {"baseline": list(plan.speeds), "since": 0}

        def hook(next_fine_step: int, ev):
            # feed measured per-device interval latencies into the profiler;
            # work is nominal seconds at v=1 so observed_v converges on the
            # device's true effective speed
            hetero.feed_profiler(profiler, cm, ev.substeps, ev.patches,
                                 true_speeds)
            state["since"] += 1
            if state["since"] < config.rebalance_every:
                return None
            state["since"] = 0
            drift = profiler.drift(state["baseline"])
            if drift <= config.rebalance_threshold:
                return None
            f_rem = plan.temporal.m_base - next_fine_step
            tiers = tuple(t for t in config.tiers if f_rem % t == 0) or (1,)
            knobs = dataclasses.replace(config, m_base=f_rem, m_warmup=0,
                                        tiers=tiers)
            new = get_planner(config.planner)(profiler.speeds, knobs,
                                              self.p_total)
            if f_rem % new.temporal.lcm:
                return None              # cannot fit an interval; keep going
            if self.plan_cache is not None and self.last_plan_key:
                # the persisted plan was computed from speeds that no
                # longer hold — drop it so the next plan() re-searches
                self.plan_cache.invalidate(self.last_plan_key)
            replans.append(ReplanEvent(next_fine_step, drift,
                                       list(state["baseline"]),
                                       list(profiler.speeds), new))
            state["baseline"] = list(profiler.speeds)
            return new.temporal, new.patches

        return hook
