"""Heterogeneous-cluster latency simulator.

This container has one CPU core (DESIGN.md §2): STADI's *numerics* run for
real in the emulation engine, while heterogeneous *wall-clock* is modeled by
replaying the engine's :class:`ExecutionTrace` against per-device effective
speeds with a calibrated per-step cost model

    t_i(P) = (t_fixed + t_row * P) / v_i          [seconds]

calibrated from real measured single-step denoiser latencies at several patch
sizes on this host (benchmarks/bench_latency.py does the calibration). The
paper's own Fig. 9 observation — "single-step delay no longer maintains a
linear relationship with the patch size due to some fixed overhead" — is the
t_fixed term.

Communication: sync all-gather of x at every interval boundary (bytes =
latent slab sizes) + warmup per-layer activation sync; async KV broadcasts
are overlapped with compute (DistriFusion masking) and only charged when
they exceed the interval's compute time.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

from repro.core.patch_parallel import ExecutionTrace, IntervalEvent


def build_trace(plan, patches: Sequence[int], cfg, batch: int = 1) -> ExecutionTrace:
    """Schedule trace without running numerics (latency-only replay).

    Mirrors the events :func:`repro.core.patch_parallel.run_schedule` would
    emit for (plan, patches); the ``"simulate"`` pipeline backend replays it
    against a :class:`CostModel` instead of executing the denoiser.
    """
    R = plan.lcm
    F = plan.m_base - plan.m_warmup
    events = [IntervalEvent(m, [1 if not e else 0 for e in plan.excluded],
                            list(patches), synchronous=True)
              for m in range(plan.m_warmup)]
    for it in range(F // R):
        events.append(IntervalEvent(plan.m_warmup + it * R,
                                    [R // r if r else 0 for r in plan.ratios],
                                    list(patches)))
    H = cfg.latent_size
    lat_bytes = int(batch * H * H * cfg.channels * 4)
    kv_bytes = [int(2 * cfg.n_layers * batch * pr * cfg.tokens_per_side
                    * cfg.d_model * 2) for pr in patches]
    return ExecutionTrace(events, plan, list(patches), cfg.n_tokens,
                          lat_bytes, kv_bytes)


@dataclasses.dataclass
class CostModel:
    t_fixed: float            # per-step fixed overhead (s) at v=1
    t_row: float              # per token-row marginal cost (s) at v=1
    link_bw: float = 25e9     # bytes/s (PCIe4 x16 ~ paper's testbed)
    link_latency: float = 30e-6

    def step_time(self, rows: int, v: float) -> float:
        return (self.t_fixed + self.t_row * rows) / max(v, 1e-9)


def fit_cost_model(rows: Sequence[int], times: Sequence[float], **kw) -> CostModel:
    """Least-squares fit t = t_fixed + t_row * rows."""
    n = len(rows)
    sx = sum(rows); sy = sum(times)
    sxx = sum(r * r for r in rows); sxy = sum(r * t for r, t in zip(rows, times))
    denom = n * sxx - sx * sx
    t_row = (n * sxy - sx * sy) / denom if denom else 0.0
    t_fixed = max((sy - t_row * sx) / n, 1e-6)
    return CostModel(t_fixed=t_fixed, t_row=max(t_row, 1e-9), **kw)


def simulate_trace(trace: ExecutionTrace, speeds: Sequence[float],
                   cm: CostModel) -> float:
    """End-to-end makespan (s) of a schedule on devices with given speeds."""
    total = 0.0
    for ev in trace.events:
        compute = 0.0
        for i, (sub, rows) in enumerate(zip(ev.substeps, ev.patches)):
            if sub == 0 or rows == 0:
                continue
            compute = max(compute, sub * cm.step_time(rows, speeds[i]))
        # interval-boundary sync all-gather of x (+ staged KV for warmup sync)
        comm_bytes = trace.latent_bytes
        if ev.synchronous:
            comm_bytes += sum(trace.kv_bytes_per_worker)   # per-step activation sync
        comm = comm_bytes / cm.link_bw + cm.link_latency
        # async KV publication is masked by compute; charge only the excess
        async_bytes = max((trace.kv_bytes_per_worker[i]
                           for i, s in enumerate(ev.substeps) if s), default=0)
        async_t = async_bytes / cm.link_bw
        total += max(compute, async_t) + comm
    return total


def simulate_tensor_parallel(n_steps: int, n_devices: int, n_layers: int,
                             full_rows: int, speeds: Sequence[float],
                             cm: CostModel, act_bytes_per_layer: int) -> float:
    """Baseline TP: every layer's work split 1/N across devices with a
    synchronous all-reduce per layer => straggler-bound per layer."""
    per_layer_compute = max(
        cm.step_time(full_rows, v) / (n_layers * n_devices) for v in speeds)
    # ring all-reduce ~ 2*(N-1)/N * bytes / bw
    ar = 2 * (n_devices - 1) / n_devices * act_bytes_per_layer / cm.link_bw \
        + cm.link_latency
    per_step = n_layers * (per_layer_compute + ar) + cm.t_fixed / min(speeds)
    return n_steps * per_step


def uniform_pp_latency(n_steps: int, rows_total: int, speeds: Sequence[float],
                       cm: CostModel, latent_bytes: int) -> float:
    """Closed-form patch-parallelism latency (equal patches, equal steps)."""
    n = len(speeds)
    rows = rows_total / n
    per_step = max(cm.step_time(rows, v) for v in speeds)
    comm = latent_bytes / cm.link_bw + cm.link_latency
    return n_steps * (per_step + comm)


@dataclasses.dataclass
class LatencyReport:
    method: str
    occupancies: List[float]
    latency_s: float
    speedup_vs: dict
