"""Heterogeneous-cluster latency simulator.

This container has one CPU core (DESIGN.md §2): STADI's *numerics* run for
real in the emulation engine, while heterogeneous *wall-clock* is modeled by
replaying the engine's :class:`ExecutionTrace` against per-device effective
speeds with a calibrated per-step cost model

    t_i(P) = (t_fixed + t_row * P) / v_i          [seconds]

calibrated from real measured single-step denoiser latencies at several patch
sizes on this host (benchmarks/bench_latency.py does the calibration). The
paper's own Fig. 9 observation — "single-step delay no longer maintains a
linear relationship with the patch size due to some fixed overhead" — is the
t_fixed term.

Communication (DESIGN.md §10): boundary cost depends on each event's
exchange kind. "full" charges the uneven latent all-gather (per-worker
padded-slab wire bytes, NOT the full image — each worker only contributes
its own slab) plus link latency, with async KV publication masked by
compute (DistriFusion overlap) and only the excess charged. "skip" and
"predict" boundaries move no bytes at all (prediction is local compute),
which is exactly the modeled saving of the stale_async / predictive
policies. Warmup steps add the per-step staged activation sync.

The trace itself is no longer built here by a duplicated schedule loop:
:func:`build_trace` replays the SAME event stream
(:func:`repro.core.events.replay`) the execution engines interpret, so
latency modeling can never disagree with the numerics about schedule
structure.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core import comm as comm_lib
from repro.core import events as ir
from repro.core.events import ExecutionTrace, IntervalEvent  # noqa: F401


def build_trace(plan, patches: Sequence[int], cfg, batch: int = 1,
                exchange: str = "sync", exchange_refresh: int = 2,
                stages: Optional[Sequence[int]] = None,
                guidance=None, seq=None, frames=None,
                cond_tokens: Optional[int] = None) -> ExecutionTrace:
    """Schedule trace without running numerics (latency-only replay).

    Replays :func:`repro.core.events.lower` for (plan, patches, policy) —
    the identical stream :func:`repro.core.patch_parallel.run_schedule`
    interprets — and converts it to trace records; the ``"simulate"``
    pipeline backend replays the result against a :class:`CostModel`
    instead of executing the denoiser. ``stages`` produces a displaced
    patch-pipeline trace (DESIGN.md §11) with pipeline-fill provenance;
    ``guidance`` a CFG trace (DESIGN.md §12) with uncond-refresh
    provenance; ``seq`` (a :class:`repro.core.seqpar.SeqPlan`, DESIGN.md
    §13) a sequence-sharded trace whose records carry per-interval ring
    hops; ``frames`` (a :class:`repro.core.frames.FramePlan`, DESIGN.md
    §16) a multi-frame trace whose byte sizes are per frame.
    """
    policy = comm_lib.get_exchange(exchange, exchange_refresh)
    records = ir.replay(plan, patches, policy, stages=stages,
                        guidance=guidance, seq_shards=seq, frames=frames)
    return ir.make_trace(records, plan, list(patches), cfg, batch,
                         stages=stages, guidance=guidance, seq=seq,
                         frames=frames, cond_tokens=cond_tokens)


@dataclasses.dataclass
class CostModel:
    t_fixed: float            # per-step fixed overhead (s) at v=1
    t_row: float              # per token-row marginal cost (s) at v=1
    link_bw: float = 25e9     # bytes/s (PCIe4 x16 ~ paper's testbed)
    link_latency: float = 30e-6
    # per context-token-row x full-head attention K/V read cost (s) at v=1
    # (DESIGN.md §13): self-attention reads the WHOLE context's K/V with
    # every head regardless of how few query rows the patch owns, so at
    # high-resolution latents this memory-bound term dwarfs t_row * rows
    # and no patch split cuts it. Sequence sharding divides it by the head
    # fraction — the Ulysses motivation. 0.0 (default) reproduces the
    # pre-seq model exactly.
    t_ctx: float = 0.0
    # per query-row x prompt-token cross-attention read cost (s) at v=1
    # (DESIGN.md §17): a prompt-conditioned eval reads the whole prompt
    # sequence's K/V from every query row in every block, so the term
    # scales with rows x cond_tokens and is paid by BOTH guidance branches
    # (the null branch runs the identical dense math over zero tokens).
    # 0.0 (default) reproduces the class-conditional model exactly.
    t_xattn: float = 0.0

    def step_time(self, rows: int, v: float) -> float:
        return (self.t_fixed + self.t_row * rows) / max(v, 1e-9)

    def attn_time(self, ctx_rows: int, heads_frac: float, v: float) -> float:
        """Per-step attention context-read time: proportional to context
        rows x resident head fraction, independent of query rows."""
        return self.t_ctx * ctx_rows * heads_frac / max(v, 1e-9)

    def xattn_time(self, rows: int, cond_tokens: int, v: float) -> float:
        """Per-eval prompt cross-attention read time (DESIGN.md §17)."""
        return self.t_xattn * rows * cond_tokens / max(v, 1e-9)


def fit_cost_model(rows: Sequence[int], times: Sequence[float], **kw) -> CostModel:
    """Least-squares fit t = t_fixed + t_row * rows."""
    n = len(rows)
    sx = sum(rows); sy = sum(times)
    sxx = sum(r * r for r in rows); sxy = sum(r * t for r, t in zip(rows, times))
    denom = n * sxx - sx * sx
    t_row = (n * sxy - sx * sy) / denom if denom else 0.0
    t_fixed = max((sy - t_row * sx) / n, 1e-6)
    return CostModel(t_fixed=t_fixed, t_row=max(t_row, 1e-9), **kw)


def _kv_bytes_per_row(trace: ExecutionTrace) -> float:
    """Staged-K/V wire bytes per token row, derived from the trace's initial
    allocation so post-replan events are charged for their ACTUAL slabs."""
    for b, p in zip(trace.kv_bytes_per_worker, trace.patches):
        if p > 0:
            return b / p
    return 0.0


# ----------------------------------------------------------------------
# displaced patch-pipeline costing (DESIGN.md §11)
# ----------------------------------------------------------------------
#
# In pipefuse mode trace "workers" are patch micro-batches that ALL stream
# through every stage device, so the per-worker max-compute model above does
# not apply. Stage d (chain order, placed on the d-th fastest device) runs
# its block share of every micro-task; steady state is bottleneck-bound and
# the pipeline bubble is charged only on fill intervals (after warmup and
# after every draining "full" boundary — the IR's StageShift). Stage
# handoffs are point-to-point activation slabs, overlapped with compute in
# steady state, so they enter as a bandwidth bottleneck term rather than a
# per-boundary stall; K/V never crosses stages (each stage owns its own
# blocks' context), which is the structural comm saving over patch
# parallelism's staged-KV broadcast.

def chain_speeds(speeds: Sequence[float], n_stages: int) -> List[float]:
    """The stage chain runs on the ``n_stages`` fastest devices, in speed
    order (stage 0 = fastest) — the placement convention every consumer of
    a staged plan shares (planner, simulator, serving engine)."""
    return sorted(speeds, reverse=True)[:n_stages]


def pipefuse_stage_seconds(stages: Sequence[int], chain: Sequence[float],
                           cm: CostModel,
                           tasks: Sequence[Tuple[int, float]]) -> List[float]:
    """Per-stage busy seconds for a stream of micro-tasks.

    tasks: (substeps, effective_rows) per micro-batch; both the per-step
    fixed overhead and the row work are depth-proportional, so stage d pays
    its block fraction of each.
    """
    L = sum(stages)
    work = sum(s * (cm.t_fixed + cm.t_row * r) for s, r in tasks)
    return [b / L * work / max(v, 1e-9) for b, v in zip(stages, chain)]


def pipefuse_fill_bubble(stages: Sequence[int], chain: Sequence[float],
                         cm: CostModel, rows: float) -> float:
    """Pipeline-fill bubble: the first micro-task traverses the whole chain
    before steady state; everything but its bottleneck-stage share is
    un-overlapped startup latency (plus one p2p hop per handoff)."""
    L = sum(stages)
    per = [b / L * (cm.t_fixed + cm.t_row * rows) / max(v, 1e-9)
           for b, v in zip(stages, chain)]
    return sum(per) - max(per) + (len(stages) - 1) * cm.link_latency


def pipefuse_warmup_seconds(stages: Sequence[int], chain: Sequence[float],
                            cm: CostModel, rows: float,
                            act_row_bytes: float) -> float:
    """One synchronous full-image task, sequential through the chain (exact
    handoffs; the fill price of synchronous steps)."""
    per = pipefuse_stage_seconds(stages, chain, cm, [(1, rows)])
    hop = act_row_bytes * rows / cm.link_bw + cm.link_latency
    return sum(per) + (len(stages) - 1) * hop


def pipefuse_interval_seconds(stages: Sequence[int], chain: Sequence[float],
                              cm: CostModel,
                              tasks: Sequence[Tuple[int, float]],
                              fill: bool, kind: str, latent_bytes: float,
                              act_row_bytes: float) -> float:
    """Modeled seconds of one adaptive interval through the stage chain —
    the ONE place the staged interval cost lives; the trace replay and the
    serving engine's round costing both call it, so they cannot diverge.

    Steady state is bottleneck-bound; the p2p activation stream of every
    non-final stage is async, so it competes with compute as a bandwidth
    bottleneck (the analogue of the masked async KV). Fill intervals pay
    the pipeline bubble; "full" boundaries drain and add the latent ring
    handoff back to stage 0 (K/V stays put).
    """
    busy = pipefuse_stage_seconds(stages, chain, cm, tasks)
    handoff = sum(s * act_row_bytes * r for s, r in tasks) / cm.link_bw \
        if len(stages) > 1 else 0.0
    total = max(max(busy), handoff)
    if fill:
        total += pipefuse_fill_bubble(stages, chain, cm, tasks[0][1])
    if kind == "full":
        total += latent_bytes / cm.link_bw + cm.link_latency
    return total


def _simulate_staged(trace: ExecutionTrace, speeds: Sequence[float],
                     cm: CostModel) -> float:
    stages = trace.stages
    if trace.cond_tokens:
        # prompt cross-attention (DESIGN.md §17) is per-row work spread
        # over the block depth exactly like t_row, so fold it in before
        # the shared pipefuse helpers price the stage stream
        cm = dataclasses.replace(
            cm, t_row=cm.t_row + cm.t_xattn * trace.cond_tokens)
    chain = chain_speeds(speeds, len(stages))
    total = 0.0
    rows_total = max(sum(trace.patches), 1)
    # guided staged runs (DESIGN.md §12): both CFG branches stream through
    # the chain as one branch-vmapped micro-task, so every task carries 2x
    # the row work (the per-task fixed overhead is shared); the eps combine
    # is chain-local, and each stage's doubled K/V context never crosses
    # devices, so no extra wire term appears
    mult = 2 if trace.guidance is not None else 1
    for ev in trace.events:
        tasks = [(sub, rows * mult) for sub, rows
                 in zip(ev.substeps, ev.patches) if sub > 0 and rows > 0]
        if not tasks:
            continue
        if ev.synchronous:
            total += pipefuse_warmup_seconds(stages, chain, cm,
                                             rows_total * mult,
                                             trace.act_row_bytes)
        else:
            total += pipefuse_interval_seconds(
                stages, chain, cm, tasks, ev.fill, ev.exchange,
                trace.latent_bytes, trace.act_row_bytes)
    return total


# ----------------------------------------------------------------------
# classifier-free guidance costing (DESIGN.md §12)
# ----------------------------------------------------------------------
#
# Guided traces price the cond/uncond branches by placement mode. The
# binding constraint CFG adds is FABRIC CONTENTION: fused guidance doubles
# every staged-K/V payload and broadcasts both branches over one fabric
# domain, so a "full" boundary moves 2x the K/V bytes serially. Split
# guidance maps the two branch groups onto disjoint fabric domains (e.g.
# two nodes): each group broadcasts one branch's K/V concurrently, and the
# only cross-domain traffic is the per-substep epsilon combine (latent-
# sized — orders of magnitude below staged K/V). Interleaved guidance
# additionally idles STRAGGLER pairs' uncond devices on non-refresh
# intervals (the cond side reuses the cached eps_u, so their interval runs
# at the cond device's speed and no epsilon crosses); fast pairs keep
# computing fresh.

def _guided_eps_seconds(ev, g, cm: CostModel, row_bytes: float,
                        pairs: List[int], fresh: bool) -> float:
    """Cross-group epsilon traffic of one interval: each pair exchanges
    its slab's eps both ways at every substep it executes — none for
    reusing (straggler) workers on interleaved reuse intervals, whose
    cached eps_u lives cond-side."""
    subs = {i: (ev.substeps[i] if fresh or not g.worker_reuses(i) else 0)
            for i in pairs}
    bytes_ = sum(2 * subs[i] * ev.patches[i] * row_bytes for i in pairs)
    hops = max(subs.values(), default=0)
    return bytes_ / cm.link_bw + hops * cm.link_latency


def _simulate_guided(trace: ExecutionTrace, speeds: Sequence[float],
                     cm: CostModel) -> float:
    g = trace.guidance
    kv_row = _kv_bytes_per_row(trace)
    rows_total = max(sum(trace.patches), 1)
    row_bytes = trace.latent_bytes / rows_total
    # prompt-token read (DESIGN.md §17): per-row like t_row, paid by each
    # branch a device evaluates (2x fused, 1x per split/interleaved device)
    t_row_eff = cm.t_row + cm.t_xattn * trace.cond_tokens
    total = 0.0
    for ev in trace.events:
        parts = [i for i, (sub, rows) in
                 enumerate(zip(ev.substeps, ev.patches))
                 if sub > 0 and rows > 0]
        if not parts:
            continue
        fresh = ev.uncond_fresh
        compute = 0.0
        for i in parts:
            step_t = cm.t_fixed + t_row_eff * ev.patches[i] \
                * (2.0 if g.mode == "fused" else 1.0)
            if g.mode == "fused":
                t = ev.substeps[i] * step_t / max(speeds[i], 1e-9)
            else:                        # worker i is a device PAIR
                vc = speeds[g.cond_devices[i]]
                vu = speeds[g.uncond_devices[i]]
                if fresh or not g.worker_reuses(i):
                    t = ev.substeps[i] * step_t / max(min(vc, vu), 1e-9)
                else:                    # reuse: uncond idles, cond runs
                    t = ev.substeps[i] * step_t / max(vc, 1e-9)
            compute = max(compute, t)
        eps_t = 0.0
        if g.mode != "fused":
            eps_t = _guided_eps_seconds(ev, g, cm, row_bytes, parts, fresh)
        gather_rows = comm_lib.uneven_all_gather_rows(
            [ev.patches[i] for i in parts])
        kind = "full" if ev.synchronous else ev.exchange
        if kind != "full" or len(parts) <= 1:
            total += compute + eps_t     # no broadcast, no gather
            continue
        # "full" boundary: each branch domain broadcasts its staged K/V —
        # fused serializes both branches on one fabric, split runs the two
        # domains concurrently (one branch's worth of bytes)
        branch_factor = 2.0 if g.mode == "fused" else 1.0
        kv_bytes = branch_factor * sum(kv_row * ev.patches[i] for i in parts)
        comm = gather_rows * row_bytes / cm.link_bw + cm.link_latency
        total += max(compute, kv_bytes / cm.link_bw) + comm + eps_t
    return total


# ----------------------------------------------------------------------
# sequence-parallel ring-contention costing (DESIGN.md §13)
# ----------------------------------------------------------------------
#
# In a seq-sharded run trace "workers" are device GROUPS of S members (the
# column-dealt placement of seqpar.seq_group_speeds). Member j of a group
# computes its speed-proportional ring-segment share of the worker's query
# rows and — the point of the axis — reads the full context with only its
# head fraction, so the memory-bound t_ctx term divides by headf[j] where a
# pure patch worker pays it whole. What seq adds back is the ring: every
# attention performs S-1 ppermute hops, each forwarding one K/V segment
# padded to the largest (comm.ring_hop_rows convention), and hops overlap
# with compute exactly like DistriFusion's async halos (the "ring" policy's
# degraded boundaries) — so ring traffic enters as a bandwidth bottleneck
# competing with compute, not a per-hop stall, with only the per-hop link
# latency unavoidable.

def _simulate_seq(trace: ExecutionTrace, speeds: Sequence[float],
                  cm: CostModel) -> float:
    """Makespan of a sequence-sharded trace: member-level compute split
    (segments x heads) + per-substep ring hops. Guidance does not compose
    with the seq axis in the cost model yet (the planner only pairs seq
    with unguided plans); staged plans dispatch before seq."""
    from repro.core import seqpar as seqpar_lib

    seq = trace.seq
    S = len(seq.segments)
    groups, _ = seqpar_lib.seq_group_speeds(speeds, S)
    headf, segf = seq.head_fracs, seq.seg_fracs
    seg_pad = max(segf)
    kv_row = _kv_bytes_per_row(trace)
    total = 0.0
    for ev in trace.events:
        parts: List[int] = []
        total_rows = max(sum(ev.patches), 1)
        row_bytes = trace.latent_bytes / total_rows
        compute = 0.0
        ring_t = 0.0
        # synchronous warmup steps ring too (the attention is sharded in
        # every jitted step); adaptive intervals carry the IR's hop count
        hops = (S - 1) if ev.synchronous else ev.seq_hops
        for i, (sub, rows) in enumerate(zip(ev.substeps, ev.patches)):
            if sub == 0 or rows == 0:
                continue
            parts.append(i)
            g = groups[i] if i < len(groups) else groups[-1]
            wt = max((cm.t_fixed
                      + (cm.t_row + cm.t_xattn * trace.cond_tokens)
                      * rows * segf[j])
                     / max(v, 1e-9) + cm.attn_time(total_rows, headf[j], v)
                     for j, v in enumerate(g))
            compute = max(compute, sub * wt)
            hop_bytes = kv_row * rows * seg_pad
            ring_t = max(ring_t, sub * hops *
                         (hop_bytes / cm.link_bw + cm.link_latency))
        if not parts:
            continue
        gather_rows = comm_lib.uneven_all_gather_rows(
            [ev.patches[i] for i in parts])
        kind = "full" if ev.synchronous else ev.exchange
        if kind != "full" or len(parts) <= 1:
            # degraded boundary: ring hops carry stale neighbors like
            # DistriFusion halos — fully overlapped, pay only the excess
            total += max(compute, ring_t)
            continue
        comm = gather_rows * row_bytes / cm.link_bw + cm.link_latency
        async_bytes = max(kv_row * ev.patches[i] for i in parts)
        total += max(compute, async_bytes / cm.link_bw, ring_t) + comm
    return total


# ----------------------------------------------------------------------
# frame-axis costing (DESIGN.md §16)
# ----------------------------------------------------------------------
#
# In a multi-frame run trace "workers" are patch-worker COLUMNS shared by
# every member row of the row-dealt frame placement (frames.
# frame_group_layout); member (g, w) steps its row's frame chunk over the
# column's token rows each fine step. Frame f > 0 attends over the 2N
# (own ⊕ previous frame) published context, so the t_ctx term charges
# ~2x context rows per owned frame — the wall frame parallelism divides
# along with the per-frame fixed overhead. Trace byte sizes are PER
# FRAME; a "full" boundary wires every frame's K/V + latent slabs, and a
# multi-row placement adds the (G-1) cross-row previous-frame K/V
# handoffs. The frame-sequential placement (one group) is the same model
# with every device owning all F frames.

def _simulate_frames(trace: ExecutionTrace, speeds: Sequence[float],
                     cm: CostModel) -> float:
    """Makespan of a multi-frame trace: per-member frame-chunk compute
    with the cross-frame context attention term + per-frame boundary
    wire. Fused classifier-free guidance composes (DESIGN.md §17): every
    member evaluates both branches branch-vmapped, so row work, context
    reads, and published K/V double while the fixed overhead is shared —
    exactly the _simulate_guided fused convention. Split/interleaved
    guidance, seq, and stages still do not compose with the frame axis
    (the pipeline rejects those configs loudly)."""
    from repro.core import frames as frames_lib

    fplan = trace.frames
    F = fplan.num_frames
    G = fplan.n_groups
    # fused-CFG branch factor (trace.guidance is fused-mode or None here)
    mult = 2 if trace.guidance is not None else 1
    t_row_eff = cm.t_row + cm.t_xattn * trace.cond_tokens
    if G > 1:
        rows_layout, _ = frames_lib.frame_group_layout(speeds, G)
        n_cols = len(rows_layout[0])
    else:
        rows_layout, n_cols = None, len(speeds)
    kv_row = _kv_bytes_per_row(trace) * mult
    total = 0.0
    for ev in trace.events:
        parts: List[int] = []
        total_rows = max(sum(ev.patches), 1)
        row_bytes = trace.latent_bytes / total_rows
        # context rows a member row reads per fine step: 2N per owned
        # frame, minus the previous-frame half frame 0 does not have
        ctx = [mult * total_rows
               * (2 * fplan.groups[g] - (1 if g == 0 else 0))
               for g in range(G)]
        compute = async_b = 0.0
        for i, (sub, rows) in enumerate(zip(ev.substeps, ev.patches)):
            if sub == 0 or rows == 0:
                continue
            parts.append(i)
            members = ([(rows_layout[g][min(i, n_cols - 1)], g)
                        for g in range(G)] if rows_layout is not None
                       else [(speeds[i], 0)])
            wt = max(fplan.groups[g]
                     * (cm.t_fixed + t_row_eff * rows * mult)
                     / max(v, 1e-9) + cm.attn_time(ctx[g], 1.0, v)
                     for v, g in members)
            compute = max(compute, sub * wt)
            async_b = max(async_b, max(kv_row * rows * fplan.groups[g]
                                       for _, g in members))
        if not parts:
            continue
        gather_rows = comm_lib.uneven_all_gather_rows(
            [ev.patches[i] for i in parts])
        handoff = (G - 1) * kv_row * total_rows / cm.link_bw
        if ev.synchronous:
            # warmup: per-step per-frame activation sync + latent slabs
            comm_bytes = gather_rows * row_bytes * F
            if len(parts) > 1:
                comm_bytes += F * sum(kv_row * ev.patches[i] for i in parts)
                total += compute + comm_bytes / cm.link_bw \
                    + handoff + cm.link_latency
            else:
                total += compute + handoff
            continue
        kind = ev.exchange
        if kind != "full" or len(parts) <= 1:
            # stale/predictive boundary: pure compute, nothing moves
            total += compute
            continue
        comm = gather_rows * row_bytes * F / cm.link_bw + cm.link_latency
        total += max(compute, async_b / cm.link_bw) + comm + handoff
    return total


def simulate_trace(trace: ExecutionTrace, speeds: Sequence[float],
                   cm: CostModel) -> float:
    """End-to-end makespan (s) of a schedule on devices with given speeds."""
    if trace.stages and len(trace.stages) > 1:
        return _simulate_staged(trace, speeds, cm)
    if trace.seq is not None and len(trace.seq.segments) > 1:
        return _simulate_seq(trace, speeds, cm)
    # frames dispatch BEFORE guidance: a guided multi-frame trace (fused
    # CFG x frames, DESIGN.md §17) is a frame trace whose members evaluate
    # both branches — _simulate_frames owns the branch factor
    if trace.frames is not None and trace.frames.num_frames > 1:
        return _simulate_frames(trace, speeds, cm)
    if trace.guidance is not None:
        return _simulate_guided(trace, speeds, cm)
    total = 0.0
    kv_row = _kv_bytes_per_row(trace)
    for ev in trace.events:
        compute = 0.0
        parts: List[int] = []            # workers that actually exchanged
        total_rows = max(sum(ev.patches), 1)
        for i, (sub, rows) in enumerate(zip(ev.substeps, ev.patches)):
            if sub == 0 or rows == 0:
                continue
            parts.append(i)
            # every patch worker reads the FULL context's K/V with all
            # heads (heads_frac 1.0) — the attention wall seq sharding cuts
            step_t = cm.step_time(rows, speeds[i]) \
                + cm.attn_time(total_rows, 1.0, speeds[i]) \
                + cm.xattn_time(rows, trace.cond_tokens, speeds[i])
            compute = max(compute, sub * step_t)
        row_bytes = trace.latent_bytes / total_rows
        # uneven all-gather of x: per-worker padded slab wire bytes — a lone
        # worker (or an all-skip boundary) moves nothing
        gather_rows = comm_lib.uneven_all_gather_rows(
            [ev.patches[i] for i in parts])
        if ev.synchronous:
            # warmup: per-step activation sync (staged K/V) + latent slabs
            comm_bytes = gather_rows * row_bytes
            if len(parts) > 1:
                comm_bytes += sum(kv_row * ev.patches[i] for i in parts)
                total += compute + comm_bytes / cm.link_bw + cm.link_latency
            else:
                total += compute
            continue
        kind = ev.exchange
        if kind != "full" or len(parts) <= 1:
            # stale/predictive boundary (or nothing to exchange): pure
            # compute — no gather, no KV broadcast, no link latency
            total += compute
            continue
        comm = gather_rows * row_bytes / cm.link_bw + cm.link_latency
        # async KV publication is masked by compute; charge only the excess
        async_bytes = max(kv_row * ev.patches[i] for i in parts)
        async_t = async_bytes / cm.link_bw
        total += max(compute, async_t) + comm
    return total


def simulate_tensor_parallel(n_steps: int, n_devices: int, n_layers: int,
                             full_rows: int, speeds: Sequence[float],
                             cm: CostModel, act_bytes_per_layer: int) -> float:
    """Baseline TP: every layer's work split 1/N across devices with a
    synchronous all-reduce per layer => straggler-bound per layer."""
    per_layer_compute = max(
        cm.step_time(full_rows, v) / (n_layers * n_devices) for v in speeds)
    # ring all-reduce ~ 2*(N-1)/N * bytes / bw
    ar = 2 * (n_devices - 1) / n_devices * act_bytes_per_layer / cm.link_bw \
        + cm.link_latency
    per_step = n_layers * (per_layer_compute + ar) + cm.t_fixed / min(speeds)
    return n_steps * per_step


def uniform_pp_latency(n_steps: int, rows_total: int, speeds: Sequence[float],
                       cm: CostModel, latent_bytes: int) -> float:
    """Closed-form patch-parallelism latency (equal patches, equal steps)."""
    n = len(speeds)
    rows = rows_total / n
    per_step = max(cm.step_time(rows, v) for v in speeds)
    comm = latent_bytes / cm.link_bw + cm.link_latency
    return n_steps * (per_step + comm)


@dataclasses.dataclass
class LatencyReport:
    method: str
    occupancies: List[float]
    latency_s: float
    speedup_vs: dict
