"""Schedule IR: ONE generator lowers (TemporalPlan, patches, exchange
policy) into a typed stream of interval events, and every executor is an
interpreter of that stream (DESIGN.md §10).

Before this module the STADI interval schedule (warmup -> LCM-sized adaptive
intervals -> publish/merge) was re-implemented three times — the emulated
engine (`patch_parallel.run_schedule`), the SPMD backend
(`spmd.run_spmd` / `spmd.make_interval_step`) and the latency simulator
(`simulate.build_trace`) — and the three copies could (and did) drift.
Now :func:`lower` is the single source of schedule structure. The FULL
six-axis event grammar (steps x patches x stages x guidance x sequence
x frames — this block is the one authoritative statement of it; the
per-event docstrings below only add detail):

    stream   := Warmup*  adaptive*
    adaptive := StageShift?  GuidanceExchange?  SeqShard?  FrameShard?
                ComputeInterval  Exchange  Replan?

    Warmup(m)             one synchronous full-image fine step (all axes
                          collapse: every worker runs the exact forward)
    StageShift(m, stages) DEPTH axis (DESIGN.md §11): the displaced patch
                          pipeline (re)fills — stage contexts reset to the
                          published buffers. Emitted before the first
                          adaptive interval and again after every draining
                          ("full") boundary, only when lowering with a
                          ``stages`` partition of depth > 1
    GuidanceExchange(m)   GUIDANCE axis (DESIGN.md §12): emitted before
                          every adaptive interval of a split/interleaved
                          CFG plan, carrying the uncond-recompute verdict
                          for the coming interval
    SeqShard(m)           SEQUENCE axis (DESIGN.md §13): emitted before
                          every adaptive interval of a seq-sharded plan,
                          carrying the Ulysses head partition and the ring
                          segment sizing every attention in the interval
                          scatters over (hops = shards - 1 per attention)
    FrameShard(m)         FRAME axis (DESIGN.md §16): emitted before every
                          adaptive interval of a multi-frame plan, carrying
                          the per-group-member frame counts. Within the
                          interval every frame f > 0 attends over its own
                          published context CONCATENATED with frame f-1's
                          published K/V (a 2N-token cross-frame stale
                          context); frame 0 attends own-frame only, so its
                          trajectory is bitwise the image path
    ComputeInterval(m0,R) STEPS x PATCHES axes: R fine steps of stale-KV
                          patch compute (per-worker substeps = R / ratio)
    Exchange(m, kind)     the interval boundary; ``kind`` comes from the
                          :class:`repro.core.comm.BoundaryExchange` policy:
                          "full" (latent all-gather + KV merge), "skip"
                          (stale-async: no traffic, buffers stay stale —
                          also what the "ring" policy emits between
                          refreshes) or "predict" (extrapolate remote K/V
                          from the last two exchanged versions)
    Replan(m, plan)       an online re-allocation took effect at boundary m

Consumers either iterate the stream (``for ev in lower(...)``) or drive it
as a coroutine: replying to an :class:`Exchange` event with a new
``(TemporalPlan, patches)`` via ``gen.send`` makes the generator emit a
:class:`Replan` event and continue lowering under the new allocation — this
is how `run_schedule`'s online-rebalancing hook is expressed on the IR.

The trace record types (:class:`IntervalEvent` / :class:`ExecutionTrace`)
live here too: :func:`replay` converts any event stream into the records the
latency simulator consumes, so `simulate.build_trace` and the trace
`run_schedule` returns are produced by the SAME code path and can never
disagree about which workers are active (an active-but-zero-patch device
used to yield divergent traces).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence, Tuple

from repro.core import comm as comm_lib
from repro.core.schedule import TemporalPlan


# ----------------------------------------------------------------------
# trace records (replayed by the latency simulator)
# ----------------------------------------------------------------------

@dataclasses.dataclass
class IntervalEvent:
    """One executed interval: per-worker (sub-steps, patch rows) plus the
    boundary-exchange kind that followed it ("full" / "skip" / "predict";
    warmup steps are synchronous and always exchange in full). ``fill`` marks
    intervals that begin with a displaced-pipeline (re)fill (DESIGN.md §11) —
    the simulator charges the pipeline bubble there."""
    fine_step: int                       # first fine step of the interval
    substeps: List[int]                  # steps executed by each worker
    patches: List[int]                   # token-rows per worker
    synchronous: bool = False            # warmup intervals sync every layer
    exchange: str = "full"               # boundary kind after this interval
    fill: bool = False                   # first interval after a StageShift
    # guidance provenance (DESIGN.md §12): did this interval recompute the
    # unconditional branch? Always True except on interleaved-guidance
    # intervals that reuse the cached eps_u (the simulator idles the uncond
    # group there and charges no cross-branch eps traffic)
    uncond_fresh: bool = True
    # sequence provenance (DESIGN.md §13): ring hops per attention in this
    # interval (= seq shards - 1; 0 = unsharded) — the simulator prices the
    # per-hop staged K/V segments against the link model here
    seq_hops: int = 0
    # frame provenance (DESIGN.md §16): latent frames evaluated per substep
    # in this interval (1 = image). The simulator multiplies per-substep
    # fixed cost by the frames each member row owns and widens the stale
    # attention context to 2N rows for every frame past the first.
    frames: int = 1


@dataclasses.dataclass
class ExecutionTrace:
    events: List[IntervalEvent]
    plan: Optional[TemporalPlan]
    patches: List[int]
    n_tokens: int                        # full image tokens (comm sizing)
    latent_bytes: int
    kv_bytes_per_worker: List[int]
    # displaced patch-pipeline provenance (DESIGN.md §11): blocks per stage
    # (None = depth-unpartitioned) and hidden-state bytes per token row for
    # pricing the point-to-point stage handoffs
    stages: Optional[List[int]] = None
    act_row_bytes: int = 0
    # guidance provenance (DESIGN.md §12): the GuidancePlan the schedule
    # executed under (None = unguided). In split/interleaved mode trace
    # "workers" are logical device PAIRS, not devices — the guided cost
    # model maps them back through the plan's pairing.
    guidance: Optional[object] = None
    # sequence provenance (DESIGN.md §13): the SeqPlan (head partition +
    # ring segment sizing) the schedule executed under (None = unsharded).
    # Trace "workers" of a seq-sharded run are logical device GROUPS of
    # ``seq.n_shards`` devices each — the ring cost model maps them back
    # through the speed-sorted grouping convention.
    seq: Optional[object] = None
    # frame provenance (DESIGN.md §16): the FramePlan (frame count + frames
    # per group-member row) the schedule executed under (None = image).
    # With more than one group, trace "workers" are logical device GROUPS
    # of ``frames.n_groups`` members each — the frame cost model maps them
    # back through the column-dealt grouping convention.
    frames: Optional[object] = None
    # prompt provenance (DESIGN.md §17): prompt tokens cross-attended per
    # denoiser evaluation (0 = class-conditional). Every query row reads
    # the whole prompt sequence each block, so the cost model charges
    # CostModel.t_xattn * rows * cond_tokens per eval — on BOTH guidance
    # branches (the null branch runs the same dense math over zero tokens).
    cond_tokens: int = 0


# ----------------------------------------------------------------------
# the IR event types
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Warmup:
    """One synchronous fine step: every worker runs the full-image forward."""
    fine_step: int
    substeps: Tuple[int, ...]            # 1 for each active worker, else 0
    patches: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class ComputeInterval:
    """R = ``length`` fine steps of patch compute against stale buffers."""
    fine_step: int                       # first fine step of the interval
    length: int                          # fine steps in the interval (lcm)
    substeps: Tuple[int, ...]            # length // ratio_i per active worker
    ratios: Tuple[int, ...]
    patches: Tuple[int, ...]

    @property
    def workers(self) -> List[int]:
        return [i for i, s in enumerate(self.substeps) if s > 0]


@dataclasses.dataclass(frozen=True)
class Exchange:
    """The boundary after a compute interval. ``kind`` is the policy verdict;
    the final boundary of a run is always "full" (the image must assemble)."""
    fine_step: int                       # first fine step AFTER the interval
    kind: str                            # "full" | "skip" | "predict"
    index: int                           # 0-based boundary counter
    substeps: Tuple[int, ...]            # of the interval that just ended
    patches: Tuple[int, ...]
    last: bool


@dataclasses.dataclass(frozen=True)
class Replan:
    """An online re-allocation (sent into the generator) took effect."""
    fine_step: int
    plan: TemporalPlan
    patches: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class StageShift:
    """The displaced patch pipeline (re)fills (DESIGN.md §11): every stage's
    in-flight activation context resets to the last published buffers.
    Emitted once when the adaptive phase begins and again after every
    draining ("full") exchange; "skip"/"predict" boundaries keep the pipe
    full, so no StageShift follows them — that is precisely how the
    stale-async policies compose with depth pipelining (fewer drains)."""
    fine_step: int                       # first fine step of the refilled pipe
    stages: Tuple[int, ...]              # DiT blocks per stage (chain order)


@dataclasses.dataclass(frozen=True)
class GuidanceExchange:
    """Cross-branch epsilon reconciliation (DESIGN.md §12): emitted before
    each adaptive interval when lowering a split/interleaved
    :class:`~repro.core.guidance.GuidancePlan`. Within the coming interval
    every fine step combines ``eps = eps_u + w*(eps_c - eps_u)`` across the
    cond/uncond device groups — only the epsilon crosses the group
    boundary; each branch's staged K/V stays inside its group. ``fresh``
    is False on interleaved reuse intervals: straggler pairs reuse the
    eps_u cached at the last refresh interval (their uncond device idles
    and no eps crosses); non-straggler pairs always compute fresh."""
    fine_step: int                       # first fine step of the interval
    mode: str                            # "split" | "interleaved"
    fresh: bool                          # uncond branch recomputed?
    index: int                           # 0-based adaptive interval counter


@dataclasses.dataclass(frozen=True)
class SeqShard:
    """Sequence-parallel attention staging (DESIGN.md §13): emitted before
    each adaptive interval when lowering a seq-sharded plan. Within the
    coming interval every attention scatters its heads over ``len(heads)``
    sequence shards (Ulysses all-to-all) and assembles the worker's fresh
    K/V through ``hops`` ring hops of speed-proportionally sized segments
    — each hop carries staged neighbor K/V exactly like a DistriFusion
    halo, which is how the "ring" boundary policy composes with
    stale_async/predictive: degraded boundaries leave the cross-worker
    buffers stale while the ring keeps the within-worker context fresh."""
    fine_step: int                       # first fine step of the interval
    heads: Tuple[int, ...]               # attention heads per seq shard
    segments: Tuple[int, ...]            # ring segment token-rows per shard
    index: int                           # 0-based adaptive interval counter

    @property
    def hops(self) -> int:
        return len(self.segments) - 1


@dataclasses.dataclass(frozen=True)
class FrameShard:
    """Multi-frame staging (DESIGN.md §16): emitted before each adaptive
    interval when lowering a multi-frame plan. ``frames`` is the number of
    latent frames each group-member row evaluates this interval (the
    speed-proportional frame partition); within the interval every frame
    ``f > 0`` attends over its own-frame published context concatenated
    with frame ``f-1``'s published K/V — a 2N-token cross-frame stale
    context that ages under exactly the same full/skip/predict boundary
    policy as the within-frame halo, which is how stale_async / predictive
    / ring compose with the frame axis for free. Frame 0 has no previous
    frame: its context is the plain N-token image context and its
    trajectory is bitwise the image run."""
    fine_step: int                       # first fine step of the interval
    frames: Tuple[int, ...]              # latent frames per group-member row
    index: int                           # 0-based adaptive interval counter

    @property
    def num_frames(self) -> int:
        return sum(self.frames)


Event = object   # Warmup | StageShift | ComputeInterval | Exchange | Replan
                 # | GuidanceExchange | SeqShard | FrameShard


def active_workers(plan: TemporalPlan, patches: Sequence[int]) -> List[int]:
    """The workers that actually execute: planned active AND own >=1 row."""
    return [i for i in plan.active if patches[i] > 0]


# ----------------------------------------------------------------------
# lowering
# ----------------------------------------------------------------------

def lower(plan: TemporalPlan, patches: Sequence[int],
          policy: Optional["comm_lib.BoundaryExchange"] = None,
          stages: Optional[Sequence[int]] = None,
          guidance=None, seq_shards=None, frames=None) -> Iterator[Event]:
    """Lower (plan, patches, exchange policy[, stage split[, guidance
    [, seq shards[, frames]]]]) into events — see the module docstring for
    the one authoritative statement of the six-axis event grammar.

    A coroutine-style generator: iterate it normally, or reply to an
    :class:`Exchange` event with ``gen.send((new_plan, new_patches))`` to
    re-allocate the remaining fine steps (the new plan's interval LCM must
    divide them); the generator then emits a :class:`Replan` and continues.

    ``stages`` (blocks per pipeline stage, DESIGN.md §11) adds the depth
    dimension: with more than one stage a :class:`StageShift` is emitted
    before the first adaptive interval and after every draining ("full")
    boundary, so every executor agrees on exactly when the displaced
    pipeline refills.

    ``guidance`` (a :class:`~repro.core.guidance.GuidancePlan`, DESIGN.md
    §12) adds the CFG dimension: split/interleaved plans emit a
    :class:`GuidanceExchange` before every adaptive interval carrying the
    uncond-recompute verdict, so the emulated engine, the SPMD guidance
    body and the latency simulator agree on the interleaved reuse cadence.
    Fused guidance emits no extra events (the combine is worker-local).

    ``seq_shards`` (a :class:`~repro.core.seqpar.SeqPlan`, DESIGN.md §13)
    adds the sequence dimension: plans with more than one shard emit a
    :class:`SeqShard` before every adaptive interval carrying the head
    partition and ring segment sizing, so the emulated reference, the SPMD
    seq body and the ring-contention cost model agree on exactly how many
    hops every attention pays. A single-shard plan emits nothing — the
    stream (and therefore every executor's numerics) is identical to the
    unsharded lowering by construction.

    ``frames`` (a :class:`~repro.core.frames.FramePlan`, DESIGN.md §16)
    adds the frame dimension: plans with more than one latent frame emit a
    :class:`FrameShard` before every adaptive interval carrying the
    speed-proportional frame partition, so the emulated reference, the
    SPMD frames body and the frame cost model agree on which rows own
    which frames and on the 2N-token cross-frame context every frame past
    the first attends over. A single-frame plan emits nothing — the stream
    degenerates to the image lowering by construction.
    """
    policy = policy or comm_lib.get_exchange("sync")
    patches = list(patches)
    n = len(patches)
    stages = tuple(stages) if stages else ()
    pipelined = len(stages) > 1
    guided_exchange = guidance is not None and guidance.mode != "fused"
    seq_sharded = seq_shards is not None and len(seq_shards.segments) > 1
    framed = frames is not None and frames.num_frames > 1
    # fine steps count in ABSOLUTE coordinates of the original plan; a
    # replanned TemporalPlan covers the remaining steps (its m_base is the
    # remaining count) and only contributes ratios/activity from then on
    m_base = plan.m_base
    workers = active_workers(plan, patches)
    for m in range(plan.m_warmup):
        yield Warmup(m, tuple(1 if i in workers else 0 for i in range(n)),
                     tuple(patches))
    m0 = plan.m_warmup
    boundary = 0
    interval_idx = 0
    refill = pipelined                   # the pipe fills entering adaptive
    while m0 + plan.lcm <= m_base:
        if refill:
            yield StageShift(m0, stages)
            refill = False
        if guided_exchange:
            yield GuidanceExchange(m0, guidance.mode,
                                   guidance.uncond_fresh(interval_idx),
                                   interval_idx)
        if seq_sharded:
            yield SeqShard(m0, tuple(seq_shards.heads),
                           tuple(seq_shards.segments), interval_idx)
        if framed:
            yield FrameShard(m0, tuple(frames.groups), interval_idx)
        interval_idx += 1
        R = plan.lcm
        workers = active_workers(plan, patches)
        subs = tuple(R // plan.ratios[i] if i in workers else 0
                     for i in range(n))
        yield ComputeInterval(m0, R, subs, tuple(plan.ratios), tuple(patches))
        m0 += R
        last = m0 + plan.lcm > m_base
        kind = "full" if last else policy.kind(boundary)
        upd = yield Exchange(m0, kind, boundary, subs, tuple(patches), last)
        if pipelined and kind == "full" and not last:
            refill = True                # a sync boundary drains the pipe
        boundary += 1
        if upd is not None:
            plan, patches = upd
            patches = list(patches)
            assert (m_base - m0) % plan.lcm == 0, (
                "replanned LCM must divide the remaining fine steps",
                m_base - m0, plan.lcm)
            yield Replan(m0, plan, tuple(patches))


# ----------------------------------------------------------------------
# replay: event stream -> trace records / full ExecutionTrace
# ----------------------------------------------------------------------

def record(interval: ComputeInterval, kind: str, fill: bool = False,
           uncond_fresh: bool = True, seq_hops: int = 0,
           frames: int = 1) -> IntervalEvent:
    """The trace record for one adaptive interval + its boundary kind."""
    return IntervalEvent(interval.fine_step, list(interval.substeps),
                         list(interval.patches), exchange=kind, fill=fill,
                         uncond_fresh=uncond_fresh, seq_hops=seq_hops,
                         frames=frames)


def warmup_record(ev: Warmup, frames: int = 1) -> IntervalEvent:
    return IntervalEvent(ev.fine_step, list(ev.substeps), list(ev.patches),
                         synchronous=True, frames=frames)


def replay(plan: TemporalPlan, patches: Sequence[int],
           policy: Optional["comm_lib.BoundaryExchange"] = None,
           stages: Optional[Sequence[int]] = None,
           guidance=None, seq_shards=None,
           frames=None) -> List[IntervalEvent]:
    """Trace records of the whole schedule without executing any numerics —
    the latency-only path (`simulate.build_trace`) and the numerics paths
    (`patch_parallel.run_schedule`, `pipefuse.run_pipefuse`) all derive
    their records from :func:`lower`, so they are structurally identical by
    construction."""
    out: List[IntervalEvent] = []
    pending: Optional[ComputeInterval] = None
    fill = False
    fresh = True
    hops = 0
    n_frames = frames.num_frames if frames is not None else 1
    for ev in lower(plan, patches, policy, stages, guidance=guidance,
                    seq_shards=seq_shards, frames=frames):
        if isinstance(ev, Warmup):
            out.append(warmup_record(ev, frames=n_frames))
        elif isinstance(ev, StageShift):
            fill = True
        elif isinstance(ev, GuidanceExchange):
            fresh = ev.fresh
        elif isinstance(ev, SeqShard):
            hops = ev.hops
        elif isinstance(ev, ComputeInterval):
            pending = ev
        elif isinstance(ev, Exchange):
            out.append(record(pending, ev.kind, fill=fill,
                              uncond_fresh=fresh, seq_hops=hops,
                              frames=n_frames))
            fill = False
            fresh = True
    return out


def make_trace(records: List[IntervalEvent], plan: TemporalPlan,
               patches: Sequence[int], cfg, batch: int,
               stages: Optional[Sequence[int]] = None,
               guidance=None, seq=None, frames=None,
               cond_tokens: Optional[int] = None) -> ExecutionTrace:
    """Byte-size provenance shared by every trace producer. Byte sizes are
    PER FRAME — the frame cost model multiplies by the frame counts the
    trace's ``frames`` plan assigns to each member row. ``cond_tokens``
    (DESIGN.md §17) defaults to the model's declared prompt bucket
    (``cond_seq_len`` when ``cross_attn``); serving passes the lane's
    ACTUAL bucket so shorter prompts are priced shorter."""
    H = cfg.latent_size
    lat_bytes = int(batch * H * H * cfg.channels * 4)
    kv_bytes = [int(2 * cfg.n_layers * batch * pr * cfg.tokens_per_side
                    * cfg.d_model * 2) for pr in patches]
    act_row = int(batch * cfg.tokens_per_side * cfg.d_model * 4)
    if cond_tokens is None:
        cond_tokens = (cfg.cond_seq_len
                       if getattr(cfg, "cross_attn", False) else 0)
    return ExecutionTrace(records, plan, list(patches), cfg.n_tokens,
                          lat_bytes, kv_bytes,
                          stages=list(stages) if stages else None,
                          act_row_bytes=act_row, guidance=guidance, seq=seq,
                          frames=frames, cond_tokens=int(cond_tokens))
