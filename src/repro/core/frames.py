"""Video / multi-frame diffusion as the sixth schedule dimension (DESIGN.md
§16): a frame axis on the latent with cross-frame stale-K/V attention,
composed with the STADI IR.

A video latent is ``[B, F, H, W, C]`` — F frames denoised jointly. Each
frame keeps its own DistriFusion published-K/V state; temporal coherence
comes from the CROSS-FRAME stale context: every frame ``f > 0`` attends
over its own-frame published context concatenated with frame ``f-1``'s
published K/V (a 2N-token ``(frames, tokens)`` layout fed straight into
``dit.block_stack`` — the block math and the padded stale-KV Pallas kernel
are oblivious, the fresh overwrite lands in the first N tokens). The
previous-frame half ages under exactly the same full/skip/predict boundary
policy as the within-frame halo, so stale_async / predictive / ring
compose with the frame axis for free.

Two placements, one numerics:

  * frame-SEQUENTIAL (``n_groups == 1``): every patch worker evaluates all
    F frames of its rows each substep — F x the fixed per-eval cost and
    F x the attention context reads per device.
  * frame-PARALLEL (``n_groups > 1``): the device list is dealt into
    ``n_groups`` member ROWS of ``n // n_groups`` patch-worker columns
    (:func:`frame_group_layout`); row ``g`` owns a contiguous,
    speed-proportional chunk of frames (:func:`frame_partition` — the
    frame analogue of the depth allocator) and pays only its own chunk's
    fixed cost + attention wall. The price: the previous-frame K/V of each
    chunk's first frame crosses a row boundary at every full exchange, and
    patches are split over fewer columns.

Frame evals within a fine step follow SNAPSHOT semantics — every frame's
substep reads the published buffers of the LAST boundary; publishes land
at the next one. Numerics are therefore placement invariant (independent
of ``n_groups``, like the seq dimension's shard-count invariance) and
frame 0's trajectory — which never sees a previous frame — is bitwise the
image path. :func:`run_frames` is the emulated reference realizing this;
the mesh realization lives in :func:`repro.core.spmd.run_spmd_frames`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import buffers as buf_lib
from repro.core import comm as comm_lib
from repro.core import events as ir
from repro.core import hetero
from repro.core import patch_parallel as pp
from repro.core import sampler as sampler_lib
from repro.core.schedule import patch_bounds
from repro.models.diffusion import dit


@dataclasses.dataclass(frozen=True)
class FramePlan:
    """The frame-axis allocation every consumer shares (DESIGN.md §16).

    num_frames: latent frames F (1 = image; the whole axis degenerates)
    groups:     frames per group-member row, sum == F. ``(F,)`` is the
                frame-sequential placement; ``len(groups) > 1`` deals the
                cluster into member rows x patch-worker columns. Row ``g``
                owns the contiguous frame chunk ``bounds[g]``, so exactly
                one previous-frame context crosses each row boundary.
    """
    num_frames: int
    groups: Tuple[int, ...]

    def __post_init__(self):
        if self.num_frames < 1:
            raise ValueError(f"need at least one frame, got {self.num_frames}")
        if not self.groups:
            raise ValueError("frame plan needs at least one group")
        if any(g < 1 for g in self.groups):
            raise ValueError(f"every frame group needs >= 1 frame, got "
                             f"{list(self.groups)}")
        if sum(self.groups) != self.num_frames:
            raise ValueError(f"frame groups {list(self.groups)} sum to "
                             f"{sum(self.groups)}, plan has "
                             f"{self.num_frames} frames")

    @property
    def n_groups(self) -> int:
        return len(self.groups)

    @property
    def framed(self) -> bool:
        """True when the frame axis is non-degenerate (events are emitted)."""
        return self.num_frames > 1

    @property
    def bounds(self) -> List[Tuple[int, int]]:
        """Contiguous [lo, hi) frame ids per group-member row."""
        lo = 0
        out = []
        for g in self.groups:
            out.append((lo, lo + g))
            lo += g
        return out


def frame_partition(num_frames: int, n_groups: int,
                    speeds: Optional[Sequence[float]] = None) -> List[int]:
    """Frames per group-member row, speed-proportional with every row
    keeping at least one frame — the frame analogue of the depth allocator
    (:func:`repro.core.hetero.stage_partition`, same largest-remainder
    rounding). ``speeds=None`` partitions uniformly."""
    if n_groups < 1:
        raise ValueError(f"need at least one frame group, got {n_groups}")
    if n_groups > num_frames:
        raise ValueError(f"frame_groups={n_groups} cannot split "
                         f"{num_frames} frames (>= 1 frame per group)")
    sp = list(speeds)[:n_groups] if speeds else [1.0] * n_groups
    if len(sp) < n_groups:
        sp = sp + [sp[-1]] * (n_groups - len(sp))
    return hetero.stage_partition(num_frames, sp)


def make_frame_plan(num_frames: int, n_groups: int = 1,
                    speeds: Optional[Sequence[float]] = None) -> FramePlan:
    """The FramePlan for ``n_groups`` member rows; ``speeds`` are per-ROW
    aggregate speeds (see :func:`frame_group_layout`), None = uniform."""
    return FramePlan(num_frames,
                     tuple(frame_partition(num_frames, n_groups, speeds)))


def frame_group_layout(speeds: Sequence[float], n_groups: int
                       ) -> Tuple[List[List[float]], List[float]]:
    """Device placement convention of a frame-parallel plan — the ONE
    grouping the planner, the frame cost model and the ``spmd_frames``
    mesh share.

    Unlike the seq grouping (column-dealt so every shard ROW mixes speeds),
    the speed-sorted device list is dealt ROW-wise into ``n_groups``
    contiguous blocks of ``n // n_groups`` patch-worker columns: member
    row ``g`` is the g-th fastest block, so each row has near-uniform
    member speeds and ONE global frame partition fits every column.
    Leftover devices (n % n_groups) idle, like temporally excluded
    workers. Returns (rows, row_speeds): ``rows[g]`` = member speeds of
    row g (column order, fastest first), ``row_speeds[g]`` = aggregate
    speed of row g.
    """
    n = len(speeds)
    if n_groups < 1:
        raise ValueError(f"need at least one frame group, got {n_groups}")
    n_cols = n // n_groups
    if n_cols < 1:
        raise ValueError(
            f"frame_groups={n_groups} needs at least {n_groups} devices, "
            f"the cluster has {n}")
    order = sorted(speeds, reverse=True)
    rows = [[order[g * n_cols + w] for w in range(n_cols)]
            for g in range(n_groups)]
    return rows, [sum(r) for r in rows]


def validate_frames(frames: FramePlan, x_T) -> None:
    """Fail fast when a video latent does not match the frame plan."""
    if x_T.ndim != 5:
        raise ValueError(
            f"multi-frame generation needs a [B, F, H, W, C] latent, got "
            f"shape {tuple(x_T.shape)}")
    if x_T.shape[1] != frames.num_frames:
        raise ValueError(
            f"latent carries {x_T.shape[1]} frames, the frame plan expects "
            f"{frames.num_frames}")


# ----------------------------------------------------------------------
# jitted step bodies (module-level: shared compile cache across runs)
# ----------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def _jit_frame_full_step(params, cfg, x, t, cond, frame):
    """Frame f > 0 bootstrap step: own-frame full attention (no cross
    context exists yet), frame-index conditioned."""
    return dit.forward_patch(params, cfg, x, t, cond, 0, buffers=None,
                             return_kv=True, frame=frame)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _jit_frame_full_ctx_step(params, cfg, x, t, cond, frame, bk, bv):
    """Frame f > 0 warmup step against the 2N-token (own ⊕ previous frame)
    published context: the own-frame half is entirely overwritten fresh
    inside ``forward_patch`` (row_start 0, full rows), so this is full
    self-attention + stale previous-frame context."""
    return dit.forward_patch(params, cfg, x, t, cond, 0, buffers=(bk, bv),
                             return_kv=True, frame=frame)


@functools.partial(jax.jit, static_argnames=("cfg", "row_start"))
def _jit_frame_patch_step(params, cfg, x_loc, t, cond, frame, row_start,
                          bk, bv):
    """Frame f > 0 adaptive substep: stale-KV patch step over the 2N-token
    cross-frame context, frame-index conditioned. ``frame`` is TRACED, so
    one compile per (cfg, row_start) covers every frame."""
    return dit.forward_patch(params, cfg, x_loc, t, cond, row_start,
                             buffers=(bk, bv), return_kv=True, frame=frame)


def _ctx(own: buf_lib.Published, prev: buf_lib.Published,
         tok_axis: int = 2) -> Tuple:
    """The 2N-token cross-frame context: own-frame published K/V ⊕ previous
    frame's published K/V along the token axis (axis 3 when the buffers
    carry the leading CFG branch axis — guided video, DESIGN.md §17)."""
    return (jnp.concatenate([own.k, prev.k], axis=tok_axis),
            jnp.concatenate([own.v, prev.v], axis=tok_axis))


# ----------------------------------------------------------------------
# guided (fused CFG) frame steps — DESIGN.md §17
# ----------------------------------------------------------------------
#
# Fused classifier-free guidance is the ONE mode that composes with the
# frame axis: both branches are branch-vmapped inside every member's eval
# (buffers branch-stacked [2, L, B, N(, 2N), H, hd]) and the combine is
# worker-local, so the IR emits no GuidanceExchange events and the
# boundary grammar is untouched. Frame 0 runs patch_parallel's guided
# steps — bitwise the guided image trajectory.

@functools.partial(jax.jit, static_argnames=("cfg",))
def _jit_guided_frame_full_step(params, cfg, x, t, cond, frame, scale):
    """Guided frame f > 0 bootstrap step (own-frame full attention)."""
    def one(c):
        return dit.forward_patch(params, cfg, x, t, c, 0, buffers=None,
                                 return_kv=True, frame=frame)
    eps2, kvs2 = jax.vmap(one)(dit.guidance_conds(cond))
    return pp._cfg_tail(cfg, eps2, scale) + (kvs2,)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _jit_guided_frame_full_ctx_step(params, cfg, x, t, cond, frame, bk2,
                                    bv2, scale):
    """Guided frame f > 0 warmup step against the branch-stacked 2N-token
    (own ⊕ previous frame) published context."""
    def one(c, bk, bv):
        return dit.forward_patch(params, cfg, x, t, c, 0, buffers=(bk, bv),
                                 return_kv=True, frame=frame)
    eps2, kvs2 = jax.vmap(one)(dit.guidance_conds(cond), bk2, bv2)
    return pp._cfg_tail(cfg, eps2, scale) + (kvs2,)


@functools.partial(jax.jit, static_argnames=("cfg", "row_start"))
def _jit_guided_frame_patch_step(params, cfg, x_loc, t, cond, frame,
                                 row_start, bk2, bv2, scale):
    """Guided frame f > 0 adaptive substep over the branch-stacked 2N-token
    cross-frame context. ``frame`` is TRACED — one compile per
    (cfg, row_start) covers every frame."""
    def one(c, bk, bv):
        return dit.forward_patch(params, cfg, x_loc, t, c, row_start,
                                 buffers=(bk, bv), return_kv=True,
                                 frame=frame)
    eps2, kvs2 = jax.vmap(one)(dit.guidance_conds(cond), bk2, bv2)
    return pp._cfg_tail(cfg, eps2, scale) + (kvs2,)


# ----------------------------------------------------------------------
# emulated reference executor
# ----------------------------------------------------------------------

def run_frames(params, cfg, sched, x_T, cond, plan, patches,
               interval_hook=None, exchange: str = "sync",
               exchange_refresh: int = 2,
               frames: Optional[FramePlan] = None,
               guidance=None) -> pp.RunResult:
    """Emulated multi-frame reference (DESIGN.md §16).

    Interprets the same IR stream as ``run_schedule`` — including the
    :class:`~repro.core.events.FrameShard` events a multi-frame plan
    lowers to — holding one DistriFusion published-K/V state PER FRAME.
    Every substep of frame f > 0 attends over ``concat(pub[f], pub[f-1])``
    (snapshot semantics: all frames of a fine step read the buffers of the
    last boundary; publishes land at the next one), so the numerics are
    placement invariant — independent of ``frames.groups`` — exactly like
    the emulated seq reference is shard-count invariant.

    ``frames=None`` or a single-frame plan delegates to
    :func:`repro.core.patch_parallel.run_schedule` — bitwise the image
    path (same jitted steps; a leading frame axis of 1 is squeezed in and
    restored on the way out). Frame 0 of a multi-frame run takes that same
    code path per substep and is bitwise the image trajectory.

    ``guidance`` (DESIGN.md §17): an optional FUSED
    :class:`~repro.core.guidance.GuidancePlan` — every frame eval becomes
    a branch-vmapped CFG eval against branch-stacked per-frame published
    buffers, with the combine worker-local (no GuidanceExchange events).
    Split/interleaved guidance does not compose with the frame axis and
    raises loudly; frame 0 runs patch_parallel's guided steps and stays
    bitwise the guided image trajectory.
    """
    guided = guidance is not None
    if guided:
        if guidance.mode != "fused":
            raise ValueError(
                f"guidance mode {guidance.mode!r} is not composed with the "
                "frame axis: guided video runs FUSED classifier-free "
                "guidance only (branch-vmapped per member — DESIGN.md §17)")
        if cond is None:
            raise ValueError("guided generation needs a condition")
        if interval_hook is not None:
            raise ValueError("online rebalancing is not supported with "
                             "guidance (the branch pairing is static)")
    if frames is not None and frames.num_frames > 1:
        validate_frames(frames, x_T)
    else:
        x = x_T[:, 0] if x_T.ndim == 5 else x_T
        res = pp.run_schedule(params, cfg, sched, x, cond, plan, patches,
                              interval_hook=interval_hook, exchange=exchange,
                              exchange_refresh=exchange_refresh,
                              guidance=guidance)
        if x_T.ndim == 5:
            res = pp.RunResult(res.image[:, None], res.trace)
        res.trace.frames = frames
        return res

    F = frames.num_frames
    p = cfg.patch_size
    M_base = plan.m_base
    plan0, patches0 = plan, list(patches)
    ts = sampler_lib.ddim_timesteps(sched.T, M_base)
    policy = comm_lib.get_exchange(exchange, exchange_refresh)
    tok_axis = 3 if guided else 2    # buffers gain a leading branch axis

    B = x_T.shape[0]
    xs = [x_T[:, f] for f in range(F)]       # per-frame [B,H,W,C] latents
    fids = [jnp.float32(f) for f in range(F)]
    records: List[ir.IntervalEvent] = []

    published: List[Optional[buf_lib.Published]] = [None] * F
    prev_published: List[Optional[buf_lib.Published]] = [None] * F
    read_pub: List[Optional[buf_lib.Published]] = [None] * F
    pending = [dict() for _ in range(F)]
    new_slabs = [dict() for _ in range(F)]
    interval: Optional[ir.ComputeInterval] = None

    def _frame_full(f, m):
        """One full-image eval of frame f at fine step m: the guided/
        unguided and frame-0/frame-f>0 dispatch shared by warmup and the
        M_w == 0 bootstrap. Returns (eps, kvs)."""
        if f == 0:
            # bitwise the (guided) image warmup step
            if guided:
                eps, _, kvs = pp._jit_guided_full_step(
                    params, cfg, xs[0], ts[m], cond, guidance.scale)
                return eps, kvs
            return pp._jit_full_step(params, cfg, xs[0], ts[m], cond)
        if published[f] is None:
            if guided:
                eps, _, kvs = _jit_guided_frame_full_step(
                    params, cfg, xs[f], ts[m], cond, fids[f],
                    guidance.scale)
                return eps, kvs
            return _jit_frame_full_step(params, cfg, xs[f], ts[m], cond,
                                        fids[f])
        bk, bv = _ctx(published[f], published[f - 1], tok_axis)
        if guided:
            eps, _, kvs = _jit_guided_frame_full_ctx_step(
                params, cfg, xs[f], ts[m], cond, fids[f], bk, bv,
                guidance.scale)
            return eps, kvs
        return _jit_frame_full_ctx_step(params, cfg, xs[f], ts[m], cond,
                                        fids[f], bk, bv)

    def _sync_step(m):
        """One synchronous fine step of every frame under snapshot
        semantics: all frames read the previous step's published K/V,
        then every frame's fresh K/V publishes at once."""
        kv_new = []
        for f in range(F):
            eps, kvs = _frame_full(f, m)
            xs[f] = sampler_lib.ddim_step(sched, xs[f], eps, ts[m], ts[m + 1])
            kv_new.append(kvs)
        for f in range(F):
            published[f] = buf_lib.Published(kv_new[f][0], kv_new[f][1], m)
            read_pub[f] = published[f]

    gen = ir.lower(plan, patches, policy, guidance=guidance, frames=frames)
    send = None
    while True:
        try:
            ev = gen.send(send)
        except StopIteration:
            break
        send = None

        if isinstance(ev, ir.Warmup):
            _sync_step(ev.fine_step)
            records.append(ir.warmup_record(ev, frames=F))

        elif isinstance(ev, ir.FrameShard):
            pass                     # placement only; numerics are invariant

        elif isinstance(ev, ir.ComputeInterval):
            if published[0] is None:     # M_w == 0: bootstrap buffers once
                for f in range(F):
                    _, kvs = _frame_full(f, 0)
                    published[f] = buf_lib.Published(kvs[0], kvs[1], -1)
                    read_pub[f] = published[f]
            interval = ev
            bounds_tok = patch_bounds(ev.patches)
            bounds_lat = [(a * p, b * p) for a, b in bounds_tok]
            pending = [dict() for _ in range(F)]
            new_slabs = [dict() for _ in range(F)]
            for f in range(F):
                ctx = (_ctx(read_pub[f], read_pub[f - 1], tok_axis)
                       if f else None)
                for i in ev.workers:
                    r = ev.ratios[i]
                    x_loc = pp._slab(xs[f], bounds_lat[i])
                    tok_lo = bounds_tok[i][0] * cfg.tokens_per_side
                    for s in range(ev.substeps[i]):
                        t_from = ts[ev.fine_step + s * r]
                        t_to = ts[ev.fine_step + (s + 1) * r]
                        if f == 0 and guided:
                            # bitwise the guided image substep
                            eps, _, kvs = pp._jit_guided_patch_step(
                                params, cfg, x_loc, t_from, cond,
                                bounds_tok[i][0], read_pub[0].k,
                                read_pub[0].v, guidance.scale)
                        elif f == 0:     # bitwise the image substep
                            eps, kvs = pp._jit_patch_step(
                                params, cfg, x_loc, t_from, cond,
                                bounds_tok[i][0], read_pub[0].k,
                                read_pub[0].v)
                        elif guided:
                            eps, _, kvs = _jit_guided_frame_patch_step(
                                params, cfg, x_loc, t_from, cond, fids[f],
                                bounds_tok[i][0], ctx[0], ctx[1],
                                guidance.scale)
                        else:
                            eps, kvs = _jit_frame_patch_step(
                                params, cfg, x_loc, t_from, cond, fids[f],
                                bounds_tok[i][0], ctx[0], ctx[1])
                        x_loc = sampler_lib.ddim_step(sched, x_loc, eps,
                                                      t_from, t_to)
                        if s == 0:
                            buf_lib.publish_local(pending[f], i, kvs[0],
                                                  kvs[1], tok_lo)
                    new_slabs[f][i] = x_loc

        elif isinstance(ev, ir.Exchange):
            bounds_lat = [(a * p, b * p) for a, b in
                          patch_bounds(ev.patches)]
            for f in range(F):
                for i in interval.workers:
                    lat = bounds_lat[i]
                    xs[f] = xs[f].at[:, lat[0]:lat[1]].set(new_slabs[f][i])
                if ev.kind == "full":
                    prev_published[f] = published[f]
                    published[f] = buf_lib.merge(published[f], pending[f],
                                                 ev.fine_step, axis=tok_axis)
                    read_pub[f] = published[f]
                elif ev.kind == "skip":
                    read_pub[f] = published[f]
                elif ev.kind == "predict":
                    read_pub[f] = buf_lib.extrapolate(prev_published[f],
                                                      published[f],
                                                      ev.fine_step)
            rec = ir.record(interval, ev.kind, frames=F)
            records.append(rec)
            if interval_hook is not None and ev.fine_step < M_base:
                upd = interval_hook(ev.fine_step, rec)
                if upd is not None:
                    send = upd

    trace = ir.make_trace(records, plan0, patches0, cfg, int(B),
                          guidance=guidance, frames=frames)
    return pp.RunResult(jnp.stack(xs, axis=1), trace)


def max_frame_staleness(records) -> int:
    """Worst-case age, in adaptive intervals, of the cross-frame (previous
    frame) K/V any substep attended over: the snapshot semantics make even
    a just-merged context one interval old by the time the next interval
    reads it, and every degraded ("skip"/"predict") boundary carries it
    one interval further — so the bound is ``refresh_every`` under the
    stale_async cadence (tested; the within-frame halo obeys the same
    bound, DESIGN.md §16). Warmup steps republish every fine step and
    contribute 0; single-frame records contribute 0."""
    age = 0
    worst = 0
    for ev in records:
        if ev.synchronous:
            age = 0
            continue
        age += 1
        if ev.frames > 1:
            worst = max(worst, age)
        if ev.exchange == "full":
            age = 0
    return worst
