"""Displaced patch-pipeline parallelism (PipeFusion-style) composed with the
STADI schedule IR (DESIGN.md §11).

STADI parallelizes across steps and patches, but every device still runs the
*full* DiT depth. This module adds depth as a third dimension: the block
stack is partitioned into contiguous *stages* sized to device speed
(:func:`repro.core.hetero.stage_partition`), and patch micro-batches stream
through the stage chain with **displaced** (at most one-substep-stale)
remote activations — PipeFusion's observation that diffusion's step-to-step
input similarity makes that staleness nearly free.

Single-process EMULATION with exact numerics, like ``patch_parallel``:

* The residual stream of a micro-patch passes through all stages within its
  substep EXACTLY (stage handoffs are in-order); only the attention context
  is displaced, mirroring PipeFusion where a patch's own activations are
  never stale.
* Each stage holds a persistent K/V *context* for its blocks. Micro-tasks
  update their own rows as they pass through, so when patch ``i`` reaches a
  stage, patches ahead of it in the pipe are fresh (this substep) and
  patches behind are one substep stale — the displaced contract. The
  context is strictly FRESHER than the interval-start ``Published`` buffers
  the non-pipelined engine attends to, so drift vs ``emulated`` is real but
  small (tested/benchmarked < 1 dB PSNR).
* The pipe (re)fills whenever the IR emits a :class:`~repro.core.events.
  StageShift` — entering the adaptive phase and after every draining
  ("full") exchange; "skip"/"predict" boundaries keep it full, which is how
  the PR-3 exchange policies compose with depth pipelining.
* ``num_stages == 1`` disables the context machinery and interprets the
  stream with the exact jitted steps of ``patch_parallel.run_schedule`` —
  bitwise-identical to the ``emulated`` backend by construction.

Heterogeneous wall-clock (pipeline fill bubbles, per-stage bottleneck,
point-to-point handoffs) is modeled by the simulator replaying the same
event stream (:func:`repro.core.simulate.simulate_trace` on a staged
trace); real multi-device execution lives in
:func:`repro.core.spmd.run_spmd_pipefuse`.
"""
from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.diffusion import DiTConfig
from repro.core import buffers as buf_lib
from repro.core import comm as comm_lib
from repro.core import events as ir
from repro.core import patch_parallel as pp
from repro.core import sampler as sampler_lib
from repro.core.sampler import NoiseSchedule
from repro.core.schedule import TemporalPlan, patch_bounds
from repro.models.diffusion import dit


def stage_bounds(stages: Sequence[int]) -> Tuple[Tuple[int, int], ...]:
    """Cumulative [lo, hi) block ranges of a stage partition."""
    out, lo = [], 0
    for n in stages:
        out.append((lo, lo + n))
        lo += n
    return tuple(out)


def displaced_step(params, cfg, x_loc, t, cond, row_start, ctx_k, ctx_v,
                   bounds):
    """One micro-task: a patch slab traverses every stage of the chain.

    The hidden state hands off stage-to-stage exactly; each stage attends
    over its slice of the displaced context (own rows overwritten fresh, as
    in ``forward_patch``) and then commits its fresh rows to the context so
    later micro-tasks this substep see them. Returns
    (eps, fresh_k, fresh_v [L,B,Nl,H,hd], ctx_k', ctx_v'). The serving
    engine vmaps this over request lanes; :data:`_jit_displaced_step` is
    the single-request jitted form.
    """
    rows_tok = x_loc.shape[1] // cfg.patch_size
    h, c = dit.embed_patch(params, cfg, x_loc, t, cond, row_start)
    tok_start = row_start * cfg.tokens_per_side
    Nl = h.shape[1]
    ks, vs = [], []
    for lo, hi in bounds:
        blocks = jax.tree.map(lambda a: a[lo:hi], params["blocks"])
        h, (k, v) = dit.block_stack(blocks, cfg, h, c, tok_start,
                                    buffers=(ctx_k[lo:hi], ctx_v[lo:hi]))
        ctx_k = ctx_k.at[lo:hi, :, tok_start:tok_start + Nl].set(
            k.astype(ctx_k.dtype))
        ctx_v = ctx_v.at[lo:hi, :, tok_start:tok_start + Nl].set(
            v.astype(ctx_v.dtype))
        ks.append(k)
        vs.append(v)
    eps = dit.final_head(params, cfg, h, c, rows_tok)
    return (eps, jnp.concatenate(ks, axis=0), jnp.concatenate(vs, axis=0),
            ctx_k, ctx_v)


_jit_displaced_step = functools.partial(
    jax.jit, static_argnames=("cfg", "row_start", "bounds"))(displaced_step)


@functools.partial(jax.jit, static_argnames=("cfg", "row_start", "bounds"))
def _jit_guided_displaced_step(params, cfg, x_loc, t, cond, row_start,
                               ctx_k2, ctx_v2, bounds, scale):
    """Guided micro-task (DESIGN.md §12): branch-vmapped
    :func:`displaced_step` over branch-stacked stage contexts
    [2, L, B, N, H, hd]. Returns (eps_combined, delta, k2, v2, ctx_k2',
    ctx_v2') — the CFG analogue of :data:`_jit_displaced_step`."""
    def one(c, ck, cv):
        return displaced_step(params, cfg, x_loc, t, c, row_start, ck, cv,
                              bounds)
    eps2, k2, v2, ck2, cv2 = jax.vmap(one)(dit.guidance_conds(cond),
                                           ctx_k2, ctx_v2)
    return (sampler_lib.cfg_combine(eps2[0], eps2[1], scale),
            sampler_lib.cfg_delta(eps2[0], eps2[1]), k2, v2, ck2, cv2)


def run_pipefuse(params, cfg: DiTConfig, sched: NoiseSchedule, x_T, cond,
                 plan: TemporalPlan, patches: Sequence[int],
                 stages: Sequence[int], exchange: str = "sync",
                 exchange_refresh: int = 2,
                 interval_hook=None, guidance=None) -> "pp.RunResult":
    """Execute a STADI schedule with the DiT depth pipelined over ``stages``.

    patches: token-rows per micro-batch slab (sum == cfg.tokens_per_side);
    with ``len(stages) == 1`` this is exactly ``run_schedule`` (bitwise).
    Micro-tasks are ordered substep-major, ascending slab index — the pipe
    order the displaced context emulates.

    guidance (DESIGN.md §12): micro-tasks become branch-vmapped CFG evals
    over branch-stacked stage contexts; interleaved intervals reuse the
    cached eps_u per the IR's GuidanceExchange verdicts, running only the
    cond branch through the chain.
    """
    stages = list(stages)
    if sum(stages) != cfg.n_layers:
        raise ValueError(f"stages {stages} must cover all {cfg.n_layers} "
                         "blocks")
    if interval_hook is not None:
        raise ValueError("online rebalancing is not supported by the "
                         "pipefuse backend (stage splits are static)")
    guided = guidance is not None
    if guided and cond is None:
        raise ValueError("guided generation needs a class condition")
    tok_axis = 3 if guided else 2
    S = len(stages)
    bounds = stage_bounds(stages)
    p = cfg.patch_size
    plan0, patches0 = plan, list(patches)
    ts = sampler_lib.ddim_timesteps(sched.T, plan.m_base)
    policy = comm_lib.get_exchange(exchange, exchange_refresh)

    x = x_T
    B = x.shape[0]
    records: List[ir.IntervalEvent] = []

    published: Optional[buf_lib.Published] = None
    prev_published: Optional[buf_lib.Published] = None
    read_pub: Optional[buf_lib.Published] = None   # S == 1 read source
    ctx_k = ctx_v = None                           # S > 1 displaced context
    pending = {}
    slabs = {}
    ucache = {}                          # interleaved: last eps_u per worker
    interval: Optional[ir.ComputeInterval] = None
    fill_pending = False
    fresh = True

    def _full_step(t):
        if guided:
            eps, _, kvs2 = pp._jit_guided_full_step(params, cfg, x, t, cond,
                                                    guidance.scale)
            return eps, kvs2
        return pp._jit_full_step(params, cfg, x, t, cond)

    def _bootstrap():
        nonlocal published, read_pub
        if published is None:                      # M_w == 0: one full fwd
            _, kvs = _full_step(ts[0])
            published = buf_lib.Published(kvs[0], kvs[1], -1)
            read_pub = published

    for ev in ir.lower(plan, patches, policy,
                       stages=stages if S > 1 else None, guidance=guidance):
        if isinstance(ev, ir.Warmup):
            # synchronous step: the chain handoffs are exact, so warmup is
            # the same full-image forward as the non-pipelined engine
            eps, kvs = _full_step(ts[ev.fine_step])
            x = sampler_lib.ddim_step(sched, x, eps, ts[ev.fine_step],
                                      ts[ev.fine_step + 1])
            published = buf_lib.Published(kvs[0], kvs[1], ev.fine_step)
            read_pub = published
            records.append(ir.warmup_record(ev))

        elif isinstance(ev, ir.StageShift):
            # pipeline (re)fill: stage contexts reset to the published K/V
            _bootstrap()
            ctx_k, ctx_v = published.k, published.v
            fill_pending = True

        elif isinstance(ev, ir.GuidanceExchange):
            fresh = ev.fresh

        elif isinstance(ev, ir.ComputeInterval):
            _bootstrap()
            interval = ev
            bounds_tok = patch_bounds(ev.patches)
            bounds_lat = [(a * p, b * p) for a, b in bounds_tok]
            pending = {}
            slabs = {i: pp._slab(x, bounds_lat[i]) for i in ev.workers}
            R = ev.length
            for f in range(R):                     # substep-major micro order
                for i in ev.workers:
                    r = ev.ratios[i]
                    if f % r:
                        continue
                    t_from = ts[ev.fine_step + f]
                    t_to = ts[ev.fine_step + f + r]
                    tok_lo = bounds_tok[i][0] * cfg.tokens_per_side
                    kvs = None
                    if S == 1 and not guided:      # exact emulated path
                        eps, kvs = pp._jit_patch_step(
                            params, cfg, slabs[i], t_from, cond,
                            bounds_tok[i][0], read_pub.k, read_pub.v)
                    elif S == 1:         # the shared per-substep CFG
                        # contract (pp.guided_substep), same as run_schedule
                        eps, kvs = pp.guided_substep(
                            params, cfg, slabs[i], t_from, cond,
                            bounds_tok[i][0], read_pub, published,
                            guidance, fresh, ucache, i, first=(f == 0))
                    elif not guided:
                        eps, k_loc, v_loc, ctx_k, ctx_v = _jit_displaced_step(
                            params, cfg, slabs[i], t_from, cond,
                            bounds_tok[i][0], ctx_k, ctx_v, bounds)
                        kvs = (k_loc, v_loc)
                    elif fresh or not guidance.worker_reuses(i):
                        # guided chain micro-task
                        (eps, delta, k_loc, v_loc, ctx_k,
                         ctx_v) = _jit_guided_displaced_step(
                            params, cfg, slabs[i], t_from, cond,
                            bounds_tok[i][0], ctx_k, ctx_v, bounds,
                            guidance.scale)
                        if guidance.mode == "interleaved":
                            ucache[i] = delta
                        kvs = (k_loc, v_loc)
                    else:                          # staged interleaved reuse
                        eps_c, k_c, v_c, ck, cv = _jit_displaced_step(
                            params, cfg, slabs[i], t_from, cond,
                            bounds_tok[i][0], ctx_k[0], ctx_v[0], bounds)
                        ctx_k = ctx_k.at[0].set(ck)
                        ctx_v = ctx_v.at[0].set(cv)
                        eps = sampler_lib.cfg_apply_delta(eps_c, ucache[i],
                                                          guidance.scale)
                        if f == 0:
                            kvs = pp._stack_uncond((k_c, v_c), published,
                                                   tok_lo, k_c.shape[2])
                    slabs[i] = sampler_lib.ddim_step(sched, slabs[i], eps,
                                                     t_from, t_to)
                    if f == 0:   # Alg.1: publish the interval-start K/V
                        buf_lib.publish_local(pending, i, kvs[0], kvs[1],
                                              tok_lo)

        elif isinstance(ev, ir.Exchange):
            bounds_lat = [(a * p, b * p) for a, b in
                          patch_bounds(ev.patches)]
            for i in interval.workers:
                lat = bounds_lat[i]
                x = x.at[:, lat[0]:lat[1]].set(slabs[i])
            if ev.kind == "full":
                prev_published = published
                published = buf_lib.merge(published, pending, ev.fine_step,
                                          axis=tok_axis)
                read_pub = published
            elif ev.kind == "skip":
                read_pub = published
            elif ev.kind == "predict":
                read_pub = buf_lib.extrapolate(prev_published, published,
                                               ev.fine_step)
            # S > 1: the context persists across skip/predict boundaries
            # (the pipe stays full); the next StageShift resets it
            records.append(ir.record(interval, ev.kind, fill=fill_pending,
                                     uncond_fresh=fresh))
            fill_pending = False
            fresh = True

    trace = ir.make_trace(records, plan0, patches0, cfg, int(B),
                          stages=stages, guidance=guidance)
    return pp.RunResult(x, trace)
