"""Real SPMD execution of a STADI schedule via ``jax.shard_map``.

Moved out of ``launch/stadi_infer.py`` so it is an execution *backend*
(registered as ``"spmd"`` in :mod:`repro.core.pipeline`) rather than a launch
script. Every device owns one (padded) row-slab; uneven all-gathers use the
padded strategy of :mod:`repro.core.comm`; the mixed-rate schedule runs in
SPMD lockstep with per-device activity masks — a no-op substep costs what it
costs on the slow device, the TPU analogue of the paper's per-GPU step
skipping. Set ``STADI_HOST_DEVICES=N`` (before importing jax) for N CPU host
devices.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.configs.diffusion import DiTConfig
from repro.core.sampler import NoiseSchedule
from repro.core.schedule import TemporalPlan


def _run_substeps(params, cfg: DiTConfig, sched: NoiseSchedule, ts, m_base,
                  R, my_slab, cond, pub_k, pub_v, my_start, my_tok,
                  my_ratio, m0):
    """R fine steps on this device's padded slab with activity masking: a
    device with interval ratio r only applies every r-th DDIM update (a
    no-op substep costs what it costs — the paper's per-GPU step skipping in
    SPMD lockstep). Publishes the FIRST substep's fresh K/V (Alg. 1).
    ``m0`` (first fine step) may be a python int (run_spmd's statically
    unrolled loop) or a traced scalar (round-granular serving)."""
    import jax.numpy as jnp

    from repro.core import sampler as sampler_lib
    from repro.models.diffusion import dit

    fresh_k = fresh_v = None
    for s in range(R):
        active = (s % my_ratio) == 0
        t_from = ts[m0 + s]
        t_to = ts[jnp.minimum(m0 + s + my_ratio, m_base)]
        eps, kvs = dit.forward_patch(
            params, cfg, my_slab, t_from, cond, my_start,
            buffers=(pub_k, pub_v), return_kv=True, valid_tokens=my_tok)
        stepped = sampler_lib.ddim_step(sched, my_slab, eps, t_from, t_to)
        my_slab = jnp.where(active, stepped, my_slab)
        if s == 0:                            # Alg.1: publish first substep
            fresh_k, fresh_v = kvs
    return my_slab, fresh_k, fresh_v


def _gather_and_merge(cfg: DiTConfig, patches, row_starts, my_slab,
                      fresh_k, fresh_v, pub_k, pub_v):
    """Interval boundary: uneven all-gathers (padded strategy) rebuild the
    full latent, and every device's fresh K/V valid prefix is merged into
    the (scratch-padded) published buffers."""
    import jax
    import jax.numpy as jnp

    p, wp, N = cfg.patch_size, cfg.tokens_per_side, len(patches)
    slabs = jax.lax.all_gather(my_slab, "dev")        # [N,B,Pmax*p,W,C]
    gk = jax.lax.all_gather(fresh_k, "dev")           # [N,L,B,Nl_max,H,hd]
    gv = jax.lax.all_gather(fresh_v, "dev")
    parts = [slabs[i, :, :patches[i] * p] for i in range(N) if patches[i]]
    x_full = jnp.concatenate(parts, axis=1)
    for i in range(N):                         # static merge, valid prefixes
        sz = patches[i] * wp
        if sz == 0:
            continue
        st = int(row_starts[i]) * wp
        pub_k = jax.lax.dynamic_update_slice_in_dim(
            pub_k, gk[i, :, :, :sz], st, axis=2)
        pub_v = jax.lax.dynamic_update_slice_in_dim(
            pub_v, gv[i, :, :, :sz], st, axis=2)
    return x_full, pub_k, pub_v


def make_interval_step(cfg: DiTConfig, sched: NoiseSchedule,
                       plan: TemporalPlan, patches: Sequence[int]):
    """Round-granular SPMD: one jitted shard_map call per adaptive interval.

    Returns ``fn(params, x_full [B,H,W,C], cond [B], pub_k, pub_v
    [L,B,N,H,hd], m0) -> (x_full, pub_k, pub_v)`` executing the R = plan.lcm
    fine steps starting at (traced) fine step ``m0`` with the same per-device
    activity masks, padded-slab all-gathers, and publish-at-first-substep
    buffer semantics as :func:`run_spmd`'s inner loop. Carried state lives on
    the host between calls, so the diffusion serving engine can interleave
    many request cohorts across rounds (DESIGN.md §9); stale-KV buffers are
    scratch-padded on entry and sliced back to ``cfg.n_tokens`` on exit.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import sampler as sampler_lib
    from repro.core.comm import shard_map_compat

    devices = jax.devices()
    N = len(patches)
    assert N <= len(devices), (N, len(devices))
    mesh = Mesh(np.asarray(devices[:N]), ("dev",))

    p = cfg.patch_size
    wp = cfg.tokens_per_side
    Pmax = max(patches)
    Nl_max = Pmax * wp
    row_starts = np.concatenate([[0], np.cumsum(patches)[:-1]]).astype(np.int32)
    rows_arr = jnp.asarray(patches, jnp.int32)
    starts_arr = jnp.asarray(row_starts, jnp.int32)
    ratios = [r if r else 1 for r in plan.ratios]
    ratios_arr = jnp.asarray(ratios, jnp.int32)
    ts = sampler_lib.ddim_timesteps(sched.T, plan.m_base)
    R = plan.lcm

    def body(params, x_full, cond, pub_k, pub_v, m0):
        idx = jax.lax.axis_index("dev")
        my_rows = rows_arr[idx]
        my_start = starts_arr[idx]
        my_ratio = ratios_arr[idx]
        my_tok = my_rows * wp
        pad = [(0, 0), (0, 0), (0, Nl_max), (0, 0), (0, 0)]
        pub_k = jnp.pad(pub_k, pad)               # scratch-padded buffers
        pub_v = jnp.pad(pub_v, pad)
        x_pad = jnp.pad(x_full, ((0, 0), (0, Pmax * p), (0, 0), (0, 0)))
        my_slab = jax.lax.dynamic_slice_in_dim(x_pad, my_start * p, Pmax * p,
                                               axis=1)
        my_slab, fresh_k, fresh_v = _run_substeps(
            params, cfg, sched, ts, plan.m_base, R, my_slab, cond,
            pub_k, pub_v, my_start, my_tok, my_ratio, m0)
        x_full, pub_k, pub_v = _gather_and_merge(
            cfg, patches, row_starts, my_slab, fresh_k, fresh_v,
            pub_k, pub_v)
        return x_full, pub_k[:, :, :cfg.n_tokens], pub_v[:, :, :cfg.n_tokens]

    fn = shard_map_compat(body, mesh, (P(),) * 6, (P(), P(), P()))
    return jax.jit(fn)


def run_spmd(params, cfg: DiTConfig, sched: NoiseSchedule, x_T, cond,
             plan: TemporalPlan, patches: Sequence[int]):
    """shard_map STADI across jax.devices(). Returns final image [B,H,W,C]."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import sampler as sampler_lib
    from repro.models.diffusion import dit

    devices = jax.devices()
    N = len(patches)
    assert N <= len(devices), (N, len(devices))
    mesh = Mesh(np.asarray(devices[:N]), ("dev",))

    p = cfg.patch_size
    wp = cfg.tokens_per_side
    Pmax = max(patches)
    Nl_max = Pmax * wp
    row_starts = np.concatenate([[0], np.cumsum(patches)[:-1]]).astype(np.int32)
    rows_arr = jnp.asarray(patches, jnp.int32)
    starts_arr = jnp.asarray(row_starts, jnp.int32)
    ratios = [r if r else 1 for r in plan.ratios]
    ratios_arr = jnp.asarray(ratios, jnp.int32)
    ts = sampler_lib.ddim_timesteps(sched.T, plan.m_base)
    M_w, R = plan.m_warmup, plan.lcm
    F = plan.m_base - M_w

    def body(params, x_full, cond):
        idx = jax.lax.axis_index("dev")
        my_rows = rows_arr[idx]
        my_start = starts_arr[idx]
        my_ratio = ratios_arr[idx]
        my_tok = my_rows * wp

        # ---- warmup: synchronous == full-image forward on every device ----
        pub_k = pub_v = None
        for m in range(M_w):
            eps, kvs = dit.forward_patch(params, cfg, x_full, ts[m], cond, 0,
                                         buffers=None, return_kv=True)
            x_full = sampler_lib.ddim_step(sched, x_full, eps, ts[m], ts[m + 1])
            pub_k, pub_v = kvs
        pad = [(0, 0), (0, 0), (0, Nl_max), (0, 0), (0, 0)]
        pub_k = jnp.pad(pub_k, pad)               # scratch-padded buffers
        pub_v = jnp.pad(pub_v, pad)

        # pad x so every device can slice a Pmax slab
        x_pad = jnp.pad(x_full, ((0, 0), (0, Pmax * p), (0, 0), (0, 0)))
        my_slab = jax.lax.dynamic_slice_in_dim(x_pad, my_start * p, Pmax * p, axis=1)

        for it in range(F // R):
            m0 = M_w + it * R
            my_slab, fresh_k, fresh_v = _run_substeps(
                params, cfg, sched, ts, plan.m_base, R, my_slab, cond,
                pub_k, pub_v, my_start, my_tok, my_ratio, m0)
            x_full, pub_k, pub_v = _gather_and_merge(
                cfg, patches, row_starts, my_slab, fresh_k, fresh_v,
                pub_k, pub_v)
            x_pad = jnp.pad(x_full, ((0, 0), (0, Pmax * p), (0, 0), (0, 0)))
            my_slab = jax.lax.dynamic_slice_in_dim(x_pad, my_start * p,
                                                   Pmax * p, axis=1)
        return x_full

    from repro.core.comm import shard_map_compat
    fn = shard_map_compat(body, mesh, (P(), P(), P()), P())
    return jax.jit(fn)(params, x_T, cond)
