"""Real SPMD execution of a STADI schedule via ``jax.shard_map``.

Moved out of ``launch/stadi_infer.py`` so it is an execution *backend*
(registered as ``"spmd"`` in :mod:`repro.core.pipeline`) rather than a launch
script. Every device owns one (padded) row-slab; uneven all-gathers use the
padded strategy of :mod:`repro.core.comm`; the mixed-rate schedule runs in
SPMD lockstep with per-device activity masks — a no-op substep costs what it
costs on the slow device, the TPU analogue of the paper's per-GPU step
skipping. Set ``STADI_HOST_DEVICES=N`` (before importing jax) for N CPU host
devices.

The shard_map body is GENERATED from the schedule IR (DESIGN.md §10): the
event stream of :func:`repro.core.events.lower` — the same one the emulated
engine interprets — unrolls statically into the traced program, so the
warmup / interval / merge structure exists in exactly one place. Boundary
exchange follows the event kinds: "full" gathers the latent and merges
fresh K/V, "skip" keeps buffers stale (the gather of disjoint slabs is
numerically transparent and modeled as free), "predict" extrapolates the
published K/V from the last two full exchanges with a static coefficient.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.configs.diffusion import DiTConfig
from repro.core import buffers as buf_lib
from repro.core import comm as comm_lib
from repro.core import events as ir
from repro.core.sampler import NoiseSchedule
from repro.core.schedule import TemporalPlan


def _run_substeps(params, cfg: DiTConfig, sched: NoiseSchedule, ts, m_base,
                  R, my_slab, cond, pub_k, pub_v, my_start, my_tok,
                  my_ratio, m0, guidance_scale=None, eps_combine=None,
                  attend_fn=None, frame=None, ctx_tokens=None):
    """R fine steps on this device's padded slab with activity masking: a
    device with interval ratio r only applies every r-th DDIM update (a
    no-op substep costs what it costs — the paper's per-GPU step skipping in
    SPMD lockstep). Publishes the FIRST substep's fresh K/V (Alg. 1).
    ``m0`` (first fine step) may be a python int (run_spmd's statically
    unrolled loop) or a traced scalar (round-granular serving).

    Guidance (DESIGN.md §12): ``guidance_scale`` turns each eval into a
    branch-vmapped fused CFG step against branch-stacked buffers (the
    "spmd" fused path); ``eps_combine`` post-processes the raw local eps —
    the "spmd_guidance" split path passes the cross-branch psum combine
    over the guidance mesh axis.

    ``attend_fn`` (DESIGN.md §13) replaces the buffered attention read in
    ``dit.block_stack`` — the "spmd_seq" path passes the Ulysses
    all-to-all + ring-ppermute read over the sequence mesh axis.

    ``frame`` / ``ctx_tokens`` (DESIGN.md §16): the "spmd_frames" path
    passes the latent frame index (summed into the conditioning) and the
    real-token count of its 2N cross-frame concatenated buffers.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import sampler as sampler_lib
    from repro.models.diffusion import dit

    fresh_k = fresh_v = None
    for s in range(R):
        active = (s % my_ratio) == 0
        t_from = ts[m0 + s]
        t_to = ts[jnp.minimum(m0 + s + my_ratio, m_base)]
        if guidance_scale is not None:        # fused CFG: both branches here
            def one(c, bk, bv):
                return dit.forward_patch(
                    params, cfg, my_slab, t_from, c, my_start,
                    buffers=(bk, bv), return_kv=True, valid_tokens=my_tok)
            eps2, kvs = jax.vmap(one)(dit.guidance_conds(cond), pub_k, pub_v)
            if cfg.use_pallas_attention:   # fused combine: one HBM pass
                from repro.kernels import ops as kops
                eps = kops.cfg_epilogue(eps2[0], eps2[1], guidance_scale,
                                        with_delta=False)
            else:
                eps = sampler_lib.cfg_combine(eps2[0], eps2[1],
                                              guidance_scale)
        else:
            eps, kvs = dit.forward_patch(
                params, cfg, my_slab, t_from, cond, my_start,
                buffers=(pub_k, pub_v), return_kv=True, valid_tokens=my_tok,
                attend_fn=attend_fn, frame=frame, ctx_tokens=ctx_tokens)
        if eps_combine is not None:           # split CFG: eps crosses groups
            eps = eps_combine(eps)
        stepped = sampler_lib.ddim_step(sched, my_slab, eps, t_from, t_to)
        my_slab = jnp.where(active, stepped, my_slab)
        if s == 0:                            # Alg.1: publish first substep
            fresh_k, fresh_v = kvs
    return my_slab, fresh_k, fresh_v


def _gather_and_merge(cfg: DiTConfig, patches, row_starts, my_slab,
                      fresh_k, fresh_v, pub_k, pub_v, merge_kv: bool = True,
                      tok_axis: int = 2):
    """Interval boundary: uneven all-gathers (padded strategy) rebuild the
    full latent; with ``merge_kv`` every device's fresh K/V valid prefix is
    merged into the (scratch-padded) published buffers. ``merge_kv=False``
    is the "skip" exchange kind: slabs are disjoint so the latent gather is
    numerically transparent (and modeled as free), while the K/V buffers
    deliberately stay stale. ``tok_axis`` is the buffers' token axis — 2
    for plain [L,B,N,H,hd], 3 for branch-stacked CFG buffers (§12)."""
    import jax
    import jax.numpy as jnp

    p, wp, N = cfg.patch_size, cfg.tokens_per_side, len(patches)
    slabs = jax.lax.all_gather(my_slab, "dev")        # [N,B,Pmax*p,W,C]
    parts = [slabs[i, :, :patches[i] * p] for i in range(N) if patches[i]]
    x_full = jnp.concatenate(parts, axis=1)
    if not merge_kv:
        return x_full, pub_k, pub_v
    gk = jax.lax.all_gather(fresh_k, "dev")           # [N,(2,)L,B,Nl_max,H,hd]
    gv = jax.lax.all_gather(fresh_v, "dev")
    for i in range(N):                         # static merge, valid prefixes
        sz = patches[i] * wp
        if sz == 0:
            continue
        st = int(row_starts[i]) * wp
        sl = [i] + [slice(None)] * (gk.ndim - 1)
        sl[1 + tok_axis] = slice(0, sz)
        pub_k = jax.lax.dynamic_update_slice_in_dim(
            pub_k, gk[tuple(sl)], st, axis=tok_axis)
        pub_v = jax.lax.dynamic_update_slice_in_dim(
            pub_v, gv[tuple(sl)], st, axis=tok_axis)
    return x_full, pub_k, pub_v


def _static_layout(cfg: DiTConfig, patches: Sequence[int]):
    """Shared static slab layout for the SPMD bodies."""
    import jax.numpy as jnp

    p = cfg.patch_size
    wp = cfg.tokens_per_side
    Pmax = max(patches)
    row_starts = np.concatenate([[0], np.cumsum(patches)[:-1]]).astype(np.int32)
    return dict(p=p, wp=wp, Pmax=Pmax, Nl_max=Pmax * wp,
                row_starts=row_starts,
                rows_arr=jnp.asarray(patches, jnp.int32),
                starts_arr=jnp.asarray(row_starts, jnp.int32))


def make_interval_step(cfg: DiTConfig, sched: NoiseSchedule,
                       plan: TemporalPlan, patches: Sequence[int],
                       exchange_kind: str = "full"):
    """Round-granular SPMD: one jitted shard_map call per adaptive interval.

    Returns ``fn(params, x_full [B,H,W,C], cond [B], pub_k, pub_v
    [L,B,N,H,hd], m0) -> (x_full, pub_k, pub_v)`` executing the R = plan.lcm
    fine steps starting at (traced) fine step ``m0`` with the same per-device
    activity masks, padded-slab all-gathers, and publish-at-first-substep
    buffer semantics as :func:`run_spmd`'s inner loop. Carried state lives on
    the host between calls, so the diffusion serving engine can interleave
    many request cohorts across rounds (DESIGN.md §9); stale-KV buffers are
    scratch-padded on entry and sliced back to ``cfg.n_tokens`` on exit.

    ``exchange_kind`` selects the boundary behavior of this compiled
    variant: "full" merges fresh K/V at the end of the interval, "skip"
    leaves the published buffers untouched (stale-async; the caller decides
    per boundary which variant to invoke — predictive callers extrapolate
    the buffers host-side and invoke the "skip" variant).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import sampler as sampler_lib
    from repro.core.comm import shard_map_compat

    if exchange_kind not in ("full", "skip"):
        raise ValueError(f"make_interval_step compiles 'full' or 'skip' "
                         f"variants, not {exchange_kind!r}")
    devices = jax.devices()
    N = len(patches)
    assert N <= len(devices), (N, len(devices))
    mesh = Mesh(np.asarray(devices[:N]), ("dev",))

    lay = _static_layout(cfg, patches)
    ratios = [r if r else 1 for r in plan.ratios]
    ratios_arr = jnp.asarray(ratios, jnp.int32)
    ts = sampler_lib.ddim_timesteps(sched.T, plan.m_base)
    R = plan.lcm

    def body(params, x_full, cond, pub_k, pub_v, m0):
        idx = jax.lax.axis_index("dev")
        my_rows = lay["rows_arr"][idx]
        my_start = lay["starts_arr"][idx]
        my_ratio = ratios_arr[idx]
        my_tok = my_rows * lay["wp"]
        pad = [(0, 0), (0, 0), (0, lay["Nl_max"]), (0, 0), (0, 0)]
        pub_k = jnp.pad(pub_k, pad)               # scratch-padded buffers
        pub_v = jnp.pad(pub_v, pad)
        x_pad = jnp.pad(x_full, ((0, 0), (0, lay["Pmax"] * lay["p"]),
                                 (0, 0), (0, 0)))
        my_slab = jax.lax.dynamic_slice_in_dim(x_pad, my_start * lay["p"],
                                               lay["Pmax"] * lay["p"], axis=1)
        my_slab, fresh_k, fresh_v = _run_substeps(
            params, cfg, sched, ts, plan.m_base, R, my_slab, cond,
            pub_k, pub_v, my_start, my_tok, my_ratio, m0)
        x_full, pub_k, pub_v = _gather_and_merge(
            cfg, patches, lay["row_starts"], my_slab, fresh_k, fresh_v,
            pub_k, pub_v, merge_kv=(exchange_kind == "full"))
        return x_full, pub_k[:, :, :cfg.n_tokens], pub_v[:, :, :cfg.n_tokens]

    fn = shard_map_compat(body, mesh, (P(),) * 6, (P(), P(), P()))
    return jax.jit(fn)


def run_spmd_pipefuse(params, cfg: DiTConfig, sched: NoiseSchedule, x_T,
                      cond, plan: TemporalPlan, patches: Sequence[int],
                      stages: Sequence[int], exchange: str = "sync",
                      exchange_refresh: int = 2):
    """shard_map displaced patch pipeline: devices are STAGES (DESIGN.md
    §11), not patch owners. Returns the final image [B,H,W,C].

    Mesh axis "stage" holds ``len(stages)`` devices; device ``d`` owns the
    ``stages[d]`` contiguous DiT blocks of its stage (sliced from the
    replicated parameter stack — the memory saving of real pipelining is
    not observable in host emulation) plus the displaced K/V context for
    exactly those blocks, which NEVER crosses devices. Per micro-task the
    hidden state hands off stage-to-stage through
    :func:`repro.core.comm.stage_handoff` (a point-to-point ``ppermute``,
    not a collective) and the final stage's eps is broadcast for the
    replicated DDIM update. The event stream of :func:`repro.core.events.
    lower` — including :class:`~repro.core.events.StageShift` fills —
    unrolls statically into the traced program, exactly as ``run_spmd``
    does for the patch-parallel schedule; numerics follow the same
    displaced contract as :func:`repro.core.pipefuse.run_pipefuse`
    (pipeline overlap is wall-clock, modeled by the simulator)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import sampler as sampler_lib
    from repro.core.comm import shard_map_compat, stage_handoff
    from repro.core.schedule import patch_bounds
    from repro.models.diffusion import dit

    stages = list(stages)
    S = len(stages)
    assert sum(stages) == cfg.n_layers, (stages, cfg.n_layers)
    if S == 1:
        return run_spmd(params, cfg, sched, x_T, cond, plan, patches,
                        exchange=exchange, exchange_refresh=exchange_refresh)
    policy = comm_lib.get_exchange(exchange, exchange_refresh)
    evs = list(ir.lower(plan, patches, policy, stages=stages))

    devices = jax.devices()
    assert S <= len(devices), (S, len(devices))
    mesh = Mesh(np.asarray(devices[:S]), ("stage",))

    p = cfg.patch_size
    wp = cfg.tokens_per_side
    max_blk = max(stages)
    lo_list = np.concatenate([[0], np.cumsum(stages)[:-1]]).astype(np.int32)
    bounds_tok = patch_bounds(patches)
    ts = sampler_lib.ddim_timesteps(sched.T, plan.m_base)

    def body(params, x_full, cond):
        idx = jax.lax.axis_index("stage")
        lo_arr = jnp.asarray(lo_list)
        nblk_arr = jnp.asarray(stages, jnp.int32)
        my_lo = lo_arr[idx]
        my_nblk = nblk_arr[idx]
        enable = jnp.arange(max_blk) < my_nblk
        # my stage's contiguous block slice, padded to the max stage depth
        # (disabled tail blocks are exact identities in block_stack)
        my_blocks = jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(
                jnp.pad(a, [(0, max_blk)] + [(0, 0)] * (a.ndim - 1)),
                my_lo, max_blk, axis=0),
            params["blocks"])

        my_ctx_k = my_ctx_v = None       # displaced context, my blocks only
        my_pub_k = my_pub_v = None       # last published K/V, my blocks only
        pend = {}                        # worker -> (k, v) at substep 0

        def my_layer_slice(kvs_full):
            return jax.lax.dynamic_slice_in_dim(
                jnp.pad(kvs_full, [(0, max_blk)] + [(0, 0)] * (kvs_full.ndim - 1)),
                my_lo, max_blk, axis=0)

        def micro_task(x_loc, t, row_start, ctx_k, ctx_v):
            """One slab through the whole chain: embed (replicated) ->
            masked stage compute + p2p handoff -> broadcast eps."""
            h, c = dit.embed_patch(params, cfg, x_loc, t, cond, row_start)
            rows_tok = x_loc.shape[1] // p
            tok_start = row_start * wp
            k_mine = v_mine = None
            for s in range(S):
                h_out, (k, v) = dit.block_stack(
                    my_blocks, cfg, h, c, tok_start,
                    buffers=(ctx_k, ctx_v), enable=enable)
                on = (idx == s)
                ctx_k = jnp.where(on, ctx_k.at[:, :, tok_start:tok_start
                                               + rows_tok * wp].set(
                    k.astype(ctx_k.dtype)), ctx_k)
                ctx_v = jnp.where(on, ctx_v.at[:, :, tok_start:tok_start
                                               + rows_tok * wp].set(
                    v.astype(ctx_v.dtype)), ctx_v)
                if k_mine is None:
                    k_mine = jnp.where(on, k, jnp.zeros_like(k))
                    v_mine = jnp.where(on, v, jnp.zeros_like(v))
                else:
                    k_mine = jnp.where(on, k, k_mine)
                    v_mine = jnp.where(on, v, v_mine)
                if s < S - 1:            # point-to-point: stage s -> s + 1
                    h = stage_handoff(h_out, "stage", S)
                else:
                    last = (idx == S - 1)
                    h = jax.lax.psum(jnp.where(last, h_out,
                                               jnp.zeros_like(h_out)),
                                     "stage")
            eps = dit.final_head(params, cfg, h, c, rows_tok)
            return eps, k_mine, v_mine, ctx_k, ctx_v

        for ev in evs:
            if isinstance(ev, ir.Warmup):
                # synchronous: exact full-depth forward (redundant per
                # device — the chain handoffs of a sync step are exact)
                eps, kvs = dit.forward_patch(
                    params, cfg, x_full, ts[ev.fine_step], cond, 0,
                    buffers=None, return_kv=True)
                x_full = sampler_lib.ddim_step(sched, x_full, eps,
                                               ts[ev.fine_step],
                                               ts[ev.fine_step + 1])
                my_pub_k = my_layer_slice(kvs[0])
                my_pub_v = my_layer_slice(kvs[1])

            elif isinstance(ev, ir.StageShift):
                if my_pub_k is None:      # M_w == 0: bootstrap once
                    _, kvs = dit.forward_patch(
                        params, cfg, x_full, ts[0], cond, 0,
                        buffers=None, return_kv=True)
                    my_pub_k = my_layer_slice(kvs[0])
                    my_pub_v = my_layer_slice(kvs[1])
                my_ctx_k, my_ctx_v = my_pub_k, my_pub_v

            elif isinstance(ev, ir.ComputeInterval):
                pend = {}
                for f in range(ev.length):
                    for i in ev.workers:
                        r = ev.ratios[i]
                        if f % r:
                            continue
                        a, b = bounds_tok[i]
                        x_loc = x_full[:, a * p:b * p]
                        t_from = ts[ev.fine_step + f]
                        t_to = ts[ev.fine_step + f + r]
                        eps, k_mine, v_mine, my_ctx_k, my_ctx_v = micro_task(
                            x_loc, t_from, a, my_ctx_k, my_ctx_v)
                        x_loc = sampler_lib.ddim_step(sched, x_loc, eps,
                                                      t_from, t_to)
                        x_full = jax.lax.dynamic_update_slice_in_dim(
                            x_full, x_loc, a * p, axis=1)
                        if f == 0:
                            pend[i] = (k_mine, v_mine, a * wp)

            elif isinstance(ev, ir.Exchange):
                if ev.kind == "full":    # merge substep-0 K/V, my blocks
                    for i in sorted(pend):
                        kl, vl, start = pend[i]
                        my_pub_k = jax.lax.dynamic_update_slice_in_dim(
                            my_pub_k, kl.astype(my_pub_k.dtype), start,
                            axis=2)
                        my_pub_v = jax.lax.dynamic_update_slice_in_dim(
                            my_pub_v, vl.astype(my_pub_v.dtype), start,
                            axis=2)
                # skip/predict: the pipe stays full; context persists
        return x_full

    fn = shard_map_compat(body, mesh, (P(), P(), P()), P())
    return jax.jit(fn)(params, x_T, cond)


def run_spmd(params, cfg: DiTConfig, sched: NoiseSchedule, x_T, cond,
             plan: TemporalPlan, patches: Sequence[int],
             exchange: str = "sync", exchange_refresh: int = 2,
             guidance=None):
    """shard_map STADI across jax.devices(). Returns final image [B,H,W,C].

    The body is generated by statically unrolling the schedule IR event
    stream — one :class:`~repro.core.events.Warmup` per synchronous step,
    one ``_run_substeps`` per :class:`~repro.core.events.ComputeInterval`,
    and per :class:`~repro.core.events.Exchange` a boundary whose collective
    traffic follows the event's kind.

    ``guidance`` (DESIGN.md §12): a FUSED GuidancePlan turns every eval
    into a branch-vmapped CFG step (buffers branch-stacked per device);
    split/interleaved placement needs the guidance mesh axis — use
    :func:`run_spmd_guidance` (the "spmd_guidance" backend).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import sampler as sampler_lib
    from repro.models.diffusion import dit

    if guidance is not None and guidance.mode != "fused":
        raise ValueError(
            f"run_spmd executes fused guidance only; {guidance.mode!r} "
            "placement needs the guidance mesh axis of run_spmd_guidance "
            "(backend 'spmd_guidance')")
    guided = guidance is not None
    scale = guidance.scale if guided else None
    tok_axis = 3 if guided else 2
    policy = comm_lib.get_exchange(exchange, exchange_refresh)
    evs = list(ir.lower(plan, patches, policy, guidance=guidance))

    devices = jax.devices()
    N = len(patches)
    assert N <= len(devices), (N, len(devices))
    mesh = Mesh(np.asarray(devices[:N]), ("dev",))

    lay = _static_layout(cfg, patches)
    ratios = [r if r else 1 for r in plan.ratios]
    ratios_arr = jnp.asarray(ratios, jnp.int32)
    ts = sampler_lib.ddim_timesteps(sched.T, plan.m_base)
    buf_pad = [(0, 0)] * tok_axis + [(0, lay["Nl_max"])] + [(0, 0), (0, 0)]

    def _reslice(x_full, my_start):
        x_pad = jnp.pad(x_full, ((0, 0), (0, lay["Pmax"] * lay["p"]),
                                 (0, 0), (0, 0)))
        return jax.lax.dynamic_slice_in_dim(x_pad, my_start * lay["p"],
                                            lay["Pmax"] * lay["p"], axis=1)

    def body(params, x_full, cond):
        idx = jax.lax.axis_index("dev")
        my_rows = lay["rows_arr"][idx]
        my_start = lay["starts_arr"][idx]
        my_ratio = ratios_arr[idx]
        my_tok = my_rows * lay["wp"]

        def _full_forward(x, t):
            """Synchronous full-image eval (guided => fused CFG)."""
            if guided:
                def one(c):
                    return dit.forward_patch(params, cfg, x, t, c, 0,
                                             buffers=None, return_kv=True)
                eps2, kvs = jax.vmap(one)(dit.guidance_conds(cond))
                if cfg.use_pallas_attention:
                    from repro.kernels import ops as kops
                    return kops.cfg_epilogue(eps2[0], eps2[1], scale,
                                             with_delta=False), kvs
                return sampler_lib.cfg_combine(eps2[0], eps2[1], scale), kvs
            return dit.forward_patch(params, cfg, x, t, cond, 0,
                                     buffers=None, return_kv=True)

        pub_k = pub_v = None          # last fully-exchanged K/V (padded)
        prev_k = prev_v = None        # the exchange before that (predictive)
        read_k = read_v = None        # what the substeps attend to
        my_slab = fresh_k = fresh_v = None
        m_prev, m_last = None, None   # static fine steps of those exchanges

        for ev in evs:
            if isinstance(ev, ir.Warmup):
                # synchronous == full-image forward on every device
                eps, kvs = _full_forward(x_full, ts[ev.fine_step])
                x_full = sampler_lib.ddim_step(sched, x_full, eps,
                                               ts[ev.fine_step],
                                               ts[ev.fine_step + 1])
                pub_k, pub_v = kvs
                m_last = ev.fine_step

            elif isinstance(ev, ir.ComputeInterval):
                if my_slab is None:   # entering the adaptive phase
                    if pub_k is None:             # M_w == 0: bootstrap once
                        _, kvs = _full_forward(x_full, ts[0])
                        pub_k, pub_v = kvs
                        m_last = -1
                    pub_k = jnp.pad(pub_k, buf_pad)   # scratch-padded
                    pub_v = jnp.pad(pub_v, buf_pad)
                    read_k, read_v = pub_k, pub_v
                    my_slab = _reslice(x_full, my_start)
                my_slab, fresh_k, fresh_v = _run_substeps(
                    params, cfg, sched, ts, plan.m_base, ev.length, my_slab,
                    cond, read_k, read_v, my_start, my_tok, my_ratio,
                    ev.fine_step, guidance_scale=scale)

            elif isinstance(ev, ir.Exchange):
                if ev.kind == "full":
                    prev_k, prev_v = pub_k, pub_v
                    m_prev, m_last = m_last, ev.fine_step
                    x_full, pub_k, pub_v = _gather_and_merge(
                        cfg, patches, lay["row_starts"], my_slab,
                        fresh_k, fresh_v, pub_k, pub_v, tok_axis=tok_axis)
                    read_k, read_v = pub_k, pub_v
                    my_slab = _reslice(x_full, my_start)
                elif ev.kind == "skip":
                    read_k, read_v = pub_k, pub_v     # stay stale
                elif ev.kind == "predict":
                    f = (buf_lib.extrapolation_factor(m_prev, m_last,
                                                      ev.fine_step)
                         if m_prev is not None else 0.0)
                    if f:
                        read_k = buf_lib.extrapolate_arrays(pub_k, prev_k, f)
                        read_v = buf_lib.extrapolate_arrays(pub_v, prev_v, f)
                    else:             # fewer than two exchanges: stale reuse
                        read_k, read_v = pub_k, pub_v
        return x_full

    from repro.core.comm import shard_map_compat
    fn = shard_map_compat(body, mesh, (P(), P(), P()), P())
    return jax.jit(fn)(params, x_T, cond)


def run_spmd_seq(params, cfg: DiTConfig, sched: NoiseSchedule, x_T, cond,
                 plan: TemporalPlan, patches: Sequence[int], seq,
                 exchange: str = "ring", exchange_refresh: int = 2):
    """Sequence-parallel SPMD (DESIGN.md §13): shard_map over a
    ``("seq", "dev")`` mesh — axis "dev" holds the ``len(patches)`` patch
    workers, axis "seq" the ``seq.n_shards`` sequence members of each
    worker group.

    Each seq slice runs the IDENTICAL statically-unrolled schedule body as
    :func:`run_spmd` — including the IR's :class:`~repro.core.events.
    SeqShard` events, which carry no numerics — but every buffered
    attention read routes through the sequence axis:

      1. RING: each member holds ONE token segment of the
         freshness-blended whole-image K/V; segments rotate via
         ``n_shards - 1`` ``ppermute`` hops while per-hop flash-style
         partials (normalized output + log-sum-exp) stream through an
         online softmax merge — the full context is never materialized
         on any member (O(segment) K/V memory, DESIGN.md §15). Segments
         carry exactly the fresh-local ⊕ policy-stale-remote values the
         dense read uses.
      2. ULYSSES: one ``all_to_all`` scatters query head groups over
         "seq", each member attends its ``n_heads / n_shards`` heads over
         the rotating segments, and the reverse ``all_to_all`` regathers
         heads.

    Head groups are independent under softmax and the log-sum-exp merge
    is exact, so the sharded read equals the dense ``layers.attend`` up
    to reduction order (tested <= 1e-5 vs the emulated reference). Requires ``n_heads % n_shards == 0`` (the
    all-to-all's even head split; speed-proportional uneven heads are the
    cost model's planning view) and ``n_shards * len(patches)`` devices.
    As with the other SPMD backends, the wall-clock benefit of the ring
    overlap is modeled by the simulator; this backend proves the
    collective mechanics and the numerics. Returns the final image.
    """
    import math

    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import sampler as sampler_lib
    from repro.kernels import ops as kops
    from repro.models.diffusion import dit

    if seq is None or len(seq.segments) < 2:
        return run_spmd(params, cfg, sched, x_T, cond, plan, patches,
                        exchange=exchange, exchange_refresh=exchange_refresh)
    S = len(seq.segments)
    if cfg.n_heads % S:
        raise ValueError(
            f"spmd_seq needs n_heads divisible by seq_shards for the "
            f"all-to-all head scatter: {cfg.n_heads} % {S} != 0")
    policy = comm_lib.get_exchange(exchange, exchange_refresh)
    evs = list(ir.lower(plan, patches, policy, seq_shards=seq))

    devices = jax.devices()
    N = len(patches)
    if S * N > len(devices):
        raise ValueError(
            f"seq_shards={S} over {N} patch workers needs {S * N} devices, "
            f"have {len(devices)} (set STADI_HOST_DEVICES)")
    mesh = Mesh(np.asarray(devices[:S * N]).reshape(S, N), ("seq", "dev"))

    lay = _static_layout(cfg, patches)
    ratios = [r if r else 1 for r in plan.ratios]
    ratios_arr = jnp.asarray(ratios, jnp.int32)
    ts = sampler_lib.ddim_timesteps(sched.T, plan.m_base)
    buf_pad = [(0, 0), (0, 0), (0, lay["Nl_max"]), (0, 0), (0, 0)]
    Hs = cfg.n_heads // S
    ring_perm = [(s, (s + 1) % S) for s in range(S)]

    def _segment_partial(q_g, k_h, v_h, valid_here):
        """Normalized attention of q_g over ONE ring segment plus its
        log-sum-exp: the flash-style partial the cross-hop merge combines.
        Routed through the Pallas LSE kernel when the config asks for it."""
        if cfg.use_pallas_attention:
            kops.record_kernel_hit("ring.lse")
            return kops.lse_attention(q_g, k_h, v_h, valid_here)
        hd = q_g.shape[-1]
        s = (jnp.einsum("bshd,bthd->bhst", q_g, k_h).astype(jnp.float32)
             / math.sqrt(hd))
        seg_mask = jnp.arange(k_h.shape[1]) < valid_here
        s = jnp.where(seg_mask[None, None, None, :], s, -1e30)
        m = jnp.max(s, axis=-1)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        out = jnp.einsum("bhst,bthd->bshd", p / jnp.maximum(l, 1e-30)[..., None],
                         v_h.astype(jnp.float32)).astype(q_g.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out, jnp.moveaxis(lse, 1, 2)          # [B,S,H]

    def attend_fn(q, full_k, full_v, key_mask):
        """Flash-style ring read: instead of reassembling the whole-image
        K/V on every member (O(n_tokens) memory) and attending once, each
        member holds ONE token segment, attends its Ulysses head group over
        it, and streams the per-hop (out, lse) partials through an online
        log-sum-exp merge while segments rotate via ``ppermute`` —
        O(segment) K/V memory, S-1 hops, numerically the dense softmax up
        to reduction order. A fully scratch segment contributes lse ~= -inf
        and therefore exactly zero merge weight."""
        j = jax.lax.axis_index("seq")
        n_real = cfg.n_tokens if key_mask is not None else full_k.shape[1]
        # Ulysses: scatter query head groups over "seq" (head group j of
        # every member lands on member j, token blocks concatenated)
        q_g = jax.lax.all_to_all(q, "seq", split_axis=2, concat_axis=1,
                                 tiled=True)
        cpad = -full_k.shape[1] % S
        pad4 = ((0, 0), (0, cpad), (0, 0), (0, 0))
        cseg = (full_k.shape[1] + cpad) // S
        hold_k = jax.lax.dynamic_slice_in_dim(jnp.pad(full_k, pad4),
                                              j * cseg, cseg, axis=1)
        hold_v = jax.lax.dynamic_slice_in_dim(jnp.pad(full_v, pad4),
                                              j * cseg, cseg, axis=1)
        num = den = run_m = None
        for h in range(S):
            src = (j - h) % S                 # segment id this hop holds
            valid_here = jnp.clip(n_real - src * cseg, 0, cseg)
            k_h = jax.lax.dynamic_slice_in_dim(hold_k, j * Hs, Hs, axis=2)
            v_h = jax.lax.dynamic_slice_in_dim(hold_v, j * Hs, Hs, axis=2)
            out_s, lse_s = _segment_partial(q_g, k_h, v_h, valid_here)
            out_s = out_s.astype(jnp.float32)
            if num is None:
                num, den, run_m = out_s, jnp.ones_like(lse_s), lse_s
            else:
                m_new = jnp.maximum(run_m, lse_s)
                corr = jnp.exp(run_m - m_new)
                w = jnp.exp(lse_s - m_new)
                num = num * corr[..., None] + out_s * w[..., None]
                den = den * corr + w
                run_m = m_new
            if h < S - 1:
                hold_k = jax.lax.ppermute(hold_k, "seq", ring_perm)
                hold_v = jax.lax.ppermute(hold_v, "seq", ring_perm)
        att_g = (num / jnp.maximum(den, 1e-30)[..., None]).astype(q.dtype)
        # regather: head group j returns from member j
        return jax.lax.all_to_all(att_g, "seq", split_axis=1, concat_axis=2,
                                  tiled=True)

    def _reslice(x_full, my_start):
        x_pad = jnp.pad(x_full, ((0, 0), (0, lay["Pmax"] * lay["p"]),
                                 (0, 0), (0, 0)))
        return jax.lax.dynamic_slice_in_dim(x_pad, my_start * lay["p"],
                                            lay["Pmax"] * lay["p"], axis=1)

    def body(params, x_full, cond):
        idx = jax.lax.axis_index("dev")
        my_rows = lay["rows_arr"][idx]
        my_start = lay["starts_arr"][idx]
        my_ratio = ratios_arr[idx]
        my_tok = my_rows * lay["wp"]

        pub_k = pub_v = None
        prev_k = prev_v = None
        read_k = read_v = None
        my_slab = fresh_k = fresh_v = None
        m_prev, m_last = None, None

        for ev in evs:
            if isinstance(ev, ir.Warmup):
                # synchronous == full-image forward on every device (the
                # local-only attention of an unbuffered full forward is
                # exact; no ring needed)
                eps, kvs = dit.forward_patch(
                    params, cfg, x_full, ts[ev.fine_step], cond, 0,
                    buffers=None, return_kv=True)
                x_full = sampler_lib.ddim_step(sched, x_full, eps,
                                               ts[ev.fine_step],
                                               ts[ev.fine_step + 1])
                pub_k, pub_v = kvs
                m_last = ev.fine_step

            elif isinstance(ev, ir.SeqShard):
                pass                     # repartitioning carries no numerics

            elif isinstance(ev, ir.ComputeInterval):
                if my_slab is None:
                    if pub_k is None:             # M_w == 0: bootstrap once
                        _, kvs = dit.forward_patch(
                            params, cfg, x_full, ts[0], cond, 0,
                            buffers=None, return_kv=True)
                        pub_k, pub_v = kvs
                        m_last = -1
                    pub_k = jnp.pad(pub_k, buf_pad)
                    pub_v = jnp.pad(pub_v, buf_pad)
                    read_k, read_v = pub_k, pub_v
                    my_slab = _reslice(x_full, my_start)
                my_slab, fresh_k, fresh_v = _run_substeps(
                    params, cfg, sched, ts, plan.m_base, ev.length, my_slab,
                    cond, read_k, read_v, my_start, my_tok, my_ratio,
                    ev.fine_step, attend_fn=attend_fn)

            elif isinstance(ev, ir.Exchange):
                if ev.kind == "full":
                    prev_k, prev_v = pub_k, pub_v
                    m_prev, m_last = m_last, ev.fine_step
                    # per-seq-slice gather/merge: "dev"-axis collectives
                    # run inside each seq row; published K/V stays
                    # replicated over "seq" (every member computes the
                    # identical merge)
                    x_full, pub_k, pub_v = _gather_and_merge(
                        cfg, patches, lay["row_starts"], my_slab,
                        fresh_k, fresh_v, pub_k, pub_v)
                    read_k, read_v = pub_k, pub_v
                    my_slab = _reslice(x_full, my_start)
                elif ev.kind == "skip":
                    read_k, read_v = pub_k, pub_v
                elif ev.kind == "predict":
                    f = (buf_lib.extrapolation_factor(m_prev, m_last,
                                                      ev.fine_step)
                         if m_prev is not None else 0.0)
                    if f:
                        read_k = buf_lib.extrapolate_arrays(pub_k, prev_k, f)
                        read_v = buf_lib.extrapolate_arrays(pub_v, prev_v, f)
                    else:
                        read_k, read_v = pub_k, pub_v
        return x_full

    from repro.core.comm import shard_map_compat
    fn = shard_map_compat(body, mesh, (P(), P(), P()), P())
    return jax.jit(fn)(params, x_T, cond)


def run_spmd_frames(params, cfg: DiTConfig, sched: NoiseSchedule, x_T,
                    cond, plan: TemporalPlan, patches: Sequence[int],
                    frames, exchange: str = "sync",
                    exchange_refresh: int = 2):
    """Multi-frame SPMD (DESIGN.md §16): shard_map over a
    ``("frame", "dev")`` mesh — axis "dev" holds the ``len(patches)``
    patch-worker COLUMNS every member row shares, axis "frame" the
    ``frames.n_groups`` member rows, row ``g`` owning the contiguous
    frame chunk ``frames.bounds[g]``.

    Each column runs the IDENTICAL statically-unrolled schedule body as
    :func:`run_spmd` — including the IR's :class:`~repro.core.events.
    FrameShard` events, which carry no numerics — once per frame, under
    the snapshot semantics of :func:`repro.core.frames.run_frames`:
    every substep of frame f > 0 attends over the 2N-token
    (own ⊕ previous frame) published context of the LAST boundary, with
    the fresh own-slab overwrite landing in the first N tokens
    (``ctx_tokens`` keeps the scratch mask honest about the doubled
    context). Ownership is enforced, not just asserted: a frame's
    carried state is zero-masked off its member row, so the one
    previous-frame K/V that crosses each row boundary (the chunks are
    contiguous) must arrive through a masked ``psum`` over "frame" —
    miswired mesh axes fail the parity test instead of silently
    replicating. SPMD lockstep means every row traces every frame's
    step (a non-owned step costs what it costs, like the no-op substeps
    of the activity masks); the wall-clock benefit of frame parallelism
    is modeled by the simulator — this backend proves the mesh
    mechanics and the numerics. Needs ``n_groups * len(patches)``
    devices. Returns the final video [B,F,H,W,C].

    ``frames=None`` or a single-frame plan delegates to
    :func:`run_spmd` (a leading frame axis of 1 is squeezed in and
    restored on the way out) — bitwise the image path.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import sampler as sampler_lib
    from repro.models.diffusion import dit

    if frames is None or frames.num_frames == 1:
        img = x_T[:, 0] if x_T.ndim == 5 else x_T
        out = run_spmd(params, cfg, sched, img, cond, plan, patches,
                       exchange=exchange, exchange_refresh=exchange_refresh)
        return out[:, None] if x_T.ndim == 5 else out

    from repro.core import frames as frames_lib
    frames_lib.validate_frames(frames, x_T)
    F = frames.num_frames
    G = frames.n_groups
    row_of: list = []
    for g, (lo, hi) in enumerate(frames.bounds):
        row_of += [g] * (hi - lo)
    policy = comm_lib.get_exchange(exchange, exchange_refresh)
    evs = list(ir.lower(plan, patches, policy, frames=frames))

    devices = jax.devices()
    W = len(patches)
    if G * W > len(devices):
        raise ValueError(
            f"frame_groups={G} over {W} patch workers needs {G * W} "
            f"devices, have {len(devices)} (set STADI_HOST_DEVICES)")
    mesh = Mesh(np.asarray(devices[:G * W]).reshape(G, W), ("frame", "dev"))

    lay = _static_layout(cfg, patches)
    ratios = [r if r else 1 for r in plan.ratios]
    ratios_arr = jnp.asarray(ratios, jnp.int32)
    ts = sampler_lib.ddim_timesteps(sched.T, plan.m_base)
    N = cfg.n_tokens
    buf_pad = [(0, 0), (0, 0), (0, lay["Nl_max"]), (0, 0), (0, 0)]

    def _reslice(x_full, my_start):
        x_pad = jnp.pad(x_full, ((0, 0), (0, lay["Pmax"] * lay["p"]),
                                 (0, 0), (0, 0)))
        return jax.lax.dynamic_slice_in_dim(x_pad, my_start * lay["p"],
                                            lay["Pmax"] * lay["p"], axis=1)

    def body(params, x_stack, cond):
        fidx = jax.lax.axis_index("frame")
        idx = jax.lax.axis_index("dev")
        my_start = lay["starts_arr"][idx]
        my_ratio = ratios_arr[idx]
        my_tok = lay["rows_arr"][idx] * lay["wp"]
        fids = [jnp.float32(f) for f in range(F)]

        def mask_own(f, val):
            """Frame f's state is valid ONLY on its member row; other rows
            carry zeros, so cross-row reads MUST use ``from_row``."""
            return jnp.where(fidx == row_of[f], val, jnp.zeros_like(val))

        def from_row(g, val):
            """Broadcast row g's value over "frame": a psum of the masked
            lanes — only row g contributes."""
            return jax.lax.psum(
                jnp.where(fidx == g, val, jnp.zeros_like(val)), "frame")

        def prev_kv(state, f):
            """Frame f-1's (k, v) as seen by frame f's owner row — crosses
            the mesh row boundary when f-1 lives on the previous row
            (exactly one handoff per boundary: chunks are contiguous)."""
            k, v = state[f - 1]
            if row_of[f] != row_of[f - 1]:
                k = from_row(row_of[f - 1], k)
                v = from_row(row_of[f - 1], v)
            return k, v

        def _full_forward(f, x, t):
            return dit.forward_patch(
                params, cfg, x, t, cond, 0, buffers=None, return_kv=True,
                frame=(None if f == 0 else fids[f]))

        xs = [mask_own(f, x_stack[:, f]) for f in range(F)]
        pubs = [None] * F         # last fully-exchanged K/V per frame
        prevs = [None] * F        # the exchange before that (predictive)
        reads = [None] * F        # what the substeps attend to
        slabs = [None] * F
        freshs = [None] * F
        m_prev, m_last = None, None

        for ev in evs:
            if isinstance(ev, ir.Warmup):
                # one synchronous fine step of EVERY frame under snapshot
                # semantics: all frames read the previous step's published
                # K/V, then every frame's fresh K/V publishes at once
                kv_new = []
                for f in range(F):
                    if f == 0 or pubs[f] is None:
                        eps, kvs = _full_forward(f, xs[f], ts[ev.fine_step])
                    else:
                        qk, qv = prev_kv(pubs, f)
                        eps, kvs = dit.forward_patch(
                            params, cfg, xs[f], ts[ev.fine_step], cond, 0,
                            buffers=(jnp.concatenate([pubs[f][0], qk], axis=2),
                                     jnp.concatenate([pubs[f][1], qv], axis=2)),
                            return_kv=True, frame=fids[f])
                    xs[f] = mask_own(f, sampler_lib.ddim_step(
                        sched, xs[f], eps, ts[ev.fine_step],
                        ts[ev.fine_step + 1]))
                    kv_new.append(kvs)
                for f in range(F):
                    pubs[f] = (mask_own(f, kv_new[f][0]),
                               mask_own(f, kv_new[f][1]))
                m_last = ev.fine_step

            elif isinstance(ev, ir.FrameShard):
                pass                 # placement only; numerics are invariant

            elif isinstance(ev, ir.ComputeInterval):
                if slabs[0] is None:  # entering the adaptive phase
                    if pubs[0] is None:          # M_w == 0: bootstrap once
                        for f in range(F):
                            _, kvs = _full_forward(f, xs[f], ts[0])
                            pubs[f] = (mask_own(f, kvs[0]),
                                       mask_own(f, kvs[1]))
                        m_last = -1
                    for f in range(F):
                        pubs[f] = (jnp.pad(pubs[f][0], buf_pad),
                                   jnp.pad(pubs[f][1], buf_pad))
                        reads[f] = pubs[f]
                        slabs[f] = _reslice(xs[f], my_start)
                for f in range(F):
                    if f == 0:       # the image path, bitwise run_spmd
                        slabs[0], fk, fv = _run_substeps(
                            params, cfg, sched, ts, plan.m_base, ev.length,
                            slabs[0], cond, reads[0][0], reads[0][1],
                            my_start, my_tok, my_ratio, ev.fine_step)
                    else:
                        qk, qv = prev_kv(reads, f)
                        bk = jnp.pad(jnp.concatenate(
                            [reads[f][0][:, :, :N], qk[:, :, :N]], axis=2),
                            buf_pad)
                        bv = jnp.pad(jnp.concatenate(
                            [reads[f][1][:, :, :N], qv[:, :, :N]], axis=2),
                            buf_pad)
                        slabs[f], fk, fv = _run_substeps(
                            params, cfg, sched, ts, plan.m_base, ev.length,
                            slabs[f], cond, bk, bv, my_start, my_tok,
                            my_ratio, ev.fine_step, frame=fids[f],
                            ctx_tokens=2 * N)
                    slabs[f] = mask_own(f, slabs[f])
                    freshs[f] = (fk, fv)

            elif isinstance(ev, ir.Exchange):
                for f in range(F):
                    if ev.kind == "full":
                        prevs[f] = pubs[f]
                        x_full, pk, pv = _gather_and_merge(
                            cfg, patches, lay["row_starts"], slabs[f],
                            freshs[f][0], freshs[f][1],
                            pubs[f][0], pubs[f][1])
                        pubs[f] = (mask_own(f, pk), mask_own(f, pv))
                        reads[f] = pubs[f]
                        xs[f] = mask_own(f, x_full)
                        slabs[f] = mask_own(f, _reslice(x_full, my_start))
                    elif ev.kind == "skip":
                        reads[f] = pubs[f]      # stay stale
                    elif ev.kind == "predict":
                        fac = (buf_lib.extrapolation_factor(
                            m_prev, m_last, ev.fine_step)
                            if m_prev is not None else 0.0)
                        if fac:
                            reads[f] = (
                                buf_lib.extrapolate_arrays(
                                    pubs[f][0], prevs[f][0], fac),
                                buf_lib.extrapolate_arrays(
                                    pubs[f][1], prevs[f][1], fac))
                        else:       # fewer than two exchanges: stale reuse
                            reads[f] = pubs[f]
                if ev.kind == "full":
                    m_prev, m_last = m_last, ev.fine_step
        # every frame's final latent returns from its member row
        return jnp.stack([from_row(row_of[f], xs[f]) for f in range(F)],
                         axis=1)

    from repro.core.comm import shard_map_compat
    fn = shard_map_compat(body, mesh, (P(), P(), P()), P())
    return jax.jit(fn)(params, x_T, cond)


def run_spmd_guidance(params, cfg: DiTConfig, sched: NoiseSchedule, x_T,
                      cond, plan: TemporalPlan, patches: Sequence[int],
                      guidance, exchange: str = "sync",
                      exchange_refresh: int = 2):
    """Split-guidance SPMD (DESIGN.md §12): shard_map over a
    ``("guide", "dev")`` mesh — axis "guide" (size 2) holds the cond/uncond
    branch groups, axis "dev" the ``n_pairs`` patch workers of each group.

    Each guide slice runs the IDENTICAL statically-unrolled schedule body
    as :func:`run_spmd` for its branch (cond ids on slice 0, the reserved
    NULL_COND on slice 1), with per-branch published K/V that never crosses
    the guide axis. The only cross-branch traffic is the per-substep
    epsilon combine, a single ``psum`` of ``coeff * eps`` over "guide" with
    ``coeff = (w, 1 - w)`` — algebraically ``eps_u + w*(eps_c - eps_u)``.
    Needs ``2 * n_pairs`` devices. Returns the final image [B,H,W,C].
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import sampler as sampler_lib
    from repro.models.diffusion import dit

    if guidance is None or guidance.mode not in ("split", "interleaved"):
        raise ValueError("run_spmd_guidance needs a split/interleaved "
                         f"GuidancePlan, got {guidance!r}")
    if guidance.mode == "interleaved":
        raise ValueError("interleaved uncond reuse is not implemented on "
                         "the SPMD backend; use 'emulated'/'pipefuse' for "
                         "interleaved numerics")
    scale = guidance.scale
    policy = comm_lib.get_exchange(exchange, exchange_refresh)
    evs = list(ir.lower(plan, patches, policy, guidance=guidance))

    devices = jax.devices()
    N = len(patches)                     # logical workers = device pairs
    if 2 * N > len(devices):
        raise ValueError(
            f"split guidance over {N} pairs needs {2 * N} devices, have "
            f"{len(devices)} (set STADI_HOST_DEVICES)")
    mesh = Mesh(np.asarray(devices[:2 * N]).reshape(2, N), ("guide", "dev"))

    lay = _static_layout(cfg, patches)
    ratios = [r if r else 1 for r in plan.ratios]
    ratios_arr = jnp.asarray(ratios, jnp.int32)
    ts = sampler_lib.ddim_timesteps(sched.T, plan.m_base)
    buf_pad = [(0, 0), (0, 0), (0, lay["Nl_max"]), (0, 0), (0, 0)]

    def _reslice(x_full, my_start):
        x_pad = jnp.pad(x_full, ((0, 0), (0, lay["Pmax"] * lay["p"]),
                                 (0, 0), (0, 0)))
        return jax.lax.dynamic_slice_in_dim(x_pad, my_start * lay["p"],
                                            lay["Pmax"] * lay["p"], axis=1)

    def body(params, x_full, cond):
        guide = jax.lax.axis_index("guide")
        idx = jax.lax.axis_index("dev")
        my_rows = lay["rows_arr"][idx]
        my_start = lay["starts_arr"][idx]
        my_ratio = ratios_arr[idx]
        my_tok = my_rows * lay["wp"]
        # my branch: slice 0 evaluates the conditioning (class ids or
        # prompt tokens), slice 1 the null (NULL_COND / zero tokens, §17)
        my_cond = jnp.where(guide == 0, cond, dit.null_like(cond))
        coeff = jnp.where(guide == 0, scale, 1.0 - scale)

        def eps_combine(eps):
            return jax.lax.psum(coeff * eps.astype(jnp.float32),
                                "guide").astype(eps.dtype)

        pub_k = pub_v = None
        prev_k = prev_v = None
        read_k = read_v = None
        my_slab = fresh_k = fresh_v = None
        m_prev, m_last = None, None

        for ev in evs:
            if isinstance(ev, ir.Warmup):
                eps, kvs = dit.forward_patch(
                    params, cfg, x_full, ts[ev.fine_step], my_cond, 0,
                    buffers=None, return_kv=True)
                eps = eps_combine(eps)
                x_full = sampler_lib.ddim_step(sched, x_full, eps,
                                               ts[ev.fine_step],
                                               ts[ev.fine_step + 1])
                pub_k, pub_v = kvs
                m_last = ev.fine_step

            elif isinstance(ev, ir.ComputeInterval):
                if my_slab is None:
                    if pub_k is None:             # M_w == 0: bootstrap once
                        _, kvs = dit.forward_patch(
                            params, cfg, x_full, ts[0], my_cond, 0,
                            buffers=None, return_kv=True)
                        pub_k, pub_v = kvs
                        m_last = -1
                    pub_k = jnp.pad(pub_k, buf_pad)
                    pub_v = jnp.pad(pub_v, buf_pad)
                    read_k, read_v = pub_k, pub_v
                    my_slab = _reslice(x_full, my_start)
                my_slab, fresh_k, fresh_v = _run_substeps(
                    params, cfg, sched, ts, plan.m_base, ev.length, my_slab,
                    my_cond, read_k, read_v, my_start, my_tok, my_ratio,
                    ev.fine_step, eps_combine=eps_combine)

            elif isinstance(ev, ir.Exchange):
                if ev.kind == "full":
                    prev_k, prev_v = pub_k, pub_v
                    m_prev, m_last = m_last, ev.fine_step
                    # per-branch gather/merge: "dev"-axis collectives run
                    # inside each guide slice; K/V never crosses "guide"
                    x_full, pub_k, pub_v = _gather_and_merge(
                        cfg, patches, lay["row_starts"], my_slab,
                        fresh_k, fresh_v, pub_k, pub_v)
                    read_k, read_v = pub_k, pub_v
                    my_slab = _reslice(x_full, my_start)
                elif ev.kind == "skip":
                    read_k, read_v = pub_k, pub_v
                elif ev.kind == "predict":
                    f = (buf_lib.extrapolation_factor(m_prev, m_last,
                                                      ev.fine_step)
                         if m_prev is not None else 0.0)
                    if f:
                        read_k = buf_lib.extrapolate_arrays(pub_k, prev_k, f)
                        read_v = buf_lib.extrapolate_arrays(pub_v, prev_v, f)
                    else:
                        read_k, read_v = pub_k, pub_v
        return x_full

    from repro.core.comm import shard_map_compat
    fn = shard_map_compat(body, mesh, (P(), P(), P()), P())
    return jax.jit(fn)(params, x_T, cond)
