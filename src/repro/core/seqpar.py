"""Sequence-parallel attention as the fifth schedule dimension (DESIGN.md
§13): Ulysses head scattering + ring K/V segment staging, composed with the
STADI IR.

At high-resolution latents per-patch self-attention over the FULL token
sequence becomes the wall no patch split can cut: every patch worker must
read the whole-context K/V with all heads regardless of how few query rows
it owns. This module makes the sequence itself an allocatable axis:

  * :func:`head_partition` — Ulysses all-to-all head scattering, sized
    speed-proportionally by the same largest-remainder allocator as the
    depth dimension (:func:`repro.core.hetero.stage_partition`): shard j
    attends with ``heads[j]`` of the H heads over the full context, so a
    faster shard carries more heads.
  * :func:`ring_segments` — ring-attention K/V segment sizing over the
    token rows, speed-proportional for the same reason: each ring hop
    forwards one shard's segment to its neighbor, and the slowest link /
    largest (padded) segment gates the hop.
  * :class:`SeqPlan` — the (heads, segments) pair every consumer shares:
    the IR lowers it into :class:`~repro.core.events.SeqShard` events, the
    SPMD executor (``spmd_seq``) realizes it with ``jax.lax.all_to_all`` +
    ``ppermute`` ring hops, and the ring-contention cost model
    (:func:`repro.core.simulate` ``_simulate_seq``) prices it.
  * :func:`run_seqpar` — the emulated reference. The sequence dimension
    repartitions WHERE attention is computed (heads x segments), never
    WHAT is computed: ring hops assemble exactly the fresh-local ⊕
    stale-remote context the patch engine already attends over, so the
    reference delegates to :func:`repro.core.patch_parallel.run_schedule`
    and is bitwise-identical to the ``emulated`` backend at
    ``seq_shards=1`` — and shard-count invariant beyond it. Staleness
    enters only through the boundary policy ("ring" degrades to "skip"
    between refreshes, see :mod:`repro.core.comm`), which is the ring x
    stale-exchange composition: hops carry stale cross-worker neighbors
    exactly like DistriFusion halos.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core import hetero


@dataclasses.dataclass(frozen=True)
class SeqPlan:
    """The sequence-axis allocation every consumer shares (DESIGN.md §13).

    heads:    attention heads per seq shard (Ulysses scatter), sum == H
    segments: ring K/V segment token-rows per shard, sum == p_total
    """
    heads: Tuple[int, ...]
    segments: Tuple[int, ...]

    def __post_init__(self):
        if len(self.heads) != len(self.segments):
            raise ValueError(f"head partition ({len(self.heads)} shards) and "
                             f"ring segments ({len(self.segments)} shards) "
                             "disagree on the shard count")
        if any(h < 1 for h in self.heads):
            raise ValueError(f"every seq shard needs >= 1 head, got "
                             f"{list(self.heads)}")
        if any(s < 1 for s in self.segments):
            raise ValueError(f"every ring segment needs >= 1 token row, got "
                             f"{list(self.segments)}")

    @property
    def n_shards(self) -> int:
        return len(self.heads)

    @property
    def hops(self) -> int:
        """Ring hops per attention (one fewer than the shard count)."""
        return self.n_shards - 1

    @property
    def head_fracs(self) -> List[float]:
        t = sum(self.heads)
        return [h / t for h in self.heads]

    @property
    def seg_fracs(self) -> List[float]:
        t = sum(self.segments)
        return [s / t for s in self.segments]

    def even_heads(self) -> bool:
        """True when the head scatter is uniform — the layout
        ``jax.lax.all_to_all`` can realize without padding heads."""
        return len(set(self.heads)) == 1


def head_partition(n_heads: int, n_shards: int,
                   speeds: Optional[Sequence[float]] = None) -> List[int]:
    """Heads per seq shard, speed-proportional with every shard keeping at
    least one head — the sequence analogue of the depth allocator
    (:func:`repro.core.hetero.stage_partition`, same largest-remainder
    rounding). ``speeds=None`` partitions uniformly."""
    if n_shards < 1:
        raise ValueError(f"need at least one seq shard, got {n_shards}")
    if n_shards > n_heads:
        raise ValueError(
            f"seq_shards={n_shards} cannot scatter {n_heads} attention "
            "heads (Ulysses needs >= 1 head per shard)")
    sp = list(speeds)[:n_shards] if speeds else [1.0] * n_shards
    if len(sp) < n_shards:
        sp = sp + [sp[-1]] * (n_shards - len(sp))
    return hetero.stage_partition(n_heads, sp)


def ring_segments(rows: int, n_shards: int,
                  speeds: Optional[Sequence[float]] = None) -> List[int]:
    """Ring K/V segment token-rows per shard, speed-proportional: a hop
    forwards one segment padded to max(segments) (the padded-collective
    convention of :mod:`repro.core.comm`), so sizing segments to the shard
    speeds keeps the per-hop wire/compute overlap balanced."""
    if n_shards < 1:
        raise ValueError(f"need at least one seq shard, got {n_shards}")
    if n_shards > rows:
        raise ValueError(f"seq_shards={n_shards} cannot segment {rows} "
                         "token rows (>= 1 row per ring segment)")
    sp = list(speeds)[:n_shards] if speeds else [1.0] * n_shards
    if len(sp) < n_shards:
        sp = sp + [sp[-1]] * (n_shards - len(sp))
    return hetero.stage_partition(rows, sp)


def make_seq_plan(n_heads: int, rows: int, n_shards: int,
                  speeds: Optional[Sequence[float]] = None) -> SeqPlan:
    """The (head partition, ring segments) pair for ``n_shards`` shards.

    ``speeds`` are the per-SHARD aggregate speeds (see
    :func:`seq_group_speeds`); None = uniform shards."""
    return SeqPlan(tuple(head_partition(n_heads, n_shards, speeds)),
                   tuple(ring_segments(rows, n_shards, speeds)))


def seq_group_speeds(speeds: Sequence[float], n_shards: int
                     ) -> Tuple[List[List[float]], List[float]]:
    """Device placement convention of a seq-sharded plan — the ONE grouping
    every consumer (planner, cost model, spmd_seq mesh) shares, the seq
    analogue of :func:`repro.core.simulate.chain_speeds`.

    The speed-sorted device list is dealt COLUMN-wise into
    ``n_workers = n // n_shards`` patch-worker groups of ``n_shards``
    devices: member j of group g is the (j * n_workers + g)-th fastest
    device, so shard row j has similar speed across groups and one global
    head partition fits every group. Leftover devices (n % n_shards) idle,
    like temporally excluded workers. Returns (groups, shard_speeds):
    ``groups[g]`` = member speeds of patch worker g, ``shard_speeds[j]`` =
    aggregate speed of shard row j across all groups.
    """
    n = len(speeds)
    if n_shards < 1:
        raise ValueError(f"need at least one seq shard, got {n_shards}")
    n_workers = n // n_shards
    if n_workers < 1:
        raise ValueError(
            f"seq_shards={n_shards} needs at least {n_shards} devices, "
            f"the cluster has {n}")
    order = sorted(speeds, reverse=True)
    groups = [[order[j * n_workers + g] for j in range(n_shards)]
              for g in range(n_workers)]
    shard_speeds = [sum(order[j * n_workers + g] for g in range(n_workers))
                    for j in range(n_shards)]
    return groups, shard_speeds


# ----------------------------------------------------------------------
# pure ring-attention reference (no mesh)
# ----------------------------------------------------------------------

def ring_attention_reference(q, k, v, seq: SeqPlan, mask=None):
    """Ulysses head-scatter + ring segment accumulation in plain jnp.

    Computes exactly what the ``spmd_seq`` executor computes per attention,
    without a mesh: shard j attends with its ``seq.heads[j]`` head slice,
    accumulating over K/V segments in ring arrival order (own segment
    first, then hop-1 neighbor, hop-2, ...) with streaming fp32
    log-sum-exp — the online-softmax form of ring attention. Head groups
    are independent, so the concatenated output matches
    :func:`repro.models.layers.attend` up to reduction order (tested to
    <= 1e-5): the partition changes WHERE attention happens, not WHAT.

    q: [B, S, H, hd]; k/v: [B, T, H, hd]; mask: broadcastable [B, 1, S, T]
    (True = attend), same contract as ``layers.attend``.
    """
    import jax.numpy as jnp

    B, S, H, hd = q.shape
    T = k.shape[1]
    n = seq.n_shards
    assert sum(seq.heads) == H, (seq.heads, H)
    scale = 1.0 / (hd ** 0.5)
    head_lo = [sum(seq.heads[:j]) for j in range(n)]
    seg_rows = list(seq.segments)
    total = sum(seg_rows)
    # segment bounds in key tokens: rows scale to T (the reference is used
    # on raw token grids where rows == tokens when T == sum(segments))
    per = T // total
    seg_lo = [sum(seg_rows[:j]) * per for j in range(n)]
    seg_sz = [s * per for s in seg_rows]

    outs = []
    for j in range(n):
        qj = q[:, :, head_lo[j]:head_lo[j] + seq.heads[j]].astype(jnp.float32)
        qj = jnp.einsum("bshd->bhsd", qj) * scale
        m = jnp.full(qj.shape[:3], -jnp.inf, jnp.float32)       # [B,Hj,S]
        den = jnp.zeros(qj.shape[:3], jnp.float32)
        num = jnp.zeros(qj.shape[:3] + (hd,), jnp.float32)
        for hop in range(n):                 # ring arrival order from shard j
            s = (j - hop) % n
            ks = k[:, seg_lo[s]:seg_lo[s] + seg_sz[s],
                   head_lo[j]:head_lo[j] + seq.heads[j]].astype(jnp.float32)
            vs = v[:, seg_lo[s]:seg_lo[s] + seg_sz[s],
                   head_lo[j]:head_lo[j] + seq.heads[j]].astype(jnp.float32)
            logits = jnp.einsum("bhsd,bthd->bhst", qj, ks)
            if mask is not None:
                mseg = jnp.broadcast_to(mask, (B, 1, S, T))[
                    :, :, :, seg_lo[s]:seg_lo[s] + seg_sz[s]]
                logits = jnp.where(mseg, logits, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            p = jnp.exp(logits - m_safe[..., None])
            den = den * corr + jnp.sum(p, axis=-1)
            num = num * corr[..., None] + jnp.einsum("bhst,bthd->bhsd", p, vs)
            m = m_new
        outs.append(jnp.einsum("bhsd->bshd",
                               num / jnp.maximum(den, 1e-30)[..., None]))
    return jnp.concatenate(outs, axis=2).astype(q.dtype)


# ----------------------------------------------------------------------
# emulated reference executor
# ----------------------------------------------------------------------

def validate_seq(seq: SeqPlan, n_heads: int, rows: int) -> None:
    """Fail fast when a SeqPlan does not fit the model geometry."""
    if sum(seq.heads) != n_heads:
        raise ValueError(f"head partition {list(seq.heads)} sums to "
                         f"{sum(seq.heads)}, model has {n_heads} heads")
    if sum(seq.segments) != rows:
        raise ValueError(f"ring segments {list(seq.segments)} sum to "
                         f"{sum(seq.segments)}, image has {rows} token rows")


def run_seqpar(params, cfg, sched, x_T, cond, plan, patches,
               seq: Optional[SeqPlan], exchange: str = "ring",
               exchange_refresh: int = 2, guidance=None):
    """Emulated sequence-parallel reference (DESIGN.md §13).

    Interprets the same IR stream as ``run_schedule`` — including the
    :class:`~repro.core.events.SeqShard` events a multi-shard plan lowers
    to — and returns a :class:`~repro.core.patch_parallel.RunResult` whose
    trace carries the seq provenance the ring-contention cost model needs.

    Numerics: the sequence dimension repartitions attention across heads
    and ring segments without changing what any head computes — ring hops
    assemble exactly the fresh-local ⊕ policy-stale-remote context the
    patch engine attends over (the "ring" policy's degraded boundaries are
    "skip", see :mod:`repro.core.comm`). The trajectory is therefore
    shard-count invariant and BITWISE-identical to the ``emulated``
    backend at ``seq_shards=1`` (same code path, same jitted steps); the
    real head-scatter/ppermute realization lives in
    :func:`repro.core.spmd.run_spmd_seq` and is tested against this
    reference.
    """
    from repro.core import patch_parallel as pp

    if seq is not None and seq.n_shards > 1:
        validate_seq(seq, cfg.n_heads, cfg.tokens_per_side)
    else:
        seq = None
    return pp.run_schedule(params, cfg, sched, x_T, cond, plan, patches,
                           exchange=exchange,
                           exchange_refresh=exchange_refresh,
                           guidance=guidance, seq=seq)


def max_hop_staleness(records) -> int:
    """Worst staleness age (in adaptive intervals) of the cross-worker K/V
    the ring hops carry, over a trace's records: age resets at every
    synchronous step / "full" boundary and grows by one per degraded
    boundary — bounded by ``refresh_every - 1`` under the "ring" policy
    (tested). Intervals without ring hops (unsharded) contribute 0."""
    age = 0
    worst = 0
    for ev in records:
        if ev.synchronous:
            age = 0
            continue
        if ev.seq_hops:
            worst = max(worst, age)
        age = 0 if ev.exchange == "full" else age + 1
    return worst
