"""Patch-parallel diffusion inference engine (DistriFusion + STADI schedules).

Single-process EMULATION with exact numerics: N logical workers each own a
row-slab of the latent; stale-KV semantics follow DESIGN.md §2 (buffers are
carried state; async NCCL broadcast == merge-at-next-sync). The engine also
produces an :class:`ExecutionTrace` that the latency simulator replays
against per-device speeds — so quality numerics and latency modeling come
from the SAME schedule object.

The SPMD shard_map path (real devices) lives in launch/stadi_infer.py and
reuses this module's schedule logic.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.diffusion import DiTConfig
from repro.core import buffers as buf_lib
from repro.core import sampler as sampler_lib
from repro.core.sampler import NoiseSchedule
from repro.core.schedule import TemporalPlan, patch_bounds
from repro.models.diffusion import dit


@dataclasses.dataclass
class IntervalEvent:
    """One sync interval: per-worker (sub-steps executed, patch rows)."""
    fine_step: int                       # first fine step of the interval
    substeps: List[int]                  # steps executed by each worker
    patches: List[int]                   # token-rows per worker
    synchronous: bool = False            # warmup intervals sync every layer


@dataclasses.dataclass
class ExecutionTrace:
    events: List[IntervalEvent]
    plan: Optional[TemporalPlan]
    patches: List[int]
    n_tokens: int                        # full image tokens (comm sizing)
    latent_bytes: int
    kv_bytes_per_worker: List[int]


@dataclasses.dataclass
class RunResult:
    image: jnp.ndarray                   # [B,H,W,C] final x_0
    trace: ExecutionTrace


def _slab(x, bounds_rows_latent: Tuple[int, int]):
    return x[:, bounds_rows_latent[0]:bounds_rows_latent[1]]


@functools.partial(jax.jit, static_argnames=("cfg", "row_start"))
def _jit_patch_step(params, cfg, x_loc, t, cond, row_start, bk, bv):
    """Jitted hot loop body (one denoiser eval on a patch with stale KV).
    Keeps the engine's eager dispatch count bounded: thousands of unjitted
    eager ops exhaust the LLVM JIT's mmap budget on long runs."""
    return dit.forward_patch(params, cfg, x_loc, t, cond, row_start,
                             buffers=(bk, bv), return_kv=True)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _jit_full_step(params, cfg, x, t, cond):
    return dit.forward_patch(params, cfg, x, t, cond, 0, buffers=None,
                             return_kv=True)


def run_schedule(params, cfg: DiTConfig, sched: NoiseSchedule, x_T, cond,
                 plan: TemporalPlan, patches: Sequence[int],
                 interval_hook=None) -> RunResult:
    """Execute Algorithm 1 given a temporal plan + spatial allocation.

    patches: token-rows per worker (sum == cfg.tokens_per_side; 0 = excluded).
    Uniform plan (all ratios 1, equal patches) == DistriFusion patch
    parallelism; plan from Eq. 4/5 == STADI.

    interval_hook: optional ``hook(next_fine_step, event) -> None | (plan,
    patches)`` called after every adaptive interval boundary. Returning a new
    (TemporalPlan, patches) re-allocates the remaining fine steps — the
    online-rebalancing hot path used by :class:`repro.core.pipeline.
    StadiPipeline`. The remaining fine steps must be divisible by the new
    plan's interval LCM.
    """
    p = cfg.patch_size
    M_base, M_w = plan.m_base, plan.m_warmup
    plan0, patches0 = plan, list(patches)  # trace provenance: the initial
    # allocation; per-interval events record what actually executed
    ts = sampler_lib.ddim_timesteps(sched.T, M_base)   # fine grid, len M_base+1
    workers = [i for i in plan.active if patches[i] > 0]

    x = x_T
    B = x.shape[0]
    events: List[IntervalEvent] = []

    # ---------------- warmup: synchronous steps (== exact full forward) ----
    published = None
    for m in range(M_w):
        eps, kvs = _jit_full_step(params, cfg, x, ts[m], cond)
        x = sampler_lib.ddim_step(sched, x, eps, ts[m], ts[m + 1])
        published = buf_lib.Published(kvs[0], kvs[1], m)
        events.append(IntervalEvent(m, [1 if i in workers else 0
                                        for i in range(len(patches))],
                                    list(patches), synchronous=True))
    if published is None:                # M_w == 0: bootstrap buffers once
        _, kvs = _jit_full_step(params, cfg, x, ts[0], cond)
        published = buf_lib.Published(kvs[0], kvs[1], -1)

    # ---------------- adaptive loop: intervals of R fine steps -------------
    m0 = M_w
    while m0 + plan.lcm <= M_base:
        R = plan.lcm                      # fine steps per interval
        bounds_tok = patch_bounds(patches)
        bounds_lat = [(a * p, b * p) for a, b in bounds_tok]
        workers = [i for i in plan.active if patches[i] > 0]
        pending = {}
        new_slabs = {}
        for i in workers:
            r = plan.ratios[i]
            sub = R // r                  # sub-steps this worker runs
            lat = bounds_lat[i]
            x_loc = _slab(x, lat)
            for s in range(sub):
                t_from = ts[m0 + s * r]
                t_to = ts[m0 + (s + 1) * r]
                eps, kvs = _jit_patch_step(
                    params, cfg, x_loc, t_from, cond, bounds_tok[i][0],
                    published.k, published.v)
                x_loc = sampler_lib.ddim_step(sched, x_loc, eps, t_from, t_to)
                if s == 0:   # Alg.1 l.16-17 / l.23: publish at interval start
                    buf_lib.publish_local(pending, i, kvs[0], kvs[1],
                                          bounds_tok[i][0] * cfg.tokens_per_side)
            new_slabs[i] = x_loc
        # interval boundary: sync all-gather of x + buffer merge
        for i in workers:
            lat = bounds_lat[i]
            x = x.at[:, lat[0]:lat[1]].set(new_slabs[i])
        published = buf_lib.merge(published, pending, m0 + R)
        ev = IntervalEvent(m0, [R // plan.ratios[i] if i in workers else 0
                                for i in range(len(patches))],
                           list(patches))
        events.append(ev)
        m0 += R
        if interval_hook is not None and m0 < M_base:
            upd = interval_hook(m0, ev)
            if upd is not None:
                plan, patches = upd
                assert (M_base - m0) % plan.lcm == 0, (
                    "replanned LCM must divide the remaining fine steps",
                    M_base - m0, plan.lcm)

    H = cfg.latent_size
    n_tokens = cfg.n_tokens
    lat_bytes = int(B * H * H * cfg.channels * 4)
    kv_bytes = [int(2 * cfg.n_layers * B * pr * cfg.tokens_per_side
                    * cfg.d_model * 2) for pr in patches0]
    trace = ExecutionTrace(events, plan0, patches0, n_tokens, lat_bytes, kv_bytes)
    return RunResult(x, trace)


# ----------------------------------------------------------------------
# convenience wrappers
# ----------------------------------------------------------------------

def uniform_plan(n_workers: int, m_base: int, m_warmup: int) -> TemporalPlan:
    return TemporalPlan([m_base] * n_workers, [1] * n_workers,
                        [False] * n_workers, m_base, m_warmup)


def run_distrifusion(params, cfg, sched, x_T, cond, n_workers: int,
                     m_base: int, m_warmup: int) -> RunResult:
    """Patch parallelism baseline: uniform patches, uniform steps."""
    P = cfg.tokens_per_side
    base, rem = divmod(P, n_workers)
    patches = [base + (1 if i < rem else 0) for i in range(n_workers)]
    return run_schedule(params, cfg, sched, x_T, cond,
                        uniform_plan(n_workers, m_base, m_warmup), patches)


def run_origin(params, cfg, sched, x_T, cond, m_base: int) -> jnp.ndarray:
    """Non-distributed exact DDIM ("Origin" in Table II)."""
    eps_fn = lambda x, t: dit.forward(params, cfg, x, t, cond)
    return sampler_lib.ddim_sample(eps_fn, sched, x_T, m_base)
