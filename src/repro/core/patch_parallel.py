"""Patch-parallel diffusion inference engine (DistriFusion + STADI schedules).

Single-process EMULATION with exact numerics: N logical workers each own a
row-slab of the latent; stale-KV semantics follow DESIGN.md §2 (buffers are
carried state; async NCCL broadcast == merge-at-next-sync). The engine is an
*interpreter* of the schedule IR (:mod:`repro.core.events`): one event
stream drives the numerics here, the SPMD backend (core/spmd.py) and the
latency simulator (core/simulate.py), so schedule semantics cannot drift
between them (DESIGN.md §10).

Boundary exchange is a pluggable policy (:mod:`repro.core.comm`):
``sync`` merges fresh K/V at every interval boundary (bitwise-identical to
the pre-policy engine), ``stale_async`` skips the exchange on a cadence and
denoises against staler neighbor slabs, ``predictive`` extrapolates the
remote K/V from the last two exchanged versions.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.diffusion import DiTConfig
from repro.core import buffers as buf_lib
from repro.core import comm as comm_lib
from repro.core import events as ir
from repro.core import sampler as sampler_lib
# re-exported for backward compatibility: these trace types now live in the
# IR module (events.py) next to the stream that produces them
from repro.core.events import ExecutionTrace, IntervalEvent  # noqa: F401
from repro.core.sampler import NoiseSchedule
from repro.core.schedule import TemporalPlan, patch_bounds
from repro.models.diffusion import dit


@dataclasses.dataclass
class RunResult:
    image: jnp.ndarray                   # [B,H,W,C] final x_0
    trace: ExecutionTrace


def _slab(x, bounds_rows_latent: Tuple[int, int]):
    return x[:, bounds_rows_latent[0]:bounds_rows_latent[1]]


def _stack_uncond(kv_c: Tuple, published: buf_lib.Published, tok_lo: int,
                  n_tok: int) -> Tuple:
    """Branch-stack a cond-only fresh K/V with the CURRENT published uncond
    rows (a no-op merge for the uncond branch): interleaved reuse intervals
    never recompute — and therefore never republish — a straggler worker's
    uncond branch (DESIGN.md §12). Shared by the emulated and pipefuse
    engines."""
    ku = jax.lax.dynamic_slice_in_dim(published.k[1], tok_lo, n_tok, axis=2)
    vu = jax.lax.dynamic_slice_in_dim(published.v[1], tok_lo, n_tok, axis=2)
    return jnp.stack([kv_c[0], ku]), jnp.stack([kv_c[1], vu])


@functools.partial(jax.jit, static_argnames=("cfg", "row_start"))
def _jit_patch_step(params, cfg, x_loc, t, cond, row_start, bk, bv):
    """Jitted hot loop body (one denoiser eval on a patch with stale KV).
    Keeps the engine's eager dispatch count bounded: thousands of unjitted
    eager ops exhaust the LLVM JIT's mmap budget on long runs."""
    return dit.forward_patch(params, cfg, x_loc, t, cond, row_start,
                             buffers=(bk, bv), return_kv=True)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _jit_full_step(params, cfg, x, t, cond):
    return dit.forward_patch(params, cfg, x, t, cond, 0, buffers=None,
                             return_kv=True)


# ----------------------------------------------------------------------
# classifier-free guidance steps (DESIGN.md §12)
# ----------------------------------------------------------------------
#
# One branch-vmapped dispatch evaluates the conditional and unconditional
# forwards (the fused-batch form); buffers are branch-stacked
# [2, L, B, N, H, hd]. The split/interleaved guidance modes run the SAME
# jitted functions — the placement decision moves work between devices in
# the cost model, never between math — which is why split CFG is bitwise-
# identical to the fused reference under one schedule (tested).

def _cfg_tail(cfg, eps2, scale):
    """(eps_combined, delta) from the branch pair: the fused Pallas CFG
    epilogue when the config routes attention through kernels (one HBM
    pass computes both, DESIGN.md §15), else the two sampler formulas."""
    if cfg.use_pallas_attention:
        from repro.kernels import ops as kops
        return kops.cfg_epilogue(eps2[0], eps2[1], scale)
    return (sampler_lib.cfg_combine(eps2[0], eps2[1], scale),
            sampler_lib.cfg_delta(eps2[0], eps2[1]))


@functools.partial(jax.jit, static_argnames=("cfg",))
def _jit_guided_full_step(params, cfg, x, t, cond, scale):
    """Synchronous CFG step: returns (eps_combined, delta, (k2, v2)) with
    delta the guidance direction eps_c - eps_u (the interleaved cache)."""
    def one(c):
        return dit.forward_patch(params, cfg, x, t, c, 0, buffers=None,
                                 return_kv=True)
    eps2, kvs2 = jax.vmap(one)(dit.guidance_conds(cond))
    return _cfg_tail(cfg, eps2, scale) + (kvs2,)


@functools.partial(jax.jit, static_argnames=("cfg", "row_start"))
def _jit_guided_patch_step(params, cfg, x_loc, t, cond, row_start, bk2, bv2,
                           scale):
    """Guided stale-KV patch step: bk2/bv2 are branch-stacked published
    buffers [2, L, B, N, H, hd]. Returns (eps_combined, delta, (k2, v2))
    with k2/v2 [2, L, B, Nl, H, hd] — delta (= eps_c - eps_u) feeds the
    interleaved-reuse cache, the fresh K/V the per-branch publish."""
    def one(c, bk, bv):
        return dit.forward_patch(params, cfg, x_loc, t, c, row_start,
                                 buffers=(bk, bv), return_kv=True)
    eps2, kvs2 = jax.vmap(one)(dit.guidance_conds(cond), bk2, bv2)
    return _cfg_tail(cfg, eps2, scale) + (kvs2,)


def guided_substep(params, cfg, x_loc, t_from, cond, row_start, read_pub,
                   published, guidance, fresh: bool, ucache: dict, i: int,
                   first: bool):
    """One guided patch substep for worker ``i`` — the ONE home of the
    fresh-vs-straggler-reuse dispatch shared by ``run_schedule`` and the
    single-stage ``pipefuse`` interpreter (their loop orders differ, the
    per-substep CFG contract must not). Returns (eps, kvs) where kvs is
    the branch-stacked publish payload on ``first`` substeps (None
    otherwise for reuse workers); mutates ``ucache`` with the guidance
    delta on fresh evals."""
    tok_lo = row_start * cfg.tokens_per_side
    if fresh or not guidance.worker_reuses(i):
        # fused/split, interleaved refresh intervals, and non-straggler
        # workers (always fresh)
        eps, delta, kvs = _jit_guided_patch_step(
            params, cfg, x_loc, t_from, cond, row_start,
            read_pub.k, read_pub.v, guidance.scale)
        if guidance.mode == "interleaved":   # only reuse ever reads it
            ucache[i] = delta
        return eps, kvs
    # interleaved reuse: the straggler pair's uncond device idles the whole
    # interval — the guidance delta cached at the last refresh interval
    # stands in; only the cond branch runs (against its own branch's
    # buffers), and its first substep publishes with stale uncond rows
    eps_c, kv_c = _jit_patch_step(params, cfg, x_loc, t_from, cond,
                                  row_start, read_pub.k[0], read_pub.v[0])
    eps = sampler_lib.cfg_apply_delta(eps_c, ucache[i], guidance.scale)
    kvs = (_stack_uncond(kv_c, published, tok_lo, kv_c[0].shape[2])
           if first else None)
    return eps, kvs


def run_schedule(params, cfg: DiTConfig, sched: NoiseSchedule, x_T, cond,
                 plan: TemporalPlan, patches: Sequence[int],
                 interval_hook=None, exchange: str = "sync",
                 exchange_refresh: int = 2, guidance=None,
                 seq=None) -> RunResult:
    """Execute Algorithm 1 by interpreting the schedule IR event stream.

    patches: token-rows per worker (sum == cfg.tokens_per_side; 0 = excluded).
    Uniform plan (all ratios 1, equal patches) == DistriFusion patch
    parallelism; plan from Eq. 4/5 == STADI.

    interval_hook: optional ``hook(next_fine_step, event) -> None | (plan,
    patches)`` called after every adaptive interval boundary. Returning a new
    (TemporalPlan, patches) re-allocates the remaining fine steps — the
    online-rebalancing hot path used by :class:`repro.core.pipeline.
    StadiPipeline`. The remaining fine steps must be divisible by the new
    plan's interval LCM.

    exchange / exchange_refresh: boundary-exchange policy name + refresh
    cadence (see :func:`repro.core.comm.get_exchange`). "sync" reproduces
    the pre-policy engine bitwise.

    guidance: optional :class:`repro.core.guidance.GuidancePlan` (DESIGN.md
    §12). Every denoiser eval becomes a branch-vmapped CFG eval against
    branch-stacked published buffers; "fused" and "split" are bitwise-
    identical (placement only differs in the cost model), "interleaved"
    reuses the cached eps_u on non-refresh intervals per the IR's
    :class:`~repro.core.events.GuidanceExchange` verdicts.

    seq: optional :class:`repro.core.seqpar.SeqPlan` (DESIGN.md §13). The
    sequence dimension repartitions WHERE attention runs (Ulysses head
    groups x ring K/V segments), never WHAT it computes, so the emulated
    engine's numerics are shard-count invariant: the IR's
    :class:`~repro.core.events.SeqShard` events are replayed for trace
    provenance (per-interval ring hops) and the trace carries the plan for
    the ring-contention cost model; the head-scattered realization lives
    in ``spmd_seq``.
    """
    p = cfg.patch_size
    M_base = plan.m_base
    plan0, patches0 = plan, list(patches)  # trace provenance: the initial
    # allocation; per-interval events record what actually executed
    ts = sampler_lib.ddim_timesteps(sched.T, M_base)   # fine grid, len M_base+1
    policy = comm_lib.get_exchange(exchange, exchange_refresh)
    guided = guidance is not None
    if guided:
        if cond is None:
            raise ValueError("guided generation needs a class condition")
        if interval_hook is not None:
            raise ValueError("online rebalancing is not supported with "
                             "guidance (the branch pairing is static)")
    tok_axis = 3 if guided else 2        # buffers gain a leading branch axis

    x = x_T
    B = x.shape[0]
    records: List[IntervalEvent] = []

    published: Optional[buf_lib.Published] = None   # last fully-exchanged K/V
    prev_published: Optional[buf_lib.Published] = None
    read_pub: Optional[buf_lib.Published] = None    # what substeps attend to
    pending = {}
    new_slabs = {}
    ucache = {}                          # interleaved: last eps_u per worker
    interval: Optional[ir.ComputeInterval] = None
    fresh = True                         # uncond recomputed this interval?
    seq_hops = 0                         # ring hops of the coming interval

    def _full_step(t):
        if guided:
            eps, _, kvs2 = _jit_guided_full_step(params, cfg, x, t, cond,
                                                 guidance.scale)
            return eps, kvs2
        return _jit_full_step(params, cfg, x, t, cond)

    gen = ir.lower(plan, patches, policy, guidance=guidance, seq_shards=seq)
    send = None
    while True:
        try:
            ev = gen.send(send)
        except StopIteration:
            break
        send = None

        if isinstance(ev, ir.Warmup):
            # synchronous step == exact full forward on every worker
            eps, kvs = _full_step(ts[ev.fine_step])
            x = sampler_lib.ddim_step(sched, x, eps, ts[ev.fine_step],
                                      ts[ev.fine_step + 1])
            published = buf_lib.Published(kvs[0], kvs[1], ev.fine_step)
            read_pub = published
            records.append(ir.warmup_record(ev))

        elif isinstance(ev, ir.GuidanceExchange):
            fresh = ev.fresh             # verdict for the coming interval

        elif isinstance(ev, ir.SeqShard):
            # head/segment repartitioning only moves attention across the
            # ring — no numerics here; record the hop count for the trace
            seq_hops = ev.hops

        elif isinstance(ev, ir.ComputeInterval):
            if published is None:        # M_w == 0: bootstrap buffers once
                _, kvs = _full_step(ts[0])
                published = buf_lib.Published(kvs[0], kvs[1], -1)
                read_pub = published
            interval = ev
            bounds_tok = patch_bounds(ev.patches)
            bounds_lat = [(a * p, b * p) for a, b in bounds_tok]
            pending = {}
            new_slabs = {}
            for i in ev.workers:
                r = ev.ratios[i]
                x_loc = _slab(x, bounds_lat[i])
                tok_lo = bounds_tok[i][0] * cfg.tokens_per_side
                for s in range(ev.substeps[i]):
                    t_from = ts[ev.fine_step + s * r]
                    t_to = ts[ev.fine_step + (s + 1) * r]
                    if not guided:
                        eps, kvs = _jit_patch_step(
                            params, cfg, x_loc, t_from, cond,
                            bounds_tok[i][0], read_pub.k, read_pub.v)
                    else:
                        eps, kvs = guided_substep(
                            params, cfg, x_loc, t_from, cond,
                            bounds_tok[i][0], read_pub, published,
                            guidance, fresh, ucache, i, first=(s == 0))
                    x_loc = sampler_lib.ddim_step(sched, x_loc, eps,
                                                  t_from, t_to)
                    if s == 0:   # Alg.1 l.16-17 / l.23: publish at interval start
                        buf_lib.publish_local(pending, i, kvs[0], kvs[1],
                                              tok_lo)
                new_slabs[i] = x_loc

        elif isinstance(ev, ir.Exchange):
            # every worker's slab write-back is local memory (disjoint rows);
            # the policy only gates the REMOTE traffic: K/V merge + gather
            bounds_lat = [(a * p, b * p) for a, b in
                          patch_bounds(ev.patches)]
            for i in interval.workers:
                lat = bounds_lat[i]
                x = x.at[:, lat[0]:lat[1]].set(new_slabs[i])
            if ev.kind == "full":
                prev_published = published
                published = buf_lib.merge(published, pending, ev.fine_step,
                                          axis=tok_axis)
                read_pub = published
            elif ev.kind == "skip":
                read_pub = published     # stale: pending never broadcast
            elif ev.kind == "predict":
                read_pub = buf_lib.extrapolate(prev_published, published,
                                               ev.fine_step)
            rec = ir.record(interval, ev.kind, uncond_fresh=fresh,
                            seq_hops=seq_hops)
            fresh = True
            records.append(rec)
            if interval_hook is not None and ev.fine_step < M_base:
                upd = interval_hook(ev.fine_step, rec)
                if upd is not None:
                    send = upd           # generator emits Replan + re-lowers

        # ir.Replan events need no numerics: the next ComputeInterval
        # already carries the new patches/ratios

    trace = ir.make_trace(records, plan0, patches0, cfg, int(B),
                          guidance=guidance, seq=seq)
    return RunResult(x, trace)


# ----------------------------------------------------------------------
# convenience wrappers
# ----------------------------------------------------------------------

def uniform_plan(n_workers: int, m_base: int, m_warmup: int) -> TemporalPlan:
    return TemporalPlan([m_base] * n_workers, [1] * n_workers,
                        [False] * n_workers, m_base, m_warmup)


def run_distrifusion(params, cfg, sched, x_T, cond, n_workers: int,
                     m_base: int, m_warmup: int) -> RunResult:
    """Patch parallelism baseline: uniform patches, uniform steps."""
    P = cfg.tokens_per_side
    base, rem = divmod(P, n_workers)
    patches = [base + (1 if i < rem else 0) for i in range(n_workers)]
    return run_schedule(params, cfg, sched, x_T, cond,
                        uniform_plan(n_workers, m_base, m_warmup), patches)


def run_origin(params, cfg, sched, x_T, cond, m_base: int) -> jnp.ndarray:
    """Non-distributed exact DDIM ("Origin" in Table II)."""
    eps_fn = lambda x, t: dit.forward(params, cfg, x, t, cond)
    return sampler_lib.ddim_sample(eps_fn, sched, x_T, m_base)


def run_origin_cfg(params, cfg, sched, x_T, cond, m_base: int,
                   scale: float) -> jnp.ndarray:
    """Non-distributed exact guided DDIM: the CFG "Origin" — fused-batch
    classifier-free guidance with no patching or staleness (DESIGN.md §12)."""
    eps_fn = lambda x, t: dit.forward_cfg(params, cfg, x, t, cond, scale)
    return sampler_lib.ddim_sample(eps_fn, sched, x_T, m_base)
