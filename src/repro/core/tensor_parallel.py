"""Tensor-parallel DiT baseline (paper §V-A Baselines).

"Tensor parallelism achieves distributed diffusion inference by performing
synchronous all-reduce at each layer of computation" — Megatron-style: QKV /
MLP-in column-sharded over heads/hidden, output projections row-sharded, one
all-reduce (psum) per attention and per MLP. Implemented with
``with_sharding_constraint`` annotations so GSPMD emits the all-reduces;
latency on heterogeneous devices comes from ``simulate_tensor_parallel``
(XLA assumes homogeneous SPMD — the paper's Fig. 2/8 point is precisely that
TP degrades under heterogeneity, which the simulator models as
straggler-bound per-layer sync).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.diffusion import DiTConfig


def tp_param_specs(cfg: DiTConfig):
    """PartitionSpecs for dit params under a 1-D ('model',) mesh."""
    def spec_block(_):
        return {
            "qkv": P(None, None, "model"),
            "wo": P(None, "model", None),
            "w1": P(None, None, "model"),
            "w2": P(None, "model", None),
            "mod_w": P(None, None, None),
            "mod_b": P(None, None),
        }
    return {
        "patch_embed": P(None, None),
        "patch_bias": P(None),
        "t_w1": P(None, None),
        "t_w2": P(None, None),
        "cond_embed": P(None, None),
        "blocks": spec_block(None),
        "final_mod_w": P(None, None),
        "final_mod_b": P(None),
        "final_proj": P(None, None),
    }


def tp_forward(params, cfg: DiTConfig, x, t, cond, mesh):
    """Full-image TP denoiser step; activations replicated, weights sharded.

    GSPMD inserts the per-layer all-reduces that define this baseline.
    """
    from repro.models.diffusion import dit

    def constrained(p):
        specs = tp_param_specs(cfg)
        return jax.tree.map(
            lambda a, s: jax.lax.with_sharding_constraint(
                a, jax.sharding.NamedSharding(mesh, s)),
            p, specs, is_leaf=lambda v: isinstance(v, jnp.ndarray))

    params = constrained(params)
    eps, _ = dit.forward_patch(params, cfg, x, t, cond, 0, buffers=None,
                               return_kv=False)
    return eps
