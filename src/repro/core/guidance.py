"""Classifier-free guidance as a fourth scheduling dimension (DESIGN.md §12).

Every production diffusion deployment runs CFG: two denoiser evaluations per
fine step — conditional and unconditional — combined as

    eps = eps_u + w * (eps_c - eps_u)                 (w = cfg_scale)

STADI schedules steps, patches and depth stages; this module makes the
cond/uncond split itself schedulable work (the "Conditional Guidance
Scheduling" direction of PAPERS.md). A :class:`GuidancePlan` names one of
three placements:

    fused        every patch worker computes BOTH branches in one
                 branch-vmapped dispatch (the fused-batch reference). No
                 cross-branch traffic; per-row compute and staged-K/V
                 traffic double.
    split        the cluster is bipartitioned into a cond group and an
                 uncond group sized by aggregate effective speed
                 (:func:`guidance_groups`); logical patch worker i is a
                 PAIR (cond_devices[i], uncond_devices[i]) computing the
                 same row slab, one branch each. Only the per-step epsilon
                 combine crosses the group boundary — the staged K/V of
                 each branch never leaves its group, which is the
                 structural comm saving over fused CFG. Numerics are
                 bitwise-identical to fused under the same
                 (temporal, patches) schedule by construction: the mode
                 moves work between devices, never between math.
    interleaved  split placement + DistriFusion-style staleness applied to
                 the UNCOND branch of STRAGGLER pairs (pair speed below
                 the fastest pair's): on every interval except each
                 ``uncond_refresh``-th, a straggler's uncond device idles
                 and its cond side reuses the eps_u cached at the last
                 refresh interval — staleness is spent exactly where
                 compute is scarce, fast pairs stay exact. Lossy
                 (benchmarked < 1 dB PSNR drift).

The schedule IR (:mod:`repro.core.events`) lowers split/interleaved plans
with a :class:`~repro.core.events.GuidanceExchange` event per adaptive
interval, so every executor — emulated, pipefuse, spmd (guidance mesh
axis), simulate — agrees on exactly which intervals recompute the uncond
branch and where the eps combine happens.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence, Tuple

#: reserved class id meaning "the unconditional (null) branch" — see
#: repro.models.diffusion.dit._cond_vector
NULL_COND = -1

GUIDANCE_MODES = ("fused", "split", "interleaved")


@dataclasses.dataclass(frozen=True)
class GuidancePlan:
    """One guidance-placement decision, carried on an ExecutionPlan.

    mode: "fused" | "split" | "interleaved"
    scale: the CFG weight w (> 0; w == 1 degenerates to conditional-only)
    cond_devices / uncond_devices: split/interleaved placement — parallel
        tuples, pair i computes logical patch worker i's slab (cond branch
        on cond_devices[i], uncond on uncond_devices[i]). Empty for fused.
    uncond_refresh: interleaved cadence E — a reusing worker's uncond
        branch runs on each E-th adaptive interval and idles (eps_u
        reused) on the others.
    reuse_workers: interleaved only — the logical workers whose uncond
        branch reuses (the paper-spirit "slow devices": straggler pairs,
        filled in by :func:`split_plan`). None = every worker reuses.
    """
    mode: str
    scale: float
    cond_devices: Tuple[int, ...] = ()
    uncond_devices: Tuple[int, ...] = ()
    uncond_refresh: int = 2
    reuse_workers: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.mode not in GUIDANCE_MODES:
            raise ValueError(f"unknown guidance mode {self.mode!r}; one of "
                             f"{GUIDANCE_MODES}")
        if self.scale <= 0.0:
            raise ValueError(f"cfg_scale must be > 0 for guided generation, "
                             f"got {self.scale}")
        if self.uncond_refresh < 1:
            raise ValueError(f"uncond_refresh must be >= 1, got "
                             f"{self.uncond_refresh}")
        if self.mode == "fused":
            if self.cond_devices or self.uncond_devices:
                raise ValueError("fused guidance has no device groups")
            return
        if len(self.cond_devices) != len(self.uncond_devices):
            raise ValueError(
                f"split guidance pairs devices 1:1, got "
                f"{len(self.cond_devices)} cond vs "
                f"{len(self.uncond_devices)} uncond")
        if not self.cond_devices:
            raise ValueError(f"{self.mode} guidance needs at least one "
                             "device pair")
        both = self.cond_devices + self.uncond_devices
        if len(set(both)) != len(both):
            raise ValueError(f"guidance groups must be disjoint, got "
                             f"cond={self.cond_devices} "
                             f"uncond={self.uncond_devices}")

    @property
    def n_pairs(self) -> int:
        return len(self.cond_devices)

    def pair_speeds(self, speeds: Sequence[float]) -> List[float]:
        """Effective speed of each logical worker pair: both branches must
        finish before the eps combine, so the pair runs at the slower
        branch's speed."""
        return [min(speeds[c], speeds[u])
                for c, u in zip(self.cond_devices, self.uncond_devices)]

    def uncond_fresh(self, interval_index: int) -> bool:
        """Does adaptive interval ``interval_index`` recompute eps_u?"""
        if self.mode != "interleaved":
            return True
        return interval_index % self.uncond_refresh == 0

    def worker_reuses(self, worker: int) -> bool:
        """May logical worker ``worker`` reuse eps_u on non-refresh
        intervals? (Fast pairs keep computing fresh — staleness is spent
        where compute is scarce.)"""
        if self.mode != "interleaved":
            return False
        return self.reuse_workers is None or worker in self.reuse_workers


def guidance_groups(speeds: Sequence[float]
                    ) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Bipartition devices into (cond_group, uncond_group) for split CFG.

    Both branches carry identical work, so the groups should have equal
    aggregate effective speed; group sizes may differ by at most one (each
    logical worker is a 1:1 device pair — see :class:`GuidancePlan`). For
    clusters up to 16 devices the size-constrained bipartition minimizing
    ``|sum(v_cond) - sum(v_uncond)|`` is found exhaustively; larger
    clusters fall back to greedy balancing. The cond branch (whose output
    anchors quality, and which keeps running in interleaved mode) goes to
    the group with the >= aggregate speed. Groups are disjoint and cover
    every device passed in; each is returned sorted fastest-first — pair i
    is (cond[i], uncond[i]).
    """
    n = len(speeds)
    if n < 2:
        raise ValueError(f"split guidance needs >= 2 devices, got {n}")
    ids = sorted(range(n), key=lambda i: (-speeds[i], i))
    size_a = n // 2
    if n <= 16:
        best = None
        for combo in itertools.combinations(range(n), size_a):
            a = set(combo)
            sa = sum(speeds[i] for i in a)
            sb = sum(speeds[i] for i in range(n) if i not in a)
            gap = abs(sa - sb)
            if best is None or gap < best[0] - 1e-12:
                best = (gap, a)
        group_a = best[1]
    else:                                 # greedy: fastest-first into the
        group_a, group_b = set(), set()   # lighter group, capacity-capped
        sa = sb = 0.0
        size_b = n - size_a
        for i in ids:
            to_a = (sa <= sb and len(group_a) < size_a) or \
                len(group_b) >= size_b
            if to_a:
                group_a.add(i)
                sa += speeds[i]
            else:
                group_b.add(i)
                sb += speeds[i]
    a = tuple(sorted(group_a, key=lambda i: (-speeds[i], i)))
    b = tuple(sorted((i for i in range(n) if i not in group_a),
                     key=lambda i: (-speeds[i], i)))
    sum_a = sum(speeds[i] for i in a)
    sum_b = sum(speeds[i] for i in b)
    cond, uncond = (a, b) if sum_a >= sum_b else (b, a)
    return cond, uncond


def split_plan(speeds: Sequence[float], mode: str, scale: float,
               uncond_refresh: int = 2) -> GuidancePlan:
    """Build a split/interleaved GuidancePlan from cluster speeds: balanced
    groups via :func:`guidance_groups`, then 1:1 rank-order pairing (i-th
    fastest cond device with i-th fastest uncond device). With unequal
    group sizes the slowest unpaired device idles — the guided planner's
    candidate comparison accounts for the lost capacity.

    For interleaved mode, reuse is granted to the STRAGGLER pairs only
    (pair speed strictly below the fastest pair's): staleness is applied
    where compute is scarce, and a homogeneous cluster — nothing to hide —
    degenerates to exact split numerics."""
    cond, uncond = guidance_groups(speeds)
    n_pairs = min(len(cond), len(uncond))
    gp = GuidancePlan(mode, scale, cond[:n_pairs], uncond[:n_pairs],
                      uncond_refresh=uncond_refresh)
    if mode == "interleaved":
        ps = gp.pair_speeds(speeds)
        stragglers = tuple(i for i, v in enumerate(ps)
                           if v < max(ps) - 1e-12)
        gp = dataclasses.replace(gp, reuse_workers=stragglers)
    return gp
