"""Uneven-tensor collectives (paper §V-A "All-Gather for uneven sized
tensors"), SPMD-native.

The paper implements two asynchronous workarounds for NCCL's lack of uneven
all_gather: (1) pad every rank's tensor to the max size, all_gather, unpad;
(2) emulate all_gather with per-source broadcasts. We implement both on
``shard_map`` collectives: (1) pad + ``jax.lax.all_gather``; (2) a ring of
``jax.lax.ppermute`` rounds (the SPMD analogue of N broadcasts). Both are
verified equivalent in tests; XLA's async scheduling provides the
compute/communication overlap the paper gets from CUDA streams.

These run inside ``shard_map`` bodies — callers pass the mesh axis name.

This module also owns the :class:`BoundaryExchange` policy registry
(DESIGN.md §10): the strategy deciding, per interval boundary, whether the
latent/KV exchange happens synchronously ("full"), is skipped against stale
buffers ("skip", DistriFusion-style stale-async with a corrective refresh
cadence), or is replaced by local extrapolation of the remote slabs
("predict", Reuse-then-Predict). The schedule IR (:mod:`repro.core.events`)
consults the policy when lowering; executors only ever see the resulting
per-boundary kind.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions: >= 0.5 exposes it at top level
    (``check_vma``); 0.4.x has ``jax.experimental.shard_map`` (``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def pad_to(x, rows: int, axis: int = 0):
    pad = rows - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def uneven_all_gather_padded(x_local, sizes: Sequence[int], axis_name: str,
                             axis: int = 0):
    """Strategy 1: pad to max -> all_gather -> concat valid prefixes.

    x_local: this rank's slab, shape[axis] == sizes[my_rank] (static per rank
    is impossible in SPMD, so every rank's local slab is ALREADY padded to
    max(sizes) by the caller; sizes are static Python ints).
    Returns the full concatenation [sum(sizes), ...] on every rank.
    """
    n = len(sizes)
    mx = max(sizes)
    assert x_local.shape[axis] == mx, (x_local.shape, mx)
    gathered = jax.lax.all_gather(x_local, axis_name, tiled=False)  # [N, mx, ...]
    parts = [jax.lax.index_in_dim(gathered, i, 0, keepdims=False) for i in range(n)]
    parts = [jax.lax.slice_in_dim(p, 0, sizes[i], axis=axis) for i, p in enumerate(parts)]
    return jnp.concatenate(parts, axis=axis)


def uneven_all_gather_broadcast(x_local, sizes: Sequence[int], axis_name: str,
                                axis: int = 0):
    """Strategy 2: N-1 ppermute ring rounds (broadcast emulation).

    Same contract as the padded variant (local slab padded to max(sizes)).
    """
    n = len(sizes)
    mx = max(sizes)
    assert x_local.shape[axis] == mx
    received: List = [None] * n
    idx = jax.lax.axis_index(axis_name)
    buf = x_local
    # round r: every rank holds the slab of rank (idx - r) mod n
    for r in range(n):
        # slab currently held originates from rank (idx - r); build the full
        # output with a select over static source ids per position
        received[r] = buf
        if r < n - 1:
            buf = jax.lax.ppermute(buf, axis_name,
                                   [(s, (s + 1) % n) for s in range(n)])
    # received[r] on this rank = slab of rank (idx - r) mod n; reorder to
    # global order using one-hot masks (static unroll over n)
    parts = []
    for src in range(n):
        acc = jnp.zeros_like(x_local)
        for r in range(n):
            # on ranks where (idx - r) % n == src, received[r] is src's slab
            hit = ((idx - r) % n) == src
            acc = jnp.where(hit, received[r], acc)
        parts.append(jax.lax.slice_in_dim(acc, 0, sizes[src], axis=axis))
    return jnp.concatenate(parts, axis=axis)


def stage_handoff(h, axis_name: str, n_stages: int):
    """Point-to-point pipeline handoff (DESIGN.md §11): stage ``s``'s tensor
    moves to stage ``s + 1`` via a single ``ppermute`` — the SPMD analogue
    of a NCCL send/recv pair, NOT a collective: only adjacent stages
    exchange bytes. Stage 0 receives zeros (it has no upstream; the final
    stage's output is broadcast back for the replicated DDIM update
    instead of re-entering here)."""
    return jax.lax.ppermute(h, axis_name,
                            [(s, s + 1) for s in range(n_stages - 1)])


def ring_all_reduce_bytes(n: int, nbytes: int) -> float:
    """Analytic bytes-on-wire per rank for ring all-reduce (simulator)."""
    return 2.0 * (n - 1) / n * nbytes


def ring_hop_rows(segments: Sequence[int]) -> int:
    """Modeled wire rows per rank for ONE ring hop of sequence-parallel
    attention (DESIGN.md §13): every rank forwards one K/V segment to its
    ring neighbor per hop, and uneven speed-proportional segments travel
    padded to max(segments) — the same padded-collective convention as
    :func:`uneven_all_gather_rows`. A single segment (or none) hops
    nothing."""
    active = [s for s in segments if s > 0]
    if len(active) <= 1:
        return 0
    return max(active)


def uneven_all_gather_rows(sizes: Sequence[int]) -> int:
    """Modeled wire rows per rank for the padded uneven all-gather: each of
    the N participating ranks receives N-1 remote slabs padded to
    max(sizes). A single participant (or none) exchanges nothing — the
    simulator must not charge the full-image bytes at every boundary when
    each worker only contributes its own slab."""
    active = [s for s in sizes if s > 0]
    if len(active) <= 1:
        return 0
    return (len(active) - 1) * max(active)


# ----------------------------------------------------------------------
# boundary-exchange policies (DESIGN.md §10)
# ----------------------------------------------------------------------

#: per-boundary verdicts a policy may emit
EXCHANGE_KINDS = ("full", "skip", "predict")


@dataclasses.dataclass(frozen=True)
class BoundaryExchange:
    """Decides the exchange kind at each 0-based interval boundary.

    ``refresh_every`` = E means one corrective FULL refresh every E
    boundaries (so E-1 of every E boundaries are degraded); E = 1 is fully
    synchronous. The final boundary of a run is always forced to "full" by
    the IR regardless of the policy (the image must assemble).
    """
    name: str
    refresh_every: int = 1
    degraded_kind: str = "full"          # what non-refresh boundaries emit

    def __post_init__(self):
        if self.refresh_every < 1:
            raise ValueError(f"refresh_every must be >= 1, got "
                             f"{self.refresh_every}")
        if self.degraded_kind not in EXCHANGE_KINDS:
            raise ValueError(f"unknown exchange kind {self.degraded_kind!r}")

    def kind(self, boundary_index: int) -> str:
        if (boundary_index + 1) % self.refresh_every == 0:
            return "full"
        return self.degraded_kind


EXCHANGES: Dict[str, Callable[[int], BoundaryExchange]] = {}


def register_exchange(name: str):
    def deco(factory):
        EXCHANGES[name] = factory
        return factory
    return deco


def get_exchange(name: str, refresh_every: int = 2) -> BoundaryExchange:
    """Look up a boundary-exchange policy by registry name.

    ``refresh_every`` parameterizes the degraded policies (ignored by
    "sync"): stale_async/predictive skip/predict on ``refresh_every - 1``
    of every ``refresh_every`` boundaries.
    """
    try:
        factory = EXCHANGES[name]
    except KeyError:
        raise KeyError(f"unknown exchange policy {name!r}; registered: "
                       f"{sorted(EXCHANGES)}") from None
    return factory(refresh_every)


@register_exchange("sync")
def _sync(refresh_every: int) -> BoundaryExchange:
    """Today's behavior: blocking latent all-gather + KV merge, every
    boundary. Bitwise-identical numerics to the pre-policy engine."""
    return BoundaryExchange("sync", refresh_every=1)


@register_exchange("stale_async")
def _stale_async(refresh_every: int) -> BoundaryExchange:
    """DistriFusion-style: skip the boundary exchange on E-1 of every E
    boundaries; workers denoise against neighbor slabs up to E intervals
    stale, with a corrective full refresh every E-th boundary."""
    return BoundaryExchange("stale_async", refresh_every=refresh_every,
                            degraded_kind="skip")


@register_exchange("predictive")
def _predictive(refresh_every: int) -> BoundaryExchange:
    """Reuse-then-Predict: on non-refresh boundaries, linearly extrapolate
    the remote K/V slabs from the last two fully-exchanged versions (falls
    back to stale reuse until two refreshes have landed)."""
    return BoundaryExchange("predictive", refresh_every=refresh_every,
                            degraded_kind="predict")


@register_exchange("ring")
def _ring(refresh_every: int) -> BoundaryExchange:
    """Sequence-parallel ring staging (DESIGN.md §13): per-hop staged K/V.

    Between full refreshes the cross-worker boundary is skipped — exactly
    the stale_async verdict — while WITHIN each worker the ring hops of
    every attention keep forwarding fresh per-segment K/V, so ring hops
    carry stale *neighbors* precisely the way DistriFusion halos do. The
    per-boundary kinds are therefore the existing "skip"/"full" grammar
    (nothing new for executors to interpret); what "ring" adds is the
    per-hop staging the seq-aware executors and the ring-contention cost
    model key off the IR's :class:`~repro.core.events.SeqShard` events.
    This is also why stale_async/predictive compose naturally with the
    sequence axis: the ring is orthogonal to the cross-worker verdict."""
    return BoundaryExchange("ring", refresh_every=refresh_every,
                            degraded_kind="skip")
