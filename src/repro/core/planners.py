"""Pluggable allocation planners behind a string registry (DESIGN.md §8).

A :class:`Planner` turns per-device effective speeds plus the schedule knobs
of a :class:`~repro.core.pipeline.StadiConfig` into one :class:`ExecutionPlan`
— the single currency every execution backend consumes. Registered planners:

    "uniform"   DistriFusion baseline: equal steps, equal patches (Table III "None")
    "spatial"   +SA: equal steps, Eq. 5 patches
    "temporal"  +TA: Eq. 4 steps, equal patches
    "stadi"     +TA+SA: Eq. 4 steps, Eq. 5 patches (the paper's Algorithm 1)
    "makespan"  beyond-paper exhaustive-over-tiers makespan-optimal allocator

Register your own with :func:`register_planner`; look one up by name with
:func:`get_planner`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Protocol, Sequence, runtime_checkable

from repro.core import schedule as sched_lib
from repro.core.schedule import TemporalPlan


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A complete allocation decision: who steps when, on which rows.

    temporal: per-device step counts / interval ratios (Eq. 4 or uniform)
    patches:  token-rows per device, sum == p_total (Eq. 5 or uniform)
    planner:  provenance — registry name of the planner that produced it
    speeds:   the effective speeds the plan was computed from
    modeled_interval_cost: planner-modeled cost per fine-step interval
        (the makespan and stadi_pipefuse planners fill this in)
    stages:   displaced patch pipeline (DESIGN.md §11): DiT blocks per
        pipeline stage, chain placed on the fastest ``len(stages)`` devices
        in speed order. None = depth-unpartitioned (pure patch mode). When
        set, ``temporal``/``patches`` describe patch *micro-batches*
        streaming through the stage chain, not per-device ownership.
    guidance: classifier-free guidance placement (DESIGN.md §12): a
        :class:`repro.core.guidance.GuidancePlan`. None = unguided. In
        split/interleaved mode ``temporal``/``patches`` describe logical
        workers that are cond/uncond device PAIRS, not single devices.
    seq:      sequence-parallel attention (DESIGN.md §13): a
        :class:`repro.core.seqpar.SeqPlan` (Ulysses head partition + ring
        K/V segments). None / single-shard = attention-unsharded. When
        multi-shard, ``temporal``/``patches`` describe logical workers
        that are device GROUPS of ``seq.n_shards`` members each (the
        column-dealt placement of :func:`repro.core.seqpar.
        seq_group_speeds`); ``speeds`` stays the raw cluster.
    frames:   frame axis (DESIGN.md §16): a
        :class:`repro.core.frames.FramePlan`. None / single-frame = the
        image path. With ``len(groups) > 1`` the plan is frame-parallel:
        ``temporal``/``patches`` describe patch-worker COLUMNS shared by
        every member row of the row-dealt placement of
        :func:`repro.core.frames.frame_group_layout` (row ``g`` owns the
        frame chunk ``frames.bounds[g]``); ``speeds`` stays the raw
        cluster.
    """
    temporal: TemporalPlan
    patches: List[int]
    planner: str
    speeds: List[float]
    modeled_interval_cost: Optional[float] = None
    stages: Optional[List[int]] = None
    guidance: Optional[object] = None
    seq: Optional[object] = None
    frames: Optional[object] = None

    @property
    def active(self) -> List[int]:
        return [i for i in self.temporal.active if self.patches[i] > 0]


@runtime_checkable
class Planner(Protocol):
    """Anything callable as ``planner(speeds, knobs, p_total)``.

    ``knobs`` is any object exposing ``m_base``, ``m_warmup``, ``a``, ``b``,
    ``tiers``, ``granularity`` and ``min_patch`` (in practice a
    :class:`~repro.core.pipeline.StadiConfig`).
    """

    def __call__(self, speeds: Sequence[float], knobs, p_total: int) -> ExecutionPlan:
        ...


PLANNERS: Dict[str, Planner] = {}


def register_planner(name: str) -> Callable[[Planner], Planner]:
    def deco(fn: Planner) -> Planner:
        PLANNERS[name] = fn
        return fn
    return deco


def get_planner(name: str) -> Planner:
    try:
        return PLANNERS[name]
    except KeyError:
        raise KeyError(f"unknown planner {name!r}; registered: "
                       f"{sorted(PLANNERS)}") from None


# ----------------------------------------------------------------------
# building blocks
# ----------------------------------------------------------------------

def _uniform_temporal(n: int, m_base: int, m_warmup: int) -> TemporalPlan:
    return TemporalPlan([m_base] * n, [1] * n, [False] * n, m_base, m_warmup)


def _equal_patches(plan: TemporalPlan, p_total: int) -> List[int]:
    """Equal split of token-rows over the plan's active devices."""
    active = plan.active
    base, rem = divmod(p_total, len(active))
    out, j = [], 0
    for i in range(len(plan.steps)):
        if i not in active:
            out.append(0)
        else:
            out.append(base + (1 if j < rem else 0))
            j += 1
    return out


# ----------------------------------------------------------------------
# registered planners
# ----------------------------------------------------------------------

@register_planner("uniform")
def uniform_planner(speeds, knobs, p_total) -> ExecutionPlan:
    """DistriFusion patch parallelism: no adaptation at all."""
    plan = _uniform_temporal(len(speeds), knobs.m_base, knobs.m_warmup)
    return ExecutionPlan(plan, _equal_patches(plan, p_total), "uniform",
                         list(speeds))


@register_planner("spatial")
def spatial_planner(speeds, knobs, p_total) -> ExecutionPlan:
    """+SA: uniform steps, patches mended by Eq. 5."""
    plan = _uniform_temporal(len(speeds), knobs.m_base, knobs.m_warmup)
    patches = sched_lib.spatial_allocation(speeds, plan.steps, p_total,
                                           knobs.granularity, knobs.min_patch)
    return ExecutionPlan(plan, patches, "spatial", list(speeds))


@register_planner("temporal")
def temporal_planner(speeds, knobs, p_total) -> ExecutionPlan:
    """+TA: Eq. 4 steps, equal patches over the surviving devices."""
    plan = sched_lib.temporal_allocation(speeds, knobs.m_base, knobs.m_warmup,
                                         knobs.a, knobs.b, knobs.tiers)
    return ExecutionPlan(plan, _equal_patches(plan, p_total), "temporal",
                         list(speeds))


@register_planner("stadi")
def stadi_planner(speeds, knobs, p_total) -> ExecutionPlan:
    """Full STADI: Eq. 4 then Eq. 5 (Algorithm 1 lines 1-6)."""
    plan = sched_lib.temporal_allocation(speeds, knobs.m_base, knobs.m_warmup,
                                         knobs.a, knobs.b, knobs.tiers)
    patches = sched_lib.spatial_allocation(speeds, plan.steps, p_total,
                                           knobs.granularity, knobs.min_patch)
    return ExecutionPlan(plan, patches, "stadi", list(speeds))


def _patch_plan_cost(plan: ExecutionPlan, p_total: int,
                     fixed: float = 0.05) -> float:
    """Normalized per-fine-step makespan of a pure patch-parallel plan: a
    full-depth full-image step at v=1 costs ``fixed + 1`` work units, and a
    device with interval ratio r amortizes its step over r fine steps (the
    same model :func:`repro.core.schedule.makespan_optimal_allocation`
    minimizes)."""
    cost = 0.0
    for i in plan.active:
        v, r = plan.speeds[i], plan.temporal.ratios[i]
        cost = max(cost, (fixed + plan.patches[i] / p_total) / v / r)
    return cost


def _pipefuse_plan_cost(stages: Sequence[int], chain_speeds: Sequence[float],
                        n_micro: int, fixed: float = 0.05) -> float:
    """Normalized per-fine-step steady-state cost of a displaced pipeline:
    stage d runs its block share of every one of the ``n_micro`` micro-tasks
    per fine step, so the bottleneck stage sets the rate. The depth-
    proportional fixed overhead splits with the blocks — the structural
    advantage over patch parallelism, which pays ``fixed`` whole on every
    device (DESIGN.md §11)."""
    L = sum(stages)
    return max(b / L * (n_micro * fixed + 1.0) / v
               for b, v in zip(stages, chain_speeds))


@register_planner("stadi_pipefuse")
def stadi_pipefuse_planner(speeds, knobs, p_total) -> ExecutionPlan:
    """Joint (steps, patches, stage split) search (DESIGN.md §11).

    Candidates: the pure patch-parallel STADI plan (num_stages == 1) and,
    for each stage count S, a displaced pipeline whose chain runs on the S
    fastest devices with blocks sized by :func:`repro.core.hetero.
    stage_partition` and patch micro-batches split uniformly. All candidates
    are scored with the same normalized interval-makespan model and the
    cheapest wins. ``knobs.num_stages > 0`` pins S (1 = force pure patch);
    0 = auto. ``knobs.depth`` (the DiT block count, filled in by
    StadiPipeline) is required for S > 1. ``knobs.micro_patches > 0`` pins
    the micro-batch count; 0 = auto (S or 2S, whichever models cheaper).
    """
    from repro.core import hetero
    n = len(speeds)
    forced_s = getattr(knobs, "num_stages", 0)
    depth = getattr(knobs, "depth", None)
    # normalized per-step fixed overhead: derive from the configured cost
    # model when there is one (t_fixed in units of the full-image row work),
    # else the makespan planner's default
    cm = getattr(knobs, "cost_model", None)
    fixed = (cm.t_fixed / max(cm.t_row * p_total, 1e-12)
             if cm is not None else 0.05)
    stadi = stadi_planner(speeds, knobs, p_total)
    candidates = [dataclasses.replace(
        stadi, planner="stadi_pipefuse",
        modeled_interval_cost=_patch_plan_cost(stadi, p_total, fixed))]
    if depth is None and forced_s > 1:
        raise ValueError("stadi_pipefuse needs knobs.depth (the DiT block "
                         "count) to partition stages; StadiPipeline fills "
                         "it in from the model config")
    s_options = ([forced_s] if forced_s > 0 else
                 range(2, min(n, depth or 1) + 1))
    by_speed = sorted(range(n), key=lambda d: (-speeds[d], d))
    forced_m = getattr(knobs, "micro_patches", 0)
    for S in s_options:
        if S < 2 or S > min(n, depth):
            continue
        chain = [speeds[d] for d in by_speed[:S]]
        stages = hetero.stage_partition(depth, chain)
        for M in ([forced_m] if forced_m > 0 else
                  sorted({S, min(2 * S, p_total)})):
            if M > p_total:
                continue
            temporal = _uniform_temporal(M, knobs.m_base, knobs.m_warmup)
            patches = _equal_patches(temporal, p_total)
            candidates.append(ExecutionPlan(
                temporal, patches, "stadi_pipefuse", list(speeds),
                modeled_interval_cost=_pipefuse_plan_cost(stages, chain, M,
                                                          fixed),
                stages=stages))
    if forced_s > 1 and len(candidates) == 1:
        raise ValueError(
            f"num_stages={forced_s} is infeasible: need 2 <= S <= "
            f"min(n_devices={n}, depth={depth})")
    best = min(candidates, key=lambda c: c.modeled_interval_cost)
    if forced_s > 1:                     # pinned: drop the patch fallback
        best = min(candidates[1:], key=lambda c: c.modeled_interval_cost)
    return best


def _guided_plan_cost(plan: ExecutionPlan, speeds, p_total: int, cm,
                      kv_row: float, latent_bytes: float,
                      cond_tokens: int = 0) -> float:
    """Modeled seconds of one adaptive interval ending in a full boundary,
    under the guided cost model of :func:`repro.core.simulate.
    _simulate_guided` (fabric contention: fused serializes both branches'
    staged K/V; split runs the branch domains concurrently and pays only
    the per-substep epsilon combine across them). With no byte provenance
    (kv_row == 0, standalone planner calls) this degenerates to the
    compute-only makespan. Interleaved costs average the fresh/stale
    interval mix over the uncond_refresh cadence."""
    g = plan.guidance
    t = plan.temporal
    R = t.lcm
    row_bytes = latent_bytes / max(p_total, 1)
    # prompt-token read (DESIGN.md §17): per-row like t_row, per branch
    t_row_eff = cm.t_row + getattr(cm, "t_xattn", 0.0) * cond_tokens

    def interval_cost(fresh: bool) -> float:
        compute, eps_bytes, kv_bytes, hops = 0.0, 0.0, 0.0, 0
        for i in plan.active:
            sub = R // t.ratios[i]
            rows = plan.patches[i]
            if g.mode == "fused":
                step_t = cm.t_fixed + t_row_eff * rows * 2.0
                tt = sub * step_t / max(speeds[i], 1e-9)
            else:
                vc = speeds[g.cond_devices[i]]
                vu = speeds[g.uncond_devices[i]]
                step_t = cm.t_fixed + t_row_eff * rows
                if fresh or not g.worker_reuses(i):
                    tt = sub * step_t / max(min(vc, vu), 1e-9)
                else:                    # reuse: uncond idles, cond runs
                    tt = sub * step_t / max(vc, 1e-9)
            compute = max(compute, tt)
            eps_sub = sub if fresh or not g.worker_reuses(i) else 0
            eps_bytes += 2 * eps_sub * rows * row_bytes
            kv_bytes += kv_row * rows
            hops = max(hops, eps_sub)
        eps_t = 0.0
        if g.mode != "fused":
            eps_t = eps_bytes / cm.link_bw + hops * cm.link_latency
        branch_factor = 2.0 if g.mode == "fused" else 1.0
        kv_t = branch_factor * kv_bytes / cm.link_bw
        from repro.core.comm import uneven_all_gather_rows
        gather_rows = uneven_all_gather_rows(
            [plan.patches[i] for i in plan.active])
        gather_t = gather_rows * row_bytes / cm.link_bw
        return max(compute, kv_t) + gather_t + cm.link_latency + eps_t

    if g.mode != "interleaved":
        return interval_cost(True)
    E = g.uncond_refresh
    return (interval_cost(True) + (E - 1) * interval_cost(False)) / E


@register_planner("stadi_guidance")
def stadi_guidance_planner(speeds, knobs, p_total) -> ExecutionPlan:
    """Joint (steps, patches, guidance placement) search (DESIGN.md §12).

    Candidates: FUSED — the plain STADI plan over all devices, every
    worker computing both CFG branches; SPLIT — the cluster bipartitioned
    by :func:`repro.core.guidance.guidance_groups`, logical workers =
    rank-paired (cond, uncond) devices, the STADI allocator run over the
    pairwise-min speeds; INTERLEAVED — split placement + uncond reuse on
    ``knobs.uncond_refresh`` cadence (quality-lossy, so only considered
    when forced). ``knobs.guidance`` pins the mode ("none" = auto over
    fused/split); candidates are scored with the guided fabric-contention
    cost model using ``knobs.cost_model`` byte provenance when available
    (StadiPipeline fills in ``latent_bytes``/``kv_row_bytes`` from the
    model config) and the cheapest wins. Requires ``knobs.cfg_scale > 0``.
    """
    from repro.core import guidance as guide_lib
    from repro.core.simulate import CostModel
    scale = getattr(knobs, "cfg_scale", 0.0)
    if scale <= 0.0:
        raise ValueError("the stadi_guidance planner plans GUIDED "
                         "generation: set cfg_scale > 0 (and optionally "
                         "guidance='fused'|'split'|'interleaved')")
    mode = getattr(knobs, "guidance", "none")
    refresh = getattr(knobs, "uncond_refresh", 2)
    cm = getattr(knobs, "cost_model", None) or CostModel(t_fixed=1e-3,
                                                         t_row=1e-3)
    kv_row = getattr(knobs, "kv_row_bytes", 0)
    latent_bytes = getattr(knobs, "latent_bytes", 0)
    cond_tokens = getattr(knobs, "cond_bucket", 0) or 0
    modes = [mode] if mode != "none" else ["fused", "split"]
    candidates = []
    for m in modes:
        if m == "fused":
            base = stadi_planner(speeds, knobs, p_total)
            gp = guide_lib.GuidancePlan("fused", scale)
        else:
            if len(speeds) < 2:
                if mode != "none":       # forced split on one device
                    guide_lib.guidance_groups(speeds)   # raises with context
                continue
            gp = guide_lib.split_plan(speeds, m, scale,
                                      uncond_refresh=refresh)
            base = stadi_planner(gp.pair_speeds(speeds), knobs, p_total)
        cand = dataclasses.replace(base, planner="stadi_guidance",
                                   speeds=list(speeds), guidance=gp)
        cost = _guided_plan_cost(cand, speeds, p_total, cm, kv_row,
                                 latent_bytes, cond_tokens=cond_tokens)
        candidates.append(dataclasses.replace(cand,
                                              modeled_interval_cost=cost))
    return min(candidates, key=lambda c: c.modeled_interval_cost)


def _seq_plan_cost(plan: ExecutionPlan, groups, p_total: int, cm,
                   kv_row: float, latent_bytes: float,
                   refresh: int, cond_tokens: int = 0) -> float:
    """Modeled seconds of one adaptive interval under the ring-contention
    cost model of :func:`repro.core.simulate._simulate_seq`, averaged over
    the "ring" policy's refresh cadence (1 full boundary + E-1 degraded
    per E). ``groups`` is the member-speed grouping of a multi-shard
    candidate (None for the pure patch-parallel candidate, whose workers
    are single devices). With no byte provenance (kv_row == 0, standalone
    planner calls) the wire terms vanish and the score degenerates to the
    compute makespan — where the t_ctx attention term still rewards head
    scattering on attention-bound profiles."""
    from repro.core.comm import uneven_all_gather_rows
    t = plan.temporal
    R = t.lcm
    row_bytes = latent_bytes / max(p_total, 1)
    seq = plan.seq
    if seq is not None and len(seq.segments) > 1:
        headf, segf = seq.head_fracs, seq.seg_fracs
        hops, seg_pad = len(seq.segments) - 1, max(seq.seg_fracs)
    else:
        headf, segf, hops, seg_pad = [1.0], [1.0], 0, 1.0
    compute = ring_t = async_b = 0.0
    for i in plan.active:
        sub = R // t.ratios[i]
        rows = plan.patches[i]
        members = groups[i] if groups is not None else [plan.speeds[i]]
        wt = max((cm.t_fixed
                  + (cm.t_row + getattr(cm, "t_xattn", 0.0) * cond_tokens)
                  * rows * segf[j]) / max(v, 1e-9)
                 + cm.attn_time(p_total, headf[j], v)
                 for j, v in enumerate(members))
        compute = max(compute, sub * wt)
        ring_t = max(ring_t, sub * hops * (kv_row * rows * seg_pad
                                           / cm.link_bw + cm.link_latency))
        async_b = max(async_b, kv_row * rows)
    gather_rows = uneven_all_gather_rows(
        [plan.patches[i] for i in plan.active])
    gather_t = gather_rows * row_bytes / cm.link_bw
    full = max(compute, async_b / cm.link_bw, ring_t) \
        + gather_t + cm.link_latency
    degraded = max(compute, ring_t)
    E = max(refresh, 1)
    return (full + (E - 1) * degraded) / E


@register_planner("stadi_seq")
def stadi_seq_planner(speeds, knobs, p_total) -> ExecutionPlan:
    """Joint (steps, patches, seq shards) search (DESIGN.md §13).

    Candidates: the pure patch-parallel STADI plan (seq_shards == 1) and,
    for each shard count S, a sequence-sharded plan whose workers are
    device groups of S members (column-dealt by :func:`repro.core.seqpar.
    seq_group_speeds`), with the STADI allocator run over the per-group
    aggregate speeds and the head/segment partitions sized speed-
    proportionally over the per-shard-row aggregates. All candidates are
    scored by the ring-contention cost model (:func:`_seq_plan_cost`,
    mirroring ``simulate._simulate_seq``) and the cheapest wins — on
    attention-bound profiles (``cost_model.t_ctx`` large) head scattering
    divides the context-read wall no patch split can cut, which is what
    makes a multi-shard candidate win despite its ring traffic.

    ``knobs.seq_shards > 0`` pins S (1 = force pure patch); 0 = auto.
    ``knobs.n_heads`` (filled in by StadiPipeline from the model config)
    is required for S > 1.
    """
    from repro.core import seqpar as seqpar_lib
    from repro.core.simulate import CostModel
    n = len(speeds)
    forced = getattr(knobs, "seq_shards", 0) or 0
    n_heads = getattr(knobs, "n_heads", None)
    cm = getattr(knobs, "cost_model", None) or CostModel(t_fixed=1e-3,
                                                         t_row=1e-3)
    kv_row = getattr(knobs, "kv_row_bytes", 0)
    latent_bytes = getattr(knobs, "latent_bytes", 0)
    refresh = getattr(knobs, "exchange_refresh", 2)
    cond_tokens = getattr(knobs, "cond_bucket", 0) or 0
    candidates = []
    if forced in (0, 1):
        base = stadi_planner(speeds, knobs, p_total)
        cand = dataclasses.replace(base, planner="stadi_seq")
        candidates.append(dataclasses.replace(
            cand, modeled_interval_cost=_seq_plan_cost(
                cand, None, p_total, cm, kv_row, latent_bytes, refresh,
                cond_tokens=cond_tokens)))
    if n_heads is None and forced > 1:
        raise ValueError("stadi_seq needs knobs.n_heads (the attention "
                         "head count) to scatter heads; StadiPipeline "
                         "fills it in from the model config")
    if forced == 1:                       # pinned pure patch: no seq search
        return candidates[0]
    s_options = ([forced] if forced > 1 else
                 range(2, min(n, n_heads or 1) + 1))
    for S in s_options:
        if S < 2 or S > min(n, n_heads or 0) or n // S < 1 or S > p_total:
            continue
        groups, shard_speeds = seqpar_lib.seq_group_speeds(speeds, S)
        worker_speeds = [sum(g) for g in groups]
        base = stadi_planner(worker_speeds, knobs, p_total)
        seq = seqpar_lib.make_seq_plan(n_heads, p_total, S, shard_speeds)
        cand = dataclasses.replace(base, planner="stadi_seq",
                                   speeds=list(speeds), seq=seq)
        candidates.append(dataclasses.replace(
            cand, modeled_interval_cost=_seq_plan_cost(
                cand, groups, p_total, cm, kv_row, latent_bytes, refresh,
                cond_tokens=cond_tokens)))
    if not candidates:
        raise ValueError(
            f"seq_shards={forced} is infeasible: need 1 <= S <= "
            f"min(n_devices={n}, n_heads={n_heads}, p_total={p_total})")
    return min(candidates, key=lambda c: c.modeled_interval_cost)


def _frame_plan_cost(plan: ExecutionPlan, rows, p_total: int, cm,
                     kv_row: float, latent_bytes: float,
                     refresh: int, cond_tokens: int = 0) -> float:
    """Modeled seconds of one adaptive interval under the frame cost model
    of :func:`repro.core.simulate._simulate_frames`, averaged over the
    stale_async refresh cadence (1 full boundary + E-1 degraded per E).
    ``rows`` is the member-speed layout of a frame-parallel candidate
    (``frame_group_layout`` rows, column-aligned with ``plan.patches``);
    None for the frame-sequential candidate, whose workers are single
    devices each stepping every frame. Frame f > 0 attends over the
    2x (own ⊕ previous frame) published context, so the attention term
    charges ``p_total * (2 * frames_in_row - [row owns frame 0])`` context
    rows per substep — the wall frame-parallel placements divide. A full
    boundary additionally wires every frame's K/V + latent gather, and a
    multi-row placement pays the (G-1) cross-row previous-frame K/V
    handoffs. With no byte provenance (kv_row == 0, standalone planner
    calls) the score degenerates to the compute makespan."""
    from repro.core.comm import uneven_all_gather_rows
    fplan = plan.frames
    G = fplan.n_groups
    t = plan.temporal
    R = t.lcm
    row_bytes = latent_bytes / max(p_total, 1)
    # fused-CFG x frames (DESIGN.md §17): every member evaluates both
    # branches branch-vmapped — row work, context reads, and published K/V
    # double; the fixed overhead is shared (simulate._simulate_frames)
    mult = 2 if plan.guidance is not None else 1
    t_row_eff = cm.t_row + getattr(cm, "t_xattn", 0.0) * cond_tokens
    kv_row = kv_row * mult
    # context rows a member row reads per fine step: 2N per owned frame,
    # minus the previous-frame half frame 0 does not have (it sits in the
    # first row by construction — bounds are contiguous from frame 0)
    ctx = [mult * p_total * (2 * fplan.groups[g] - (1 if g == 0 else 0))
           for g in range(G)]
    compute = async_b = 0.0
    for i in plan.active:
        sub = R // t.ratios[i]
        rows_i = plan.patches[i]
        members = ([(rows[g][i], g) for g in range(G)] if rows is not None
                   else [(plan.speeds[i], 0)])
        wt = max(fplan.groups[g] * (cm.t_fixed + t_row_eff * rows_i * mult)
                 / max(v, 1e-9) + cm.attn_time(ctx[g], 1.0, v)
                 for v, g in members)
        compute = max(compute, sub * wt)
        async_b = max(async_b, max(kv_row * rows_i * fplan.groups[g]
                                   for _, g in members))
    gather_rows = uneven_all_gather_rows(
        [plan.patches[i] for i in plan.active])
    gather_t = gather_rows * row_bytes * fplan.num_frames / cm.link_bw
    handoff_t = (G - 1) * kv_row * p_total / cm.link_bw
    full = max(compute, async_b / cm.link_bw) \
        + gather_t + handoff_t + cm.link_latency
    degraded = compute
    E = max(refresh, 1)
    return (full + (E - 1) * degraded) / E


@register_planner("stadi_video")
def stadi_video_planner(speeds, knobs, p_total) -> ExecutionPlan:
    """Joint (steps, patches, frame placement) search (DESIGN.md §16).

    Candidates: the frame-SEQUENTIAL placement — the plain STADI patch
    plan over all devices, every worker stepping all ``num_frames`` frames
    per fine step (``FramePlan(F, (F,))``) — and, for each group count G,
    a frame-PARALLEL placement: the speed-sorted cluster dealt row-wise
    into G member rows (:func:`repro.core.frames.frame_group_layout`),
    frames split speed-proportionally over the rows
    (:func:`repro.core.frames.frame_partition`), and the STADI allocator
    run over the per-column effective speeds ``min_g rows[g][w] /
    frames[g]`` so one global patch split fits every row. All candidates
    are scored by :func:`_frame_plan_cost` and the cheapest wins — frame
    parallelism divides both the per-device fixed-overhead wall (F step
    launches vs F/G) and the 2N cross-frame context-read wall, at the
    price of coarser patch splits and the cross-row K/V handoff.

    ``knobs.frame_groups > 0`` pins G (1 = force frame-sequential); 0 =
    auto. ``knobs.num_frames > 1`` is required — single-frame image plans
    come from the plain planners. ``knobs.cfg_scale > 0`` plans GUIDED
    video (DESIGN.md §17): every candidate carries a FUSED GuidancePlan —
    the only mode that composes with the frame axis — and is scored with
    the branch-doubled frame cost model; a forced split/interleaved
    ``knobs.guidance`` raises loudly.
    """
    from repro.core import frames as frames_lib
    from repro.core.simulate import CostModel
    n = len(speeds)
    F = getattr(knobs, "num_frames", 1)
    if F < 2:
        raise ValueError("the stadi_video planner plans MULTI-frame "
                         "generation: set num_frames > 1 (single-frame "
                         "image plans come from planner='stadi')")
    forced = getattr(knobs, "frame_groups", 0) or 0
    cm = getattr(knobs, "cost_model", None) or CostModel(t_fixed=1e-3,
                                                         t_row=1e-3)
    kv_row = getattr(knobs, "kv_row_bytes", 0)
    latent_bytes = getattr(knobs, "latent_bytes", 0)
    refresh = getattr(knobs, "exchange_refresh", 2)
    cond_tokens = getattr(knobs, "cond_bucket", 0) or 0
    scale = getattr(knobs, "cfg_scale", 0.0)
    gp = None
    if scale > 0.0:
        from repro.core import guidance as guide_lib
        gmode = getattr(knobs, "guidance", "none")
        if gmode not in ("none", "fused"):
            raise ValueError(
                f"guidance={gmode!r} is not composed with the frame axis: "
                "guided video runs FUSED classifier-free guidance only "
                "(branch-vmapped per member — DESIGN.md §17)")
        gp = guide_lib.GuidancePlan("fused", scale)
    candidates = []
    if forced in (0, 1):
        base = stadi_planner(speeds, knobs, p_total)
        cand = dataclasses.replace(base, planner="stadi_video",
                                   frames=frames_lib.FramePlan(F, (F,)),
                                   guidance=gp)
        candidates.append(dataclasses.replace(
            cand, modeled_interval_cost=_frame_plan_cost(
                cand, None, p_total, cm, kv_row, latent_bytes, refresh,
                cond_tokens=cond_tokens)))
    if forced == 1:                  # pinned frame-sequential: no search
        return candidates[0]
    g_options = [forced] if forced > 1 else range(2, min(n, F) + 1)
    for G in g_options:
        if G < 2 or G > min(n, F):
            continue
        rows, row_speeds = frames_lib.frame_group_layout(speeds, G)
        groups = frames_lib.frame_partition(F, G, row_speeds)
        fplan = frames_lib.FramePlan(F, tuple(groups))
        n_cols = len(rows[0])
        col_speeds = [min(rows[g][w] / groups[g] for g in range(G))
                      for w in range(n_cols)]
        base = stadi_planner(col_speeds, knobs, p_total)
        cand = dataclasses.replace(base, planner="stadi_video",
                                   speeds=list(speeds), frames=fplan,
                                   guidance=gp)
        candidates.append(dataclasses.replace(
            cand, modeled_interval_cost=_frame_plan_cost(
                cand, rows, p_total, cm, kv_row, latent_bytes, refresh,
                cond_tokens=cond_tokens)))
    if not candidates:
        raise ValueError(
            f"frame_groups={forced} is infeasible: need 1 <= G <= "
            f"min(n_devices={n}, num_frames={F})")
    return min(candidates, key=lambda c: c.modeled_interval_cost)


@register_planner("makespan")
def makespan_planner(speeds, knobs, p_total) -> ExecutionPlan:
    """Beyond-paper DP: exhaustive tier search minimizing modeled makespan.

    Searches exactly ``knobs.tiers`` (ratios not dividing the post-warmup
    step count are dropped); pass ``tiers=(1, 2, 4)`` for the generalized
    ratios of DESIGN.md §7 — the default (1, 2) restricts the search to the
    paper's two tiers.
    """
    plan, patches, cost = sched_lib.makespan_optimal_allocation(
        speeds, knobs.m_base, knobs.m_warmup, p_total,
        granularity=knobs.granularity, tiers=knobs.tiers, b=knobs.b)
    return ExecutionPlan(plan, patches, "makespan", list(speeds),
                         modeled_interval_cost=cost)
