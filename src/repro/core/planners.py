"""Pluggable allocation planners behind a string registry (DESIGN.md §8).

A :class:`Planner` turns per-device effective speeds plus the schedule knobs
of a :class:`~repro.core.pipeline.StadiConfig` into one :class:`ExecutionPlan`
— the single currency every execution backend consumes. Registered planners:

    "uniform"   DistriFusion baseline: equal steps, equal patches (Table III "None")
    "spatial"   +SA: equal steps, Eq. 5 patches
    "temporal"  +TA: Eq. 4 steps, equal patches
    "stadi"     +TA+SA: Eq. 4 steps, Eq. 5 patches (the paper's Algorithm 1)
    "makespan"  beyond-paper exhaustive-over-tiers makespan-optimal allocator

Register your own with :func:`register_planner`; look one up by name with
:func:`get_planner`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Protocol, Sequence, runtime_checkable

from repro.core import schedule as sched_lib
from repro.core.schedule import TemporalPlan


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A complete allocation decision: who steps when, on which rows.

    temporal: per-device step counts / interval ratios (Eq. 4 or uniform)
    patches:  token-rows per device, sum == p_total (Eq. 5 or uniform)
    planner:  provenance — registry name of the planner that produced it
    speeds:   the effective speeds the plan was computed from
    modeled_interval_cost: planner-modeled cost per fine-step interval
        (only the makespan planner fills this in; None otherwise)
    """
    temporal: TemporalPlan
    patches: List[int]
    planner: str
    speeds: List[float]
    modeled_interval_cost: Optional[float] = None

    @property
    def active(self) -> List[int]:
        return [i for i in self.temporal.active if self.patches[i] > 0]


@runtime_checkable
class Planner(Protocol):
    """Anything callable as ``planner(speeds, knobs, p_total)``.

    ``knobs`` is any object exposing ``m_base``, ``m_warmup``, ``a``, ``b``,
    ``tiers``, ``granularity`` and ``min_patch`` (in practice a
    :class:`~repro.core.pipeline.StadiConfig`).
    """

    def __call__(self, speeds: Sequence[float], knobs, p_total: int) -> ExecutionPlan:
        ...


PLANNERS: Dict[str, Planner] = {}


def register_planner(name: str) -> Callable[[Planner], Planner]:
    def deco(fn: Planner) -> Planner:
        PLANNERS[name] = fn
        return fn
    return deco


def get_planner(name: str) -> Planner:
    try:
        return PLANNERS[name]
    except KeyError:
        raise KeyError(f"unknown planner {name!r}; registered: "
                       f"{sorted(PLANNERS)}") from None


# ----------------------------------------------------------------------
# building blocks
# ----------------------------------------------------------------------

def _uniform_temporal(n: int, m_base: int, m_warmup: int) -> TemporalPlan:
    return TemporalPlan([m_base] * n, [1] * n, [False] * n, m_base, m_warmup)


def _equal_patches(plan: TemporalPlan, p_total: int) -> List[int]:
    """Equal split of token-rows over the plan's active devices."""
    active = plan.active
    base, rem = divmod(p_total, len(active))
    out, j = [], 0
    for i in range(len(plan.steps)):
        if i not in active:
            out.append(0)
        else:
            out.append(base + (1 if j < rem else 0))
            j += 1
    return out


# ----------------------------------------------------------------------
# registered planners
# ----------------------------------------------------------------------

@register_planner("uniform")
def uniform_planner(speeds, knobs, p_total) -> ExecutionPlan:
    """DistriFusion patch parallelism: no adaptation at all."""
    plan = _uniform_temporal(len(speeds), knobs.m_base, knobs.m_warmup)
    return ExecutionPlan(plan, _equal_patches(plan, p_total), "uniform",
                         list(speeds))


@register_planner("spatial")
def spatial_planner(speeds, knobs, p_total) -> ExecutionPlan:
    """+SA: uniform steps, patches mended by Eq. 5."""
    plan = _uniform_temporal(len(speeds), knobs.m_base, knobs.m_warmup)
    patches = sched_lib.spatial_allocation(speeds, plan.steps, p_total,
                                           knobs.granularity, knobs.min_patch)
    return ExecutionPlan(plan, patches, "spatial", list(speeds))


@register_planner("temporal")
def temporal_planner(speeds, knobs, p_total) -> ExecutionPlan:
    """+TA: Eq. 4 steps, equal patches over the surviving devices."""
    plan = sched_lib.temporal_allocation(speeds, knobs.m_base, knobs.m_warmup,
                                         knobs.a, knobs.b, knobs.tiers)
    return ExecutionPlan(plan, _equal_patches(plan, p_total), "temporal",
                         list(speeds))


@register_planner("stadi")
def stadi_planner(speeds, knobs, p_total) -> ExecutionPlan:
    """Full STADI: Eq. 4 then Eq. 5 (Algorithm 1 lines 1-6)."""
    plan = sched_lib.temporal_allocation(speeds, knobs.m_base, knobs.m_warmup,
                                         knobs.a, knobs.b, knobs.tiers)
    patches = sched_lib.spatial_allocation(speeds, plan.steps, p_total,
                                           knobs.granularity, knobs.min_patch)
    return ExecutionPlan(plan, patches, "stadi", list(speeds))


@register_planner("makespan")
def makespan_planner(speeds, knobs, p_total) -> ExecutionPlan:
    """Beyond-paper DP: exhaustive tier search minimizing modeled makespan.

    Searches exactly ``knobs.tiers`` (ratios not dividing the post-warmup
    step count are dropped); pass ``tiers=(1, 2, 4)`` for the generalized
    ratios of DESIGN.md §7 — the default (1, 2) restricts the search to the
    paper's two tiers.
    """
    plan, patches, cost = sched_lib.makespan_optimal_allocation(
        speeds, knobs.m_base, knobs.m_warmup, p_total,
        granularity=knobs.granularity, tiers=knobs.tiers, b=knobs.b)
    return ExecutionPlan(plan, patches, "makespan", list(speeds),
                         modeled_interval_cost=cost)
