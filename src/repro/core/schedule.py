"""STADI scheduling: temporal adaptation (Eq. 4) + spatial patch-size
mending (Eq. 5).

Temporal adaptation quantizes per-device step counts so that the set of
post-warmup step *intervals* has a minimal least common multiple (the paper
restricts ratios to {1, 2}: fast devices take M_base steps, medium devices
take (M_base + M_warmup)/2 — i.e. exactly half the post-warmup steps — and
devices slower than b*v_max are excluded). The beyond-paper generalized
allocator extends ratios to {1, 2, 4} and a makespan-optimal DP (see
DESIGN.md §7), still LCM-bounded.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class TemporalPlan:
    steps: List[int]          # M_i per device (0 if excluded)
    ratios: List[int]         # post-warmup interval ratio r_i (fine steps per own step)
    excluded: List[bool]
    m_base: int
    m_warmup: int

    @property
    def active(self) -> List[int]:
        return [i for i, e in enumerate(self.excluded) if not e]

    @property
    def lcm(self) -> int:
        rs = [r for r, e in zip(self.ratios, self.excluded) if not e]
        return math.lcm(*rs) if rs else 1


def effective_speed(c: float, rho: float) -> float:
    """Paper §III-B: capability c_i scaled by background occupancy ρ_i."""
    return c * max(0.0, 1.0 - rho)


def temporal_allocation(speeds: Sequence[float], m_base: int, m_warmup: int,
                        a: float = 0.75, b: float = 0.25,
                        tiers: Sequence[int] = (1, 2)) -> TemporalPlan:
    """Eq. (4). ``tiers`` lists the allowed step-interval ratios (paper: (1,2)).

    Post-warmup fine steps F = m_base - m_warmup must be divisible by every
    tier ratio; we require m_base/m_warmup chosen accordingly (validated).
    """
    if not speeds:
        raise ValueError("need at least one device")
    if not (0.0 < b < a < 1.0):
        raise ValueError(f"need 0 < b < a < 1, got a={a} b={b}")
    if m_warmup >= m_base:
        raise ValueError("m_warmup must be < m_base")
    F = m_base - m_warmup
    for r in tiers:
        if F % r:
            raise ValueError(f"post-warmup steps {F} not divisible by tier ratio {r}")

    vmax = max(speeds)
    steps, ratios, excluded = [], [], []
    # thresholds: tier k gets speeds in (thr_{k+1}, thr_k]; paper has 2 tiers
    # with thresholds (a*vmax, vmax], (b*vmax, a*vmax]. Generalized tiers
    # interpolate geometrically between a and b.
    n_t = len(tiers)
    if n_t == 1:
        thr = [b]                 # single tier: every non-excluded device
    elif n_t == 2:
        thr = [a, b]
    else:
        thr = [a * (b / a) ** (k / (n_t - 1)) for k in range(n_t)]
    for v in speeds:
        if v <= b * vmax:
            steps.append(0); ratios.append(0); excluded.append(True)
            continue
        tier = n_t - 1
        for k, th in enumerate(thr):
            if v > th * vmax:
                tier = k
                break
        r = tiers[tier]
        steps.append(m_warmup + F // r)
        ratios.append(r)
        excluded.append(False)
    if all(excluded):
        # degenerate: keep the fastest device
        i = max(range(len(speeds)), key=lambda j: speeds[j])
        steps[i], ratios[i], excluded[i] = m_base, 1, False
    return TemporalPlan(steps, ratios, excluded, m_base, m_warmup)


def spatial_allocation(speeds: Sequence[float], steps: Sequence[int],
                       p_total: int, granularity: int = 1,
                       min_patch: Optional[int] = None) -> List[int]:
    """Eq. (5): P_i ∝ v_i / M_i, integerized to multiples of ``granularity``
    by largest-remainder rounding; excluded devices (M_i == 0) get 0.

    The paper's "hardware/operator constraints (e.g. power-of-two
    dimensions)" are honored through ``granularity`` (we allocate in slabs).
    """
    if p_total % granularity:
        raise ValueError("p_total must be a multiple of granularity")
    min_patch = granularity if min_patch is None else min_patch
    rate = [ (v / m) if m else 0.0 for v, m in zip(speeds, steps) ]
    total_rate = sum(rate)
    if total_rate <= 0:
        raise ValueError("no active devices")
    slots = p_total // granularity
    ideal = [r / total_rate * slots for r in rate]
    base = [int(math.floor(x)) for x in ideal]
    # every active device gets at least min_patch worth of slots
    min_slots = max(1, min_patch // granularity)
    n_active = sum(1 for r in rate if r > 0)
    if slots < n_active * min_slots:
        raise ValueError(
            f"p_total={p_total} cannot give {n_active} active devices "
            f"min_patch={min_patch} at granularity={granularity}")
    for i, r in enumerate(rate):
        if r > 0:
            base[i] = max(base[i], min_slots)
    rem = slots - sum(base)
    order = sorted(range(len(ideal)), key=lambda i: ideal[i] - base[i], reverse=True)
    for i in order:
        if rem <= 0:
            break
        if rate[i] > 0:
            base[i] += 1
            rem -= 1
    # lifting to min_slots may have overshot: take granules back from the
    # devices furthest above their ideal share, never dropping below min_slots
    while rem < 0:
        j = max((j for j in range(len(base)) if rate[j] > 0 and base[j] > min_slots),
                key=lambda j: base[j] - ideal[j])
        base[j] -= 1
        rem += 1
    assert sum(base) == slots, (base, slots)
    return [b * granularity for b in base]


def patch_bounds(patch_sizes: Sequence[int]) -> List[tuple]:
    """Cumulative [start, end) row ranges per device (0-size for excluded)."""
    out, start = [], 0
    for p in patch_sizes:
        out.append((start, start + p))
        start += p
    return out


def makespan_optimal_allocation(speeds: Sequence[float], m_base: int, m_warmup: int,
                                p_total: int, granularity: int = 1,
                                tiers: Sequence[int] = (1, 2, 4),
                                b: float = 0.25,
                                fixed_overhead: float = 0.05):
    """Beyond-paper: exhaustive-over-tiers allocator minimizing the modeled
    makespan  max_i r_i_interval  where a device with ratio r contributes
    r * (fixed + P_i/v_i-normalized work) per LCM interval. Searches every
    tier assignment (N small), then mends patches by Eq. 5. Returns
    (TemporalPlan, patches, modeled_interval_cost).
    """
    import itertools
    N = len(speeds)
    vmax = max(speeds)
    i_fast = max(range(N), key=lambda j: speeds[j])
    active = [v > b * vmax for v in speeds]
    F = m_base - m_warmup
    tiers = [t for t in tiers if F % t == 0]
    best = None
    for assign in itertools.product(range(len(tiers)), repeat=N):
        ratios = [tiers[k] if act else 0 for k, act in zip(assign, active)]
        if ratios[i_fast] != 1:
            continue            # quality anchor: fastest device keeps M_base
                                # steps (same invariant as the paper's Eq. 4)
        if not any(ratios):
            continue
        steps = [m_warmup + F // r if r else 0 for r in ratios]
        patches = spatial_allocation(speeds, steps, p_total, granularity)
        # per fine-step interval of the fastest tier, device i runs 1/r_i of
        # a step; interval cost normalized per fine step:
        cost = 0.0
        for v, r, p in zip(speeds, ratios, patches):
            if r:
                cost = max(cost, (fixed_overhead + p / p_total) / v / r)
        if best is None or cost < best[2]:
            plan = TemporalPlan(steps, ratios, [not a for a in active], m_base, m_warmup)
            best = (plan, patches, cost)
    return best
