"""Pytree checkpointing: npz arrays + json treedef (no external deps).

Layout:  <dir>/step_<N>/arrays.npz + tree.json ; atomic via tmp+rename.
Handles nested dicts/lists/tuples of jnp/np arrays and scalars.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    leaves, treedef = _flatten(tree)
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays = {f"a{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "tree.json"), "w") as f:
        json.dump({"treedef": str(treedef), "n": len(leaves), "step": step}, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def restore_checkpoint(ckpt_dir: str, like: Any, step: Optional[int] = None) -> Any:
    """Restore into the structure of ``like`` (treedef source of truth)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(like)
    if len(leaves) != len(data.files):
        raise ValueError(f"checkpoint has {len(data.files)} leaves, "
                         f"expected {len(leaves)}")
    new_leaves = [data[f"a{i}"] for i in range(len(leaves))]
    for old, new in zip(leaves, new_leaves):
        if tuple(np.shape(old)) != tuple(new.shape):
            raise ValueError(f"shape mismatch {np.shape(old)} vs {new.shape}")
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None
