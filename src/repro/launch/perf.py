import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""§Perf hillclimbing: lower one (arch x shape) under named optimization
variants, re-derive the roofline terms, and log hypothesis -> before ->
after (EXPERIMENTS.md §Perf reads results/perf/*.json).

Variants (composable, comma-separated):
  chunked     attn_impl=chunked — flash-style online softmax; kills the
              materialized S x T score matrices (memory term)
  seqpar      shard the sequence dim of batch inputs over 'model'
              (sequence parallelism for prefill — the paper's patch
              parallelism mapped onto an LM request)
  embed_dp    embedding/vocab tables sharded vocab x 'model' -> d_model-only
              ('data') — trades the decode all-gather of logits for
              replicated vocab weights
  remat       jax.checkpoint over the layer body (memory term, train)

  PYTHONPATH=src python -m repro.launch.perf --arch llama3-405b \
      --shape train_4k --variants chunked
"""

import argparse
import json
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "perf")


def run_variant(arch: str, shape_name: str, variants: str,
                multi_pod: bool = False) -> dict:
    import jax

    from repro.configs import get_config
    from repro.launch import roofline as rl
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import SHAPES, build_lowerable, _dryrun_cfg
    from repro.sharding import specs as sh

    vset = set(v for v in variants.split(",") if v)
    cfg = _dryrun_cfg(arch)
    if "chunked" in vset:
        cfg = cfg.replace(attn_impl="chunked", attn_chunk=2048)
    if "actbatch" in vset:
        cfg = cfg.replace(act_shard="batch")
    if "actseq" in vset:
        cfg = cfg.replace(act_shard="seqpar")
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"

    old_rules = dict(sh._RULES)
    old_cache = sh.cache_specs
    if "embed_dp" in vset:
        sh._RULES["embed"] = (None, "data")
        sh._RULES["head"] = ("data", None)
    if "cache_nosplit" in vset:
        # kv caches: batch-sharded only (no T-over-model fallback that makes
        # GSPMD emit grouped partial-sum all-reduces on the kv path)
        from jax.sharding import PartitionSpec as P

        def cache_specs_nosplit(cache, mesh_):
            import numpy as np
            ba = sh.batch_axes(mesh_)

            def spec(leaf):
                shape = np.shape(leaf)
                if len(shape) == 5:
                    b_ax = ba if sh._div(shape[1], mesh_, ba) else None
                    return P(None, b_ax, None, None, None)
                if len(shape) == 0:
                    return P()
                return P(*([None] * len(shape)))
            import jax as _jax
            return _jax.tree.map(spec, cache)

        sh.cache_specs = cache_specs_nosplit

    fn, args, shardings = build_lowerable(arch, shape_name, cfg=cfg)
    in_sh = shardings(mesh)

    if "seqpar" in vset:
        # re-spec batch leaves: dim1 (sequence) over 'model'
        from jax.sharding import NamedSharding, PartitionSpec as P

        def reseq(ns):
            spec = ns.spec
            if len(spec) >= 2 and spec[1] is None:
                parts = list(spec)
                parts[1] = "model"
                return NamedSharding(mesh, P(*parts))
            return ns
        # batch structs are the last element for train/prefill
        idx = 2 if SHAPES[shape_name].kind == "train" else 1
        lst = list(in_sh)
        lst[idx] = jax.tree.map(reseq, lst[idx])
        in_sh = tuple(lst)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        compiled = lowered.compile()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):      # jax 0.4.x returns [dict]
            cost = cost[0] if cost else {}
        coll = rl.collective_bytes(compiled.as_text())
        mem = compiled.memory_analysis()
    sh._RULES.clear()
    sh._RULES.update(old_rules)
    sh.cache_specs = old_cache

    roof = rl.build(arch, shape_name, mesh_name, mesh.devices.size, cost,
                    coll, flash="chunked" in vset)
    report = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "variants": sorted(vset) or ["baseline"],
        "compile_s": round(time.time() - t0, 1),
        "temp_bytes_per_dev": getattr(mem, "temp_size_in_bytes", None),
        "collective_bytes": {k: v for k, v in coll.items() if k != "_counts"},
        "roofline": roof.to_dict(),
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    tag = "-".join(sorted(vset)) or "baseline"
    out = os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{tag}.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    r = roof
    print(f"[{arch} x {shape_name} | {tag}] compute={r.compute_s:.4g}s "
          f"memory={r.memory_s:.4g}s collective={r.collective_s:.4g}s "
          f"dom={r.dominant} temp={report['temp_bytes_per_dev']/1e9:.1f}GB "
          f"(compile {report['compile_s']}s)", flush=True)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    run_variant(args.arch, args.shape, args.variants, args.multi_pod)


if __name__ == "__main__":
    main()
