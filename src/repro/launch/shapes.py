"""Assigned input shapes + ``input_specs``: ShapeDtypeStruct stand-ins for
every model input (weak-type-correct, shardable, no device allocation), and
the step functions the dry-run lowers for each shape kind.

  train_4k     seq=  4,096 batch=256  -> train_step (loss+grads+AdamW)
  prefill_32k  seq= 32,768 batch= 32  -> prefill (full forward + cache build)
  decode_32k   seq= 32,768 batch=128  -> serve_step: ONE token, KV len 32,768
  long_500k    seq=524,288 batch=  1  -> serve_step with sub-quadratic attn
                                         (SSM state / sliding window 4,096)

Shape-applicability carve-outs are in DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import encdec
from repro.models.api import Model, build_model
from repro.optim import adamw
from repro.sharding import specs as sh


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

_I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dryrun_cfg(arch: str):
    """bf16 everywhere for roofline consistency with the 197 TF bf16 peak."""
    return get_config(arch).replace(param_dtype="bfloat16", dtype="bfloat16")


def batch_structs(cfg, model: Model, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.batch, shape.seq
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "encdec":
        St = encdec.tgt_len_for(S)
        return {"src_embeds": _sds((B, S, cfg.d_model), dt),
                "tgt_tokens": _sds((B, St), _I32),
                "labels": _sds((B, St), _I32)}
    if cfg.family == "vlm":
        text = S - cfg.n_vision_tokens
        return {"tokens": _sds((B, text), _I32),
                "labels": _sds((B, text), _I32),
                "vision_embeds": _sds((B, cfg.n_vision_tokens, cfg.d_model), dt)}
    return {"tokens": _sds((B, S), _I32), "labels": _sds((B, S), _I32)}


def decode_window(cfg, shape: ShapeSpec) -> int:
    """Sub-quadratic carve-out: long_500k uses a sliding window on attention
    archs (cfg.long_context_window); natively-windowed archs keep their own."""
    if cfg.sliding_window:
        return cfg.sliding_window
    if shape.name == "long_500k":
        return cfg.long_context_window
    return 0


def build_lowerable(arch: str, shape_name: str, cfg=None, shape=None
                    ) -> Tuple[Callable, Tuple[Any, ...], Callable]:
    """Returns (fn, args_structs, shardings_builder(mesh) -> in_shardings).

    cfg/shape overrides support launch/perf.py variant runs (e.g.
    attn_impl/act_shard overrides) and ad-hoc reduced-size probes."""
    cfg = cfg or _dryrun_cfg(arch)
    model = build_model(cfg)
    shape = shape or SHAPES[shape_name]
    opt_cfg = adamw.AdamWConfig()

    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    if shape.kind == "train":
        batch_s = batch_structs(cfg, model, shape)
        opt_s = jax.eval_shape(adamw.adamw_init, params_s)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            params, opt_state = adamw.adamw_update(params, grads, opt_state, opt_cfg)
            return params, opt_state, loss

        def shardings(mesh):
            ps = sh.param_specs(params_s, mesh, cfg)
            os_ = {"mu": ps, "nu": ps, "count": jax.sharding.PartitionSpec()}
            bs = sh.batch_specs(batch_s, mesh)
            return jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s),
                                (ps, os_, bs),
                                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

        return train_step, (params_s, opt_s, batch_s), shardings

    if shape.kind == "prefill":
        batch_s = batch_structs(cfg, model, shape)
        window = cfg.sliding_window
        kw = dict(window=window)
        if cfg.family == "encdec":
            cache_s = jax.eval_shape(
                lambda: model.init_cache(shape.batch, encdec.tgt_len_for(shape.seq),
                                         src_len=shape.seq))
        else:
            prefill_len = shape.seq + (cfg.n_vision_tokens if cfg.family == "vlm" else 0)
            cache_s = jax.eval_shape(
                lambda: model.init_cache(shape.batch, prefill_len, window=window))

        def prefill_fn(params, batch, cache):
            return model.prefill(params, batch, cache, **kw)

        def shardings(mesh):
            ps = sh.param_specs(params_s, mesh, cfg)
            bs = sh.batch_specs(batch_s, mesh)
            cs = sh.cache_specs(cache_s, mesh)
            return jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s),
                                (ps, bs, cs),
                                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

        return prefill_fn, (params_s, batch_s, cache_s), shardings

    # decode kinds
    window = decode_window(cfg, shape)
    if cfg.family == "encdec":
        # cached encoder memory over the full source + windowed self-attn
        cache_s = jax.eval_shape(
            lambda: model.init_cache(shape.batch, shape.seq, window=window,
                                     src_len=shape.seq))
    else:
        cache_s = jax.eval_shape(
            lambda: model.init_cache(shape.batch, shape.seq, window=window))
    # caches start mid-stream: pos = seq - 1 (cache holds seq_len context)
    token_s = _sds((shape.batch,), _I32)

    def decode_fn(params, cache, token):
        return model.decode_step(params, cache, token, window=window)

    def shardings(mesh):
        ps = sh.param_specs(params_s, mesh, cfg)
        cs = sh.cache_specs(cache_s, mesh)
        ba = sh.batch_axes(mesh)
        tok_spec = sh.batch_specs(token_s, mesh)
        return jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s),
                            (ps, cs, tok_spec),
                            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    return decode_fn, (params_s, cache_s, token_s), shardings
