"""Serving driver: batched LLM requests through the ServingEngine, or
batched diffusion generation requests through :class:`StadiPipeline`.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --requests 8
  PYTHONPATH=src python -m repro.launch.serve --diffusion --arch tiny-dit \
      --occupancies 0.0,0.6 --requests 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServingEngine


def serve(arch: str, *, n_requests: int = 8, slots: int = 4,
          prompt_len: int = 16, max_new: int = 12, reduced: bool = True,
          window: int = 0, seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    engine = ServingEngine(model, params, slots=slots,
                           max_len=prompt_len + max_new + 8,
                           window=window or cfg.sliding_window)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    for uid in range(n_requests):
        prompt = rng.integers(0, cfg.vocab, prompt_len).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt, max_new_tokens=max_new))
    done = engine.run_to_completion()
    dt = time.time() - t0
    tok = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)}/{n_requests} requests, {tok} tokens in "
          f"{dt:.2f}s ({tok/dt:.1f} tok/s)")
    return done


def serve_diffusion(arch: str = "tiny-dit", *, occupancies=(0.0, 0.6),
                    n_requests: int = 4, batch: int = 2, m_base: int = 16,
                    m_warmup: int = 4, planner: str = "stadi",
                    backend: str = "emulated", reduced: bool = True,
                    seed: int = 0):
    """Micro-batched class-conditional generation on a heterogeneous cluster:
    every micro-batch is one ``StadiPipeline.generate`` call."""
    import jax.numpy as jnp

    from repro.core import sampler as sampler_lib
    from repro.core.pipeline import StadiConfig, StadiPipeline
    from repro.models.diffusion import dit

    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    params = dit.init_params(jax.random.PRNGKey(seed), cfg)
    sched = sampler_lib.linear_schedule(T=1000)
    config = StadiConfig.from_occupancies(list(occupancies), m_base=m_base,
                                          m_warmup=m_warmup, planner=planner,
                                          backend=backend)
    pipe = StadiPipeline(cfg, params, sched, config)
    rng = np.random.default_rng(seed)
    done, t0 = [], time.time()
    for lo in range(0, n_requests, batch):
        n = min(batch, n_requests - lo)
        x_T = jax.random.normal(jax.random.PRNGKey(seed + 1 + lo),
                                (n, cfg.latent_size, cfg.latent_size,
                                 cfg.channels))
        cond = jnp.asarray(rng.integers(0, cfg.n_classes, n))
        res = pipe.generate(x_T, cond)
        assert np.all(np.isfinite(np.asarray(res.image)))
        done.append(res)
    dt = time.time() - t0
    print(f"served {n_requests} generation requests in {dt:.2f}s "
          f"({n_requests/dt:.2f} img/s) planner={planner} backend={backend} "
          f"patches={done[0].plan.patches}")
    return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--diffusion", action="store_true",
                    help="serve diffusion requests via StadiPipeline")
    ap.add_argument("--occupancies", default="0.0,0.6")
    ap.add_argument("--planner", default="stadi")
    ap.add_argument("--backend", default="emulated",
                    choices=["emulated", "spmd"])   # serving needs images
    args = ap.parse_args()
    if args.diffusion:
        if args.arch == ap.get_default("arch"):
            args.arch = "tiny-dit"       # LLM default doesn't apply here
        elif "dit" not in args.arch:
            ap.error(f"--diffusion serves DiT archs, not {args.arch!r}")
        serve_diffusion(args.arch,
                        occupancies=[float(x) for x in
                                     args.occupancies.split(",")],
                        n_requests=args.requests, planner=args.planner,
                        backend=args.backend)
    else:
        serve(args.arch, n_requests=args.requests, slots=args.slots,
              prompt_len=args.prompt_len, max_new=args.max_new)


if __name__ == "__main__":
    main()
