"""Serving driver: batched requests through the ServingEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServingEngine


def serve(arch: str, *, n_requests: int = 8, slots: int = 4,
          prompt_len: int = 16, max_new: int = 12, reduced: bool = True,
          window: int = 0, seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    engine = ServingEngine(model, params, slots=slots,
                           max_len=prompt_len + max_new + 8,
                           window=window or cfg.sliding_window)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    for uid in range(n_requests):
        prompt = rng.integers(0, cfg.vocab, prompt_len).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt, max_new_tokens=max_new))
    done = engine.run_to_completion()
    dt = time.time() - t0
    tok = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)}/{n_requests} requests, {tok} tokens in "
          f"{dt:.2f}s ({tok/dt:.1f} tok/s)")
    return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()
    serve(args.arch, n_requests=args.requests, slots=args.slots,
          prompt_len=args.prompt_len, max_new=args.max_new)


if __name__ == "__main__":
    main()
