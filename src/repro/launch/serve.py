"""Serving driver: batched LLM requests through the ServingEngine, or a
diffusion request queue through the continuous-batching
:class:`~repro.serving.diffusion_engine.DiffusionServingEngine`.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --requests 8
  PYTHONPATH=src python -m repro.launch.serve --diffusion --arch tiny-dit \
      --occupancies 0.0,0.6 --requests 8 --slots 4 --slo-ms 200
  STADI_HOST_DEVICES=2 PYTHONPATH=src python -m repro.launch.serve \
      --diffusion --backend spmd --requests 4
"""
from __future__ import annotations

from repro.hostenv import force_host_devices
force_host_devices()                        # --backend spmd on CPU hosts

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServingEngine


def serve(arch: str, *, n_requests: int = 8, slots: int = 4,
          prompt_len: int = 16, max_new: int = 12, reduced: bool = True,
          window: int = 0, seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    engine = ServingEngine(model, params, slots=slots,
                           max_len=prompt_len + max_new + 8,
                           window=window or cfg.sliding_window)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    for uid in range(n_requests):
        prompt = rng.integers(0, cfg.vocab, prompt_len).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt, max_new_tokens=max_new))
    done = engine.run_to_completion()
    dt = time.time() - t0
    tok = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)}/{n_requests} requests, {tok} tokens in "
          f"{dt:.2f}s ({tok/dt:.1f} tok/s)")
    return done


def serve_diffusion(arch: str = "tiny-dit", *, occupancies=(0.0, 0.6),
                    n_requests: int = 4, slots: int = 4, m_base: int = 16,
                    m_warmup: int = 4, planner: str = "stadi",
                    backend: str = "emulated", reduced: bool = True,
                    slo_s: float = None, seed: int = 0,
                    exchange: str = "sync", exchange_refresh: int = 2,
                    num_stages: int = 1, cfg_scale: float = 0.0,
                    seq_shards: int = 1, num_frames: int = 1,
                    frame_groups: int = 0, plan_cache_dir: str = None,
                    prompt: str = None, cond_tokens: int = None,
                    cond_seq_len: int = 32):
    """Continuous batching on a heterogeneous cluster: requests enter a FIFO
    queue, the :class:`DiffusionServingEngine` admits them into ``slots``
    concurrent lanes and drains the queue with batched denoise rounds.
    ``cfg_scale > 0`` makes every other request a classifier-free-guidance
    one (DESIGN.md §12) — the mixed CFG / non-CFG workload the engine's
    per-lane guidance state exists for."""
    from repro.core import sampler as sampler_lib
    from repro.core.pipeline import StadiConfig, StadiPipeline
    from repro.models.diffusion import dit
    from repro.serving import DiffusionServingEngine

    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    text_mode = prompt is not None or cond_tokens is not None
    if text_mode:                          # prompt lanes (DESIGN.md §17)
        cfg = cfg.text_conditioned(cond_seq_len=cond_seq_len)
    params = dit.init_params(jax.random.PRNGKey(seed), cfg)
    sched = sampler_lib.linear_schedule(T=1000)
    config = StadiConfig.from_occupancies(list(occupancies), m_base=m_base,
                                          m_warmup=m_warmup, planner=planner,
                                          backend=backend, exchange=exchange,
                                          exchange_refresh=exchange_refresh,
                                          num_stages=num_stages,
                                          seq_shards=seq_shards,
                                          num_frames=num_frames,
                                          frame_groups=frame_groups,
                                          plan_cache_dir=plan_cache_dir)
    pipe = StadiPipeline(cfg, params, sched, config)
    engine = DiffusionServingEngine(pipe, slots=slots)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    n_guided = 0
    shape = (1, cfg.latent_size, cfg.latent_size, cfg.channels)
    if num_frames > 1:                     # video lanes: one clip per request
        shape = shape[:1] + (num_frames,) + shape[1:]
    for uid in range(n_requests):
        x_T = jax.random.normal(jax.random.PRNGKey(seed + 1 + uid), shape)
        scale = cfg_scale if (cfg_scale > 0 and uid % 2 == 0) else None
        n_guided += scale is not None
        if prompt is not None:
            from repro.models import text_encoder
            cond = text_encoder.encode([f"{prompt} #{uid}"], cfg)[0]
        elif cond_tokens is not None:
            # vary the token count per request so the engine's
            # length-bucketed lane groups actually get exercised
            import jax.numpy as jnp
            from repro.models import text_encoder
            n_tok = 1 + (uid % cond_tokens)
            L = text_encoder.bucket_length(n_tok, cfg.cond_seq_len)
            feats = jax.random.normal(jax.random.PRNGKey(seed + 7 + uid),
                                      (L, cfg.cond_dim))
            mask = (jnp.arange(L) < n_tok).astype(jnp.float32)[:, None]
            cond = jnp.concatenate([feats * mask, mask], axis=-1)
        else:
            cond = int(rng.integers(0, cfg.n_classes))
        engine.submit(x_T, cond, slo_s=slo_s, cfg_scale=scale)
    done = engine.run_to_completion()
    dt = time.time() - t0
    for req in done:
        assert np.all(np.isfinite(np.asarray(req.image)))
    stats = engine.stats()
    note = ("" if stats["cost_model"] == "configured"
            else " [default-uncalibrated cost model]")
    print(f"served {stats['n_completed']}/{n_requests} generation requests "
          f"({n_guided} CFG) in {dt:.2f}s ({stats['n_completed']/dt:.2f} "
          f"img/s wall, {stats['throughput_modeled_rps']:.2f} img/s "
          f"modeled{note}) planner={planner} backend={backend} "
          f"slots={slots} rounds={stats['rounds']} "
          f"patches={engine.plan.patches} stages={engine.stages} "
          f"seq={engine.seq} frames={engine.frames}")
    if stats["plan_cache"] is not None:
        c = stats["plan_cache"]
        print(f"  plan cache: {c['hits']} hits / {c['misses']} misses "
              f"(hit rate {c['hit_rate']:.0%}), "
              f"{c['invalidations']} invalidated — a warm cache skips "
              "planner search on restart")
    for r in stats["requests"]:
        slo = "" if r["slo_met"] is None else f" slo_met={r['slo_met']}"
        print(f"  req {r['uid']}: queued {r['queue_rounds']} rounds, "
              f"served {r['service_rounds']} rounds, modeled latency "
              f"{r['modeled_latency_s']*1e3:.1f} ms{slo}")
    return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--diffusion", action="store_true",
                    help="serve diffusion requests via StadiPipeline")
    ap.add_argument("--occupancies", default="0.0,0.6")
    ap.add_argument("--planner", default="stadi",
                    help="allocation planner (diffusion only): uniform / "
                         "spatial / temporal / stadi / makespan / "
                         "stadi_pipefuse (joint step+patch+stage search)")
    ap.add_argument("--backend", default="emulated",
                    choices=["emulated", "spmd", "pipefuse"],
                    help="serving needs images; 'pipefuse' runs the "
                         "displaced patch pipeline (DESIGN.md §11) — the "
                         "engine places stage chains instead of "
                         "whole-model workers")
    ap.add_argument("--m-base", type=int, default=16)
    ap.add_argument("--m-warmup", type=int, default=4)
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="per-request modeled-latency SLO (diffusion only)")
    ap.add_argument("--exchange", default="sync",
                    choices=["sync", "stale_async", "predictive", "ring"],
                    help="boundary-exchange policy (diffusion only, "
                         "DESIGN.md §10; 'ring' = per-hop-staged seq-"
                         "parallel variant, DESIGN.md §13)")
    ap.add_argument("--exchange-refresh", type=int, default=2,
                    help="full refresh every E boundaries (stale/predictive)")
    ap.add_argument("--num-stages", type=int, default=1,
                    help="depth stages for --backend pipefuse (diffusion "
                         "only, DESIGN.md §11): DiT blocks are split over a "
                         "speed-proportional stage chain; 1 = pure patch "
                         "parallelism, 0 = let stadi_pipefuse search")
    ap.add_argument("--cfg-scale", type=float, default=0.0,
                    help="classifier-free guidance weight (diffusion only, "
                         "DESIGN.md §12): > 0 submits every other request "
                         "as a CFG request — a mixed guided/unguided batch")
    ap.add_argument("--plan-cache", default=None, metavar="DIR",
                    help="persistent plan-cache directory (diffusion only, "
                         "DESIGN.md §14): planner outputs are keyed by "
                         "(cluster, model, workload) and reused across "
                         "restarts; e.g. results/plan_cache")
    ap.add_argument("--seq-shards", type=int, default=1,
                    help="sequence-parallel attention (diffusion only, "
                         "DESIGN.md §13): Ulysses/ring shards per patch "
                         "worker; lanes batch by ring-hop identity (1 = "
                         "attention-unsharded, 0 = let stadi_seq search)")
    ap.add_argument("--num-frames", type=int, default=1,
                    help="video serving lanes (diffusion only, DESIGN.md "
                         "§16): latent frames per request (1 = image; > 1 "
                         "serves one clip per request, run-to-completion "
                         "in its admission round)")
    ap.add_argument("--frame-groups", type=int, default=0,
                    help="frame placement (diffusion only): 1 = frame-"
                         "sequential, > 1 = frame-parallel member rows "
                         "(needs --planner stadi_video), 0 = auto search")
    cond_group = ap.add_mutually_exclusive_group()
    cond_group.add_argument("--prompt", default=None,
                            help="text prompt (diffusion only, DESIGN.md "
                                 "§17): the model is built text-conditioned "
                                 "and every request carries encoded prompt "
                                 "tokens (suffixed per uid for variety)")
    cond_group.add_argument("--cond-tokens", type=int, default=None,
                            metavar="L",
                            help="prompt lanes with up to L random-normal "
                                 "conditioning tokens per request (lengths "
                                 "vary per uid to exercise the engine's "
                                 "length-bucketed lane groups)")
    ap.add_argument("--cond-seq-len", type=int, default=32,
                    help="text-conditioned models: max prompt bucket "
                         "(DiTConfig.cond_seq_len)")
    args = ap.parse_args()
    if args.diffusion:
        if args.arch == ap.get_default("arch"):
            args.arch = "tiny-dit"       # LLM default doesn't apply here
        elif "dit" not in args.arch:
            ap.error(f"--diffusion serves DiT archs, not {args.arch!r}")
        serve_diffusion(args.arch,
                        occupancies=[float(x) for x in
                                     args.occupancies.split(",")],
                        n_requests=args.requests, slots=args.slots,
                        m_base=args.m_base, m_warmup=args.m_warmup,
                        planner=args.planner, backend=args.backend,
                        slo_s=(args.slo_ms / 1e3
                               if args.slo_ms is not None else None),
                        exchange=args.exchange,
                        exchange_refresh=args.exchange_refresh,
                        num_stages=args.num_stages,
                        cfg_scale=args.cfg_scale,
                        seq_shards=args.seq_shards,
                        num_frames=args.num_frames,
                        frame_groups=args.frame_groups,
                        plan_cache_dir=args.plan_cache,
                        prompt=args.prompt, cond_tokens=args.cond_tokens,
                        cond_seq_len=args.cond_seq_len)
    else:
        if args.prompt is not None or args.cond_tokens is not None:
            ap.error("--prompt/--cond-tokens are diffusion-only "
                     "(use --diffusion)")
        serve(args.arch, n_requests=args.requests, slots=args.slots,
              prompt_len=args.prompt_len, max_new=args.max_new)


if __name__ == "__main__":
    main()
