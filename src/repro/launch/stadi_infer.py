import os
if os.environ.get("STADI_HOST_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={os.environ['STADI_HOST_DEVICES']} "
        + os.environ.get("XLA_FLAGS", ""))

"""STADI inference driver — the paper's system (launchable).

Two execution modes:
  emulated (default): exact-numerics logical-worker engine + calibrated
      latency simulator (core/patch_parallel.py + core/simulate.py).
  --spmd: REAL distributed execution via shard_map over the available
      devices (set STADI_HOST_DEVICES=8 for CPU host devices). Every device
      owns one (padded) row-slab; uneven all-gathers use core/comm.py; the
      mixed-rate schedule runs in SPMD lockstep with per-device activity
      masks (a no-op substep costs what it costs on the slow device — the
      TPU analogue of the paper's per-GPU step skipping).

Usage:
  STADI_HOST_DEVICES=4 PYTHONPATH=src python -m repro.launch.stadi_infer \
      --spmd --occupancies 0.0,0.5 --m-base 16 --m-warmup 4
"""

import argparse
import json
import time

import numpy as np


def run_spmd(params, cfg, sched, x_T, cond, plan, patches):
    """shard_map STADI across jax.devices(). Returns final image [B,H,W,C]."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import sampler as sampler_lib
    from repro.models.diffusion import dit

    devices = jax.devices()
    N = len(patches)
    assert N <= len(devices), (N, len(devices))
    mesh = Mesh(np.asarray(devices[:N]), ("dev",))

    p = cfg.patch_size
    wp = cfg.tokens_per_side
    Pmax = max(patches)
    Nl_max = Pmax * wp
    n_tok = cfg.n_tokens
    row_starts = np.concatenate([[0], np.cumsum(patches)[:-1]]).astype(np.int32)
    rows_arr = jnp.asarray(patches, jnp.int32)
    starts_arr = jnp.asarray(row_starts, jnp.int32)
    ratios = [r if r else 1 for r in plan.ratios]
    ratios_arr = jnp.asarray(ratios, jnp.int32)
    ts = sampler_lib.ddim_timesteps(sched.T, plan.m_base)
    M_w, R = plan.m_warmup, plan.lcm
    F = plan.m_base - M_w

    def body(params, x_full, cond):
        idx = jax.lax.axis_index("dev")
        my_rows = rows_arr[idx]
        my_start = starts_arr[idx]
        my_ratio = ratios_arr[idx]
        my_tok = my_rows * wp

        # ---- warmup: synchronous == full-image forward on every device ----
        pub_k = pub_v = None
        for m in range(M_w):
            eps, kvs = dit.forward_patch(params, cfg, x_full, ts[m], cond, 0,
                                         buffers=None, return_kv=True)
            x_full = sampler_lib.ddim_step(sched, x_full, eps, ts[m], ts[m + 1])
            pub_k, pub_v = kvs
        pad = [(0, 0), (0, 0), (0, Nl_max), (0, 0), (0, 0)]
        pub_k = jnp.pad(pub_k, pad)               # scratch-padded buffers
        pub_v = jnp.pad(pub_v, pad)

        # pad x so every device can slice a Pmax slab
        x_pad = jnp.pad(x_full, ((0, 0), (0, Pmax * p), (0, 0), (0, 0)))
        my_slab = jax.lax.dynamic_slice_in_dim(x_pad, my_start * p, Pmax * p, axis=1)

        for it in range(F // R):
            m0 = M_w + it * R
            fresh_k = fresh_v = None
            for s in range(R):
                active = (s % my_ratio) == 0
                t_from = ts[m0 + s]
                t_to = ts[jnp.minimum(m0 + s + my_ratio, plan.m_base)]
                eps, kvs = dit.forward_patch(
                    params, cfg, my_slab, t_from, cond, my_start,
                    buffers=(pub_k, pub_v), return_kv=True,
                    valid_tokens=my_tok)
                stepped = sampler_lib.ddim_step(sched, my_slab, eps, t_from, t_to)
                my_slab = jnp.where(active, stepped, my_slab)
                if s == 0:                        # Alg.1: publish first substep
                    fresh_k, fresh_v = kvs
            # ---- interval boundary: uneven all-gathers (padded strategy) ----
            slabs = jax.lax.all_gather(my_slab, "dev")        # [N,B,Pmax*p,W,C]
            gk = jax.lax.all_gather(fresh_k, "dev")           # [N,L,B,Nl_max,H,hd]
            gv = jax.lax.all_gather(fresh_v, "dev")
            parts = [slabs[i, :, :patches[i] * p] for i in range(N) if patches[i]]
            x_full = jnp.concatenate(parts, axis=1)
            x_pad = jnp.pad(x_full, ((0, 0), (0, Pmax * p), (0, 0), (0, 0)))
            my_slab = jax.lax.dynamic_slice_in_dim(x_pad, my_start * p, Pmax * p, axis=1)
            for i in range(N):                     # static merge, valid prefixes
                sz = patches[i] * wp
                if sz == 0:
                    continue
                st = int(row_starts[i]) * wp
                pub_k = jax.lax.dynamic_update_slice_in_dim(
                    pub_k, gk[i, :, :, :sz], st, axis=2)
                pub_v = jax.lax.dynamic_update_slice_in_dim(
                    pub_v, gv[i, :, :, :sz], st, axis=2)
        return x_full

    fn = jax.shard_map(body, mesh=mesh,
                       in_specs=(P(), P(), P()), out_specs=P(),
                       check_vma=False)
    return jax.jit(fn)(params, x_T, cond)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--occupancies", default="0.0,0.6")
    ap.add_argument("--capabilities", default=None)
    ap.add_argument("--m-base", type=int, default=16)
    ap.add_argument("--m-warmup", type=int, default=4)
    ap.add_argument("--a", type=float, default=0.75)
    ap.add_argument("--b", type=float, default=0.25)
    ap.add_argument("--arch", default="tiny-dit")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--spmd", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check-vs-emulation", action="store_true")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import hetero, sampler as sampler_lib, schedule as sched_lib
    from repro.core import patch_parallel as pp
    from repro.core import stadi as stadi_lib
    from repro.models.diffusion import dit

    occ = [float(x) for x in args.occupancies.split(",")]
    caps = ([float(x) for x in args.capabilities.split(",")]
            if args.capabilities else None)
    cluster = hetero.make_cluster(occ, caps)
    speeds = hetero.speeds(cluster)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = dit.init_params(jax.random.PRNGKey(args.seed), cfg)
    sched = sampler_lib.linear_schedule(T=1000)
    x_T = jax.random.normal(jax.random.PRNGKey(args.seed + 1),
                            (args.batch, cfg.latent_size, cfg.latent_size,
                             cfg.channels))
    cond = jnp.zeros((args.batch,), jnp.int32)

    plan = sched_lib.temporal_allocation(speeds, args.m_base, args.m_warmup,
                                         args.a, args.b)
    patches = sched_lib.spatial_allocation(speeds, plan.steps,
                                           cfg.tokens_per_side)
    print(f"speeds={speeds} steps={plan.steps} ratios={plan.ratios} "
          f"patches={patches}")

    if args.spmd:
        t0 = time.time()
        img = run_spmd(params, cfg, sched, x_T, cond, plan, patches)
        img = np.asarray(img)
        print(f"spmd run ({len(jax.devices())} devices): {time.time()-t0:.2f}s "
              f"image {img.shape} finite={np.all(np.isfinite(img))}")
        if args.check_vs_emulation:
            res = pp.run_schedule(params, cfg, sched, x_T, cond, plan, patches)
            ref = np.asarray(res.image)
            err = float(np.linalg.norm(img - ref) / np.linalg.norm(ref))
            print(f"rel_err_vs_emulation={err:.3e}")
            assert err < 1e-3, err
    else:
        res = stadi_lib.stadi_infer(params, cfg, sched, x_T, cond, speeds,
                                    args.m_base, args.m_warmup, args.a, args.b)
        img = np.asarray(res.image)
        print(f"emulated run: image {img.shape} finite={np.all(np.isfinite(img))}")
    print(json.dumps({"patches": patches, "steps": plan.steps,
                      "finite": bool(np.all(np.isfinite(img)))}))


if __name__ == "__main__":
    main()
