from repro.hostenv import force_host_devices
force_host_devices()

"""STADI inference driver — the paper's system (launchable).

Thin CLI over :class:`repro.core.pipeline.StadiPipeline`; strategy selection
is ``--planner`` (uniform / spatial / temporal / stadi / makespan) and
``--backend`` (emulated / spmd / simulate). ``--spmd`` is kept as an alias
for ``--backend spmd``:

  emulated (default): exact-numerics logical-worker engine + calibrated
      latency simulator (core/patch_parallel.py + core/simulate.py).
  spmd: REAL distributed execution via shard_map over the available devices
      (set STADI_HOST_DEVICES=8 for CPU host devices); see core/spmd.py.

Usage:
  STADI_HOST_DEVICES=4 PYTHONPATH=src python -m repro.launch.stadi_infer \
      --spmd --occupancies 0.0,0.5 --m-base 16 --m-warmup 4
"""

import argparse
import dataclasses
import json
import time


def run_spmd(params, cfg, sched, x_T, cond, plan, patches):
    """Deprecated location — moved to repro.core.spmd.run_spmd."""
    from repro.core.spmd import run_spmd as _run_spmd
    return _run_spmd(params, cfg, sched, x_T, cond, plan, patches)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--occupancies", default="0.0,0.6")
    ap.add_argument("--capabilities", default=None)
    ap.add_argument("--m-base", type=int, default=16)
    ap.add_argument("--m-warmup", type=int, default=4)
    ap.add_argument("--a", type=float, default=0.75)
    ap.add_argument("--b", type=float, default=0.25)
    ap.add_argument("--arch", default="tiny-dit")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--planner", default="stadi",
                    choices=["uniform", "spatial", "temporal", "stadi",
                             "makespan", "stadi_pipefuse", "stadi_guidance",
                             "stadi_seq", "stadi_video"])
    ap.add_argument("--backend", default="emulated",
                    choices=["emulated", "spmd", "simulate", "pipefuse",
                             "spmd_pipefuse", "spmd_guidance", "spmd_seq",
                             "spmd_frames"])
    ap.add_argument("--spmd", action="store_true",
                    help="alias for --backend spmd")
    ap.add_argument("--num-stages", type=int, default=1,
                    help="displaced patch pipeline (DESIGN.md §11): depth "
                         "stages for the pipefuse backends (1 = pure patch "
                         "parallelism, 0 = let stadi_pipefuse search)")
    ap.add_argument("--micro-patches", type=int, default=0,
                    help="micro-batches streaming through the stage chain "
                         "(0 = auto)")
    ap.add_argument("--cfg-scale", type=float, default=0.0,
                    help="classifier-free guidance weight w (DESIGN.md "
                         "§12): 0 = unguided; > 0 runs CFG "
                         "(eps_u + w*(eps_c - eps_u))")
    ap.add_argument("--guidance", default="none",
                    choices=["none", "fused", "split", "interleaved"],
                    help="CFG placement: fused-batch on every worker, "
                         "split cond/uncond device groups, or interleaved "
                         "uncond reuse; split/interleaved need "
                         "--planner stadi_guidance ('none' + --cfg-scale "
                         "lets stadi_guidance auto-search)")
    ap.add_argument("--uncond-refresh", type=int, default=2,
                    help="interleaved guidance: recompute the uncond "
                         "branch every E adaptive intervals")
    ap.add_argument("--seq-shards", type=int, default=1,
                    help="sequence-parallel attention (DESIGN.md §13): "
                         "Ulysses/ring shards per patch worker (1 = "
                         "attention-unsharded, 0 = let stadi_seq search; "
                         "spmd_seq needs seq_shards * workers host devices)")
    ap.add_argument("--num-frames", type=int, default=1,
                    help="video / multi-frame diffusion (DESIGN.md §16): "
                         "latent frames denoised jointly (1 = image; > 1 "
                         "needs a frame backend — emulated / simulate / "
                         "spmd_frames)")
    ap.add_argument("--frame-groups", type=int, default=0,
                    help="frame placement: 1 = frame-sequential, > 1 = "
                         "frame-parallel member rows (needs --planner "
                         "stadi_video; spmd_frames needs groups * workers "
                         "host devices), 0 = let stadi_video search")
    cond_group = ap.add_mutually_exclusive_group()
    cond_group.add_argument("--cond", type=int, default=None,
                            help="class id to condition on (default 0; "
                                 "mutually exclusive with --prompt / "
                                 "--cond-tokens)")
    cond_group.add_argument("--prompt", default=None,
                            help="text prompt (DESIGN.md §17): encodes "
                                 "through the frozen text encoder and runs "
                                 "the cross-attention path (the model is "
                                 "built text-conditioned)")
    cond_group.add_argument("--cond-tokens", type=int, default=None,
                            metavar="L",
                            help="run the prompt path with L random-normal "
                                 "conditioning tokens instead of an encoded "
                                 "prompt (planner/perf runs that don't care "
                                 "about the text)")
    ap.add_argument("--cond-seq-len", type=int, default=32,
                    help="text-conditioned models: the max prompt bucket "
                         "(DiTConfig.cond_seq_len)")
    ap.add_argument("--rebalance-every", type=int, default=0)
    ap.add_argument("--exchange", default="sync",
                    choices=["sync", "stale_async", "predictive", "ring"],
                    help="boundary-exchange policy (DESIGN.md §10; 'ring' "
                         "is the per-hop-staged seq-parallel variant, "
                         "DESIGN.md §13)")
    ap.add_argument("--exchange-refresh", type=int, default=2,
                    help="full refresh every E boundaries (stale/predictive)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check-vs-emulation", action="store_true")
    ap.add_argument("--use-pallas", action="store_true",
                    help="route attention + CFG epilogue through the Pallas "
                         "kernels (DESIGN.md §15; interpret mode off-TPU)")
    ap.add_argument("--verbose", action="store_true",
                    help="print the trace-time kernel path hit/miss "
                         "counters after the run")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.core import sampler as sampler_lib
    from repro.core.pipeline import StadiConfig, StadiPipeline
    from repro.models.diffusion import dit

    occ = [float(x) for x in args.occupancies.split(",")]
    caps = ([float(x) for x in args.capabilities.split(",")]
            if args.capabilities else None)
    backend = "spmd" if args.spmd else args.backend

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    text_mode = args.prompt is not None or args.cond_tokens is not None
    if text_mode:
        cfg = cfg.text_conditioned(cond_seq_len=args.cond_seq_len)
    params = dit.init_params(jax.random.PRNGKey(args.seed), cfg)
    sched = sampler_lib.linear_schedule(T=1000)
    shape = (args.batch, cfg.latent_size, cfg.latent_size, cfg.channels)
    if args.num_frames > 1:          # video latent: [B, F, H, W, C]
        shape = shape[:1] + (args.num_frames,) + shape[1:]
    x_T = jax.random.normal(jax.random.PRNGKey(args.seed + 1), shape)
    if args.prompt is not None:
        from repro.models import text_encoder
        tok = text_encoder.encode([args.prompt], cfg)
        cond = jnp.broadcast_to(tok, (args.batch,) + tok.shape[1:])
        print(f"prompt bucket={tok.shape[1]} (of {cfg.cond_seq_len})")
    elif args.cond_tokens is not None:
        from repro.models import text_encoder
        L = text_encoder.bucket_length(args.cond_tokens, cfg.cond_seq_len)
        feats = jax.random.normal(jax.random.PRNGKey(args.seed + 2),
                                  (args.batch, L, cfg.cond_dim))
        mask = (jnp.arange(L) < args.cond_tokens).astype(jnp.float32)
        mask = jnp.broadcast_to(mask[None, :, None], (args.batch, L, 1))
        cond = jnp.concatenate([feats * mask, mask], axis=-1)
        print(f"cond tokens={args.cond_tokens} bucket={L}")
    else:
        cond = jnp.full((args.batch,), (args.cond or 0) % cfg.n_classes,
                        jnp.int32)

    knobs = {}
    if backend == "simulate":
        # nominal per-step cost model; calibrate for real numbers with
        # benchmarks/common.calibrate_cost_model
        from repro.core.simulate import CostModel
        knobs["cost_model"] = CostModel(t_fixed=1e-3, t_row=5e-4)
    if args.planner == "makespan":
        knobs["tiers"] = (1, 2, 4)        # generalized ratios (DESIGN.md §7)
    config = StadiConfig.from_occupancies(
        occ, caps, m_base=args.m_base, m_warmup=args.m_warmup,
        a=args.a, b=args.b, planner=args.planner, backend=backend,
        rebalance_every=args.rebalance_every, exchange=args.exchange,
        exchange_refresh=args.exchange_refresh,
        num_stages=args.num_stages, micro_patches=args.micro_patches,
        guidance=args.guidance, cfg_scale=args.cfg_scale,
        uncond_refresh=args.uncond_refresh,
        seq_shards=args.seq_shards,
        num_frames=args.num_frames, frame_groups=args.frame_groups,
        use_pallas_attention=args.use_pallas,
        **knobs)
    pipe = StadiPipeline(cfg, params, sched, config)
    plan = pipe.plan()
    print(f"speeds={config.speeds} steps={plan.temporal.steps} "
          f"ratios={plan.temporal.ratios} patches={plan.patches} "
          f"stages={plan.stages} "
          f"guidance={plan.guidance} "
          f"seq={plan.seq} "
          f"frames={plan.frames}")

    t0 = time.time()
    res = pipe.generate(x_T, cond)
    if res.image is None:                  # trace-only backend
        print(f"{backend} run: modeled latency {res.latency_s:.3f}s")
        print(json.dumps({"patches": plan.patches, "steps": plan.temporal.steps,
                          "planner": args.planner, "backend": backend,
                          "latency_s": res.latency_s}))
        return
    img = np.asarray(res.image)
    print(f"{backend} run ({len(jax.devices())} devices): "
          f"{time.time()-t0:.2f}s image {img.shape} "
          f"finite={np.all(np.isfinite(img))}")
    if args.verbose:
        # trace-time counters: which kernel bodies the compiled program
        # contains, and why any layout refused the kernel (DESIGN.md §15)
        print(f"kernel_stats={json.dumps(res.kernel_stats, sort_keys=True)}")
    if (backend in ("spmd", "spmd_guidance", "spmd_seq", "spmd_frames")
            and args.check_vs_emulation):
        emu = StadiPipeline(cfg, params, sched,
                            dataclasses.replace(config, backend="emulated"))
        ref = np.asarray(emu.generate(x_T, cond).image)
        err = float(np.linalg.norm(img - ref) / np.linalg.norm(ref))
        print(f"rel_err_vs_emulation={err:.3e}")
        assert err < 1e-3, err
    print(json.dumps({"patches": plan.patches, "steps": plan.temporal.steps,
                      "planner": args.planner, "backend": backend,
                      "finite": bool(np.all(np.isfinite(img)))}))


if __name__ == "__main__":
    main()
