"""pjit training driver: ``--arch <id> [--reduced] --steps N``.

Shards params/optimizer by sharding/specs.py rules over the local mesh
(1 device in this container; the production mesh in the dry-run). Synthetic
Markov token stream (data/tokens.py), AdamW + cosine schedule, periodic
checkpointing. Used end-to-end by examples/ and tests.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.data import TokenStream
from repro.launch.mesh import make_local_mesh
from repro.models import build_model, encdec
from repro.optim import adamw
from repro.sharding import specs as sh


def make_train_step(model, opt_cfg, total_steps: int):
    from repro.optim.schedules import cosine_schedule

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        lr_scale = cosine_schedule(opt_state["count"], total_steps,
                                   warmup_steps=min(20, total_steps // 10))
        params, opt_state = adamw.adamw_update(params, grads, opt_state,
                                               opt_cfg, lr_scale)
        return params, opt_state, loss

    return jax.jit(train_step, donate_argnums=(0, 1))


def train(arch: str, *, steps: int = 50, batch: int = 4, seq: int = 64,
          reduced: bool = True, lr: float = 1e-3, ckpt_dir: str = None,
          log_every: int = 10, seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    mesh = make_local_mesh()
    params = model.init(jax.random.PRNGKey(seed))
    n_params = sum(np.prod(np.shape(l)) for l in jax.tree.leaves(params))
    opt_cfg = adamw.AdamWConfig(lr=lr)
    opt_state = adamw.adamw_init(params)
    step_fn = make_train_step(model, opt_cfg, steps)

    stream = iter(TokenStream(cfg.vocab, seq, batch, seed=seed))
    rng = jax.random.PRNGKey(seed + 1)
    losses = []
    t0 = time.time()
    with mesh:
        for step in range(steps):
            raw = next(stream)
            batch_d = {"tokens": jnp.asarray(raw["tokens"]),
                       "labels": jnp.asarray(raw["labels"])}
            if cfg.family == "vlm":
                rng, k = jax.random.split(rng)
                batch_d["vision_embeds"] = jax.random.normal(
                    k, (batch, cfg.n_vision_tokens, cfg.d_model),
                    jnp.dtype(cfg.dtype)) * 0.02
            if cfg.family == "encdec":
                rng, k = jax.random.split(rng)
                st = encdec.tgt_len_for(seq)
                batch_d = {"src_embeds": jax.random.normal(
                    k, (batch, seq, cfg.d_model), jnp.dtype(cfg.dtype)) * 0.02,
                    "tgt_tokens": batch_d["tokens"][:, :st],
                    "labels": batch_d["labels"][:, :st]}
            params, opt_state, loss = step_fn(params, opt_state, batch_d)
            losses.append(float(loss))
            if step % log_every == 0 or step == steps - 1:
                print(f"step {step:5d} loss {float(loss):.4f} "
                      f"({(time.time()-t0)/(step+1):.2f}s/step)")
    if ckpt_dir:
        save_checkpoint(ckpt_dir, steps, {"params": params, "opt": opt_state})
    print(f"trained {arch} ({n_params/1e6:.1f}M params) {steps} steps: "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true", help="full-size config")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
          reduced=not args.full, lr=args.lr, ckpt_dir=args.ckpt_dir)


if __name__ == "__main__":
    main()
