"""Three-term roofline from compiled dry-run artifacts (deliverable g).

  compute term    = HLO_FLOPs / (chips * 197e12)
  memory term     = HLO_bytes / (chips * 819e9)
  collective term = collective_bytes / (chips * 50e9)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective
bytes are parsed from the post-partitioning HLO text (``compiled.as_text()``)
by summing operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops.

NOTE on per-device accounting: with GSPMD the compiled module IS the
per-device program, so cost_analysis flops/bytes are per-device already;
we therefore divide by 1 chip (not by `chips`) for the time terms and
multiply MODEL_FLOPS by 1/chips for the usefulness ratio. Both raw and
derived values are recorded so the convention is auditable.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# shapes like bf16[8,128]{1,0} or (bf16[2], f32[4]) tuples
_SHAPE_RE = re.compile(r"\b([a-z]+\d*(?:e\d+m\d+(?:fn)?)?)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _instr_bytes(rhs: str, op_start: int, op_end: int) -> int:
    call = rhs[op_end:]
    shapes = _SHAPE_RE.findall(call)
    if shapes:
        return sum(_shape_bytes(d, s) for d, s in shapes)
    res = _SHAPE_RE.findall(rhs[:op_start])      # result-shape fallback
    return sum(_shape_bytes(d, s) for d, s in res)


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    """computation name -> its instruction lines (post-optimization HLO)."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)(?:\.clone)?\s*\(.*\)\s*->.*\{", s)
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if s == "}":
            cur = None
            continue
        if cur is not None and s:
            comps[cur].append(s)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """Largest integer constant in the while condition ~ trip count."""
    best = 1
    for l in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", l):
            best = max(best, int(m.group(1)))
    return best


def _comp_multipliers(comps: Dict[str, List[str]]) -> Dict[str, float]:
    """Execution-count multiplier per computation: while bodies inherit the
    loop trip count (nested loops multiply); calls/fusions inherit x1."""
    refs: Dict[str, List] = {name: [] for name in comps}
    referenced = set()
    for name, lines in comps.items():
        for l in lines:
            wm = re.search(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)", l)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trip = _trip_count(comps.get(cond, []))
                refs[name] += [(body, trip), (cond, trip)]
                referenced.update((cond, body))
            for cm in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", l):
                refs[name].append((cm.group(1), 1))
                referenced.add(cm.group(1))
    roots = [n for n in comps if n not in referenced] or \
        [n for n in comps if "main" in n]
    mult: Dict[str, float] = {}
    stack = [(r, 1.0) for r in roots]
    while stack:
        name, m = stack.pop()
        if mult.get(name, 0.0) >= m:
            continue
        mult[name] = m
        for child, trip in refs.get(name, []):
            stack.append((child, m * trip))
    return mult


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from (post-SPMD) HLO text.

    Loop-aware: a collective inside a while (lax.scan) body is scaled by the
    loop's trip count (parsed from the condition's comparison constant), so
    per-layer collectives count once per layer, not once per program."""
    comps = _split_computations(hlo_text)
    mult = _comp_multipliers(comps)
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for name, lines in comps.items():
        m_comp = mult.get(name, 1.0)
        for line in lines:
            m = re.match(r"%?[\w.\-]+\s*=\s*(.+)$", line)
            if not m:
                continue
            rhs = m.group(1)
            opm = re.search(r"\b(" + "|".join(_COLLECTIVES) +
                            r")(?:-start|-done)?\(", rhs)
            if not opm:
                continue
            kind = opm.group(1)
            if "-done(" in rhs:
                continue                  # counted at -start
            out[kind] += int(_instr_bytes(rhs, opm.start(), opm.end()) * m_comp)
            counts[kind] += 1
    out["_counts"] = counts
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float               # analytic (matmul-exact) / chips
    bytes_per_device: float               # analytic one-pass HBM model / chips
    collective_bytes_per_device: float    # loop-aware HLO parse (per device)
    model_flops: float                    # 6*N(active)*D tokens-based, global
    compute_s: float
    memory_s: float
    collective_s: float
    raw_hlo_flops: float = 0.0            # compiled cost_analysis (body-once)
    raw_hlo_bytes: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        tot = self.flops_per_device * self.chips
        return self.model_flops / tot if tot else 0.0

    def to_dict(self) -> dict:
        return {**dataclasses.asdict(self),
                "dominant": self.dominant, "useful_ratio": self.useful_ratio}


def model_flops_for(arch: str, shape_name: str) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); D = tokens processed."""
    from repro.configs import get_config
    from repro.launch.shapes import SHAPES
    from repro.models import encdec as encdec_lib

    cfg = get_config(arch)
    s = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if s.kind == "train":
        tokens = s.batch * s.seq
        if cfg.family == "encdec":
            tokens = s.batch * (s.seq + encdec_lib.tgt_len_for(s.seq))
        return 6.0 * n_active * tokens
    if s.kind == "prefill":
        tokens = s.batch * s.seq
        if cfg.family == "encdec":
            tokens = s.batch * (s.seq + encdec_lib.tgt_len_for(s.seq))
        return 2.0 * n_active * tokens
    return 2.0 * n_active * s.batch          # decode: one token per request


def build(arch: str, shape: str, mesh_name: str, chips: int,
          cost: Dict, coll: Dict, flash: bool = False) -> Roofline:
    """Roofline terms: compute/memory from the analytic matmul-exact model
    divided by chips (idealized perfectly-sharded bound — XLA's
    cost_analysis counts scan bodies once, see launch/analytic.py);
    collective from the loop-aware per-device HLO parse. Raw compiled
    numbers are retained alongside."""
    from repro.launch import analytic

    per_dev = analytic.per_device(arch, shape, chips, flash=flash)
    cb = float(coll.get("total", 0))
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=per_dev.flops, bytes_per_device=per_dev.bytes,
        collective_bytes_per_device=cb,
        model_flops=model_flops_for(arch, shape),
        compute_s=per_dev.flops / PEAK_FLOPS_BF16,
        memory_s=per_dev.bytes / HBM_BW,
        collective_s=cb / ICI_BW,
        raw_hlo_flops=float(cost.get("flops", 0.0) or 0.0),
        raw_hlo_bytes=float(cost.get("bytes accessed", 0.0) or 0.0),
    )
