"""Analytic per-(arch x shape) FLOP and HBM-byte accounting.

Why this exists: XLA's HloCostAnalysis counts a while (lax.scan) body ONCE
and GSPMD re-partitions differently at different probe depths, so neither
raw nor depth-probed compiled costs reconstruct true per-device work
(EXPERIMENTS.md §Dry-run documents the measurements). We own every einsum in
repro.models, so exact matmul-level accounting is available analytically.
The roofline table uses these for the compute/memory terms (divided by chip
count = the idealized perfectly-sharded bound) and keeps the raw compiled
numbers alongside as the compiler view; the collective term stays
HLO-derived (loop-aware parser in roofline.py).

Conventions:
  - flops: 2*M*N*K per matmul; backward = 2x forward; train = 3x forward.
  - bytes: every major intermediate read+written once in activation dtype
    (2 bytes bf16) + weight traffic once per step + optimizer traffic for
    train (3 reads + 2 writes x 4 bytes f32) - a one-pass HBM model.
  - naive attention materializes S x T scores (fp32): counted; the chunked/
    flash variant drops those terms (attn_impl-aware) - this is how the
    Sec-Perf memory-term fix is quantified.
"""
from __future__ import annotations

import dataclasses

from repro.configs import get_config
from repro.launch.shapes import SHAPES
from repro.models import encdec as encdec_lib


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0

    def __add__(self, o):
        return Cost(self.flops + o.flops, self.bytes + o.bytes)

    def __mul__(self, k):
        return Cost(self.flops * k, self.bytes * k)

    __rmul__ = __mul__


def _mm(m, n, k, dtype_bytes=2):
    """One matmul: flops + (A + B + C) traffic."""
    return Cost(2.0 * m * n * k, dtype_bytes * (m * k + k * n + m * n))


def _attn(cfg, B, S, T, flash: bool):
    """QK^T + PV for H heads (scores fp32 when materialized)."""
    H, hd = cfg.n_heads, cfg.hd
    c = Cost(2.0 * B * H * S * T * hd * 2, 0.0)
    if flash:
        # streaming: read q,k,v + write o once
        c.bytes = 2.0 * B * (S + 2 * T + S) * H * hd
        return c
    # naive: scores + probs materialized in fp32 (write + read each)
    score_bytes = 4.0 * B * H * S * T
    c = Cost(c.flops, 2.0 * B * (S + 2 * T + S) * H * hd + 4 * score_bytes)
    return c


def _block_tokens(cfg, B, T, ctx, flash):
    """One decoder block over T tokens attending to ctx keys."""
    D, F = cfg.d_model, cfg.d_ff
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    BT = B * T
    c = _mm(BT, H * hd, D) + 2 * _mm(BT, K * hd, D) + _mm(BT, D, H * hd)
    c = c + _attn(cfg, B, T, ctx, flash)
    if cfg.n_experts:
        act = cfg.top_k + cfg.n_shared_experts
        c = c + _mm(BT, cfg.n_experts, D)                    # router
        c = c + 3 * act * _mm(BT, F, D)                      # swiglu experts
        # expert weights touched: top_k experts' weights stream per step
        c.bytes += 2.0 * 3 * min(cfg.n_experts, 256) * D * F / max(1, 1)
    else:
        n_mat = 3 if cfg.activation in ("swiglu", "geglu") else 2
        c = c + n_mat * _mm(BT, F, D)
    c.bytes += 2.0 * BT * D * 6                              # norms/residuals
    return c


def _head(cfg, B, T):
    return _mm(B * T, cfg.vocab, cfg.d_model)


def _ssm_block(cfg, B, T):
    """xLSTM mLSTM block (proj_factor inner width)."""
    D = cfg.d_model
    Di = int(cfg.proj_factor * D)
    H = cfg.n_heads
    dh = Di // H
    BT = B * T
    c = _mm(BT, 2 * Di, D) + 3 * _mm(BT, Di, Di) + _mm(BT, D, Di)
    # cell: C update (~4 * H*dh^2) + C q (2 H dh^2) per token, fp32 state
    c = c + Cost(6.0 * BT * H * dh * dh, 4.0 * BT * H * dh * dh / 64)
    c.bytes += 4.0 * B * H * dh * dh * 2 * min(T, 1)          # state r/w once
    return c


def _mamba_branch(cfg, B, T):
    D, N = cfg.d_model, cfg.ssm_state
    BT = B * T
    c = _mm(BT, 2 * D, D) + _mm(BT, 2 * N, D) + _mm(BT, D, D)
    c = c + Cost(6.0 * BT * D * N, 2.0 * BT * D * N / 16)     # recurrence
    return c


def _hybrid_block(cfg, B, T, ctx, flash):
    c = _block_tokens(cfg, B, T, min(ctx, cfg.sliding_window or ctx), flash)
    return c + _mamba_branch(cfg, B, T)


def _enc_block(cfg, B, T, flash):
    return _block_tokens(cfg, B, T, T, flash)


def params_bytes(cfg) -> float:
    return 2.0 * cfg.param_count()


def forward_cost(arch: str, shape_name: str, flash: bool = False) -> Cost:
    cfg = get_config(arch)
    s = SHAPES[shape_name]
    B, S = s.batch, s.seq
    fam = cfg.family
    kind = s.kind
    if kind == "decode":
        T = 1
        ctx = cfg.sliding_window or (cfg.long_context_window
                                     if shape_name == "long_500k" else S)
    else:
        T, ctx = S, S

    if fam == "ssm":
        c = cfg.n_layers * _ssm_block(cfg, B, T)
        c = c + _head(cfg, B, T)
    elif fam == "hybrid":
        c = cfg.n_layers * _hybrid_block(cfg, B, T + cfg.n_meta_tokens
                                         if kind != "decode" else T, ctx, flash)
        c = c + _head(cfg, B, T)
    elif fam == "encdec":
        St = encdec_lib.tgt_len_for(S) if kind != "decode" else 1
        if kind != "decode":
            c = cfg.n_enc_layers * _enc_block(cfg, B, S, flash)
        else:
            c = Cost()
        dec = _block_tokens(cfg, B, St, St if kind != "decode" else ctx, flash)
        dec = dec + _attn(cfg, B, St, S, flash)               # cross attention
        dec = dec + _mm(B * St, cfg.n_kv_heads * cfg.hd, cfg.d_model)
        c = c + cfg.n_layers * dec
        c = c + _head(cfg, B, St)
    else:                                                     # dense/moe/vlm
        Tv = T + (cfg.n_vision_tokens if fam == "vlm" and kind != "decode" else 0)
        c = cfg.n_layers * _block_tokens(cfg, B, Tv, ctx if kind == "decode" else Tv, flash)
        c = c + _head(cfg, B, Tv)
    # weights streamed once (MoE: only active experts' ffn weights)
    wb = 2.0 * cfg.active_param_count() if kind == "decode" else params_bytes(cfg)
    c.bytes += wb
    # kv cache traffic for decode
    if kind == "decode" and fam not in ("ssm",):
        c.bytes += 2.0 * 2 * cfg.n_layers * B * ctx * cfg.n_kv_heads * cfg.hd
    return c


def step_cost(arch: str, shape_name: str, flash: bool = False) -> Cost:
    """Full lowered-step cost: train = fwd + bwd(2x) + optimizer traffic."""
    cfg = get_config(arch)
    s = SHAPES[shape_name]
    c = forward_cost(arch, shape_name, flash)
    if s.kind == "train":
        c = Cost(3.0 * c.flops, 3.0 * c.bytes)
        n = cfg.param_count()
        c.bytes += 4.0 * n * (3 + 2)          # adam m/v/param r+w (f32)
        c.flops += 10.0 * n
    return c


def per_device(arch: str, shape_name: str, chips: int, flash: bool = False) -> Cost:
    c = step_cost(arch, shape_name, flash)
    return Cost(c.flops / chips, c.bytes / chips)
