import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape x mesh):
    jax.jit(step, in_shardings=...).lower(**input_specs).compile()
on the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh, printing
memory_analysis (fits?) and cost_analysis (roofline feed). Results land in
results/dryrun/<arch>__<shape>__<mesh>.json for EXPERIMENTS.md §Dry-run and
benchmarks/bench_roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, build_lowerable

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _out_path(arch: str, shape: str, mesh_name: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_name}.json")


def applicable(arch: str, shape: str) -> bool:
    """DESIGN.md §4 carve-outs (none skipped: sliding-window variant covers
    long_500k on full-attention archs)."""
    return True


def run_one(arch: str, shape: str, multi_pod: bool, verbose: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    fn, args, shardings = build_lowerable(arch, shape)
    in_sh = shardings(mesh)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):      # jax 0.4.x returns [dict]
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
    coll = rl.collective_bytes(hlo)
    roof = rl.build(arch, shape, mesh_name, chips, cost, coll)
    mem_d = {}
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "temp_size_in_bytes",
                 "alias_size_in_bytes"):
        mem_d[attr] = getattr(mem, attr, None)
    report = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "chips": chips,
        "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_d,
        "cost_analysis": {k: cost.get(k) for k in
                          ("flops", "bytes accessed", "transcendentals")},
        "collective_bytes": {k: v for k, v in coll.items() if k != "_counts"},
        "collective_counts": coll.get("_counts"),
        "roofline": roof.to_dict(),
    }
    if verbose:
        print(f"[{arch} x {shape} x {mesh_name}] OK "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s")
        print(f"  memory_analysis: {mem_d}")
        print(f"  cost_analysis:   flops={cost.get('flops'):.3e} "
              f"bytes={cost.get('bytes accessed'):.3e}" if cost.get("flops")
              else f"  cost_analysis:   {cost}")
        print(f"  collectives:     {report['collective_bytes']}")
        print(f"  roofline:        compute={roof.compute_s:.4f}s "
              f"memory={roof.memory_s:.4f}s collective={roof.collective_s:.4f}s "
              f"dominant={roof.dominant}")
    with open(_out_path(arch, shape, mesh_name), "w") as f:
        json.dump(report, f, indent=2)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            if not applicable(arch, shape):
                continue
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = _out_path(arch, shape, mesh_name)
                if args.skip_done and os.path.exists(path):
                    print(f"[{arch} x {shape} x {mesh_name}] cached, skipping")
                    continue
                try:
                    run_one(arch, shape, mp)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mesh_name, repr(e)))
                    traceback.print_exc()
                    with open(path, "w") as f:
                        json.dump({"arch": arch, "shape": shape,
                                   "mesh": mesh_name, "ok": False,
                                   "error": repr(e)}, f, indent=2)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f4 in failures:
            print("  ", f4)
        raise SystemExit(1)
    print("\nall dry-runs OK")


if __name__ == "__main__":
    main()
