"""Production mesh builders (TPU v5e pods; CPU placeholder devices in the
dry-run). Functions, not module-level constants — importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips/pod; multi-pod adds a leading pod=2 axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)}; the "
            "dry-run entrypoint must set XLA_FLAGS="
            "--xla_force_host_platform_device_count=512 before importing jax")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_local_mesh(axes=("data", "model")) -> Mesh:
    """Whatever devices exist, as a 1xN or NxM mesh (tests / examples)."""
    devices = np.asarray(jax.devices())
    if len(axes) == 1:
        return Mesh(devices, axes)
    return Mesh(devices.reshape(1, -1), axes)


# TPU v5e per-chip constants for the roofline model (see brief).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link
