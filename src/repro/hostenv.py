"""Pre-jax environment setup. jax-free on purpose: callers (launch scripts,
tests/conftest.py) must run this BEFORE anything imports jax, because XLA
reads XLA_FLAGS exactly once at backend initialization."""
import os


def force_host_devices() -> None:
    """Translate ``STADI_HOST_DEVICES=N`` into N forced XLA host platform
    devices (CPU SPMD). No-op when unset or 0."""
    n = os.environ.get("STADI_HOST_DEVICES", "")
    if n not in ("", "0"):
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n} "
            + os.environ.get("XLA_FLAGS", ""))
