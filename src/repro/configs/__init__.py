"""Config registry: ``get_config("<arch-id>")`` / ``--arch <id>``.

Each assigned architecture (public-literature pool) has one module here with
the exact assigned config; ``sdxl_dit`` / ``tiny_dit`` / ``tiny_unet`` are the
paper's own diffusion models.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig

# assigned architecture ids (module name = id with - -> _)
ASSIGNED: List[str] = [
    "xlstm-125m",
    "olmoe-1b-7b",
    "seamless-m4t-medium",
    "yi-9b",
    "minitron-8b",
    "hymba-1.5b",
    "llama3-405b",
    "gemma-2b",
    "deepseek-moe-16b",
    "internvl2-76b",
]

DIFFUSION: List[str] = ["sdxl-dit", "tiny-dit"]

ALL_ARCHS: List[str] = ASSIGNED + DIFFUSION

_cache: Dict[str, ArchConfig] = {}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _cache:
        modname = arch_id.replace("-", "_").replace(".", "_")
        mod = importlib.import_module(f"repro.configs.{modname}")
        _cache[arch_id] = mod.CONFIG
    return _cache[arch_id]


def list_archs() -> List[str]:
    return list(ALL_ARCHS)
