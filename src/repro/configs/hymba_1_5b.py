"""Hymba-1.5B [hybrid] — parallel attn+mamba heads [arXiv:2411.13676].

Assigned: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Each block runs attention heads and mamba (SSM) heads in PARALLEL on the same
input and fuses them (mean of per-branch normed outputs), per the paper.
128 learnable meta tokens are prepended. Attention is sliding-window (Hymba
uses global attention only in 3 layers; we use SWA everywhere + meta tokens,
noted in DESIGN.md) — hence long_500k runs natively.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="hymba-1.5b",
    family="hybrid",
    source="arXiv:2411.13676 (Hymba)",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    ssm_conv=4,
    n_meta_tokens=128,
    sliding_window=1024,
)
