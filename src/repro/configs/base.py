"""Architecture configuration system.

Every assigned architecture gets one ``<id>.py`` in this package defining
``CONFIG`` (the exact full-size config from the assignment) built from
:class:`ArchConfig`. ``ArchConfig.reduced()`` produces the smoke-test
variant (<=2 layers, d_model<=512, <=4 experts) of the *same family*.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity
    arch_id: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    source: str = ""                 # paper / model-card citation

    # transformer core
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: Optional[int] = None   # default: d_model // n_heads
    d_ff: int = 1024                 # dense FFN width (for moe: expert width)
    vocab: int = 1024
    activation: str = "swiglu"       # swiglu | geglu
    norm: str = "rmsnorm"
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float = 0.0       # gemma-style soft capping (0 = off)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM / recurrent
    ssm_state: int = 0               # mamba state size N
    ssm_conv: int = 4                # depthwise conv width
    slstm_every: int = 0             # xLSTM: every k-th block is sLSTM (0=never)
    proj_factor: float = 2.0         # xLSTM up-projection factor

    # hybrid (hymba)
    n_meta_tokens: int = 0

    # enc-dec (seamless)
    n_enc_layers: int = 0            # 0 => decoder-only
    cross_attention: bool = False

    # vlm
    n_vision_tokens: int = 0

    # attention variant for long-context decode (sub-quadratic carve-out)
    sliding_window: int = 0          # 0 = full attention
    long_context_window: int = 4096  # window used when shape requires sub-quadratic

    # numerics / implementation selection
    param_dtype: str = "float32"
    dtype: str = "float32"
    attn_impl: str = "naive"         # naive | chunked (flash-style online softmax;
                                     # the Pallas kernel replaces it on real TPU)
    attn_chunk: int = 512            # KV chunk for attn_impl="chunked"
    act_shard: str = ""              # "" | batch | seqpar: with_sharding_constraint
                                     # on the residual stream per block (Sec-Perf)

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch run long_500k decode natively (O(1)/O(w) state)?"""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Smoke-test variant of the same family: tiny but structurally identical."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        hd = max(8, d_model // n_heads)
        kv = max(1, min(self.n_kv_heads, n_heads))
        # keep GQA ratio structure: kv divides heads
        while n_heads % kv:
            kv -= 1
        kw = dict(
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=kv,
            head_dim=hd,
            d_ff=max(32, min(self.d_ff, 512)) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
        )
        if self.n_experts:
            kw.update(n_experts=4, top_k=min(2, self.top_k),
                      n_shared_experts=min(self.n_shared_experts, 1))
        if self.n_enc_layers:
            kw.update(n_enc_layers=2)
        if self.n_vision_tokens:
            kw.update(n_vision_tokens=16)
        if self.n_meta_tokens:
            kw.update(n_meta_tokens=8)
        if self.sliding_window:
            kw.update(sliding_window=64)
        kw.update(long_context_window=min(self.long_context_window, 64))
        return self.replace(**kw)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (for 6*N*D roofline bookkeeping)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        H, K, hd = self.n_heads, self.n_kv_heads, self.hd
        attn = D * H * hd + 2 * D * K * hd + H * hd * D
        if self.activation in ("swiglu", "geglu"):
            ffn = 3 * D * F
        else:
            ffn = 2 * D * F
        if self.n_experts:
            moe = self.n_experts * ffn + D * self.n_experts
            moe += self.n_shared_experts * ffn
            block = attn + moe
        elif self.family == "ssm":
            # xLSTM block approximation: up/down proj + qkv + gates
            dp = int(self.proj_factor * D)
            block = 2 * D * dp + 3 * dp * dp // max(1, self.n_heads) + 4 * dp
            block = 2 * D * dp + 3 * dp * hd * self.n_heads // max(1, self.n_heads) + 4 * dp
        else:
            block = attn + ffn
        if self.family == "hybrid":
            dp = D  # mamba inner ~ D
            block += 2 * D * dp + dp * self.ssm_state * 2
        total = L * block + V * D * (1 if self.tie_embeddings else 2)
        if self.n_enc_layers:
            total += self.n_enc_layers * (attn + ffn)      # encoder stack
            total += L * attn                               # cross attention
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top_k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        H, K, hd = self.n_heads, self.n_kv_heads, self.hd
        attn = D * H * hd + 2 * D * K * hd + H * hd * D
        ffn = 3 * D * F
        act_block = attn + (self.top_k + self.n_shared_experts) * ffn + D * self.n_experts
        return int(L * act_block + V * D * 2)
