"""OLMoE-1B-7B [moe] — 64 experts top-8 [arXiv:2409.02060].

Assigned: 16L d_model=2048 16H (GQA kv=16) d_ff=1024 (expert width)
vocab=50304, MoE 64e top-8, no shared experts.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="olmoe-1b-7b",
    family="moe",
    source="arXiv:2409.02060 (OLMoE)",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    n_experts=64,
    top_k=8,
    n_shared_experts=0,
)
