"""xLSTM-125M [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

Assigned: 12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304.
d_ff=0 => no separate FFN; xLSTM blocks carry their own up/down projection
(proj_factor=2, as in the paper's mLSTM block). Every 4th block is an sLSTM
block (xLSTM[.., 1] style mixing), the rest are chunkwise mLSTM.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="xlstm-125m",
    family="ssm",
    source="arXiv:2405.04517 (xLSTM)",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    proj_factor=2.0,
    slstm_every=4,
    ssm_conv=4,
    tie_embeddings=False,
)
