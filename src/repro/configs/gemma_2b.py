"""Gemma-2B [dense] — GeGLU, head_dim=256, MQA [arXiv:2403.08295].

Assigned: 18L d_model=2048 8H (GQA kv=1 => MQA) d_ff=16384 vocab=256000.
head_dim=256 (explicit, attn_dim = 8*256 = 2048).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="gemma-2b",
    family="dense",
    source="arXiv:2403.08295 (Gemma)",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=256000,
    activation="geglu",
    tie_embeddings=True,
    logit_softcap=30.0,
)
