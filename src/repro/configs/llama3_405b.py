"""Llama-3.1-405B [dense] — GQA, 128k vocab [arXiv:2407.21783].

Assigned: 126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
Full attention; long_500k decode uses the sliding-window variant
(long_context_window=4096) — recorded in DESIGN.md shape-applicability.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama3-405b",
    family="dense",
    source="arXiv:2407.21783 (Llama 3)",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab=128256,
    rope_theta=500_000.0,
    param_dtype="bfloat16",
    dtype="bfloat16",
)
