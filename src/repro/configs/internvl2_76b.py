"""InternVL2-76B [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].

Assigned: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
Per the brief, the vision frontend (InternViT-6B + MLP projector) is a STUB:
``input_specs`` provides 1024 precomputed patch embeddings of shape
(batch, n_vision_tokens, d_model); this module implements the language
decoder that consumes them interleaved with text tokens.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="internvl2-76b",
    family="vlm",
    source="arXiv:2404.16821 (InternVL2)",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    n_vision_tokens=1024,
    param_dtype="bfloat16",
    dtype="bfloat16",
)
