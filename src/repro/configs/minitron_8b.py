"""Minitron-8B [dense] — pruned Nemotron [arXiv:2407.14679].

Assigned: 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="minitron-8b",
    family="dense",
    source="arXiv:2407.14679 (Minitron)",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab=256000,
    activation="swiglu",   # nemotron uses squared-relu; swiglu width kept per assignment
)
