"""Tiny DiT denoiser — trainable on CPU in minutes; quality-experiment model."""
from repro.configs.diffusion import DiTConfig

CONFIG = DiTConfig(
    arch_id="tiny-dit",
    latent_size=32,
    channels=3,
    patch_size=2,
    n_layers=4,
    d_model=192,
    n_heads=6,
    mlp_ratio=4.0,
    cond_dim=64,
    n_classes=16,
)
