"""SeamlessM4T-medium [audio] — encoder-decoder, multimodal [arXiv:2308.11596].

Assigned: 12L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206.
Interpreted as 12 encoder + 12 decoder layers (the text-to-text backbone);
the speech frontend (mel-spectrogram + conformer feature extractor) is a
STUB per the brief — ``input_specs`` provides precomputed frame embeddings
of shape (batch, src_len, d_model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="seamless-m4t-medium",
    family="encdec",
    source="arXiv:2308.11596 (SeamlessM4T)",
    n_layers=12,           # decoder layers
    n_enc_layers=12,       # encoder layers
    cross_attention=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    activation="geglu",
)
