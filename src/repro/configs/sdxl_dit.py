"""The paper's own diffusion model, adapted for TPU (DESIGN.md §2).

The paper serves SDXL (2.3B UNet) at 1024x1024 => 128x128 latents. Our
distributed denoiser is a DiT of comparable scale class (DiT-XL/2-like) on a
128x128x4 latent grid — P_total = 32 patch rows of 2-pixel granularity matches
the paper's ``P_total = 32`` operator constraint (latent 128 / patch_size 2 /
"power-of-two friendly" rows = 64 tokens-per-side, grouped into 32 allocatable
slabs of 2 token-rows each).
"""
from repro.configs.diffusion import DiTConfig

CONFIG = DiTConfig(
    arch_id="sdxl-dit",
    source="arXiv:2307.01952 (SDXL) adapted to DiT-XL/2 [arXiv:2212.09748]",
    latent_size=128,
    channels=4,
    patch_size=2,
    n_layers=28,
    d_model=1152,
    n_heads=16,
    mlp_ratio=4.0,
    cond_dim=256,
    n_classes=1000,
    param_dtype="bfloat16",
    dtype="bfloat16",
)
