"""Diffusion-model (denoiser) configs for the STADI wing."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DiTConfig:
    arch_id: str = "tiny-dit"
    family: str = "dit"
    source: str = "arXiv:2212.09748 (DiT)"
    # latent grid
    latent_size: int = 32            # H = W (latent resolution)
    channels: int = 4                # latent channels
    patch_size: int = 2              # patchify
    # transformer
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    mlp_ratio: float = 4.0
    cond_dim: int = 64               # class/prompt conditioning embedding dim
    n_classes: int = 16              # synthetic conditioning vocabulary
    # prompt conditioning (DESIGN.md §17): cond_seq_len > 0 declares the
    # workload prompt-conditioned — the frozen text encoder
    # (repro.models.text_encoder) emits [B, L <= cond_seq_len, cond_dim]
    # prompt tokens (plus a trailing validity-mask channel) and cross_attn
    # interleaves a prompt cross-attention read into every DiT block.
    # Defaults (0 / False) keep the class-conditional path BITWISE: no new
    # params are drawn and no new ops are traced.
    cond_seq_len: int = 0
    cross_attn: bool = False
    # numerics
    param_dtype: str = "float32"
    dtype: str = "float32"
    # run the Pallas stale-KV attention kernel (repro.kernels.
    # stale_kv_attention) for buffered patch attention instead of the
    # reference rewrite-then-attend path; interpret mode off-TPU. Falls
    # back to the reference when the patch layout misses the kernel's tile
    # constraints (traced offsets, SPMD padding, indivisible block sizes).
    use_pallas_attention: bool = False

    @property
    def tokens_per_side(self) -> int:
        return self.latent_size // self.patch_size

    @property
    def n_tokens(self) -> int:
        return self.tokens_per_side ** 2

    @property
    def token_dim(self) -> int:
        return self.channels * self.patch_size ** 2

    def replace(self, **kw) -> "DiTConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "DiTConfig":
        return self.replace(n_layers=2, d_model=128, n_heads=4, latent_size=16)

    def text_conditioned(self, cond_seq_len: int = 32) -> "DiTConfig":
        """Prompt-conditioned variant (DESIGN.md §17): enables the per-block
        prompt cross-attention and declares the max prompt-token bucket."""
        return self.replace(cond_seq_len=cond_seq_len, cross_attn=True)


@dataclasses.dataclass(frozen=True)
class UNetConfig:
    arch_id: str = "tiny-unet"
    family: str = "unet"
    source: str = "arXiv:2307.01952 (SDXL; scaled-down)"
    image_size: int = 32
    channels: int = 3
    base_width: int = 32
    channel_mults: tuple = (1, 2, 2)
    attn_levels: tuple = (2,)        # attention at these downsample levels
    n_res_blocks: int = 1
    cond_dim: int = 64
    n_classes: int = 16
    param_dtype: str = "float32"
    dtype: str = "float32"

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)
