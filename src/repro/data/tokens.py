"""Deterministic synthetic token pipeline (offline container: no corpora).

Generates a Markov-ish token stream with learnable structure (n-gram
transitions seeded per document) so language-model training loss actually
decreases — a flat-random stream would make convergence tests meaningless.
Shard-aware: each data-parallel rank draws a disjoint document range.
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0,
                 shard: int = 0, n_shards: int = 1, order: int = 2):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch
        self.order = order
        self.rng = np.random.default_rng(seed + shard * 10_007)
        # shared sparse bigram transition structure
        g = np.random.default_rng(seed)
        self.n_next = min(8, vocab)
        self.table = g.integers(0, vocab, size=(min(vocab, 4096), self.n_next))

    def _doc(self, length: int) -> np.ndarray:
        out = np.empty(length, np.int64)
        out[0] = self.rng.integers(0, self.vocab)
        for i in range(1, length):
            prev = out[i - 1] % self.table.shape[0]
            if self.rng.random() < 0.85:
                out[i] = self.table[prev, self.rng.integers(0, self.n_next)]
            else:
                out[i] = self.rng.integers(0, self.vocab)
        return out

    def __iter__(self):
        return self

    def __next__(self):
        toks = np.stack([self._doc(self.seq_len) for _ in range(self.batch)])
        return {"tokens": toks.astype(np.int32), "labels": toks.astype(np.int32)}
