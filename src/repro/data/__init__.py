from repro.data.tokens import TokenStream  # noqa
from repro.data.images import SyntheticImages  # noqa
