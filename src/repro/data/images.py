"""Synthetic structured image dataset for the diffusion quality wing.

Class-conditional images with real spatial structure (oriented Gaussian
blobs + class-dependent stripe frequency/phase on a shaded background), in
[-1, 1]. A tiny DiT trained on these gives a meaningful Table-II analogue:
PSNR / feature-distance / Frechet-proxy between Origin / Patch-Parallel /
STADI outputs (see DESIGN.md §6).
"""
from __future__ import annotations

import numpy as np


class SyntheticImages:
    def __init__(self, size: int = 32, channels: int = 3, n_classes: int = 16,
                 seed: int = 0):
        self.size = size
        self.channels = channels
        self.n_classes = n_classes
        g = np.random.default_rng(seed)
        # per-class style parameters
        self.freq = g.uniform(1.0, 4.0, n_classes)
        self.angle = g.uniform(0, np.pi, n_classes)
        self.tint = g.uniform(-0.5, 0.5, (n_classes, channels))

    def sample(self, rng: np.random.Generator, batch: int):
        S, C = self.size, self.channels
        cls = rng.integers(0, self.n_classes, batch)
        yy, xx = np.mgrid[0:S, 0:S] / S
        imgs = np.empty((batch, S, S, C), np.float32)
        for i, c in enumerate(cls):
            cx, cy = rng.uniform(0.25, 0.75, 2)
            sx, sy = rng.uniform(0.08, 0.2, 2)
            th = self.angle[c] + rng.normal(0, 0.15)
            u = (xx - cx) * np.cos(th) + (yy - cy) * np.sin(th)
            v = -(xx - cx) * np.sin(th) + (yy - cy) * np.cos(th)
            blob = np.exp(-(u ** 2 / (2 * sx ** 2) + v ** 2 / (2 * sy ** 2)))
            stripes = 0.4 * np.sin(2 * np.pi * self.freq[c] * u * S / 8 + rng.uniform(0, 2 * np.pi))
            shade = 0.3 * (yy - 0.5)
            base = blob + stripes * blob + shade
            for ch in range(C):
                imgs[i, :, :, ch] = base + self.tint[c, ch]
        return np.clip(imgs, -1, 1), cls.astype(np.int32)

    def batches(self, batch: int, seed: int = 1):
        rng = np.random.default_rng(seed)
        while True:
            yield self.sample(rng, batch)
