"""Persistent plan cache (DESIGN.md §14).

Planner search is pure — (cluster speeds, model config, workload shape)
fully determine the ExecutionPlan — so serving restarts and repeated
workload shapes should never pay for the same search twice. PlanCache
persists planner outputs as one JSON file per key under a cache directory
(default ``results/plan_cache/``):

    key  = sha256(canonical JSON of {cluster, model, workload})
    file = <cache_dir>/<key>.json   (atomic tmp+rename writes)

The *cluster signature* rounds profiled speeds to ``speed_decimals`` so
measurement jitter below the rebalance threshold maps to the same entry;
the *model* component is a content hash of the DiTConfig; the *workload*
component is every planner-visible knob (resolution enters through
p_total and the byte provenance, steps through m_base, plus guidance /
seq / stage knobs).

``StadiPipeline.plan()`` consults the cache before any planner search when
``StadiConfig.plan_cache_dir`` is set, and OnlineProfiler drift (the
pipeline rebalance hook or the serving engine's replanner) invalidates the
entry the drifted run was planned from. Corrupted or unreadable entries
fall back to live planning loudly — a warning and a ``corrupt`` counter,
never a crash. Hit/miss/invalidation counters are surfaced through
``DiffusionServingEngine.stats()``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import warnings
from typing import Dict, Optional, Sequence

from repro.core.frames import FramePlan
from repro.core.guidance import GuidancePlan
from repro.core.planners import ExecutionPlan
from repro.core.schedule import TemporalPlan
from repro.core.seqpar import SeqPlan

#: bump when the serialized plan layout changes — old entries miss cleanly
#: (2: the frame axis, DESIGN.md §16; 3: the prompt bucket in the workload
#: key + prompt-priced plans, DESIGN.md §17 — a v2 entry was priced with
#: t_xattn unthreaded and must invalidate loudly, not deserialize)
CACHE_VERSION = 3

DEFAULT_CACHE_DIR = os.path.join("results", "plan_cache")


def plan_to_dict(plan: ExecutionPlan) -> Dict:
    """JSON-ready dict for a fully-populated six-axis ExecutionPlan."""
    t = plan.temporal
    d = {
        "version": CACHE_VERSION,
        "temporal": {"steps": list(t.steps), "ratios": list(t.ratios),
                     "excluded": list(t.excluded), "m_base": t.m_base,
                     "m_warmup": t.m_warmup},
        "patches": list(plan.patches),
        "planner": plan.planner,
        "speeds": list(plan.speeds),
        "modeled_interval_cost": plan.modeled_interval_cost,
        "stages": None if plan.stages is None else list(plan.stages),
        "guidance": None,
        "seq": None,
        "frames": None,
    }
    if plan.guidance is not None:
        g = plan.guidance
        d["guidance"] = {
            "mode": g.mode, "scale": g.scale,
            "cond_devices": list(g.cond_devices),
            "uncond_devices": list(g.uncond_devices),
            "uncond_refresh": g.uncond_refresh,
            "reuse_workers": (None if g.reuse_workers is None
                              else list(g.reuse_workers)),
        }
    if plan.seq is not None:
        d["seq"] = {"heads": list(plan.seq.heads),
                    "segments": list(plan.seq.segments)}
    if plan.frames is not None:
        d["frames"] = {"num_frames": plan.frames.num_frames,
                       "groups": list(plan.frames.groups)}
    return d


def plan_from_dict(d: Dict) -> ExecutionPlan:
    """Inverse of :func:`plan_to_dict`; raises on any layout mismatch
    (the caller treats that as a corrupt entry)."""
    if d.get("version") != CACHE_VERSION:
        raise ValueError(f"plan-cache entry version {d.get('version')!r} "
                         f"!= {CACHE_VERSION}")
    t = d["temporal"]
    temporal = TemporalPlan(steps=[int(s) for s in t["steps"]],
                            ratios=[int(r) for r in t["ratios"]],
                            excluded=[bool(e) for e in t["excluded"]],
                            m_base=int(t["m_base"]),
                            m_warmup=int(t["m_warmup"]))
    guidance = None
    if d["guidance"] is not None:
        g = d["guidance"]
        guidance = GuidancePlan(
            mode=g["mode"], scale=float(g["scale"]),
            cond_devices=tuple(int(i) for i in g["cond_devices"]),
            uncond_devices=tuple(int(i) for i in g["uncond_devices"]),
            uncond_refresh=int(g["uncond_refresh"]),
            reuse_workers=(None if g["reuse_workers"] is None
                           else tuple(int(i) for i in g["reuse_workers"])))
    seq = None
    if d["seq"] is not None:
        seq = SeqPlan(heads=tuple(int(h) for h in d["seq"]["heads"]),
                      segments=tuple(int(s) for s in d["seq"]["segments"]))
    frames = None
    if d["frames"] is not None:
        frames = FramePlan(num_frames=int(d["frames"]["num_frames"]),
                           groups=tuple(int(g) for g in
                                        d["frames"]["groups"]))
    mic = d["modeled_interval_cost"]
    return ExecutionPlan(temporal=temporal,
                         patches=[int(p) for p in d["patches"]],
                         planner=str(d["planner"]),
                         speeds=[float(v) for v in d["speeds"]],
                         modeled_interval_cost=(None if mic is None
                                                else float(mic)),
                         stages=(None if d["stages"] is None
                                 else [int(s) for s in d["stages"]]),
                         guidance=guidance, seq=seq, frames=frames)


@dataclasses.dataclass
class PlanCache:
    """Disk-backed planner-output cache with hit/miss/invalidation stats."""

    cache_dir: str = DEFAULT_CACHE_DIR
    #: profiled speeds are rounded to this many decimals in the cluster
    #: signature, so sub-threshold measurement jitter shares one entry
    speed_decimals: int = 2

    hits: int = 0
    misses: int = 0
    invalidations: int = 0
    corrupt: int = 0

    def signature(self, speeds: Sequence[float], model_key: str,
                  workload: Dict) -> str:
        """The cache key: sha256 over the canonical JSON of (cluster
        signature from rounded speeds, model config hash, workload shape)."""
        cluster = [round(float(v), self.speed_decimals) for v in speeds]
        payload = {"version": CACHE_VERSION, "cluster": cluster,
                   "model": model_key, "workload": workload}
        blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()[:32]

    def _path(self, key: str) -> str:
        return os.path.join(self.cache_dir, f"{key}.json")

    def get(self, key: str) -> Optional[ExecutionPlan]:
        """The cached plan for ``key``, or None (counted as a miss).
        A corrupted entry warns, counts as corrupt + miss, is removed, and
        planning proceeds live — never a crash."""
        path = self._path(key)
        try:
            with open(path) as f:
                raw = f.read()
        except OSError:
            self.misses += 1
            return None
        try:
            plan = plan_from_dict(json.loads(raw))
        except Exception as e:  # corrupt/garbage/stale-layout entry
            self.corrupt += 1
            self.misses += 1
            warnings.warn(f"plan cache entry {path} is unreadable "
                          f"({type(e).__name__}: {e}); falling back to live "
                          "planning", RuntimeWarning, stacklevel=2)
            try:
                os.remove(path)
            except OSError:
                pass
            return None
        self.hits += 1
        return plan

    def put(self, key: str, plan: ExecutionPlan) -> None:
        """Persist atomically (tmp file + rename) so a crashed writer can
        never leave a half-written entry behind."""
        os.makedirs(self.cache_dir, exist_ok=True)
        blob = json.dumps(plan_to_dict(plan), sort_keys=True)
        fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(blob)
            os.replace(tmp, self._path(key))
        except BaseException:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise

    def invalidate(self, key: str) -> bool:
        """Drop ``key`` (profiled speeds drifted past the threshold, so the
        persisted plan no longer matches the cluster). True if an entry was
        actually removed."""
        try:
            os.remove(self._path(key))
        except OSError:
            return False
        self.invalidations += 1
        return True

    def stats(self) -> Dict:
        total = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "invalidations": self.invalidations, "corrupt": self.corrupt,
                "hit_rate": (self.hits / total) if total else 0.0}
