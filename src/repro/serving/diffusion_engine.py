"""Slot-based continuous batching for diffusion requests over StadiPipeline.

The LLM engine (:mod:`repro.serving.engine`) batches decode steps; this is
its diffusion counterpart (DESIGN.md §9). Each :class:`DiffusionRequest`
carries its own position on the fine DDIM grid, so requests admitted at
different times coexist in one denoise dispatch:

    pipe   = StadiPipeline(cfg, params, sched, config)      # any planner
    engine = DiffusionServingEngine(pipe, slots=8)
    reqs   = [engine.submit(x_T, cond) for ...]             # FIFO queue
    engine.run_to_completion()
    stats  = engine.stats()          # per-request latency / SLO, throughput

One scheduling **round** = admit (FIFO, lowest free slot) -> one warmup fine
step for warmup-phase lanes -> one adaptive interval (``plan.lcm`` fine
steps) for adaptive-phase lanes -> retire finished lanes. All per-lane state
(latent, stale-KV ``Published`` buffers, class condition) lives in
slot-major stacked arrays, so a batched step is a gather / one vmapped
denoiser dispatch / scatter.

Numerics: the "emulated" stepper mirrors ``patch_parallel.run_schedule``
call-for-call — same jit boundaries, eager DDIM updates, publish-at-first-
substep and merge-at-interval-boundary buffer semantics — and vmap lanes are
computed independently, so every request's final image is **bitwise
identical** to a single-request ``pipe.generate`` (tested). The "spmd"
stepper instead shard_maps each interval across ``jax.devices()`` for
cohorts of requests that share a fine-step position.

Latency: every round is costed against ``StadiConfig.cluster`` with the
``simulate`` cost model — per-round device placement assigns the heaviest
patch-worker load to the fastest device (deterministic) — and each request
accrues modeled wall-clock from submission to completion, giving queueing +
service latency and SLO accounting that tests can assert exactly.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import buffers as buf_lib
from repro.core import comm as comm_lib
from repro.core import events as ir
from repro.core import hetero
from repro.core import patch_parallel as pp
from repro.core import pipefuse as pipefuse_lib
from repro.core import sampler as sampler_lib
from repro.core import simulate as sim
from repro.core.pipeline import (ReplanEvent, StadiPipeline,
                                 check_backend_can_run, get_stepper_factory,
                                 register_stepper_factory)
from repro.core.planners import ExecutionPlan
from repro.core.schedule import patch_bounds
from repro.core.simulate import CostModel
from repro.models.diffusion import dit


@dataclasses.dataclass
class DiffusionRequest:
    """One queued generation request plus its serving statistics.

    ``fine_step`` is the request's own position on the fine DDIM grid
    (0..m_base); the engine advances it by 1 per warmup round and by
    ``plan.lcm`` per adaptive round.
    """
    uid: int
    x_T: jnp.ndarray                     # [1, H, W, C]
    # class conditioning: [1] int32; prompt conditioning (DESIGN.md §17):
    # [1, L, cond_dim+1] float32 tokens+mask, L the request's length bucket
    cond: jnp.ndarray
    slo_s: Optional[float] = None        # modeled-latency SLO target
    # classifier-free guidance (DESIGN.md §12): None = unguided request;
    # > 0 = this request denoises with eps_u + cfg_scale*(eps_c - eps_u)
    # (per-lane state; CFG and non-CFG requests coexist in one batch)
    cfg_scale: Optional[float] = None

    @property
    def guided(self) -> bool:
        return self.cfg_scale is not None and self.cfg_scale > 0.0
    # engine-owned state
    fine_step: int = 0
    image: Optional[jnp.ndarray] = None
    done: bool = False
    preempt_count: int = 0               # evictions back to the queue head
    # statistics (rounds are engine scheduling rounds; latency is modeled
    # wall-clock on the configured cluster, queueing included)
    submit_round: int = -1
    admit_round: int = -1
    finish_round: int = -1
    submit_clock_s: float = 0.0
    modeled_latency_s: float = 0.0
    wall_latency_s: float = 0.0
    _submit_wall: float = 0.0

    @property
    def queue_rounds(self) -> int:
        return self.admit_round - self.submit_round

    @property
    def slo_met(self) -> Optional[bool]:
        if self.slo_s is None or not self.done:
            return None
        return self.modeled_latency_s <= self.slo_s


@dataclasses.dataclass
class RoundReport:
    """What one scheduling round did (admissions, groups, placement, cost)."""
    index: int
    admitted: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    warmup_lanes: List[int] = dataclasses.field(default_factory=list)
    adaptive_lanes: List[int] = dataclasses.field(default_factory=list)
    exchange_kinds: List[str] = dataclasses.field(default_factory=list)
    placement: Optional[Tuple[Tuple[int, int], ...]] = None  # (worker, device)
    modeled_s: float = 0.0
    wall_s: float = 0.0


# ----------------------------------------------------------------------
# steppers (registered into repro.core.pipeline.STEPPER_FACTORIES)
# ----------------------------------------------------------------------
#
# The vmapped denoiser steps are MODULE-LEVEL jitted functions (params as an
# argument, cfg/row_start static) so every engine instance shares one
# compilation cache — per-instance jax.jit wrappers would recompile the hot
# loop for each engine and hand the throughput win back to the sequential
# baseline, whose pp._jit_* functions are likewise cached at module level.

@functools.partial(jax.jit, static_argnames=("cfg",))
def _vmap_full_step(params, cfg, xs, ts, conds):
    """Lane-stacked synchronous full-image step: xs [G,1,H,W,C], ts [G]."""
    def one(x, t, cond):
        return dit.forward_patch(params, cfg, x, t, cond, 0, buffers=None,
                                 return_kv=True)
    return jax.vmap(one)(xs, ts, conds)


@functools.partial(jax.jit, static_argnames=("cfg", "row_start"))
def _vmap_patch_step(params, cfg, xs_loc, ts, conds, bks, bvs, row_start):
    """Lane-stacked stale-KV patch step (vmapped ``pp._jit_patch_step``)."""
    def one(x_loc, t, cond, bk, bv):
        return dit.forward_patch(params, cfg, x_loc, t, cond, row_start,
                                 buffers=(bk, bv), return_kv=True)
    return jax.vmap(one)(xs_loc, ts, conds, bks, bvs)


# Guided (classifier-free guidance, DESIGN.md §12) lane steps: the per-lane
# body is the SAME branch-vmapped fused-CFG eval as the single-request
# engine's pp._jit_guided_*_step, lane-vmapped on top — so a guided lane
# stays bitwise identical to a single-request guided ``generate``. scales
# is per-lane data: one compiled program serves every cfg_scale in flight.
# With Pallas on, the combine is the same fused epilogue generate uses
# (DESIGN.md §15) — applied inside the lane vmap so scale stays scalar and
# the kernel path is taken; XLA fuses the batched program differently from
# the unbatched one, so the engine≡generate guarantee is bitwise for
# reference numerics and ≈1e-6 relative under forced kernels.


def _lane_cfg_combine(cfg, eps2, scale):
    if cfg.use_pallas_attention:
        from repro.kernels import ops as kops
        return kops.cfg_epilogue(eps2[0], eps2[1], scale, with_delta=False)
    return sampler_lib.cfg_combine(eps2[0], eps2[1], scale)

@functools.partial(jax.jit, static_argnames=("cfg",))
def _vmap_guided_full_step(params, cfg, xs, ts, conds, scales):
    """Lane-stacked guided synchronous step: xs [G,1,H,W,C], scales [G].
    Returns (eps [G,1,H,W,C], (k2, v2) [G,2,L,1,N,H,hd])."""
    def one(x, t, cond, scale):
        def branch(c):
            return dit.forward_patch(params, cfg, x, t, c, 0, buffers=None,
                                     return_kv=True)
        eps2, kv2 = jax.vmap(branch)(dit.guidance_conds(cond))
        return _lane_cfg_combine(cfg, eps2, scale), kv2
    return jax.vmap(one)(xs, ts, conds, scales)


@functools.partial(jax.jit, static_argnames=("cfg", "row_start"))
def _vmap_guided_patch_step(params, cfg, xs_loc, ts, conds, bk2s, bv2s,
                            scales, row_start):
    """Lane-stacked guided stale-KV patch step against branch-stacked
    published buffers bk2s/bv2s [G,2,L,1,N,H,hd]."""
    def one(x_loc, t, cond, bk2, bv2, scale):
        def branch(c, bk, bv):
            return dit.forward_patch(params, cfg, x_loc, t, c, row_start,
                                     buffers=(bk, bv), return_kv=True)
        eps2, kv2 = jax.vmap(branch)(dit.guidance_conds(cond), bk2, bv2)
        return _lane_cfg_combine(cfg, eps2, scale), kv2
    return jax.vmap(one)(xs_loc, ts, conds, bk2s, bv2s, scales)


class _VmapWarmupMixin:
    """Warmup / bootstrap steps shared by both steppers: synchronous
    full-image forwards, vmapped over lanes (per-lane timestep)."""

    #: can this stepper run guided (CFG) lanes? (DESIGN.md §12)
    supports_guidance = False

    def _init_warmup(self, params, model_cfg, sched):
        self.params = params
        self.model_cfg = model_cfg
        self.sched = sched

    def _warmup_finish(self, xs, t_from, t_to, eps, ks, vs):
        shape = (xs.shape[0],) + (1,) * (xs.ndim - 1)
        xs = sampler_lib.ddim_step(self.sched, xs, eps,
                                   t_from.reshape(shape), t_to.reshape(shape))
        return xs, ks, vs

    def warmup_step(self, xs, t_from, t_to, conds):
        """One synchronous fine step per lane: returns (xs', ks, vs)."""
        eps, (ks, vs) = _vmap_full_step(self.params, self.model_cfg, xs,
                                        t_from, conds)
        return self._warmup_finish(xs, t_from, t_to, eps, ks, vs)

    def warmup_step_guided(self, xs, t_from, t_to, conds, scales):
        """Guided synchronous step per lane: returns (xs', k2s, v2s) with
        branch-stacked fresh K/V [G,2,L,1,N,H,hd]."""
        eps, (k2s, v2s) = _vmap_guided_full_step(self.params, self.model_cfg,
                                                 xs, t_from, conds, scales)
        return self._warmup_finish(xs, t_from, t_to, eps, k2s, v2s)



@register_stepper_factory("emulated")
class EmulatedStepper(_VmapWarmupMixin):
    """vmapped mirror of ``run_schedule``'s adaptive loop: per (worker,
    substep) one jitted denoiser dispatch covers every lane, lanes may sit at
    different fine steps (timestep is per-lane data). Bitwise identical per
    lane to the single-request engine."""

    cohort_only = False
    supports_guidance = True

    def __init__(self, pipeline: StadiPipeline, plan: ExecutionPlan,
                 slots: int):
        self._init_warmup(pipeline.params, pipeline.model_cfg, pipeline.sched)
        self.plan = plan
        self._ts = sampler_lib.ddim_timesteps(pipeline.sched.T,
                                              plan.temporal.m_base)

    def _interval_impl(self, xs, fine0, conds, pub_k, pub_v, merge,
                       step_fn, tok_axis):
        """The ONE lane-interval loop both the plain and guided entry
        points share: per (worker, substep) one ``step_fn`` dispatch covers
        every lane, slabs scatter back, and first-substep K/V merges into
        the published buffers at ``tok_axis`` (3 plain, 4 branch-stacked)
        in ascending worker order — mirroring ``buffers.merge``."""
        plan, cfg = self.plan.temporal, self.model_cfg
        R, p = plan.lcm, cfg.patch_size
        G = xs.shape[0]
        fine0 = np.asarray(fine0)
        bounds_tok = patch_bounds(self.plan.patches)
        bounds_lat = [(a * p, b * p) for a, b in bounds_tok]
        workers = [i for i in plan.active if self.plan.patches[i] > 0]
        tshape = (G,) + (1,) * (xs.ndim - 1)

        pending, new_slabs = {}, {}
        for i in workers:
            r = plan.ratios[i]
            lo, hi = bounds_lat[i]
            x_loc = xs[:, :, lo:hi]
            for s in range(R // r):
                t_from = self._ts[fine0 + s * r]
                t_to = self._ts[fine0 + (s + 1) * r]
                eps, (k, v) = step_fn(x_loc, t_from, bounds_tok[i][0])
                x_loc = sampler_lib.ddim_step(self.sched, x_loc, eps,
                                              t_from.reshape(tshape),
                                              t_to.reshape(tshape))
                if s == 0:           # Alg.1: publish the first substep's KV
                    pending[i] = (k, v)
            new_slabs[i] = x_loc
        # interval boundary: all-gather of x + buffer merge (same order as
        # buffers.merge: ascending worker id)
        for i in workers:
            lo, hi = bounds_lat[i]
            xs = xs.at[:, :, lo:hi].set(new_slabs[i])
        if merge:
            for i in sorted(pending):
                k, v = pending[i]
                start = bounds_tok[i][0] * cfg.tokens_per_side
                pub_k = jax.lax.dynamic_update_slice_in_dim(
                    pub_k, k.astype(pub_k.dtype), start, axis=tok_axis)
                pub_v = jax.lax.dynamic_update_slice_in_dim(
                    pub_v, v.astype(pub_v.dtype), start, axis=tok_axis)
        return xs, pub_k, pub_v

    def interval(self, xs, fine0, conds, pub_k, pub_v, merge: bool = True):
        """One adaptive interval (plan.lcm fine steps) for every lane.

        xs [G,1,H,W,C]; fine0 int per lane; pub_{k,v} [G,L,1,N,H,hd] — the
        READ buffers (the engine passes extrapolated copies for predictive
        boundaries). ``merge=False`` is the "skip"/"predict" trailing
        boundary: fresh K/V is never broadcast, the buffers come back
        untouched.
        """
        def step(x_loc, t_from, row0):
            return _vmap_patch_step(self.params, self.model_cfg, x_loc,
                                    t_from, conds, pub_k, pub_v, row0)
        return self._interval_impl(xs, fine0, conds, pub_k, pub_v, merge,
                                   step, tok_axis=3)

    def interval_guided(self, xs, fine0, conds, scales, pub_k, pub_v,
                        merge: bool = True):
        """One adaptive interval for GUIDED lanes (DESIGN.md §12): the
        same worker/substep structure as :meth:`interval`, every denoiser
        dispatch a branch-vmapped fused-CFG eval against branch-stacked
        buffers pub_{k,v} [G,2,L,1,N,H,hd]; scales [G] is per-lane data."""
        def step(x_loc, t_from, row0):
            return _vmap_guided_patch_step(self.params, self.model_cfg,
                                           x_loc, t_from, conds, pub_k,
                                           pub_v, scales, row0)
        return self._interval_impl(xs, fine0, conds, pub_k, pub_v, merge,
                                   step, tok_axis=4)


@functools.partial(jax.jit, static_argnames=("cfg", "row_start", "bounds"))
def _vmap_displaced_step(params, cfg, xs_loc, ts, conds, ctx_ks, ctx_vs,
                         row_start, bounds):
    """Lane-stacked displaced micro-task (vmapped ``pipefuse.
    displaced_step``): every lane carries its own stage contexts."""
    def one(x_loc, t, cond, ck, cv):
        return pipefuse_lib.displaced_step(params, cfg, x_loc, t, cond,
                                           row_start, ck, cv, bounds)
    return jax.vmap(one)(xs_loc, ts, conds, ctx_ks, ctx_vs)


@register_stepper_factory("pipefuse")
class PipefuseStepper(EmulatedStepper):
    """Displaced patch-pipeline serving (DESIGN.md §11): at one stage this
    IS the EmulatedStepper (bitwise); at S > 1 each interval runs the same
    substep-major micro order as ``pipefuse.run_pipefuse`` with lane-stacked
    displaced contexts, so per-request images stay bitwise identical to a
    single-request ``generate`` on the pipefuse backend."""

    def __init__(self, pipeline: StadiPipeline, plan: ExecutionPlan,
                 slots: int):
        super().__init__(pipeline, plan, slots)
        self.stages = plan.stages or [pipeline.model_cfg.n_layers]
        self.bounds = pipefuse_lib.stage_bounds(self.stages)

    @property
    def wants_ctx(self) -> bool:
        return len(self.stages) > 1

    @property
    def supports_guidance(self) -> bool:
        # at one stage this IS the EmulatedStepper; lane-stacked displaced
        # contexts don't carry guided branch state (future work)
        return not self.wants_ctx

    def interval_ctx(self, xs, fine0, conds, pub_k, pub_v, ctx_k, ctx_v,
                     merge: bool = True):
        """One adaptive interval through the stage chain.

        ctx_{k,v} [G,L,1,N,H,hd] are the lanes' displaced contexts (reset to
        the published buffers by the engine on fill intervals). Returns
        (xs', pub_k', pub_v', ctx_k', ctx_v').
        """
        plan, cfg = self.plan.temporal, self.model_cfg
        R, p = plan.lcm, cfg.patch_size
        G = xs.shape[0]
        fine0 = np.asarray(fine0)
        bounds_tok = patch_bounds(self.plan.patches)
        bounds_lat = [(a * p, b * p) for a, b in bounds_tok]
        workers = [i for i in plan.active if self.plan.patches[i] > 0]
        tshape = (G,) + (1,) * (xs.ndim - 1)

        pending, slabs = {}, {}
        for i in workers:
            lo, hi = bounds_lat[i]
            slabs[i] = xs[:, :, lo:hi]
        for f in range(R):                   # substep-major micro order
            for i in workers:
                r = plan.ratios[i]
                if f % r:
                    continue
                t_from = self._ts[fine0 + f]
                t_to = self._ts[fine0 + f + r]
                eps, k, v, ctx_k, ctx_v = _vmap_displaced_step(
                    self.params, cfg, slabs[i], t_from, conds, ctx_k, ctx_v,
                    bounds_tok[i][0], self.bounds)
                slabs[i] = sampler_lib.ddim_step(self.sched, slabs[i], eps,
                                                 t_from.reshape(tshape),
                                                 t_to.reshape(tshape))
                if f == 0:
                    pending[i] = (k, v)
        for i in workers:
            lo, hi = bounds_lat[i]
            xs = xs.at[:, :, lo:hi].set(slabs[i])
        if merge:
            for i in sorted(pending):
                k, v = pending[i]
                start = bounds_tok[i][0] * cfg.tokens_per_side
                pub_k = jax.lax.dynamic_update_slice_in_dim(
                    pub_k, k.astype(pub_k.dtype), start, axis=3)
                pub_v = jax.lax.dynamic_update_slice_in_dim(
                    pub_v, v.astype(pub_v.dtype), start, axis=3)
        return xs, pub_k, pub_v, ctx_k, ctx_v


@register_stepper_factory("spmd")
class SpmdStepper(_VmapWarmupMixin):
    """shard_map adaptive intervals over real ``jax.devices()``: lanes are
    stacked on the model batch axis, so every lane of one call must share a
    fine-step position (``cohort_only``) — the engine groups cohorts by
    ``fine_step``. Warmup stays on the host (synchronous steps are exact
    full-image forwards, which SPMD executes redundantly anyway)."""

    cohort_only = True

    _cache: Dict[Tuple, object] = {}          # shared across engine instances

    def __init__(self, pipeline: StadiPipeline, plan: ExecutionPlan,
                 slots: int):
        from repro.core import spmd
        self._init_warmup(pipeline.params, pipeline.model_cfg, pipeline.sched)
        self.plan = plan
        n_workers = len(plan.patches)
        if n_workers > len(jax.devices()):
            raise ValueError(
                f"spmd serving needs {n_workers} devices, have "
                f"{len(jax.devices())} (set STADI_HOST_DEVICES)")
        sched = pipeline.sched            # content-keyed: id() could alias
        self._key = (pipeline.model_cfg, tuple(plan.patches),
                     tuple(plan.temporal.ratios), plan.temporal.m_base,
                     plan.temporal.m_warmup, sched.T,
                     np.asarray(sched.alpha_bar).tobytes())
        self._spmd = spmd
        self._variant("full")             # compile the common case eagerly

    def _variant(self, kind: str):
        """One compiled interval program per boundary kind ("full" merges
        fresh K/V, "skip" leaves the buffers stale — predictive callers
        extrapolate host-side and use the "skip" variant)."""
        key = self._key + (kind,)
        if key not in SpmdStepper._cache:
            SpmdStepper._cache[key] = self._spmd.make_interval_step(
                self.model_cfg, self.sched, self.plan.temporal,
                self.plan.patches, exchange_kind=kind)
        return SpmdStepper._cache[key]

    def interval(self, xs, fine0, conds, pub_k, pub_v, merge: bool = True):
        fine0 = np.asarray(fine0)
        assert (fine0 == fine0[0]).all(), \
            "spmd stepper is cohort-only: lanes must share fine_step"
        # lane-major [G,1,...] -> batch-major [G,...] / [L,G,N,H,hd]
        x = xs[:, 0]
        bk = jnp.moveaxis(pub_k[:, :, 0], 0, 1)
        bv = jnp.moveaxis(pub_v[:, :, 0], 0, 1)
        fn = self._variant("full" if merge else "skip")
        x, bk, bv = fn(self.params, x, conds[:, 0], bk, bv,
                       jnp.int32(fine0[0]))
        return (x[:, None], jnp.moveaxis(bk, 1, 0)[:, :, None],
                jnp.moveaxis(bv, 1, 0)[:, :, None])


# ----------------------------------------------------------------------
# the engine
# ----------------------------------------------------------------------

class DiffusionServingEngine:
    """Continuous batching of diffusion requests over one StadiPipeline.

    Admission: FIFO queue into the lowest free slot at the start of every
    round; a slot freed this round is refilled next round. Placement: each
    round the plan's patch-workers are assigned to cluster devices by the
    cost model (heaviest load -> fastest device, deterministic ties), and the
    modeled round time — batched compute, boundary all-gather, masked async
    KV — is accrued to every in-flight request.
    """

    def __init__(self, pipeline: StadiPipeline, *, slots: int = 4,
                 cost_model: Optional[CostModel] = None,
                 rebalance_every: int = 0,
                 rebalance_threshold: float = 0.2,
                 measured_speeds: Optional[Sequence[float]] = None):
        config = pipeline.config
        if config.rebalance_every:
            raise ValueError("serving drives placement per round; disable "
                             "rebalance_every on the pipeline config (the "
                             "engine's own rebalance_every kwarg replans "
                             "between rounds)")
        if slots < 1:
            raise ValueError("need at least one slot")
        self.pipeline = pipeline
        self.slots = slots
        self.plan = pipeline.plan()
        check_backend_can_run(self.plan, config)
        # classifier-free guidance (DESIGN.md §12/§14): serving batches
        # FUSED lane cohorts (every worker computes both branches) and
        # SPLIT lane cohorts (workers are cond/uncond device PAIRS, eps
        # exchanged between dispatches — same numerics by construction,
        # pair-placed cost). Interleaved uncond reuse remains a
        # per-generation optimization.
        gplan = self.plan.guidance
        if gplan is not None and gplan.mode == "interleaved":
            raise ValueError(
                "serving batches fused- or split-CFG lane cohorts; "
                "'interleaved' uncond reuse is per-generation — use "
                "pipe.generate, or set guidance='fused'|'split'")
        self.default_scale = gplan.scale if gplan is not None else None
        self.cm = cost_model or config.cost_model
        # placement needs SOME cost model; flag the uncalibrated fallback so
        # modeled latencies / SLO verdicts are never mistaken for calibrated
        self.cm_calibrated = self.cm is not None
        if self.cm is None:
            self.cm = CostModel(t_fixed=1e-3, t_row=1e-3)
        cfg = pipeline.model_cfg
        self._ts = sampler_lib.ddim_timesteps(pipeline.sched.T,
                                              self.plan.temporal.m_base)
        H, C = cfg.latent_size, cfg.channels
        self._x = jnp.zeros((slots, 1, H, H, C), jnp.float32)
        kshape = (slots,) + dit.buffer_shape(cfg, 1)
        kdt = jnp.dtype(cfg.dtype)
        self._kshape = kshape
        self._pub_k = jnp.zeros(kshape, kdt)
        self._pub_v = jnp.zeros(kshape, kdt)
        self._cond = jnp.zeros((slots, 1), jnp.int32)
        # prompt conditioning (DESIGN.md §17): with a text-conditioned
        # model every request carries a [1, L, cond_dim+1] token tensor.
        # L varies per request (the encoder's power-of-two length bucket),
        # so prompt conds live on the requests — _conds() stacks a lane
        # group's, and the group key pins one bucket per dispatch.
        self._prompt_mode = bool(cfg.cross_attn)
        # guided lanes: branch-stacked published K/V [slots,2,L,1,N,H,hd]
        # + per-lane cfg_scale; allocated on the first guided submission so
        # CFG-free serving carries no extra state
        self._kshape2 = (slots, 2) + dit.buffer_shape(cfg, 1)
        self._kdt = kdt
        self._gk = self._gv = None
        self._prev_gk = self._prev_gv = None
        self._prev_k = self._prev_v = None
        self._scales = np.zeros(slots, np.float32)
        # displaced patch pipeline (DESIGN.md §11): stage chain + per-lane
        # displaced contexts (only materialized when depth is partitioned)
        self.stages = self.plan.stages
        staged = self.stages is not None and len(self.stages) > 1
        self._ctx_k = jnp.zeros(kshape, kdt) if staged else None
        self._ctx_v = jnp.zeros(kshape, kdt) if staged else None
        # sequence-parallel attention (DESIGN.md §13): seq sharding
        # repartitions WHERE attention runs (device groups + ring hops),
        # never WHAT is computed, so the emulated stepper serves seq-sharded
        # lanes bitwise unchanged — only the lane group key (per-interval
        # ring hop count) and the modeled round cost see the shards.
        self.seq = self.plan.seq
        if self.seq is not None and len(self.seq.segments) < 2:
            self.seq = None
        if self.seq is not None and staged:
            raise ValueError(
                "serving does not compose sequence sharding with a "
                "displaced stage chain; run seq-sharded lanes on the "
                "single-stage 'emulated' backend")
        self._seq_groups = None
        self._seq_seg_pad = 0.0
        if self.seq is not None:
            from repro.core import seqpar
            groups, _ = seqpar.seq_group_speeds(list(config.speeds),
                                                self.seq.n_shards)
            self._seq_groups = groups
            self._seq_seg_pad = max(self.seq.seg_fracs)
        # frame axis (DESIGN.md §16): video lanes. Cross-frame stale-K/V
        # state lives per CLIP (frame f attends the previous frame's
        # published buffers), not per slot, so a video request runs its
        # whole multi-frame schedule in the round it is admitted — a
        # run-to-completion lane cohort. Rounds still admit FIFO into
        # slots and accrue the frame-priced schedule makespan per clip,
        # so queueing delay, SLO verdicts and throughput stats stay
        # meaningful.
        self.frames = self.plan.frames
        if self.frames is not None and self.frames.num_frames < 2:
            self.frames = None
        if self.frames is not None and rebalance_every:
            raise ValueError(
                "the frame grouping is static — engine replanning would "
                "re-deal the frame-group rows; serve video plans with "
                "rebalance_every=0")
        self.policy = comm_lib.get_exchange(config.exchange,
                                            config.exchange_refresh)
        # online replanning (DESIGN.md §7.1 composed with §12/§14): the
        # ground-truth speeds the cluster actually runs at (emulation's
        # stand-in for per-interval timers), the drift profiler, and the
        # replan cadence. With split guidance a replan re-pairs the
        # cond/uncond device groups (the stadi_guidance planner re-runs
        # guidance_groups over the profiled speeds).
        self.measured_speeds = (list(measured_speeds)
                                if measured_speeds is not None
                                else list(config.speeds))
        if len(self.measured_speeds) != config.n_devices:
            raise ValueError(f"measured_speeds has "
                             f"{len(self.measured_speeds)} entries for a "
                             f"{config.n_devices}-device cluster")
        self.rebalance_every = int(rebalance_every)
        self.rebalance_threshold = rebalance_threshold
        self.replans: List[ReplanEvent] = []
        self.preemptions = 0
        self._pending_plan: Optional[Tuple[ExecutionPlan, float]] = None
        self._rounds_since_check = 0
        self.profiler: Optional[hetero.OnlineProfiler] = None
        if self.rebalance_every:
            if staged or self.seq is not None:
                raise ValueError(
                    "engine replanning re-deals patch workers; staged / "
                    "seq-sharded plans pin their device grouping — serve "
                    "them with rebalance_every=0")
            self.profiler = hetero.OnlineProfiler(
                list(config.speeds), alpha=config.profiler_alpha)
            self._baseline = list(config.speeds)
        # kernel-path visibility (DESIGN.md §15): the engine's steppers
        # trace their own programs (not pipeline.generate), so attribute
        # every hit/miss traced after construction to this engine
        from repro.kernels import ops as kops
        self._kernel_stats_base = kops.kernel_stats_snapshot()
        self.queue: List[DiffusionRequest] = []
        self.active: Dict[int, DiffusionRequest] = {}   # slot -> request
        self.completed: List[DiffusionRequest] = []
        self.rounds: List[RoundReport] = []
        self.modeled_clock_s = 0.0
        self._next_uid = 0
        self._install_plan(self.plan)
        if self.rebalance_every and self.stepper.cohort_only:
            raise ValueError("engine replanning rebuilds the lane stepper "
                             "per plan; the cohort-only (spmd) stepper "
                             "compiles one static program — serve it with "
                             "rebalance_every=0")

    def _install_plan(self, plan: ExecutionPlan) -> None:
        """(Re)build every plan-derived piece of engine state: the lane
        stepper, the split-guidance pair map, the per-fine-step boundary
        info, the predictive-extrapolation buffers, and the comm byte
        sizing. Called once at construction and again at every online
        replan (same m_base/m_warmup grid; stages/seq replans are rejected
        up front)."""
        pipeline, config = self.pipeline, self.pipeline.config
        cfg = pipeline.model_cfg
        self.plan = plan
        if self.frames is not None:
            # video lanes (DESIGN.md §16): no batched lane stepper — each
            # clip's schedule runs whole through the configured frame
            # executor in _frames_round. The per-clip modeled cost comes
            # from the SAME frame-priced trace the simulate backend
            # replays, so serving accounting cannot diverge from
            # simulate_trace's.
            self._guide_pairs = None
            self.stepper = None
            self._interval_info = {}
            self._track_prev = False
            trace = sim.build_trace(plan.temporal, plan.patches, cfg,
                                    batch=1, exchange=config.exchange,
                                    exchange_refresh=config.exchange_refresh,
                                    frames=self.frames,
                                    guidance=plan.guidance,
                                    cond_tokens=(config.cond_bucket or None))
            self._latent_bytes = trace.latent_bytes
            self._kv_bytes = trace.kv_bytes_per_worker
            self._act_row_bytes = trace.act_row_bytes
            self._clip_cost_s = sim.simulate_trace(
                trace, self.measured_speeds, self.cm)
            return
        gplan = plan.guidance
        # split-guidance lane cohorts: logical worker i is the device pair
        # (cond_devices[i], uncond_devices[i]) — used for pair-placed round
        # costs and for feeding the profiler both pair members
        self._guide_pairs = (list(zip(gplan.cond_devices,
                                      gplan.uncond_devices))
                             if gplan is not None and gplan.mode == "split"
                             else None)
        self.stepper = get_stepper_factory(config.backend)(
            pipeline, plan, self.slots)
        if (self.default_scale is not None
                and not self.stepper.supports_guidance):
            raise ValueError(f"backend {config.backend!r} has no guided "
                             "serving stepper (guided lanes need "
                             "'emulated' or single-stage 'pipefuse')")
        staged = self.stages is not None and len(self.stages) > 1
        # boundary-exchange policy (DESIGN.md §10): replay the SAME schedule
        # IR every lane follows and precompute, per adaptive-interval start
        # fine step, (read_factor, trail_kind, fill): read_factor is the K/V
        # extrapolation coefficient applied BEFORE the interval (0.0 =
        # fresh/stale reuse), trail_kind the exchange at the boundary AFTER
        # it, fill whether the displaced pipe refills entering it. Lanes are
        # grouped by this info, so one batched dispatch never mixes boundary
        # behaviors.
        self._interval_info: Dict[int, Tuple[float, str, bool, int]] = {}
        read_factor = 0.0
        m_prev: Optional[int] = None
        m_last = plan.temporal.m_warmup - 1   # warmup publish (-1 = boot)
        cur: Optional[int] = None
        fill = False
        seq_hops = 0
        for ev in ir.lower(plan.temporal, plan.patches, self.policy,
                           stages=self.stages if staged else None,
                           seq_shards=self.seq):
            if isinstance(ev, ir.StageShift):
                fill = True
            elif isinstance(ev, ir.SeqShard):
                seq_hops = ev.hops
            elif isinstance(ev, ir.ComputeInterval):
                cur = ev.fine_step
            elif isinstance(ev, ir.Exchange):
                self._interval_info[cur] = (read_factor, ev.kind, fill,
                                            seq_hops)
                fill = False
                seq_hops = 0
                if ev.kind == "full":
                    m_prev, m_last = m_last, ev.fine_step
                    read_factor = 0.0
                elif ev.kind == "skip":
                    read_factor = 0.0            # stale reuse
                elif ev.kind == "predict":
                    read_factor = (buf_lib.extrapolation_factor(
                        m_prev, m_last, ev.fine_step)
                        if m_prev is not None else 0.0)
        # last-but-one published K/V per lane (predictive extrapolation
        # base): these double the per-slot staged-KV footprint and cost a
        # copy per full boundary, so only materialize them when some
        # boundary actually extrapolates — never for staged steppers,
        # whose displaced contexts subsume prediction (predict == skip at
        # S > 1; extrapolated pub buffers would never be attended)
        self._track_prev = (not staged
                            and any(info[0] for info in
                                    self._interval_info.values()))
        if self._track_prev and self._prev_k is None:
            self._prev_k = jnp.zeros(self._kshape, self._kdt)
            self._prev_v = jnp.zeros(self._kshape, self._kdt)
        if self._track_prev and self._gk is not None and self._prev_gk is None:
            self._prev_gk = jnp.zeros(self._kshape2, self._kdt)
            self._prev_gv = jnp.zeros(self._kshape2, self._kdt)
        # per-lane comm sizing: taken from the same trace builder the
        # simulate backend replays, so serving cost accounting cannot
        # diverge from simulate_trace's
        trace = sim.build_trace(plan.temporal, plan.patches, cfg,
                                batch=1, stages=self.stages)
        self._latent_bytes = trace.latent_bytes
        self._kv_bytes = trace.kv_bytes_per_worker
        self._act_row_bytes = trace.act_row_bytes

    # ---------------- submission & admission ----------------

    def submit(self, x_T, cond, *, slo_s: Optional[float] = None,
               uid: Optional[int] = None,
               cfg_scale: Optional[float] = None) -> DiffusionRequest:
        """Queue one request. x_T: [H,W,C] or [1,H,W,C]; cond: int or [1].

        cfg_scale > 0 makes this a GUIDED request (classifier-free
        guidance, DESIGN.md §12); None inherits the pipeline config's
        cfg_scale (0 = unguided). CFG and non-CFG requests mix freely —
        guidance state is per lane.

        With a text-conditioned model (DESIGN.md §17) ``cond`` is a
        prompt-token tensor ``[L, cond_dim+1]`` or ``[1, L, cond_dim+1]``
        from :func:`repro.models.text_encoder.encode`; lane groups are
        keyed by the length bucket L, so one batched dispatch never mixes
        buckets.
        """
        x_T = jnp.asarray(x_T)
        if self.frames is not None:
            # video lane request: one clip = [F,H,W,C] or [1,F,H,W,C]
            if x_T.ndim == 4:
                x_T = x_T[None]
            if x_T.ndim != 5 or x_T.shape[0] != 1:
                raise ValueError(
                    "one request = one clip; video lanes take [F,H,W,C] "
                    f"or [1,F,H,W,C], got shape {tuple(x_T.shape)}")
            if x_T.shape[1] != self.frames.num_frames:
                raise ValueError(
                    f"request carries {x_T.shape[1]} frames, the plan "
                    f"serves {self.frames.num_frames}")
            if cfg_scale is not None and cfg_scale > 0:
                # guided video (DESIGN.md §17): the clip runs its WHOLE
                # schedule through the frame executor under the PLAN's
                # fused guidance — a per-request scale cannot override it
                gplan = self.plan.guidance
                if gplan is None:
                    raise ValueError(
                        "guided video lanes run the plan's fused CFG: "
                        "plan with cfg_scale > 0 (e.g. "
                        "planner='stadi_video') instead of a per-request "
                        "scale")
                if float(cfg_scale) != float(gplan.scale):
                    raise ValueError(
                        "video lanes run whole-clip schedules through the "
                        f"planned executor: per-request cfg_scale="
                        f"{cfg_scale} cannot override the plan's fused "
                        f"scale {gplan.scale}")
        elif x_T.ndim == 3:
            x_T = x_T[None]
        if x_T.shape[0] != 1:
            raise ValueError("one request = one image; got batch "
                             f"{x_T.shape[0]} (submit per image)")
        if self._prompt_mode:
            cond = jnp.asarray(cond, jnp.float32)
            if cond.ndim == 2:
                cond = cond[None]
            if cond.ndim != 3 or cond.shape[0] != 1:
                raise ValueError(
                    "a text-conditioned model takes prompt tokens "
                    "[L, cond_dim+1] or [1, L, cond_dim+1] (see "
                    "repro.models.text_encoder.encode), got shape "
                    f"{tuple(jnp.shape(cond))}")
            mcfg = self.pipeline.model_cfg
            if cond.shape[-1] != mcfg.cond_dim + 1:
                raise ValueError(
                    f"prompt tokens carry cond_dim+1={mcfg.cond_dim + 1} "
                    f"channels (features + validity mask), got "
                    f"{cond.shape[-1]}")
            if not 1 <= cond.shape[1] <= mcfg.cond_seq_len:
                raise ValueError(
                    f"prompt bucket {cond.shape[1]} is outside "
                    f"[1, cond_seq_len={mcfg.cond_seq_len}]")
        else:
            if getattr(np.asarray(cond), "ndim", 0) >= 2:
                raise ValueError(
                    "prompt-token cond needs a text-conditioned model "
                    "(DiTConfig.cross_attn=True, e.g. "
                    "cfg.text_conditioned()); this engine serves class-"
                    "conditional requests")
            cond = jnp.asarray(cond, jnp.int32).reshape((1,))
        if uid is None:
            uid, self._next_uid = self._next_uid, self._next_uid + 1
        else:
            self._next_uid = max(self._next_uid, uid + 1)
        if cfg_scale is None:
            cfg_scale = self.default_scale
        req = DiffusionRequest(uid=uid, x_T=x_T, cond=cond, slo_s=slo_s,
                               cfg_scale=cfg_scale)
        if req.guided and self.frames is None:
            if not self.stepper.supports_guidance:
                raise ValueError(
                    f"backend {self.pipeline.config.backend!r} has no "
                    "guided serving stepper (guided requests need "
                    "'emulated' or single-stage 'pipefuse')")
            if self._gk is None:
                self._gk = jnp.zeros(self._kshape2, self._kdt)
                self._gv = jnp.zeros(self._kshape2, self._kdt)
                if self._track_prev:
                    self._prev_gk = jnp.zeros(self._kshape2, self._kdt)
                    self._prev_gv = jnp.zeros(self._kshape2, self._kdt)
        req.submit_round = len(self.rounds)
        req.submit_clock_s = self.modeled_clock_s
        req._submit_wall = time.perf_counter()
        self.queue.append(req)
        return req

    def _admit(self, report: RoundReport) -> None:
        M_w = self.plan.temporal.m_warmup
        while self.queue and len(self.active) < self.slots:
            req = self.queue.pop(0)
            slot = next(s for s in range(self.slots) if s not in self.active)
            self._x = self._x.at[slot].set(req.x_T)
            if not self._prompt_mode:    # prompt conds live on the request
                self._cond = self._cond.at[slot].set(req.cond)
            self._scales[slot] = req.cfg_scale if req.guided else 0.0
            req.fine_step = 0
            req.admit_round = report.index
            if M_w == 0:
                # run_schedule's buffer bootstrap: one full forward at ts[0]
                # (shares the jit cache with the single-request engine)
                if req.guided:
                    _, _, kvs2 = pp._jit_guided_full_step(
                        self.pipeline.params, self.pipeline.model_cfg,
                        req.x_T, self._ts[0], req.cond, req.cfg_scale)
                    self._gk = self._gk.at[slot].set(kvs2[0])
                    self._gv = self._gv.at[slot].set(kvs2[1])
                else:
                    _, kvs = pp._jit_full_step(self.pipeline.params,
                                               self.pipeline.model_cfg,
                                               req.x_T, self._ts[0],
                                               req.cond)
                    self._pub_k = self._pub_k.at[slot].set(kvs[0])
                    self._pub_v = self._pub_v.at[slot].set(kvs[1])
            self.active[slot] = req
            report.admitted.append((req.uid, slot))

    def preempt(self, uid: int) -> bool:
        """Evict an active request back to the FRONT of the queue (it
        restarts from x_T on readmission — diffusion state is cheap to
        recompute relative to holding a slot past an SLO breach). True if
        the request was active; False if it was queued or already done."""
        for slot, req in list(self.active.items()):
            if req.uid == uid:
                del self.active[slot]
                req.fine_step = 0
                req.preempt_count += 1
                self.preemptions += 1
                self.queue.insert(0, req)
                return True
        return False

    # ---------------- online replanning (DESIGN.md §7.1 + §12/§14) -------

    def _feed_profiler(self) -> None:
        """One adaptive round's synthesized per-device interval timings.
        Under split guidance each logical worker feeds BOTH its pair
        devices, so the profiler sees every device's true speed."""
        temporal = self.plan.temporal
        subs = [0] * len(self.plan.patches)
        for i in temporal.active:
            if self.plan.patches[i] > 0:
                subs[i] = temporal.lcm // temporal.ratios[i]
        hetero.feed_profiler(self.profiler, self.cm, subs, self.plan.patches,
                             self.measured_speeds,
                             device_map=self._guide_pairs)

    def _maybe_replan(self) -> None:
        """Drift check at the rebalance cadence: when the profiled speeds
        left the planned ones behind, re-run the configured planner over
        them (re-pairing cond/uncond device groups under split guidance),
        invalidate the now-stale plan-cache entry, and stage the new plan
        for installation at the next grid-aligned round."""
        drift = self.profiler.drift(self._baseline)
        if drift <= self.rebalance_threshold:
            return
        pipe = self.pipeline
        stale_key = pipe.last_plan_key
        new = pipe.plan(self.profiler.speeds)
        if (pipe.plan_cache is not None and stale_key
                and stale_key != pipe.last_plan_key):
            pipe.plan_cache.invalidate(stale_key)
        self._pending_plan = (new, drift)

    def _try_install_pending(self) -> None:
        """Install a staged replan once every active adaptive lane sits on
        the new plan's interval grid (lanes advance plan.lcm fine steps per
        round, so a misaligned cohort retries next round)."""
        new, drift = self._pending_plan
        M_w = self.plan.temporal.m_warmup
        for req in self.active.values():
            if req.fine_step > M_w and (req.fine_step - M_w) % new.temporal.lcm:
                return
        self._pending_plan = None
        fine = min((r.fine_step for r in self.active.values()), default=M_w)
        self.replans.append(ReplanEvent(fine, drift, list(self._baseline),
                                        list(self.profiler.speeds), new))
        self._baseline = list(self.profiler.speeds)
        self._install_plan(new)

    # ---------------- one scheduling round ----------------

    def step(self) -> List[DiffusionRequest]:
        """One round: admit -> warmup group -> adaptive group(s) -> retire."""
        report = RoundReport(index=len(self.rounds))
        wall0 = time.perf_counter()
        if self.frames is not None:
            return self._frames_round(report, wall0)
        if self._pending_plan is not None:
            self._try_install_pending()
        self._admit(report)
        temporal = self.plan.temporal
        M_w, M_base, R = temporal.m_warmup, temporal.m_base, temporal.lcm
        warm = sorted(s for s, r in self.active.items()
                      if r.fine_step < M_w)
        adapt = sorted(s for s, r in self.active.items()
                       if r.fine_step >= M_w)
        report.warmup_lanes, report.adaptive_lanes = warm, adapt

        for guided, bucket, lanes in self._by_guided(warm):
            idx = self._pad(lanes)
            fine = np.asarray([self.active[s].fine_step for s in idx])
            if guided:
                xs, k2s, v2s = self.stepper.warmup_step_guided(
                    self._x[idx], self._ts[fine], self._ts[fine + 1],
                    self._conds(idx), jnp.asarray(self._scales[idx]))
                self._x = self._x.at[idx].set(xs)
                self._gk = self._gk.at[idx].set(k2s)
                self._gv = self._gv.at[idx].set(v2s)
            else:
                xs, ks, vs = self.stepper.warmup_step(
                    self._x[idx], self._ts[fine], self._ts[fine + 1],
                    self._conds(idx))
                self._scatter(idx, xs, ks, vs)
            for s in lanes:
                self.active[s].fine_step += 1
            _, cost = self._phase_cost(len(lanes), warm=True, guided=guided,
                                       cond_tokens=bucket)
            report.modeled_s += cost

        if adapt:
            placement = None
            wants_ctx = getattr(self.stepper, "wants_ctx", False)
            for group, (read_factor, trail_kind, fill, seq_hops,
                        guided, bucket) in self._groups(adapt):
                idx = self._pad(group)
                fine = np.asarray([self.active[s].fine_step for s in idx])
                merge = trail_kind == "full"
                if guided:           # branch-stacked per-lane CFG state
                    bk, bv = self._gk[idx], self._gv[idx]
                    if read_factor:
                        bk = buf_lib.extrapolate_arrays(
                            bk, self._prev_gk[idx], read_factor)
                        bv = buf_lib.extrapolate_arrays(
                            bv, self._prev_gv[idx], read_factor)
                    xs, ks, vs = self.stepper.interval_guided(
                        self._x[idx], fine, self._conds(idx),
                        jnp.asarray(self._scales[idx]), bk, bv, merge=merge)
                    self._x = self._x.at[idx].set(xs)
                    if merge:
                        if self._track_prev:
                            self._prev_gk = self._prev_gk.at[idx].set(
                                self._gk[idx])
                            self._prev_gv = self._prev_gv.at[idx].set(
                                self._gv[idx])
                        self._gk = self._gk.at[idx].set(ks)
                        self._gv = self._gv.at[idx].set(vs)
                    for s in group:
                        self.active[s].fine_step += R
                    placement, cost = self._phase_cost(
                        len(group), warm=False, kind=trail_kind, fill=fill,
                        guided=True, seq_hops=seq_hops, cond_tokens=bucket)
                    report.modeled_s += cost
                    report.exchange_kinds.append(trail_kind)
                    continue
                bk, bv = self._pub_k[idx], self._pub_v[idx]
                # predictive boundary before this group — staged steppers
                # never read the extrapolation (ctx subsumes it), so skip
                if read_factor and not wants_ctx:
                    bk = buf_lib.extrapolate_arrays(bk, self._prev_k[idx],
                                                    read_factor)
                    bv = buf_lib.extrapolate_arrays(bv, self._prev_v[idx],
                                                    read_factor)
                if wants_ctx:
                    if fill:         # pipe refill: contexts <- published
                        self._ctx_k = self._ctx_k.at[idx].set(
                            self._pub_k[idx])
                        self._ctx_v = self._ctx_v.at[idx].set(
                            self._pub_v[idx])
                    xs, ks, vs, ck, cv = self.stepper.interval_ctx(
                        self._x[idx], fine, self._conds(idx), bk, bv,
                        self._ctx_k[idx], self._ctx_v[idx],
                        merge=merge)
                    self._ctx_k = self._ctx_k.at[idx].set(ck)
                    self._ctx_v = self._ctx_v.at[idx].set(cv)
                else:
                    xs, ks, vs = self.stepper.interval(
                        self._x[idx], fine, self._conds(idx), bk, bv,
                        merge=merge)
                self._x = self._x.at[idx].set(xs)
                if merge:
                    if self._track_prev:
                        # pre-merge buffers become the extrapolation base
                        self._prev_k = self._prev_k.at[idx].set(
                            self._pub_k[idx])
                        self._prev_v = self._prev_v.at[idx].set(
                            self._pub_v[idx])
                    self._pub_k = self._pub_k.at[idx].set(ks)
                    self._pub_v = self._pub_v.at[idx].set(vs)
                for s in group:
                    self.active[s].fine_step += R
                placement, cost = self._phase_cost(len(group), warm=False,
                                                   kind=trail_kind,
                                                   fill=fill,
                                                   seq_hops=seq_hops,
                                                   cond_tokens=bucket)
                report.modeled_s += cost
                report.exchange_kinds.append(trail_kind)
            report.placement = placement
            if self.profiler is not None:
                self._feed_profiler()
                self._rounds_since_check += 1
                if (self._rounds_since_check >= self.rebalance_every
                        and self._pending_plan is None):
                    self._rounds_since_check = 0
                    self._maybe_replan()

        self.modeled_clock_s += report.modeled_s
        done_slots = [s for s, r in sorted(self.active.items())
                      if r.fine_step >= M_base]
        if done_slots:           # flush async dispatch BEFORE stamping wall
            jax.block_until_ready(self._x)
        finished = []
        for slot in done_slots:
            req = self.active.pop(slot)
            req.image = self._x[slot]
            req.done = True
            req.finish_round = report.index
            req.modeled_latency_s = self.modeled_clock_s - req.submit_clock_s
            req.wall_latency_s = time.perf_counter() - req._submit_wall
            finished.append(req)
        self.completed.extend(finished)
        report.wall_s = time.perf_counter() - wall0
        self.rounds.append(report)
        return finished

    def _frames_round(self, report: RoundReport,
                      wall0: float) -> List[DiffusionRequest]:
        """One video round (DESIGN.md §16): admit FIFO into free slots,
        then run every admitted clip's full multi-frame schedule
        back-to-back on the cluster through the configured frame executor.
        Each clip accrues the frame-priced schedule makespan (the same
        number ``simulate_trace`` gives the planner), sequentially — the
        cluster serves one clip at a time, so later clips in the round
        see the earlier clips' service time as queueing delay."""
        from repro.core.pipeline import get_executor
        config = self.pipeline.config
        M_base = self.plan.temporal.m_base
        while self.queue and len(self.active) < self.slots:
            req = self.queue.pop(0)
            slot = next(s for s in range(self.slots) if s not in self.active)
            req.fine_step = 0
            req.admit_round = report.index
            self.active[slot] = req
            report.admitted.append((req.uid, slot))
        executor = get_executor(config.backend)
        finished: List[DiffusionRequest] = []
        for slot in sorted(self.active):
            req = self.active.pop(slot)
            image, _ = executor(
                params=self.pipeline.params,
                model_cfg=self.pipeline.model_cfg,
                sched=self.pipeline.sched, x_T=req.x_T, cond=req.cond,
                plan=self.plan, config=config, interval_hook=None)
            image = jax.block_until_ready(image)
            report.modeled_s += self._clip_cost_s
            self.modeled_clock_s += self._clip_cost_s
            req.image = image
            req.fine_step = M_base
            req.done = True
            req.finish_round = report.index
            req.modeled_latency_s = self.modeled_clock_s - req.submit_clock_s
            req.wall_latency_s = time.perf_counter() - req._submit_wall
            finished.append(req)
        self.completed.extend(finished)
        report.wall_s = time.perf_counter() - wall0
        self.rounds.append(report)
        return finished

    def run_to_completion(self, max_rounds: int = 100_000
                          ) -> List[DiffusionRequest]:
        done: List[DiffusionRequest] = []
        rounds = 0
        while (self.queue or self.active) and rounds < max_rounds:
            done.extend(self.step())
            rounds += 1
        if self.queue or self.active:
            raise RuntimeError(f"undrained after {max_rounds} rounds")
        return done

    # ---------------- lane plumbing ----------------

    def _pad(self, lanes: Sequence[int]) -> np.ndarray:
        """Pad a lane group to the full slot count (stable jit shapes) by
        repeating the first lane; duplicate lanes compute duplicate values,
        so the scatter-back is value-identical regardless of write order."""
        return np.asarray(list(lanes)
                          + [lanes[0]] * (self.slots - len(lanes)))

    def _conds(self, idx: np.ndarray) -> jnp.ndarray:
        """Lane-stacked conditioning for a padded lane group: the
        slot-major int buffer for class lanes; in prompt mode (§17) a
        stack of the requests' token tensors [G, 1, L, cond_dim+1] — the
        lane-group key pins one length bucket L per dispatch, so the
        stack is rectangular by construction."""
        if not self._prompt_mode:
            return self._cond[idx]
        return jnp.stack([self.active[s].cond for s in idx])

    def _scatter(self, idx: np.ndarray, xs, ks, vs) -> None:
        self._x = self._x.at[idx].set(xs)
        self._pub_k = self._pub_k.at[idx].set(ks)
        self._pub_v = self._pub_v.at[idx].set(vs)

    def _lane_bucket(self, slot: int) -> int:
        """The lane's prompt length bucket (0 for class-conditional
        lanes): prompt-token tensors of different buckets cannot share a
        stacked dispatch, so the bucket joins every lane-group key (§17)."""
        return (self.active[slot].cond.shape[1] if self._prompt_mode
                else 0)

    def _by_guided(self, lanes: List[int]
                   ) -> List[Tuple[bool, int, List[int]]]:
        """Split a lane list into (guided?, bucket, lanes) batches, plain
        first — CFG and non-CFG lanes run different dispatch shapes, and
        prompt lanes of different length buckets different cond shapes."""
        keyed: Dict[Tuple[bool, int], List[int]] = {}
        for s in lanes:
            keyed.setdefault((self.active[s].guided,
                              self._lane_bucket(s)), []).append(s)
        return [(g, b, keyed[(g, b)]) for g, b in sorted(keyed)]

    def _groups(self, lanes: List[int]
                ) -> List[Tuple[List[int],
                                Tuple[float, str, bool, int, bool, int]]]:
        """Batchable lane groups + their (read_factor, trail_kind, fill,
        seq_hops, guided, bucket) info. The vmapped stepper batches every
        lane whose boundary behavior, seq-shard ring identity, guidance
        state AND prompt length bucket match (under "sync" with no CFG
        lanes, no seq sharding and one bucket that is ONE group, as
        before); the cohort-only (spmd) stepper groups by fine-step
        position and bucket, which pins the exchange info automatically
        (it never serves guided lanes)."""
        if not self.stepper.cohort_only:
            keyed: Dict[Tuple[float, str, bool, int, bool, int],
                        List[int]] = {}
            for s in lanes:
                keyed.setdefault(self._lane_info(s), []).append(s)
            return [(keyed[k], k) for k in sorted(keyed)]
        cohorts: Dict[Tuple[int, int], List[int]] = {}
        for s in lanes:
            key = (self.active[s].fine_step, self._lane_bucket(s))
            cohorts.setdefault(key, []).append(s)
        return [(cohorts[k], self._lane_info(cohorts[k][0]))
                for k in sorted(cohorts)]

    def _lane_info(self, slot: int
                   ) -> Tuple[float, str, bool, int, bool, int]:
        info = self._interval_info[self.active[slot].fine_step]
        return info + (self.active[slot].guided, self._lane_bucket(slot))

    # ---------------- modeled cost & placement ----------------

    def _phase_cost(self, group: int, warm: bool, kind: str = "full",
                    fill: bool = False, guided: bool = False,
                    seq_hops: int = 0, cond_tokens: int = 0
                    ) -> Tuple[Tuple[Tuple[int, int], ...], float]:
        """Placement + modeled seconds for one batched phase of a round.

        Mirrors ``simulate.simulate_trace`` with compute scaled by the lane
        count: batching multiplies the per-row work but amortizes t_fixed —
        the modeled reason continuous batching beats sequential serving.
        Latent traffic is the per-worker uneven all-gather (padded slabs),
        and "skip"/"predict" boundaries move no bytes at all. With a stage
        chain (DESIGN.md §11) the placement maps STAGES to devices instead
        of whole-model patch workers. Guided (fused-CFG) phases double the
        per-row work and the staged-K/V payload — both branches ride every
        lane (DESIGN.md §12). Sequence-sharded lanes (DESIGN.md §13) run
        each patch worker on a GROUP of ``seq.n_shards`` devices (placement
        entries map workers to groups, speed = group aggregate) and overlap
        ``seq_hops`` ring K/V hops per substep with compute, exactly as in
        ``simulate._simulate_seq``. Prompt lanes (DESIGN.md §17) add the
        cross-attention read ``t_xattn * cond_tokens`` per row per branch,
        exactly as ``simulate_trace`` prices it.
        """
        if self.stages is not None and len(self.stages) > 1:
            return self._staged_phase_cost(group, warm, kind, fill,
                                           cond_tokens)
        if guided and self._guide_pairs is not None:
            return self._split_phase_cost(group, warm, kind, cond_tokens)
        plan, cm = self.plan, self.cm
        temporal = plan.temporal
        branch = 2 if guided else 1
        t_row_eff = cm.t_row + cm.t_xattn * cond_tokens
        workers = [i for i in temporal.active if plan.patches[i] > 0]
        loads = {}
        for i in workers:
            sub = 1 if warm else temporal.lcm // temporal.ratios[i]
            loads[i] = sub * (cm.t_fixed
                              + t_row_eff * plan.patches[i] * group * branch)
        by_load = sorted(workers, key=lambda i: (-loads[i], i))
        speeds = self.measured_speeds
        if self._seq_groups is not None:
            # each worker = one device group; the group's members split the
            # worker's rows/heads, so its serving throughput is the sum
            speeds = [sum(g) for g in self._seq_groups]
        by_speed = sorted(range(len(speeds)), key=lambda d: (-speeds[d], d))
        placement = tuple(sorted((w, d) for w, d in zip(by_load, by_speed)))
        compute = max(loads[w] / max(speeds[d], 1e-9)
                      for w, d in placement)
        ring_t = 0.0
        if self._seq_groups is not None:
            hops = (self.seq.n_shards - 1) if warm else seq_hops
            if hops:
                for w in workers:
                    sub = 1 if warm else temporal.lcm // temporal.ratios[w]
                    ring_t = max(ring_t, sub * hops * (
                        self._kv_bytes[w] * self._seq_seg_pad * group
                        * branch / cm.link_bw + cm.link_latency))
        if (not warm and kind != "full") or len(workers) <= 1:
            # stale/predict (or lone worker): no gather, but ring hops
            # still serialize against compute
            return placement, max(compute, ring_t)
        rows_total = max(sum(plan.patches), 1)
        row_bytes = self._latent_bytes / rows_total
        gather_rows = comm_lib.uneven_all_gather_rows(
            [plan.patches[i] for i in workers])
        comm_bytes = gather_rows * row_bytes * group
        if warm:
            comm_bytes += sum(self._kv_bytes[w] for w in workers) \
                * group * branch
            async_t = 0.0
        else:
            async_t = max(self._kv_bytes[w] for w, _ in placement) \
                * group * branch / cm.link_bw
        comm = comm_bytes / cm.link_bw + cm.link_latency
        return placement, max(compute, async_t, ring_t) + comm

    def _split_phase_cost(self, group: int, warm: bool, kind: str = "full",
                          cond_tokens: int = 0
                          ) -> Tuple[Tuple[Tuple[int, int], ...], float]:
        """Split-guidance cohort placement + modeled seconds (DESIGN.md
        §12/§14): logical worker i runs BOTH branches concurrently on its
        (cond, uncond) device pair — per-row work is NOT doubled but the
        pair moves at its slower member — and every substep exchanges the
        two branches' epsilons across the pair link before the CFG combine.
        Mirrors ``planners._guided_plan_cost``'s fresh split interval (the
        planner's scoring and the engine's accounting cannot diverge);
        batching scales row work and wire bytes by the lane count.
        Placement entries are (worker, cond_device) — the pairing is the
        plan's, not a per-round search (re-pairing happens at replans).
        """
        plan, cm, g = self.plan, self.cm, self.plan.guidance
        temporal = plan.temporal
        speeds = self.measured_speeds
        workers = [i for i in temporal.active if plan.patches[i] > 0]
        rows_total = max(sum(plan.patches), 1)
        row_bytes = self._latent_bytes / rows_total
        compute, eps_bytes, hops = 0.0, 0.0, 0
        for i in workers:
            sub = 1 if warm else temporal.lcm // temporal.ratios[i]
            rows = plan.patches[i]
            pair_v = min(speeds[g.cond_devices[i]],
                         speeds[g.uncond_devices[i]])
            step_t = cm.t_fixed + (cm.t_row + cm.t_xattn * cond_tokens) \
                * rows * group
            compute = max(compute, sub * step_t / max(pair_v, 1e-9))
            eps_bytes += 2 * sub * rows * row_bytes * group
            hops = max(hops, sub)
        eps_t = eps_bytes / cm.link_bw + hops * cm.link_latency
        placement = tuple(sorted((i, g.cond_devices[i]) for i in workers))
        if (not warm and kind != "full") or len(workers) <= 1:
            return placement, compute + eps_t
        gather_rows = comm_lib.uneven_all_gather_rows(
            [plan.patches[i] for i in workers])
        comm_bytes = gather_rows * row_bytes * group
        if warm:
            # branch factor 1: each branch's staged K/V stays inside its
            # own device group, the two groups broadcast concurrently
            comm_bytes += sum(self._kv_bytes[w] for w in workers) * group
            async_t = 0.0
        else:
            async_t = max(self._kv_bytes[w] for w in workers) \
                * group / cm.link_bw
        comm = comm_bytes / cm.link_bw + cm.link_latency
        return placement, max(compute, async_t) + comm + eps_t

    def _staged_phase_cost(self, group: int, warm: bool, kind: str,
                           fill: bool, cond_tokens: int = 0
                           ) -> Tuple[Tuple[Tuple[int, int], ...], float]:
        """Stage-chain placement + modeled seconds (DESIGN.md §11): stage d
        (chain order, heaviest block share first by construction) runs on
        the d-th fastest device; micro-batches stream through the chain, so
        steady state is bottleneck-stage-bound with point-to-point
        activation handoffs, a fill bubble on refill rounds, and a latent
        ring handoff on draining boundaries. K/V never crosses stages.
        Placement entries are (stage, device)."""
        plan, cm = self.plan, self.cm
        if cond_tokens:
            # fold the cross-attn read into the row rate, exactly as
            # simulate._simulate_staged does (DESIGN.md §17)
            cm = dataclasses.replace(
                cm, t_row=cm.t_row + cm.t_xattn * cond_tokens)
        temporal = plan.temporal
        S = len(self.stages)
        speeds = self.measured_speeds
        by_speed = sorted(range(len(speeds)), key=lambda d: (-speeds[d], d))
        chain = [speeds[d] for d in by_speed[:S]]
        placement = tuple((s, by_speed[s]) for s in range(S))
        if warm:
            return placement, sim.pipefuse_warmup_seconds(
                self.stages, chain, cm, sum(plan.patches) * group,
                self._act_row_bytes)
        workers = [i for i in temporal.active if plan.patches[i] > 0]
        tasks = [(temporal.lcm // temporal.ratios[i],
                  plan.patches[i] * group) for i in workers]
        return placement, sim.pipefuse_interval_seconds(
            self.stages, chain, cm, tasks, fill, kind,
            self._latent_bytes * group, self._act_row_bytes)

    # ---------------- reporting ----------------

    def stats(self) -> Dict:
        """Aggregate + per-request serving statistics (modeled + wall)."""
        from repro.kernels import ops as kops
        done = sorted(self.completed, key=lambda r: r.uid)
        lats = [r.modeled_latency_s for r in done]
        wall = sum(r.wall_s for r in self.rounds)
        slo = [r.slo_met for r in done if r.slo_met is not None]
        cache = self.pipeline.plan_cache
        return {
            "n_completed": len(done),
            "cost_model": ("configured" if self.cm_calibrated
                           else "default-uncalibrated"),
            "rounds": len(self.rounds),
            "replans": len(self.replans),
            "preemptions": self.preemptions,
            "planner_calls": self.pipeline.planner_calls,
            "plan_cache": cache.stats() if cache is not None else None,
            # trace-time Pallas kernel path counters (DESIGN.md §15):
            # answers "did the programs compiled since this engine was
            # built contain the kernels?"
            "kernels": kops.kernel_stats_delta(
                self._kernel_stats_base, kops.kernel_stats_snapshot()),
            "modeled_makespan_s": self.modeled_clock_s,
            "wall_s": wall,
            "throughput_modeled_rps": (len(done) / self.modeled_clock_s
                                       if self.modeled_clock_s else 0.0),
            "throughput_wall_rps": len(done) / wall if wall else 0.0,
            "latency_mean_s": float(np.mean(lats)) if lats else 0.0,
            "latency_p95_s": float(np.percentile(lats, 95)) if lats else 0.0,
            "slo_met_frac": (sum(slo) / len(slo)) if slo else None,
            "requests": [{
                "uid": r.uid,
                "queue_rounds": r.queue_rounds,
                "service_rounds": r.finish_round - r.admit_round + 1,
                "modeled_latency_s": r.modeled_latency_s,
                "wall_latency_s": r.wall_latency_s,
                "slo_s": r.slo_s,
                "slo_met": r.slo_met,
                "preemptions": r.preempt_count,
            } for r in done],
        }
