from repro.serving.engine import ServingEngine, Request  # noqa
from repro.serving.diffusion_engine import (  # noqa
    DiffusionRequest, DiffusionServingEngine)
from repro.serving.plan_cache import PlanCache  # noqa
