"""Batched serving engine with a fixed-slot KV cache (continuous-batching
lite): requests occupy slots; finished slots are refilled from the queue
each scheduling round. Decode is one jitted step over the whole slot batch;
per-slot position masking handles ragged prompts.

The STADI analogue for LLM serving — heterogeneity-aware uneven sequence
sharding — is exposed through ``core.schedule.spatial_allocation`` and used
by launch/serve.py when sharding prefill across unequal devices.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # [S] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model: Model, params, *, slots: int = 4,
                 max_len: int = 256, window: int = 0, eos_id: Optional[int] = None):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.window = window
        self.eos_id = eos_id
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}
        cfg = model.cfg
        self._decode = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t, window=window))
        self._caches: Dict[int, object] = {}

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        while self.queue and len(self.active) < self.slots:
            req = self.queue.pop(0)
            slot = next(i for i in range(self.slots) if i not in self.active)
            cache = self.model.init_cache(1, self.max_len, window=self.window)
            batch = {"tokens": jnp.asarray(req.prompt[None])}
            if self.model.family == "encdec":
                raise NotImplementedError("enc-dec serving uses launch/serve.py")
            logits, cache = self.model.prefill(self.params, batch, cache,
                                               window=self.window)
            tok = int(jnp.argmax(logits[0]))
            req.out_tokens.append(tok)
            self._caches[slot] = cache
            self.active[slot] = req

    def step(self):
        """One scheduling round: admit, then one decode step per active slot."""
        self._admit()
        finished = []
        for slot, req in list(self.active.items()):
            cache = self._caches[slot]
            tok = jnp.asarray([req.out_tokens[-1]], jnp.int32)
            logits, cache = self._decode(self.params, cache, tok)
            nxt = int(jnp.argmax(logits[0]))
            req.out_tokens.append(nxt)
            self._caches[slot] = cache
            if len(req.out_tokens) >= req.max_new_tokens or \
               (self.eos_id is not None and nxt == self.eos_id):
                req.done = True
                finished.append(req)
                del self.active[slot]
                del self._caches[slot]
        return finished

    def run_to_completion(self, max_rounds: int = 1000) -> List[Request]:
        done: List[Request] = []
        rounds = 0
        while (self.queue or self.active) and rounds < max_rounds:
            done.extend(self.step())
            rounds += 1
        return done
