from repro.sharding.specs import (  # noqa
    param_specs, batch_specs, cache_specs, named, tree_named,
)
