"""Logical-axis sharding rules -> PartitionSpecs.

Production mesh axes (launch/mesh.py): ``(data=16, model=16)`` single-pod,
``(pod=2, data=16, model=16)`` multi-pod. Logical mapping (DESIGN.md §5):

  batch                  -> ('pod','data') when divisible, else replicated
  heads / d_ff / experts / vocab-partition dims -> 'model'  (tensor/expert par.)
  d_model on weight matrices                    -> 'data'   (FSDP-style, so
                                                  405B-class weights fit)
  layer-stack dim / norms / biases / small dims -> replicated
  KV-cache: kv-head dim over 'model' if divisible, else sequence dim

Rules key off parameter *path names* (the naming conventions of
repro.models.*) + ndim, so new modules compose for free.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


def _div(n: int, mesh: Mesh, axis) -> bool:
    """Is dim n evenly divisible by the (possibly tuple) mesh axis?"""
    if axis is None:
        return True
    sz = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        sz *= _axis_size(mesh, a)
    return sz <= n and n % sz == 0


def _guard(spec: Sequence, shape, mesh: Mesh) -> P:
    """Drop axis assignments that don't divide the dim (GSPMD could pad, but
    even sharding keeps memory analysis honest)."""
    out = []
    for dim, ax in zip(shape, spec):
        out.append(ax if _div(dim, mesh, ax) else None)
    return P(*out)


# per-leaf-name rules: rightmost dims (left-padded with None for stacking)
_RULES = {
    # embeddings / unembedding
    "embed": ("model", "data"),
    "head": ("data", "model"),
    "cond_embed": (None, "data"),
    "meta": (None, "data"),
    # attention
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    "qkv": ("data", "model"),
    # dense mlp
    "w_gate": ("data", "model"),
    "w_up": ("data", "model"),
    "w_down": ("model", "data"),
    "w1": ("data", "model"),
    "w2": ("model", "data"),
    # moe
    "router": ("data", None),
    # xlstm / mamba
    "w_in": ("data", "model"),
    "w_x": ("data", "model"),
    "r_h": ("model", None, None),
    "conv": (None, "model"),
    "w_bc": ("model", None),
    "w_dt1": ("model", None),
    "w_dt2": (None, "model"),
    "w_if": ("model", None),
    # dit
    "patch_embed": (None, "data"),
    "mod_w": ("data", "model"),
    "t_w1": (None, "data"),
    "t_w2": ("data", None),
    "final_proj": ("data", None),
}

# moe expert stacks: [L, E, D, F]-style; expert dim -> 'model'
_EXPERT_RULES = {
    "w_gate": ("model", "data", None),
    "w_up": ("model", "data", None),
    "w_down": ("model", None, "data"),
}


def _leaf_spec(path, leaf, mesh: Mesh, cfg=None) -> P:
    names = [p.key for p in path if hasattr(p, "key")]
    name = names[-1] if names else ""
    in_experts = "experts" in names
    rules = _EXPERT_RULES if (in_experts and name in _EXPERT_RULES) else _RULES
    rule = rules.get(name)
    shape = np.shape(leaf)
    if rule is None or len(shape) < len(rule):
        return P()                                  # norms, biases, scalars
    spec = (None,) * (len(shape) - len(rule)) + tuple(rule)
    # GQA/MQA head-count-aware attention sharding: sharding a projection's
    # (heads*hd) dim over 'model' when the head count does not divide the
    # model axis shards head_dim ITSELF, making every attention score
    # contraction a partial sum that GSPMD resolves with a full [B,H,S,T]
    # fp32 all-reduce PER LAYER (measured on gemma-2b prefill_32k, §Perf).
    # Standard fix: replicate those projections across 'model' (head-dim
    # must never split). Applies to q (n_heads) and k/v (n_kv_heads).
    if cfg is not None and not in_experts and name in ("wq", "wk", "wv", "wo"):
        ms = _axis_size(mesh, "model")
        heads = cfg.n_heads if name in ("wq", "wo") else cfg.n_kv_heads
        if heads % ms:
            if name == "wo":               # input dim is heads*hd
                spec = spec[:-2] + (None, spec[-1])
            else:                          # output dim is heads*hd
                spec = spec[:-1] + (None,)
    return _guard(spec, shape, mesh)


def param_specs(params: Any, mesh: Mesh, cfg=None):
    """Pytree of PartitionSpec matching ``params`` (works on shape structs).

    cfg (optional ArchConfig) enables architecture-aware rules (GQA KV
    replication)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, mesh, cfg), params)


# ----------------------------------------------------------------------
# activations
# ----------------------------------------------------------------------

def batch_axes(mesh: Mesh):
    # bare string (not a 1-tuple) so PartitionSpec equality is stable across
    # jax versions: 0.4.x does not normalize P(("data",)) to P("data")
    return ("pod", "data") if "pod" in mesh.shape else "data"


def batch_specs(batch: Any, mesh: Mesh, *, seq_axis: Optional[str] = None):
    """Shard the leading batch dim over ('pod','data') when divisible.
    ``seq_axis='model'`` additionally shards dim 1 (sequence parallelism for
    long prefill)."""
    ba = batch_axes(mesh)

    def spec(leaf):
        shape = np.shape(leaf)
        if not shape:
            return P()
        dims = [ba if _div(shape[0], mesh, ba) else None]
        if len(shape) > 1:
            dims.append(seq_axis if (seq_axis and _div(shape[1], mesh, seq_axis)) else None)
        dims += [None] * (len(shape) - len(dims))
        return P(*dims)

    return jax.tree.map(spec, batch)


def cache_specs(cache: Any, mesh: Mesh):
    """KV caches [L,B,T,K,hd]: batch->('pod','data'); kv-heads->'model' when
    divisible else sequence->'model'. SSM states [.., B, ...]: batch only.
    """
    ba = batch_axes(mesh)

    def spec(leaf):
        shape = np.shape(leaf)
        if len(shape) == 5:                         # [L,B,T,K,hd]
            L, B, T, K, hd = shape
            b_ax = ba if _div(B, mesh, ba) else None
            if _div(K, mesh, "model"):
                return P(None, b_ax, None, "model", None)
            if _div(T, mesh, "model"):
                return P(None, b_ax, "model", None, None)
            return P(None, b_ax, None, None, None)
        if len(shape) == 0:
            return P()
        # ssm/conv states: [L,B,...] or [B,...]; find a batch-like dim
        dims = [None] * len(shape)
        for i, d in enumerate(shape[:2]):
            if _div(d, mesh, ba) and d > 1:
                dims[i] = ba
                break
        # shard the widest remaining dim over model if divisible
        rest = [(d, i) for i, d in enumerate(shape) if dims[i] is None]
        if rest:
            d, i = max(rest)
            if _div(d, mesh, "model") and d >= _axis_size(mesh, "model"):
                dims[i] = "model"
        return P(*dims)

    return jax.tree.map(spec, cache)


# ----------------------------------------------------------------------
# helpers
# ----------------------------------------------------------------------

def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_named(mesh: Mesh, specs: Any):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
