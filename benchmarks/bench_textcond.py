"""Text-conditioned guided video sweep (DESIGN.md §17): prompt
cross-attention as a priced workload axis, composed with classifier-free
guidance AND the frame axis — the full text-to-video serving shape.

Latency: the ``"simulate"`` backend replays the prompt-priced schedule IR
for a text-conditioned sdxl-dit (77-token prompt bucket) running fused
CFG over a 4-frame clip on two fast + two half-speed nodes. The cost
model charges ``t_xattn * cond_tokens`` per evaluated row — every query
row attends the full prompt K/V in every block, and BOTH guidance
branches pay it (the null branch runs identical dense math over zero
tokens). That makes the per-row cost high enough that frame-sequential
pure patch parallelism leaves the slow tier reading cross-frame context
AND prompt K/V for all F frames; the ``stadi_video`` planner splits the
frame set into member rows instead. Acceptance: the planner-chosen
guided-video plan models >= 20% end-to-end reduction vs fused-CFG
frame-sequential patch parallelism on the same cluster, with guidance
and frames BOTH populated on the winning plan (the CFG x frames
composition this PR lifts the loud error for).

Quality: real numerics on a text-conditioned tiny-dit, F = 3, encoded
prompt, fused CFG. Measured as PSNR drift of the stale_async boundary
policy vs the single-device sync origin of the same guided clip; bar
< 1 dB — staleness tolerance is unchanged by the conditioning pathway.

Kernels: the Pallas attention kernel has no cross-attention body yet, so
a ``use_pallas_attention`` run on a text-conditioned model must record
the miss honestly — asserted here as ``cross-attn-unsupported`` in
``kernel_stats["misses"]`` (DESIGN.md §15's no-silent-fallback rule).

Writes results/textcond.json (CI artifact).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks import common
from repro.configs import get_config
from repro.core import sampler as sampler_lib
from repro.core.pipeline import StadiConfig, StadiPipeline
from repro.core.simulate import CostModel

# 2-tier heterogeneous cluster (bench_video's shape) + the prompt term:
# t_xattn * 77 tokens ~ 2.3e-4 s/row rivals the cross-frame context read,
# so conditioning meaningfully moves the planner's frame/patch tradeoff.
OCCUPANCIES = [0.0, 0.0, 0.5, 0.5]
CLUSTER_CM = CostModel(t_fixed=2e-3, t_row=1e-4, t_ctx=3e-4,
                       t_xattn=3e-6, link_bw=50e9, link_latency=20e-6)
COND_SEQ_LEN = 77            # the modeled prompt bucket (CLIP-length)
CFG_SCALE = 4.0
M_BASE_LAT, M_WARMUP_LAT = 100, 4
F_LAT = 4                    # modeled clip length
F_QUAL = 3                   # measured clip length (real numerics)
REFRESH = 4


def modeled_latency(m_base: int, m_warmup: int):
    cfg = get_config("sdxl-dit").text_conditioned(cond_seq_len=COND_SEQ_LEN)
    base = StadiConfig.from_occupancies(
        OCCUPANCIES, m_base=m_base, m_warmup=m_warmup, backend="simulate",
        cost_model=CLUSTER_CM, exchange="stale_async",
        exchange_refresh=REFRESH, num_frames=F_LAT,
        guidance="fused", cfg_scale=CFG_SCALE)
    runs = {
        # fused-CFG frame-sequential pure patch parallelism: every worker
        # runs both guidance branches for all F frames back-to-back (the
        # baseline the acceptance bar is measured against)
        "cfg_fseq": dataclasses.replace(base, planner="stadi"),
        "stadi_video_g2": dataclasses.replace(base, planner="stadi_video",
                                              frame_groups=2),
        "stadi_video_auto": dataclasses.replace(base, planner="stadi_video",
                                                frame_groups=0),
    }
    out = {}
    for name, config in runs.items():
        pipe = StadiPipeline(cfg, None, None, config)
        res = pipe.generate()
        fplan, gplan = res.plan.frames, res.plan.guidance
        out[name] = {"latency_s": res.latency_s,
                     "patches": res.plan.patches,
                     "cond_bucket": config.cond_bucket or COND_SEQ_LEN,
                     "guidance": None if gplan is None else gplan.mode,
                     "frame_groups": list(fplan.groups) if fplan else None}
    for name in runs:
        out[name]["reduction_vs_cfg_fseq_pct"] = (
            (1.0 - out[name]["latency_s"] / out["cfg_fseq"]["latency_s"])
            * 100.0)
    return out


def quality(m_base: int, m_warmup: int):
    """Guided text-to-video staleness PSNR, real numerics."""
    from repro.models import text_encoder
    from repro.models.diffusion import dit
    cfg = get_config("tiny-dit").reduced().text_conditioned(cond_seq_len=16)
    params = dit.nondegenerate_params(
        dit.init_params(jax.random.PRNGKey(0), cfg))
    sched = sampler_lib.linear_schedule(T=100)
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (1, F_QUAL, cfg.latent_size, cfg.latent_size,
                             cfg.channels))
    cond = text_encoder.encode(["a red fox in the snow"], cfg)
    base = StadiConfig.from_occupancies(
        [0.0, 0.2, 0.4, 0.5], m_base=m_base, m_warmup=m_warmup,
        planner="stadi_video", num_frames=F_QUAL, exchange="sync",
        guidance="fused", cfg_scale=3.0)
    # single-device sync origin: the undisplaced guided clip trajectory
    origin = np.asarray(StadiPipeline(
        cfg, params, sched,
        StadiConfig.from_occupancies(
            [0.0], m_base=m_base, m_warmup=m_warmup, num_frames=F_QUAL,
            guidance="fused", cfg_scale=3.0)).generate(x_T, cond).image)
    sync = np.asarray(StadiPipeline(
        cfg, params, sched,
        dataclasses.replace(base, frame_groups=1)).generate(
            x_T, cond).image)
    stale = np.asarray(StadiPipeline(
        cfg, params, sched,
        dataclasses.replace(base, frame_groups=1, exchange="stale_async",
                            exchange_refresh=REFRESH)).generate(
            x_T, cond).image)
    out = {
        "sync": {"psnr_vs_origin_db": common.psnr(sync, origin)},
        "stale": {"psnr_vs_origin_db": common.psnr(stale, origin)},
    }
    out["stale"]["psnr_drift_vs_sync_db"] = (
        out["sync"]["psnr_vs_origin_db"]
        - out["stale"]["psnr_vs_origin_db"])
    return out


def kernel_miss(m_base: int, m_warmup: int):
    """A Pallas-kernel run on a text-conditioned model records the
    cross-attention gap instead of silently falling back."""
    from repro.models import text_encoder
    from repro.models.diffusion import dit
    cfg = get_config("tiny-dit").reduced().text_conditioned(cond_seq_len=8)
    params = dit.nondegenerate_params(
        dit.init_params(jax.random.PRNGKey(0), cfg))
    sched = sampler_lib.linear_schedule(T=100)
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (1, cfg.latent_size, cfg.latent_size,
                             cfg.channels))
    cond = text_encoder.encode(["fox"], cfg)
    config = StadiConfig.from_occupancies([0.0, 0.5], m_base=m_base,
                                          m_warmup=m_warmup,
                                          use_pallas_attention=True)
    res = StadiPipeline(cfg, params, sched, config).generate(x_T, cond)
    assert np.isfinite(np.asarray(res.image)).all()
    return res.kernel_stats


def run(emit: bool = True):
    smoke = common.smoke()
    lat = modeled_latency(m_base=20 if smoke else M_BASE_LAT,
                          m_warmup=2 if smoke else M_WARMUP_LAT)
    qual = quality(m_base=8 if smoke else 16, m_warmup=2 if smoke else 4)
    ks = kernel_miss(m_base=8, m_warmup=2)
    if emit:
        for name, d in lat.items():
            common.emit(f"textcond/{name}/latency", d["latency_s"] * 1e6,
                        f"reduction={d['reduction_vs_cfg_fseq_pct']:.1f}% "
                        f"groups={d['frame_groups']} "
                        f"guidance={d['guidance']}")
        drift_db = qual["stale"]["psnr_drift_vs_sync_db"]
        common.emit("textcond/stale/psnr",
                    qual["stale"]["psnr_vs_origin_db"],
                    f"drift={drift_db:+.2f}dB")
    payload = {
        "cluster": {"occupancies": OCCUPANCIES,
                    "cost_model": dataclasses.asdict(CLUSTER_CM)},
        "cond_seq_len": COND_SEQ_LEN, "cfg_scale": CFG_SCALE,
        "num_frames": {"latency": F_LAT, "quality": F_QUAL},
        "latency_arch": "sdxl-dit(text)",
        "quality_arch": "tiny-dit(reduced,text)",
        "latency": lat, "quality": qual, "kernel_stats": ks,
    }
    common.write_json("textcond.json", payload)
    return payload


def main():
    res = run()
    lat, qual, ks = res["latency"], res["quality"], res["kernel_stats"]
    auto = lat["stadi_video_auto"]
    red = auto["reduction_vs_cfg_fseq_pct"]
    print(f"# stadi_video(auto) guided-video modeled reduction vs fused-CFG "
          f"frame-sequential patch parallelism: {red:.1f}% (acceptance: "
          f">= 20%) — picked groups={auto['frame_groups']} "
          f"patches={auto['patches']} guidance={auto['guidance']} "
          f"cond_bucket={auto['cond_bucket']}")
    print(f"# pinned G=2 reduction: "
          f"{lat['stadi_video_g2']['reduction_vs_cfg_fseq_pct']:.1f}%")
    drift = qual["stale"]["psnr_drift_vs_sync_db"]
    print(f"# stale_async guided text-to-video: PSNR "
          f"{qual['stale']['psnr_vs_origin_db']:.2f} dB "
          f"(drift {drift:+.2f} dB vs synchronous; bar < 1 dB)")
    print(f"# pallas kernel on cross-attention model: "
          f"misses={ks['misses']}")
    assert auto["guidance"] == "fused" and auto["frame_groups"], \
        "the winning plan must compose CFG with the frame axis"
    assert red >= 20.0, (red, lat)
    assert drift < 1.0, (drift, qual)
    assert ks["misses"].get("cross-attn-unsupported"), ks


if __name__ == "__main__":
    main()
